"""§Roofline report: three terms per (arch x shape) cell from the dry-run
artifact (results/dryrun.json), TPU v5e constants.

  compute term     = flops_per_chip / peak_FLOP/s
  memory term      = hbm_bytes_per_chip / HBM_bw
  collective term  = collective_link_bytes_per_chip / link_bw

flops/hbm come from the loop-weighted HLO analyzer (launch/hlo.py) — the
raw cost_analysis() counts while-bodies once and is recorded alongside.
MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (inference),
per chip; the ratio against HLO flops exposes remat/dispatch waste.
"""

from __future__ import annotations

import json
import os

from repro.configs.base import SHAPES
from repro.core.cost_model import TPU_V5E


def model_flops_per_chip(rec: dict) -> float:
    shape = SHAPES[rec["shape"]]
    n_active = rec["active_params"]
    chips = rec["n_chips"]
    if rec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    tokens = shape.global_batch            # one token per sequence
    return 2.0 * n_active * tokens / chips


def analytic_mem_gib(rec: dict, hw=TPU_V5E) -> float:
    """Analytic per-chip HBM model for the TPU target.  The dry-run's
    memory_analysis() comes from the CPU backend, whose list scheduler is
    not memory-aware (it interleaves all layers' remat recomputes), so for
    big cells it wildly over-reports peaks the TPU scheduler would never
    see.  This model counts what MUST be resident:

      params/chip + optimizer moments (train) + grads (train)
      + saved scan carries (remat) + one layer's working set
      + KV cache (decode) / collected cache (prefill).
    """
    from repro import configs
    shape = SHAPES[rec["shape"]]
    cfg = configs.get_config(rec["arch"])
    chips = rec["n_chips"]
    p_bytes = rec["params"] * 2 / chips
    d = cfg.d_model
    total = p_bytes
    if rec["kind"] == "train":
        mom = 2 if cfg.moment_dtype == "bfloat16" else 4
        total += rec["params"] * 2 * mom / chips      # mu, nu
        total += p_bytes                               # grad buffer
        b_loc = shape.global_batch // (chips // 16)    # data-axis shard
        b_micro = max(1, b_loc // max(cfg.accum_steps, 1))
        s_loc = shape.seq_len // 16
        total += cfg.n_layers * b_micro * s_loc * d * 2       # scan carries
        total += 6 * b_micro * shape.seq_len * d * 2          # working set
    elif rec["kind"] == "prefill":
        b_loc = shape.global_batch // (chips // 16)
        kvp = max(cfg.n_kv_heads, 1)
        hd = cfg.head_dim if cfg.n_heads else 0
        total += (cfg.n_layers * b_loc * (shape.seq_len // 16)
                  * 2 * kvp * hd * 2)                         # cache out
        total += 8 * b_loc * shape.seq_len * d * 2            # working set
    else:                                                     # decode
        n_sh = chips
        kvp = max(cfg.n_kv_heads, 1)
        hd = cfg.head_dim if cfg.n_heads else 0
        layers_full = cfg.n_layers
        win = cfg.sliding_window
        if cfg.family == "hybrid" and win:
            n_glob = len(cfg.full_attn_layers)
            cache = (n_glob * shape.seq_len + (cfg.n_layers - n_glob)
                     * min(win, shape.seq_len))
        elif cfg.family == "ssm":
            cache = 0
        else:
            cache = layers_full * shape.seq_len
        total += cache / n_sh * shape.global_batch * 2 * kvp * hd * 2
        if cfg.ssm is not None:
            total += (cfg.n_layers * shape.global_batch * cfg.ssm_heads
                      * cfg.ssm.headdim * cfg.ssm.d_state * 4 / 16)
    return total / 2**30


def roofline_row(rec: dict, hw=TPU_V5E) -> dict:
    ct = rec["flops_per_chip"] / hw.peak_flops
    mt = rec["hbm_bytes_per_chip"] / hw.hbm_bw
    lt = rec["collective_bytes_per_chip"] / hw.link_bw
    terms = {"compute": ct, "memory": mt, "collective": lt}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_chip(rec)
    util = mf / max(rec["flops_per_chip"], 1e-30)
    bound = max(ct, mt, lt)
    if rec["kind"] == "decode":
        # decode is inherently bandwidth-bound: the roofline fraction is
        # ideal-bytes (params + cache read once per token) over HLO bytes
        ideal = (rec["params"] * 2 / rec["n_chips"]
                 + analytic_mem_gib(rec, hw) * 2**30)
        frac = ideal / max(rec["hbm_bytes_per_chip"], 1e-30)
    else:
        # useful-compute time over the binding term
        frac = (mf / hw.peak_flops) / max(bound, 1e-30)
    return {
        "cell": f'{rec["arch"]}|{rec["shape"]}|{rec["mesh"]}',
        "compute_s": ct, "memory_s": mt, "collective_s": lt,
        "dominant": dominant, "model_flops_per_chip": mf,
        "model_over_hlo_flops": util, "roofline_fraction": frac,
        "peak_mem_gib": rec.get("memory", {}).get("peak_bytes", 0) / 2**30,
        "mem_model_gib": analytic_mem_gib(rec, hw),
    }


def report(path: str = "results/dryrun.json",
           mesh: str = "16x16") -> list[dict]:
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, rec in sorted(results.items()):
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        rows.append(roofline_row(rec))
    return rows


def rows_as_csv(rows: list[dict]) -> list[tuple[str, float, str]]:
    out = []
    for r in rows:
        out.append((f'roofline_{r["cell"]}',
                    r[r["dominant"] + "_s"] * 1e6,
                    f'dom={r["dominant"]} frac={r["roofline_fraction"]:.3f} '
                    f'useful={r["model_over_hlo_flops"]:.2f} '
                    f'mem={r["peak_mem_gib"]:.1f}GiB'))
    return out


def print_table(rows: list[dict]) -> None:
    hdr = (f'{"cell":44s} {"compute_s":>10s} {"memory_s":>10s} '
           f'{"collect_s":>10s} {"dominant":>10s} {"useful":>7s} '
           f'{"frac":>6s} {"cpu GiB":>8s} {"tpu GiB":>8s}')
    print(hdr)
    for r in rows:
        print(f'{r["cell"]:44s} {r["compute_s"]:10.4f} '
              f'{r["memory_s"]:10.4f} {r["collective_s"]:10.4f} '
              f'{r["dominant"]:>10s} {r["model_over_hlo_flops"]:7.2f} '
              f'{r["roofline_fraction"]:6.3f} {r["peak_mem_gib"]:8.2f} '
              f'{r["mem_model_gib"]:8.2f}')


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print_table(report(mesh=mesh))
