"""§Perf comparison tables: baseline vs experiment cells.

Reads results/dryrun.json (baselines) + results/perf.json (experiments)
and prints per-cell roofline terms plus two schedule bounds:

  serialized bound  = compute + collective       (bulk: the consumer matmul
                      waits for the whole collective)
  overlapped bound  = max(compute, collective)   (interleaved rings / async)

The paper's technique does not change collective BYTES — it changes which
bound applies; the beyond-paper mesh re-roling changes the bytes too.
"""

from __future__ import annotations

import json
import os

from benchmarks.roofline import model_flops_per_chip, roofline_row

CELLS = ("granite-34b|train_4k", "nemotron-4-340b|train_4k",
         "moonshot-v1-16b-a3b|train_4k")


def load(*paths: str) -> dict:
    out = {}
    for p in paths:
        if os.path.exists(p):
            with open(p) as f:
                out.update(json.load(f))
    return out


def report(paths=("results/dryrun.json", "results/perf.json")) -> None:
    results = load(*paths)
    hdr = (f'{"cell":52s} {"comp_s":>8s} {"mem_s":>8s} {"coll_s":>8s} '
           f'{"serial":>8s} {"overlap":>8s} {"frac":>6s} {"useful":>7s}')
    print(hdr)
    for cell in CELLS:
        rows = [(k, v) for k, v in sorted(results.items())
                if k.startswith(cell) and v.get("status") == "ok"
                and "2x16x16" not in k]
        for key, rec in rows:
            r = roofline_row(rec)
            serial = r["compute_s"] + r["collective_s"]
            overlap = max(r["compute_s"], r["collective_s"])
            print(f'{key:52s} {r["compute_s"]:8.2f} {r["memory_s"]:8.2f} '
                  f'{r["collective_s"]:8.2f} {serial:8.2f} {overlap:8.2f} '
                  f'{r["roofline_fraction"]:6.3f} '
                  f'{r["model_over_hlo_flops"]:7.2f}')
        print()


if __name__ == "__main__":
    report()
