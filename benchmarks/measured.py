import os
if "--child" in __import__("sys").argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Measured multi-device microbenchmarks (8 forced host devices).

Invoked by benchmarks/run.py as a SUBPROCESS (``--child``) so the main
process keeps its single-device view.  CPU 'ICI' has no async DMA engine,
so interleaved modes measure the schedule's pure overhead here; the
``derived`` column carries the cost model's TPU v5e prediction, and the
dist test suite checks numerical equivalence.  What IS physically measured
on CPU: per-message costs (the paper's latency-dominance effect) and the
bulk-vs-chunked message-count tradeoff.
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import cost_model as cm
from repro.core import managed
from repro.core import halo
from repro.parallel.sharding import smap

REPS = int(os.environ.get("MDMP_BENCH_REPS", "10"))   # smoke: set to 1-2


def _time(fn, *args) -> float:
    """Best-of-REPS wall clock (min is the noise-robust estimator on a
    shared host; the mean is hostage to scheduler hiccups)."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_managed_collectives(mesh) -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    for mb in (1, 8):
        x = jnp.asarray(rng.normal(size=(8 * mb * 32768, 4))
                        .astype(np.float32))          # mb MiB per shard
        for mode, chunks in (("bulk", 1), ("interleaved", 1),
                             ("interleaved", 4)):
            fn = jax.jit(smap(
                lambda a: managed.managed_all_gather(a, "x", mode, chunks),
                mesh, in_specs=(P("x"),), out_specs=P(None)))
            t = _time(fn, x)
            d = cm.decide(int(x.nbytes // 8), 8, compute_time_s=0.0)
            rows.append((f"ag_{mb}MiB_{mode}{chunks}", t * 1e6,
                         f"v5e_bulk={d.comm_time_s*1e6:.0f}us"))
    return rows


def bench_pingpong(mesh) -> list[tuple[str, float, str]]:
    """Measured PingPong between 2 of the 8 devices: one bulk message vs
    n_msg chunked messages (the paper's fine-grained limit)."""
    rows = []
    perm = [(0, 1), (1, 0)]
    n = 4096
    x = jnp.arange(8 * n, dtype=jnp.float32)

    def bulk(a):
        return lax.ppermute(a, "x", perm)

    def chunked(n_msg):
        def fn(a):
            pieces = jnp.split(a, n_msg)
            return jnp.concatenate(
                [lax.ppermute(p, "x", perm) for p in pieces])
        return fn

    t_bulk = _time(jax.jit(smap(bulk, mesh, in_specs=(P("x"),),
                                out_specs=P("x"))), x)
    rows.append(("pingpong_bulk_4096el", t_bulk * 1e6, ""))
    for n_msg in (4, 16, 64):
        t = _time(jax.jit(smap(chunked(n_msg), mesh, in_specs=(P("x"),),
                               out_specs=P("x"))), x)
        rows.append((f"pingpong_{n_msg}msgs", t * 1e6,
                     f"x{t / t_bulk:.2f} (latency-dominance, paper Fig5a)"))
    return rows


def bench_jacobi(mesh) -> list[tuple[str, float, str]]:
    """The paper's Jacobi example: bulk (Fig 2) vs intermingled (Fig 3) vs
    aggregated (k sweeps per k-row halo exchange — the temporally-blocked
    deep-halo pipeline), distributed over 8 shards.  The aggregated rows
    sweep k in {1,2,4,8}; every variant is asserted allclose against the
    bulk oracle, and the cost-model k lands in the decision trail row."""
    rows = []
    iters = 16
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.normal(size=(1024, 514)).astype(np.float32))
    f = jnp.asarray(rng.normal(size=(1024, 514)).astype(np.float32))

    def solve(mode, **kw):
        fn = jax.jit(smap(
            lambda a, b: halo.jacobi_solve(a, b, "x", iters, mode, **kw),
            mesh, in_specs=(P("x"), P("x")), out_specs=P("x")))
        return fn, np.asarray(fn(u, f))

    baseline, oracle = solve("bulk")
    t_bulk = _time(baseline, u, f)
    rows.append((f"jacobi_{iters}sweeps_bulk", t_bulk * 1e6, ""))
    fn, out = solve("interleaved")
    np.testing.assert_allclose(out, oracle, rtol=1e-5, atol=1e-5)
    rows.append((f"jacobi_{iters}sweeps_interleaved", _time(fn, u, f) * 1e6,
                 ""))

    # the managed decision: cost-model-chosen k, logged in the trail
    managed.clear_decision_log()
    decision = managed.resolve_halo_aggregation(
        "x", 8, u.shape[0] // 8, u.shape[1])
    rec = managed.decision_log()[-1]
    times = {}
    for k in (1, 2, 4, 8):
        fn, out = solve("aggregated", k=k)
        np.testing.assert_allclose(out, oracle, rtol=1e-5, atol=1e-5)
        times[k] = _time(fn, u, f)
        note = "allclose=bulk"
        if k == decision.k:
            note += f"; cost-model pick (pred x{decision.predicted_speedup:.2f}/sweep)"
        rows.append((f"jacobi_{iters}sweeps_aggregated_k{k}",
                     times[k] * 1e6, f"x{t_bulk / times[k]:.2f} vs bulk; "
                     + note))
    rows.append((f"jacobi_decision_k{decision.k}",
                 decision.aggregated_sweep_s * 1e6,
                 f"v5e per-sweep model; trail={rec.mode}(k={rec.chunks})"))
    return rows


def bench_ring_attention(mesh) -> list[tuple[str, float, str]]:
    """Long-context causal prefill attention: bulk KV-gather vs ulysses
    a2a vs ring streaming (PR 2 tentpole).  Every schedule is asserted
    allclose against the attention_sp bulk oracle; the managed collective
    is also measured head-to-head (all-gather-KV flash vs streamed ring
    with causal step-skipping), and the cost model's three-way decision
    lands in the trail row."""
    from repro.configs.base import ModelConfig
    from repro.models import attention
    from repro.parallel.sharding import MeshCtx, smap as smap2

    rows = []
    tp = 8
    mesh2 = jax.make_mesh((1, tp), ("data", "model"))
    cfg = ModelConfig(name="bench", family="dense", n_layers=1,
                      d_model=128, n_heads=8, n_kv_heads=2, d_ff=256,
                      vocab_size=256, d_head=64, tp_multiple=tp)
    hp, hd = cfg.padded_heads, cfg.head_dim
    kvh = attention.padded_kv_heads(cfg)
    rng = np.random.default_rng(7)
    b, S, d = 1, 4096, cfg.d_model
    x = jnp.asarray(rng.normal(size=(b, S, d)).astype(np.float32) * 0.1)
    params = (
        jnp.asarray(rng.normal(size=(d, hp * hd)).astype(np.float32) * 0.1),
        jnp.asarray(rng.normal(size=(d, 2 * kvh * hd)).astype(np.float32)
                    * 0.1),
        jnp.asarray(rng.normal(size=(hp * hd, d)).astype(np.float32) * 0.1),
    )
    pspecs = (P(None, "model"), P(None, None), P("model", None))

    def build(fn, mode):
        ctx = MeshCtx.from_mesh(mesh2, mdmp_mode=mode)

        def body(x_, wq, wkv, wo):
            return fn(x_, {"w_q": wq, "w_kv": wkv, "w_o": wo}, cfg, ctx,
                      causal=True)
        return jax.jit(smap2(body, mesh2,
                             in_specs=(P(None, "model"),) + pspecs,
                             out_specs=P(None, "model")))

    oracle_fn = build(attention.attention_sp, "bulk")
    oracle = np.asarray(oracle_fn(x, *params))
    t_bulk = _time(oracle_fn, x, *params)
    rows.append((f"ring_attn_S{S}_bulk_gather", t_bulk * 1e6, ""))
    for name, fn, mode in (
            ("ulysses", attention.attention_sp_ulysses, "bulk"),
            ("ring", attention.attention_sp_ring, "interleaved")):
        f = build(fn, mode)
        np.testing.assert_allclose(np.asarray(f(x, *params)), oracle,
                                   rtol=3e-4, atol=3e-5)
        t = _time(f, x, *params)
        rows.append((f"ring_attn_S{S}_{name}", t * 1e6,
                     f"x{t_bulk / t:.2f} vs bulk; allclose=bulk"))

    # the managed collective head-to-head: all-gather-KV flash vs streamed
    # ring (causal step-skipping) on the same qkv operands
    q = jnp.asarray(rng.normal(size=(b, S, hp, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, S, kvh, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, S, kvh, hd)).astype(np.float32))
    mesh1 = jax.make_mesh((8,), ("x",))
    times = {}
    outs = {}
    for mode in ("bulk", "interleaved"):
        f = jax.jit(smap(
            lambda q_, k_, v_, mode=mode: managed.managed_ring_attention(
                q_, k_, v_, "x", True, 0, mode),
            mesh1, in_specs=(P(None, "x"),) * 3, out_specs=P(None, "x")))
        outs[mode] = np.asarray(f(q, k, v))
        times[mode] = _time(f, q, k, v)
    np.testing.assert_allclose(outs["interleaved"], outs["bulk"],
                               rtol=3e-4, atol=3e-5)
    rows.append((f"ring_attn_op_S{S}_kvgather", times["bulk"] * 1e6, ""))
    rows.append((f"ring_attn_op_S{S}_streamed", times["interleaved"] * 1e6,
                 f"x{times['bulk'] / times['interleaved']:.2f} vs KV-gather"
                 f" (causal step-skip); allclose"))

    # the managed decision: cost-model seed -> measured override (the
    # paper's iteration-(k)->(k+1) adaptation) -> logged in the trail
    from repro.core.tuner import ScheduleTuner
    tuner = ScheduleTuner()
    entry = tuner.decide_attention("model", tp, b, S // tp, hp, kvh, hd, d,
                                   dtype_str="float32", dtype_bytes=4)
    seed_schedule = entry.mode
    measured = {"bulk": t_bulk,
                "ulysses": next(t for n, t, _ in rows
                                if n.endswith("_ulysses")) / 1e6,
                "ring": next(t for n, t, _ in rows
                             if n.endswith("_ring")) / 1e6}
    for sched, t in measured.items():
        tuner.record(entry.key, sched, 1, t)
    winner = tuner.entries[entry.key].mode
    managed.clear_decision_log()
    decision = managed.resolve_attention_schedule(
        "model", tp, b, S // tp, hp, kvh, hd, d, dtype_bytes=4,
        causal=True, schedule=winner)
    rec = managed.decision_log()[-1]
    rows.append((f"ring_attn_decision_{decision.schedule}",
                 measured[winner] * 1e6,
                 f"tuner-measured winner (seed={seed_schedule}); "
                 f"trail={rec.op}({rec.mode})"))
    return rows


def bench_pipeline(mesh) -> list[tuple[str, float, str]]:
    """Pipeline-parallel training step (PR 4 tentpole): gpipe vs 1f1b vs
    interleaved over 8 stages, full backward through the pipeline.  Every
    schedule is asserted allclose against the others for loss AND grads
    (the sequential-oracle equivalence lives in the dist suite); the
    derived column carries tick counts and the speedup vs the gpipe
    baseline (1f1b runs the same work in ~2/3 the ticks, each tick one
    fwd+bwd ppermute pair), plus the O(S)-vs-O(M) stash contrast.  The
    decision row closes the MDMP loop: cost-model seed -> measured winner
    recorded by the tuner -> pinned into the decision trail."""
    from repro.core.tuner import ScheduleTuner

    rows = []
    s_pipe, n_layers, d, m, b = 8, 16, 64, 16, 8
    rng = np.random.default_rng(3)
    ws = jnp.asarray(rng.normal(size=(n_layers, d, d)).astype(np.float32)
                     * 0.25)
    xs = jnp.asarray(rng.normal(size=(m, b, d)).astype(np.float32))
    tg = jnp.asarray(rng.normal(size=(m, b, d)).astype(np.float32))

    def layer_fn(x, w):
        return jnp.tanh(x @ w)

    from repro.parallel import pipeline as pipe

    def build(name, v):
        sched = pipe.build_schedule(name, m, s_pipe, v)
        n_virtual = s_pipe * sched.virtual

        def run(p):
            def chunk_fn(pp, q, mb, x):
                x = jnp.where(q == 0, xs[mb], x)
                cp, per = pipe.slice_chunk_params(pp, n_layers, n_virtual,
                                                  q)
                return pipe.masked_chunk_apply(layer_fn, cp, per, x)

            def loss_fn(pp, y, mb):
                return jnp.mean((y - tg[mb]) ** 2)

            return pipe.pipeline_value_and_grad(
                chunk_fn, loss_fn, p,
                jax.ShapeDtypeStruct((b, d), np.float32), sched, "x")

        fn = jax.jit(smap(run, mesh, in_specs=(P(None),),
                          out_specs=(P(None), P(None))))
        return sched, fn

    times, outs = {}, {}
    for name, v in (("gpipe", 1), ("1f1b", 1), ("interleaved", 2)):
        sched, fn = build(name, v)
        outs[name] = jax.tree.map(np.asarray, fn(ws))
        times[name] = _time(fn, ws)
        note = (f"ticks={sched.ticks} stash={sched.n_stash}"
                if name == "gpipe" else
                f"x{times['gpipe'] / times[name]:.2f} vs gpipe; "
                f"ticks={sched.ticks} stash={sched.n_stash}; "
                "allclose=gpipe")
        if name != "gpipe":
            np.testing.assert_allclose(outs[name][0], outs["gpipe"][0],
                                       rtol=1e-5)
            np.testing.assert_allclose(outs[name][1], outs["gpipe"][1],
                                       rtol=3e-4, atol=1e-6)
        rows.append((f"pipeline_M{m}_S{s_pipe}_{name}", times[name] * 1e6,
                     note))

    # the managed decision: cost-model seed -> measured override -> trail
    tuner = ScheduleTuner()
    batch_fwd_s = 2.0 * 2.0 * m * b * d * d * (n_layers / s_pipe) / 197e12
    entry = tuner.decide_pipeline("x", s_pipe, n_layers, (m * b, d),
                                  batch_fwd_s, m * b * d * 4)
    seed = f"{entry.mode}:{entry.chunks}"
    for name, t in times.items():
        tuner.record(entry.key, name, m, t)
    win = tuner.entries[entry.key]
    managed.clear_decision_log()
    decision = managed.resolve_pipeline_schedule(
        "x", s_pipe, batch_fwd_s, m * b * d * 4, n_layers=n_layers,
        schedule=win.mode, n_micro=win.chunks,
        virtual=2 if win.mode == "interleaved" else 1)
    rec = managed.decision_log()[-1]
    rows.append((f"pipeline_decision_{decision.schedule}",
                 times[win.mode] * 1e6,
                 f"tuner-measured winner (seed={seed}); "
                 f"trail={rec.op}({rec.mode} M={rec.chunks})"))
    return rows


def bench_serving() -> list[tuple[str, float, str]]:
    """Serving runtime (PR 3 tentpole): static waves vs continuous
    batching over the paged KV cache on a mixed-prompt-length queue.
    Every continuous variant is asserted token-equal to the static run
    per request; the value column is measured useful tokens/s and the
    derived column carries TTFT/TPOT and the speedup vs static.  The
    decision row closes the MDMP loop: cost-model seed -> measured
    winner recorded by the tuner -> pinned into the decision trail."""
    from repro.configs.base import ModelConfig
    from repro.models.model import Model
    from repro.parallel.sharding import MeshCtx, infer_shardings
    from repro.serve.engine import ServeEngine

    rows = []
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = ModelConfig(name="serve-bench", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=256, d_head=16, tp_multiple=4,
                      dtype="float32")
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))
    rng = np.random.default_rng(5)
    plens = [4, 28, 8, 44, 6, 20, 12, 36, 5, 24, 10, 40]   # mixed lengths
    n_new, slots = 16, 4
    prompts = [rng.integers(0, cfg.vocab_size - 1, size=p)
               .astype(np.int32) for p in plens]

    def run(schedule, chunk):
        eng = ServeEngine(model, mesh, params, slots=slots, max_seq=64,
                          page_size=8, schedule=schedule, chunk=chunk)
        rids = [eng.submit(p, n_new) for p in prompts]
        res = eng.run()
        return [res[r] for r in rids], eng.metrics.summary()

    out_static, m_static = run("static", 8)
    rows.append(("serve_static_c8", m_static["useful_tok_s"],
                 f"ttft={m_static['mean_ttft_s']*1e3:.0f}ms "
                 f"occ={m_static['occupancy']:.2f} "
                 f"quanta={m_static['quanta']}"))
    measured = {"static:8": 1.0 / max(m_static["useful_tok_s"], 1e-9)}
    for c in (4, 8, 16):
        out_c, m = run("continuous", c)
        for a, b in zip(out_c, out_static):
            np.testing.assert_array_equal(a, b)
        measured[f"continuous:{c}"] = 1.0 / max(m["useful_tok_s"], 1e-9)
        rows.append((f"serve_cont_c{c}", m["useful_tok_s"],
                     f"x{m['useful_tok_s']/m_static['useful_tok_s']:.2f}"
                     f" vs static; ttft={m['mean_ttft_s']*1e3:.0f}ms "
                     f"tpot={m['mean_tpot_s']*1e3:.2f}ms "
                     f"occ={m['occupancy']:.2f} quanta={m['quanta']}; "
                     "tokens==static"))

    # the managed decision: cost-model seed -> measured override -> trail
    from repro.core.tuner import ScheduleTuner
    tuner = ScheduleTuner()
    entry = tuner.decide_serve(
        slots, int(np.mean(plens)), n_new, cfg.param_count(),
        dtype_str="float32", dtype_bytes=4, max_prompt=int(max(plens)))
    seed = f"{entry.mode}:{entry.chunks}"
    for variant, s_per_tok in measured.items():
        mode, c = variant.split(":")
        tuner.record(entry.key, mode, int(c), s_per_tok)
    win = tuner.entries[entry.key]
    managed.clear_decision_log()
    decision = managed.resolve_serve_schedule(
        "serve", slots, float(np.mean(plens)), float(n_new),
        float(cfg.param_count()), dtype_bytes=4,
        max_prompt=float(max(plens)), schedule=win.mode,
        chunk=win.chunks)
    rec = managed.decision_log()[-1]
    rows.append((f"serve_decision_{decision.mode}_c{decision.chunk}",
                 1.0 / measured[f"{win.mode}:{win.chunks}"],
                 f"tuner-measured winner (seed={seed}); "
                 f"trail={rec.op}({rec.mode} C={rec.chunks})"))
    return rows


def bench_moe() -> list[tuple[str, float, str]]:
    """Managed expert dispatch (PR 5 tentpole): bulk a2a vs chunked-stream
    vs dense fallback over an 8-rank EP axis, on uniform vs skewed routing
    and across capacity factors.  Every schedule is asserted allclose
    against the bulk oracle at drop-free capacity; the derived column
    carries the speedup vs bulk.  Two decision rows close the MDMP loop:
    (1) the tuner's measured winner pinned into the decision trail, and
    (2) the capacity-factor re-resolution from the INSTRUMENTED routing
    histogram (uniform routing shrinks the buffers, skewed routing grows
    them to drop-free — the paper's runtime counters feeding iteration
    k+1)."""
    import dataclasses
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.core import instrument
    from repro.core.tuner import ScheduleTuner
    from repro.models import moe as moe_mod
    from repro.parallel.sharding import MeshCtx

    rows = []
    tp, E, K, D, F = 8, 8, 2, 128, 256
    b, S = 1, 1024                                 # t=1024, 128 per rank
    mesh2 = jax.make_mesh((1, tp), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh2, mdmp_mode="bulk")
    base = ModelConfig(name="bench-moe", family="moe", n_layers=1,
                       d_model=D, n_heads=2, n_kv_heads=2, d_ff=0,
                       vocab_size=64, tp_multiple=1, dtype="float32",
                       moe=MoEConfig(n_experts=E, top_k=K, d_ff_expert=F,
                                     impl="ep_a2a"))
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(b, S, D)).astype(np.float32))
    params = {
        "w_router": jnp.asarray(rng.normal(size=(D, E))
                                .astype(np.float32) * 0.3),
        "w1": jnp.asarray(rng.normal(size=(E, D, F)).astype(np.float32)
                          * 0.05),
        "w1_gate": jnp.asarray(rng.normal(size=(E, D, F))
                               .astype(np.float32) * 0.05),
        "w2": jnp.asarray(rng.normal(size=(E, F, D)).astype(np.float32)
                          * 0.05),
    }
    pspec = {"w_router": P(None, None), "w1": P("model", None, None),
             "w1_gate": P("model", None, None),
             "w2": P("model", None, None)}

    def build(disp, g, cf, pp):
        cfg = dataclasses.replace(base, moe=dataclasses.replace(
            base.moe, dispatch=disp, dispatch_g=g, capacity_factor=cf))
        return jax.jit(smap(
            lambda xx, qq: moe_mod.moe_block_ep(xx, qq, cfg, ctx)[0],
            mesh2, in_specs=(P(None, "model", None), pspec),
            out_specs=P(None, "model", None)))

    tuner = ScheduleTuner()
    instrument.clear_routing_log()
    # scenarios: (name, router skew, declared cf, adapt the cf from the
    # instrumented routing?).  "overprov" is the defensive static guess a
    # user ships when routing is unknown: the padding doubles bulk's rows
    # past the capacity-free dense fallback, and the adapt row shows the
    # shrink the runtime counters would apply at iteration k+1.
    for scenario, skew, declared_cf, adapt in (
            ("uniform", 0.0, 1.0, True),
            ("skewed", 2.5, 1.0, True),
            ("overprov", 0.0, 8.0, False)):
        pp = dict(params)
        if skew:
            pp["w_router"] = params["w_router"].at[:, 0].add(skew)
        # instrument the routing (the runtime counters): histogram ->
        # imbalance -> the capacity factor that drops nothing
        logits = np.asarray(x.reshape(-1, D) @ np.asarray(pp["w_router"]))
        top_idx = np.argsort(-logits, axis=1)[:, :K]
        t_loc = b * S // tp
        # capacity buffers are sized PER RANK: instrument every shard's
        # routing and let the hottest shard drive the capacity factor
        recs = [instrument.capture_routing(
                    f"bench_{scenario}_r{r}",
                    top_idx.reshape(tp, t_loc, K)[r], E,
                    cm.moe_capacity(t_loc, K, E, 1.0))
                for r in range(tp)]
        rec = max(recs, key=lambda r: r.imbalance)
        # occupancy is capacity-relative (measured at cf=1.0 buffers), so
        # only the imbalance feeds the re-resolution — the decision
        # derives the occupancy at whatever cf it picks
        decision = managed.resolve_moe_dispatch(
            "model", tp, t_loc, D, E, K, F, dtype_bytes=4,
            capacity_factor=declared_cf, measured_imbalance=rec.imbalance)
        cf = decision.capacity_factor if adapt else declared_cf
        rows.append((f"moe_dispatch_{scenario}_capacity_adapt",
                     decision.capacity_factor,
                     f"cf {declared_cf:.2f} -> "
                     f"{decision.capacity_factor:.2f} from instrumented "
                     f"routing (imbalance={rec.imbalance:.2f} "
                     f"drop@1.0={rec.drop_rate:.2f})"))

        fn_bulk = build("bulk", 1, cf, pp)
        oracle = np.asarray(fn_bulk(x, pp))
        t_bulk = _time(fn_bulk, x, pp)
        rows.append((f"moe_dispatch_{scenario}_bulk_cf{cf:g}",
                     t_bulk * 1e6, ""))
        measured = {"bulk:1": t_bulk}
        for name, disp, g in (("stream_g2", "stream", 2),
                              ("stream_g4", "stream", 4),
                              ("dense", "dense", 1)):
            fn = build(disp, g, cf, pp)
            np.testing.assert_allclose(np.asarray(fn(x, pp)), oracle,
                                       rtol=2e-4, atol=2e-5)
            t = _time(fn, x, pp)
            measured[f"{disp}:{g}"] = t
            rows.append((f"moe_dispatch_{scenario}_{name}_cf{cf:g}",
                         t * 1e6,
                         f"x{t_bulk / t:.2f} vs bulk; allclose=bulk"))

        # the managed decision: cost-model seed -> measured override ->
        # pinned into the trail (the paper's iteration-(k)->(k+1) loop)
        entry = tuner.decide_moe("model", tp, t_loc, D, E, K, F,
                                 dtype_str="float32", dtype_bytes=4,
                                 capacity_factor=cf)
        seed = f"{entry.mode}:g{entry.chunks}"
        for variant, t in measured.items():
            mode_s, g_s = variant.split(":")
            tuner.record(entry.key, mode_s, int(g_s), t)
        win = tuner.entries[entry.key]
        managed.clear_decision_log()
        managed.resolve_moe_dispatch(
            "model", tp, t_loc, D, E, K, F, dtype_bytes=4,
            capacity_factor=cf, schedule=win.mode, g=win.chunks)
        rec2 = managed.decision_log()[-1]
        rows.append((f"moe_dispatch_decision_{scenario}_{win.mode}",
                     measured[f"{win.mode}:{win.chunks}"] * 1e6,
                     f"tuner-measured winner (seed={seed}); "
                     f"trail={rec2.op}({rec2.mode} g={rec2.chunks})"))
    return rows


def bench_faults() -> list[tuple[str, float, str]]:
    """Managed fault tolerance (PR 6 tentpole): goodput — useful steps/s
    INCLUDING recovery — under an injected fault trace, managed Young/
    Daly cadence vs the fixed ckpt_every=25 every prior PR shipped.  A
    transient fault at step 15 of 20 costs the fixed-25 run its entire
    progress (its first save would land at step 20); the managed run
    re-resolves a short interval from the measured step time + write
    bandwidth (checkpoint/metrics.py) and only replays the tail.  The
    decision row pins the chosen interval into the MDMP decision trail
    (DecisionRecord(op="ckpt_interval"))."""
    import shutil
    import tempfile

    from repro import configs
    from repro.core.faults import FaultPlan
    from repro.core.tuner import ScheduleTuner
    from repro.data.pipeline import DataConfig, SyntheticLMData
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig
    from repro.parallel.sharding import MeshCtx
    from repro.train.train_loop import (TrainLoop, TrainLoopConfig,
                                        build_train_step)

    rows = []
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    cfg = configs.get_reduced("granite-34b")
    model = Model(cfg, ctx)
    total, mtbf = 20, 2.0
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=total,
                          moment_dtype=cfg.moment_dtype)
    step_fn, pshard, bshard = build_train_step(model, opt_cfg, mesh)

    def run(tag, *, managed_cadence, steps=total, fault=True):
        ckpt_dir = tempfile.mkdtemp(prefix=f"mdmp_faults_{tag}_")
        # the step must dominate the checkpoint cost for the cadence
        # trade-off to be about LOST WORK, not disk traffic: long seq +
        # bigger batch pushes the step well past the ~ms save cost
        data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=256, global_batch=8))
        loop = TrainLoop(
            step_fn, model, opt_cfg, data,
            TrainLoopConfig(total_steps=steps, ckpt_every=25,
                            ckpt_dir=ckpt_dir,
                            managed_cadence=managed_cadence,
                            mtbf_s=mtbf),
            pshard, bshard, tuner=ScheduleTuner(),
            fault_plan=FaultPlan.parse("transient@15") if fault else None)
        p, o, s0 = loop.init_state()
        out = loop.run(p, o, s0)
        shutil.rmtree(ckpt_dir, ignore_errors=True)
        return out

    # compile the train step + snapshot copy outside the measured runs
    run("warm", managed_cadence=False, steps=3, fault=False)

    managed.clear_decision_log()
    out_m = run("managed", managed_cadence=True)
    recs = [r for r in managed.decision_log() if r.op == "ckpt_interval"]
    out_f = run("fixed25", managed_cadence=False)

    def goodput(out):
        return total / out["wall_s"]

    gp_f, gp_m = goodput(out_f), goodput(out_m)
    rows.append(("faults_goodput_fixed25", gp_f,
                 f"useful steps/s; redo={out_f['steps_executed'] - total} "
                 f"restarts={out_f['restarts']}"))
    rows.append(("faults_goodput_managed", gp_m,
                 f"x{gp_m / gp_f:.2f} vs fixed25; "
                 f"interval={out_m['ckpt_interval']} "
                 f"redo={out_m['steps_executed'] - total} "
                 f"restarts={out_m['restarts']}"))
    assert recs, "managed cadence logged no ckpt_interval decision"
    rec = recs[-1]
    rows.append((f"ckpt_decision_{rec.mode}_N{rec.chunks}",
                 float(rec.chunks),
                 f"Young/Daly interval (mtbf={mtbf:g}s, "
                 f"snap={rec.nbytes / 1e6:.1f}MB); "
                 f"trail={rec.op}({rec.mode} N={rec.chunks} "
                 f"fixed_ovh={rec.predicted_bulk_s:.4f} "
                 f"chosen_ovh={rec.predicted_interleaved_s:.4f})"))
    return rows


def bench_overload() -> list[tuple[str, float, str]]:
    """Managed overload robustness (PR 7 tentpole): a bursty Zipf-ish
    trace against an UNDERSIZED page pool, three ways.  The seed row
    reproduces the old failure mode: an unchecked over-pool request
    livelocks admission and the whole queue dies on the stall backstop
    (value 0 — no goodput).  The FIFO row is the no-preemption baseline:
    commit admission (prompt+max_new reserved up front) never exhausts
    but serializes the heavy tail.  The managed row runs watermark
    admission + the cost-model-chosen preemption backstop and queue
    backpressure — asserted token-equal to FIFO per completed request
    and >= it on SLO-goodput (SLO-met tokens per wall second).  The
    decision row pins the last preempt_policy record into the trail."""
    from repro.configs.base import ModelConfig
    from repro.core.faults import FaultPlan
    from repro.models.model import Model
    from repro.parallel.sharding import MeshCtx, infer_shardings
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import Request, RequestRejected

    rows = []
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = ModelConfig(name="overload-bench", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=256, d_head=16, tp_multiple=4,
                      dtype="float32")
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))
    rng = np.random.default_rng(7)
    # Zipf-ish mixed trace: a heavy tail of long prompts over a pool
    # that holds ~1.5 fully-grown sequences
    plens = [44, 5, 4, 36, 6, 44, 4, 5, 28, 6, 4, 36]
    n_new, slots, slo = 12, 4, 5.0
    prompts = [rng.integers(0, cfg.vocab_size - 1, size=p)
               .astype(np.int32) for p in plens]

    def run(admission, preempt):
        eng = ServeEngine(
            model, mesh, params, slots=slots, max_seq=64, page_size=8,
            n_pages=8, schedule="continuous", chunk=8,
            admission=admission, preempt=preempt, max_queue=12,
            burst_new=8,
            fault_plan=FaultPlan.parse("burst@2:6"))
        rids, t0 = [], time.perf_counter()
        for p in prompts:
            try:
                rids.append(eng.submit(p, n_new))
            except RequestRejected:
                rids.append(None)
        res = eng.run()
        wall = time.perf_counter() - t0
        return rids, res, eng, wall

    # the seed failure mode: the old submit never checked the request's
    # page need against the POOL, so an over-pool (but under-max_seq)
    # request sat at the head of admission forever — reproduced here by
    # enqueueing it unchecked on a 6-page pool, caught by the stall
    # backstop.  The new typed rejection (RequestRejected at submit) is
    # what the managed rows run instead.
    eng0 = ServeEngine(model, mesh, params, slots=slots, max_seq=64,
                       page_size=8, n_pages=6, schedule="continuous",
                       chunk=8, admission="commit", preempt="none")
    eng0.submit(prompts[1], n_new)
    eng0.scheduler.pending.appendleft(Request(
        rid=999, prompt=prompts[0], max_new=20))   # 8 pages > 6-page pool
    try:
        eng0.run()
        seed_note = "UNEXPECTED: completed"
    except RuntimeError as e:
        seed_note = f"livelock caught: {str(e)[:48]}"
    rows.append(("overload_seed_commit", 0.0,
                 f"{seed_note}; queued work lost, 0 goodput"))

    rids_f, res_f, eng_f, wall_f = run("commit", "none")
    gp_f = eng_f.metrics.slo_met_tokens(slo) / wall_f
    mf = eng_f.metrics.summary()
    rows.append(("overload_fifo_goodput", gp_f,
                 f"SLO-met tok/s (slo={slo:g}s); no preemption, "
                 f"upfront reservation; sheds={mf['sheds']} "
                 f"p99_ttft={mf['p99_ttft_s'] * 1e3:.0f}ms "
                 f"quanta={mf['quanta']}"))

    managed.clear_decision_log()
    rids_m, res_m, eng_m, wall_m = run("watermark", "auto")
    gp_m = eng_m.metrics.slo_met_tokens(slo) / wall_m
    mm = eng_m.metrics.summary()
    # preemption preserved every token: completed requests match FIFO
    for rf, rm in zip(rids_f, rids_m):
        if rf is not None and rm is not None \
                and rf in res_f and rm in res_m:
            np.testing.assert_array_equal(res_m[rm], res_f[rf])
    n_sub = sum(1 for r in rids_m if r is not None) + mm["sheds"]
    assert gp_m >= gp_f, (gp_m, gp_f)
    rows.append(("overload_managed_goodput", gp_m,
                 f"x{gp_m / max(gp_f, 1e-9):.2f} vs fifo; "
                 f"shed_rate={mm['sheds'] / max(1, n_sub):.2f} "
                 f"preempts={mm['preempts']} "
                 f"p99_ttft={mm['p99_ttft_s'] * 1e3:.0f}ms "
                 f"quanta={mm['quanta']}; tokens==fifo"))

    recs = [r for r in managed.decision_log()
            if r.op == "preempt_policy"]
    assert recs, "managed overload run logged no preempt_policy decision"
    rec = recs[-1]
    rows.append((f"overload_decision_{rec.mode}", float(len(recs)),
                 f"pool-exhaustion events resolved; "
                 f"trail={rec.op}({rec.mode} pages={rec.chunks} "
                 f"recompute={rec.predicted_bulk_s * 1e3:.2f}ms "
                 f"chosen={rec.predicted_interleaved_s * 1e3:.2f}ms)"))
    return rows


def bench_program_plan(mesh) -> list[tuple[str, float, str]]:
    """Whole-program planner (PR 8 tentpole): two regions contend on one
    mesh axis — an activation gather feeding a matmul (region A, the big
    overlap donor) and a token shuffle (region B, the MoE-dispatch
    stand-in).  Priced ALONE, both regions' local resolution streams
    (each one's own compute covers its wire, so interleaved wins the
    solo model); priced JOINTLY, the shared overlap account covers both
    wires ONCE and region B's ring only adds per-step dispatch alphas,
    so the planner backs it off to ONE fused bulk a2a.  On this host
    every dispatch serialises, so the coordinated plan's lower message
    count is a real wall-clock win — measured local-knobs vs
    installed-plan on the same jitted step, outputs asserted equal."""
    from repro.plan import CommOp, plan_program

    rows = []
    n = 8
    rng = np.random.default_rng(13)
    a = jnp.asarray(rng.normal(size=(n * 2048, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(n * 4096, 64)).astype(np.float32))
    ops = [
        CommOp(kind="all_gather", label="regionA.acts",
               op_name="all_gather", axis="x", axis_size=n,
               nbytes=int(a.nbytes // n), dtype_bytes=4, phase="fwd",
               window=(0.0, 0.6),
               meta={"collective": "all_gather", "compute_time_s": 1e-3}),
        CommOp(kind="all_to_all", label="regionB.tokens",
               op_name="all_to_all", axis="x", axis_size=n,
               nbytes=int(t.nbytes // n), dtype_bytes=4, phase="fwd",
               window=(0.1, 0.7),
               meta={"collective": "all_to_all", "compute_time_s": 2e-5}),
    ]
    managed.clear_decision_log()
    plan = plan_program(ops)
    rec = [r for r in managed.decision_log()
           if r.op == "program_plan"][-1]
    assert plan.coordinated, plan.summary()
    lk = {c.op.op_name: c.local_knob for c in plan.choices}
    assert lk["all_gather"]["mode"] == "interleaved"
    assert lk["all_to_all"]["mode"] == "interleaved"
    assert plan.knob_for("all_to_all", "x")["mode"] == "bulk"

    def build(ag_mode=None, ag_chunks=None, a2a_mode=None):
        def f(a_, w_, t_):
            g = managed.managed_all_gather(a_, "x", ag_mode, ag_chunks)
            y = jnp.tanh(g @ w_)
            z = managed.managed_all_to_all(t_, "x", 0, 0, a2a_mode)
            return y, z
        return jax.jit(smap(f, mesh, in_specs=(P("x"), P(None), P("x")),
                            out_specs=(P(None), P("x"))))

    # local resolution: each region's solo-model winner, pinned
    fn_local = build(ag_mode=lk["all_gather"]["mode"],
                     ag_chunks=lk["all_gather"]["chunks"],
                     a2a_mode=lk["all_to_all"]["mode"])
    oracle = jax.tree.map(np.asarray, fn_local(a, w, t))
    t_local = _time(fn_local, a, w, t)
    rows.append(("plan_conflict_local", t_local * 1e6,
                 f"both regions stream (solo-model picks: "
                 f"ag={lk['all_gather']['mode']} "
                 f"a2a={lk['all_to_all']['mode']})"))

    # coordinated: the installed ProgramPlan drives BOTH call sites
    # (mode=None -> the resolvers consult the plan at trace time)
    with managed.use_plan(plan):
        fn_prog = build()
        out = jax.tree.map(np.asarray, fn_prog(a, w, t))
        np.testing.assert_allclose(out[0], oracle[0], rtol=1e-6)
        np.testing.assert_allclose(out[1], oracle[1], rtol=1e-6)
        t_prog = _time(fn_prog, a, w, t)
    rows.append(("plan_conflict_program", t_prog * 1e6,
                 f"x{t_local / t_prog:.2f} vs local; a2a backed off to "
                 f"bulk (1 fused dispatch vs {n - 1} ring steps); "
                 f"allclose=local"))
    rows.append(("plan_conflict_decision", plan.joint_cost_s * 1e6,
                 f"modeled joint={plan.joint_cost_s * 1e6:.1f}us "
                 f"local-joint={plan.local_joint_cost_s * 1e6:.1f}us "
                 f"local-concat={plan.local_solo_sum_s * 1e6:.1f}us; "
                 f"trail=program_plan({rec.mode} ops={rec.chunks} "
                 f"topo={rec.axis})"))
    return rows


def bench_trace_overhead() -> list[tuple[str, float, str]]:
    """mdmptrace tax: the same spanned workload with the tracer disabled
    (NULL default — every span call returns the shared no-op) vs an
    installed recording Tracer.  Acceptance: enabled overhead < 2% of
    the step, and the disabled path leaves outputs bit-identical."""
    from repro.obs import Tracer, dispatch_span, use_tracer

    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (512, 512)), jnp.float32)
    step = jax.jit(lambda a: a @ a + 1.0)
    jax.block_until_ready(step(x))
    step_s = _time(lambda a: step(a), x)

    def per_span_cost(n: int = 20000) -> float:
        best = float("inf")
        for _ in range(max(3, REPS)):
            t0 = time.perf_counter()
            for i in range(n):
                with dispatch_span("bench.span", axis="x", step=i):
                    pass
            best = min(best, (time.perf_counter() - t0) / n)
        return best

    t_null = per_span_cost()                # tracer disabled (NULL)
    tr = Tracer()
    with use_tracer(tr):
        t_span = per_span_cost()
        y_en = step(x)
    y_dis = step(x)
    identical = (np.asarray(y_dis).tobytes()
                 == np.asarray(y_en).tobytes())
    # 4 spans per step is representative of the launcher hot paths
    # (quantum + swap + two comm spans per quantum)
    ovh = 4 * t_span / step_s
    return [
        ("trace_overhead_enabled", t_span * 1e6,
         f"overhead={ovh * 100:.3f}% of a {step_s * 1e3:.2f}ms step at "
         f"4 spans/step ({t_span * 1e9:.0f}ns/span, bound 2%) "
         f"spans_recorded={tr.n_spans}"),
        ("trace_disabled_identical", t_null * 1e6,
         f"bit-identical={identical} disabled-span={t_null * 1e9:.0f}ns "
         f"(the shared no-op span)"),
    ]


_SUMMARY_MODES = (
    "aggregated", "interleaved", "bulk", "ring", "ulysses", "gpipe",
    "1f1b", "interleave", "static", "continuous", "stream", "dense",
    "swap", "recompute", "managed", "fixed25", "local", "program",
    "chosen", "original",
)


def _summary_row(name: str, us: float, derived: str) -> dict:
    """One machine-readable summary record per CSV row: op + mode parsed
    from the row name, seconds, and any speedup the derived text claims
    (``...x`` or ``speedup=...``)."""
    import re
    mode = next((m for m in _SUMMARY_MODES
                 if f"_{m}" in name or name.endswith(m)), None)
    op = name.split(f"_{mode}")[0] if mode else name
    m = re.search(r"speedup[=:]?\s*([0-9.]+)", derived) \
        or re.search(r"\b([0-9]+\.[0-9]+)x\b", derived)
    return {"name": name, "op": op, "mode": mode,
            "seconds": us / 1e6,
            "speedup": float(m.group(1)) if m else None,
            "derived": derived}


def write_summary(rows: list[tuple[str, float, str]]) -> str:
    import json
    here = os.path.dirname(os.path.abspath(__file__))
    out_dir = os.path.join(here, "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_summary.json")
    with open(path, "w") as f:
        json.dump({"rows": [_summary_row(*r) for r in rows]}, f,
                  indent=1)
    return path


def main_child() -> None:
    mesh = jax.make_mesh((8,), ("x",))
    rows = []
    rows += bench_managed_collectives(mesh)
    rows += bench_pingpong(mesh)
    rows += bench_jacobi(mesh)
    rows += bench_ring_attention(mesh)
    rows += bench_pipeline(mesh)
    rows += bench_serving()
    rows += bench_moe()
    rows += bench_faults()
    rows += bench_overload()
    rows += bench_program_plan(mesh)
    rows += bench_trace_overhead()
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    path = write_summary(rows)
    print(f"bench_summary,0.00,{len(rows)} rows -> {path}")


if __name__ == "__main__" and "--child" in sys.argv:
    main_child()
