import os
if "--child" in __import__("sys").argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Measured multi-device microbenchmarks (8 forced host devices).

Invoked by benchmarks/run.py as a SUBPROCESS (``--child``) so the main
process keeps its single-device view.  CPU 'ICI' has no async DMA engine,
so interleaved modes measure the schedule's pure overhead here; the
``derived`` column carries the cost model's TPU v5e prediction, and the
dist test suite checks numerical equivalence.  What IS physically measured
on CPU: per-message costs (the paper's latency-dominance effect) and the
bulk-vs-chunked message-count tradeoff.
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import cost_model as cm
from repro.core import managed
from repro.core import halo
from repro.parallel.sharding import smap

REPS = int(os.environ.get("MDMP_BENCH_REPS", "10"))   # smoke: set to 1-2


def _time(fn, *args) -> float:
    """Best-of-REPS wall clock (min is the noise-robust estimator on a
    shared host; the mean is hostage to scheduler hiccups)."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_managed_collectives(mesh) -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    for mb in (1, 8):
        x = jnp.asarray(rng.normal(size=(8 * mb * 32768, 4))
                        .astype(np.float32))          # mb MiB per shard
        for mode, chunks in (("bulk", 1), ("interleaved", 1),
                             ("interleaved", 4)):
            fn = jax.jit(smap(
                lambda a: managed.managed_all_gather(a, "x", mode, chunks),
                mesh, in_specs=(P("x"),), out_specs=P(None)))
            t = _time(fn, x)
            d = cm.decide(int(x.nbytes // 8), 8, compute_time_s=0.0)
            rows.append((f"ag_{mb}MiB_{mode}{chunks}", t * 1e6,
                         f"v5e_bulk={d.comm_time_s*1e6:.0f}us"))
    return rows


def bench_pingpong(mesh) -> list[tuple[str, float, str]]:
    """Measured PingPong between 2 of the 8 devices: one bulk message vs
    n_msg chunked messages (the paper's fine-grained limit)."""
    rows = []
    perm = [(0, 1), (1, 0)]
    n = 4096
    x = jnp.arange(8 * n, dtype=jnp.float32)

    def bulk(a):
        return lax.ppermute(a, "x", perm)

    def chunked(n_msg):
        def fn(a):
            pieces = jnp.split(a, n_msg)
            return jnp.concatenate(
                [lax.ppermute(p, "x", perm) for p in pieces])
        return fn

    t_bulk = _time(jax.jit(smap(bulk, mesh, in_specs=(P("x"),),
                                out_specs=P("x"))), x)
    rows.append(("pingpong_bulk_4096el", t_bulk * 1e6, ""))
    for n_msg in (4, 16, 64):
        t = _time(jax.jit(smap(chunked(n_msg), mesh, in_specs=(P("x"),),
                               out_specs=P("x"))), x)
        rows.append((f"pingpong_{n_msg}msgs", t * 1e6,
                     f"x{t / t_bulk:.2f} (latency-dominance, paper Fig5a)"))
    return rows


def bench_jacobi(mesh) -> list[tuple[str, float, str]]:
    """The paper's Jacobi example: bulk (Fig 2) vs intermingled (Fig 3) vs
    aggregated (k sweeps per k-row halo exchange — the temporally-blocked
    deep-halo pipeline), distributed over 8 shards.  The aggregated rows
    sweep k in {1,2,4,8}; every variant is asserted allclose against the
    bulk oracle, and the cost-model k lands in the decision trail row."""
    rows = []
    iters = 16
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.normal(size=(1024, 514)).astype(np.float32))
    f = jnp.asarray(rng.normal(size=(1024, 514)).astype(np.float32))

    def solve(mode, **kw):
        fn = jax.jit(smap(
            lambda a, b: halo.jacobi_solve(a, b, "x", iters, mode, **kw),
            mesh, in_specs=(P("x"), P("x")), out_specs=P("x")))
        return fn, np.asarray(fn(u, f))

    baseline, oracle = solve("bulk")
    t_bulk = _time(baseline, u, f)
    rows.append((f"jacobi_{iters}sweeps_bulk", t_bulk * 1e6, ""))
    fn, out = solve("interleaved")
    np.testing.assert_allclose(out, oracle, rtol=1e-5, atol=1e-5)
    rows.append((f"jacobi_{iters}sweeps_interleaved", _time(fn, u, f) * 1e6,
                 ""))

    # the managed decision: cost-model-chosen k, logged in the trail
    managed.clear_decision_log()
    decision = managed.resolve_halo_aggregation(
        "x", 8, u.shape[0] // 8, u.shape[1])
    rec = managed.decision_log()[-1]
    times = {}
    for k in (1, 2, 4, 8):
        fn, out = solve("aggregated", k=k)
        np.testing.assert_allclose(out, oracle, rtol=1e-5, atol=1e-5)
        times[k] = _time(fn, u, f)
        note = "allclose=bulk"
        if k == decision.k:
            note += f"; cost-model pick (pred x{decision.predicted_speedup:.2f}/sweep)"
        rows.append((f"jacobi_{iters}sweeps_aggregated_k{k}",
                     times[k] * 1e6, f"x{t_bulk / times[k]:.2f} vs bulk; "
                     + note))
    rows.append((f"jacobi_decision_k{decision.k}",
                 decision.aggregated_sweep_s * 1e6,
                 f"v5e per-sweep model; trail={rec.mode}(k={rec.chunks})"))
    return rows


def main_child() -> None:
    mesh = jax.make_mesh((8,), ("x",))
    rows = []
    rows += bench_managed_collectives(mesh)
    rows += bench_pingpong(mesh)
    rows += bench_jacobi(mesh)
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__" and "--child" in sys.argv:
    main_child()
