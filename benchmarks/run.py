"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (benchmarks/paper_tables.py), plus the
measured multi-device microbenchmarks (subprocess, 8 forced host devices)
and the §Roofline table from the dry-run artifact.  Output: CSV lines
``name,us_per_call,derived``.
"""

from __future__ import annotations

import os
import subprocess
import sys


def main() -> None:
    from benchmarks import paper_tables, roofline

    rows = paper_tables.all_tables()
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")

    # measured multi-device microbenches (own process: 8 host devices)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    here = os.path.dirname(os.path.abspath(__file__))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), os.path.join(here, ".."),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, os.path.join(here, "measured.py"), "--child"],
        env=env, capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        print(f"measured_suite,0.00,ERROR: {proc.stderr[-400:]}")
    else:
        for line in proc.stdout.splitlines():
            if line.count(",") >= 2:
                print(line)

    # roofline table (requires the dry-run artifact)
    path = os.path.join(here, "..", "results", "dryrun.json")
    if os.path.exists(path):
        for mesh in ("16x16", "2x16x16"):
            rows = roofline.report(path, mesh=mesh)
            for name, us, derived in roofline.rows_as_csv(rows):
                print(f"{name},{us:.2f},{derived}")
    else:
        print("roofline,0.00,SKIPPED (run repro.launch.dryrun first)")


if __name__ == "__main__":
    main()
