"""Paper-reproduction benchmarks — one per table/figure of the paper.

Measured parts run on this host (STREAM variants in-process; message-level
benchmarks in an 8-device subprocess, benchmarks/measured.py).  Modeled
parts use the calibrated alpha-beta machines (core/cost_model.py) for the
paper's hardware and TPU v5e — the quantitative claims of Fig 5/6 are
hardware-bound, so the reproduction target is the ORDERING and crossover
structure (EXPERIMENTS.md §Paper-repro discusses the one quantitative
discrepancy we found).
"""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core import instrument

import os

N_STREAM = 200_000
REPS = int(os.environ.get("MDMP_BENCH_REPS", "30"))   # smoke: set to 1-2


def _time(fn: Callable, *args) -> float:
    fn(*args)                                  # compile + warm
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPS


def _stream_ops():
    """The paper's STREAM kernels (Table 1/2 rows)."""
    return {
        "int_assign": lambda a, b, s: a * 0 + 3,
        "db_assign": lambda a, b, s: a * 0.0 + 3.0,
        "db_copy": lambda a, b, s: a + 0.0 * b,
        "db_scale": lambda a, b, s: s * a,
        "db_add": lambda a, b, s: a + b,
        "db_triad": lambda a, b, s: a + s * b,
    }


def table1_stream_in_region() -> list[tuple[str, float, str]]:
    """Table 1: STREAM inside a communicating region.

    * original            — plain compiled kernel;
    * mdmp_runtime        — the paper's mechanism: per-element read/write
                            counters updated at runtime (emulated with
                            counter-array updates, like the library-call
                            MDMP build);
    * mdmp_optimized      — the paper's macro build (single fused counter
                            update);
    * mdmp_trace (ours)   — the TPU adaptation: data-access analysis runs
                            at TRACE time, the runtime kernel is untouched.
                            The one-time trace cost is reported separately
                            (row `trace_analysis_once`).
    """
    rows = []
    a = jnp.arange(N_STREAM, dtype=jnp.float32)
    b = jnp.ones(N_STREAM, jnp.float32)
    s = jnp.float32(3.0)
    reads = jnp.zeros(N_STREAM, jnp.int32)
    writes = jnp.zeros(N_STREAM, jnp.int32)

    for name, op in _stream_ops().items():
        orig = jax.jit(op)
        t_orig = _time(orig, a, b, s)

        def runtime_counters(a, b, s, reads, writes, op=op):
            out = op(a, b, s)
            return out, reads + 2, writes + 1, reads * 0 + 1

        t_rt = _time(jax.jit(runtime_counters), a, b, s, reads, writes)

        def optimized_counters(a, b, s, reads, op=op):
            out = op(a, b, s)
            return out, reads + 3
        t_opt = _time(jax.jit(optimized_counters), a, b, s, reads)

        t_ours = _time(orig, a, b, s)          # identical runtime kernel
        rows.append((f"t1_{name}_original", t_orig * 1e6, ""))
        rows.append((f"t1_{name}_mdmp_runtime", t_rt * 1e6,
                     f"x{t_rt / t_orig:.2f}"))
        rows.append((f"t1_{name}_mdmp_optimized", t_opt * 1e6,
                     f"x{t_opt / t_orig:.2f}"))
        rows.append((f"t1_{name}_mdmp_trace_ours", t_ours * 1e6,
                     f"x{t_ours / t_orig:.2f}"))

    t0 = time.perf_counter()
    instrument.analyze_region(_stream_ops()["db_triad"], a, b, s,
                              tracked_args=[0, 1], labels=["a", "b"])
    rows.append(("t1_trace_analysis_once", (time.perf_counter() - t0) * 1e6,
                 "one-time"))
    return rows


def table2_stream_outside_region() -> list[tuple[str, float, str]]:
    """Table 2: outside a communicating region tracking is disabled — both
    the paper's optimized build and ours run the plain kernel."""
    rows = []
    a = jnp.arange(N_STREAM, dtype=jnp.float32)
    b = jnp.ones(N_STREAM, jnp.float32)
    s = jnp.float32(3.0)
    for name, op in _stream_ops().items():
        t = _time(jax.jit(op), a, b, s)
        rows.append((f"t2_{name}_all_variants", t * 1e6, "x1.00"))
    return rows


def fig5a_pingpong() -> list[tuple[str, float, str]]:
    """Fig 5a: PingPong runtime vs message elements — bulk (1 message) vs
    MDMP fine-grained (1 message per element), alpha-beta model per
    machine."""
    rows = []
    for hw in (cm.HECTOR_XE6, cm.HELIOS_BULLX, cm.JUQUEEN_BGQ, cm.TPU_V5E):
        for n in (64, 256, 1024):
            bulk, fine = cm.pingpong_times(n, 0.0, hw)
            rows.append((f"f5a_{hw.name}_n{n}_mpi", bulk * 1e6, ""))
            rows.append((f"f5a_{hw.name}_n{n}_mdmp", fine * 1e6,
                         f"x{fine / bulk:.2f}"))
    return rows


def fig5b_delay_pingpong() -> list[tuple[str, float, str]]:
    """Fig 5b: DelayPingPong — crossover sweep.  Element-granular (the
    paper's literal mechanism) and tile-granular (the TPU adaptation)."""
    rows = []
    for hw in (cm.HECTOR_XE6, cm.HELIOS_BULLX, cm.JUQUEEN_BGQ, cm.TPU_V5E):
        d_el = cm.crossover_compute_per_element(1024, hw=hw)
        d_tile = cm.crossover_compute_chunked(1 << 20, 8, hw=hw)
        rows.append((f"f5b_{hw.name}_crossover_element",
                     d_el if np.isfinite(d_el) else -1.0,
                     "delay elements (-1 = never)"))
        rows.append((f"f5b_{hw.name}_crossover_tile8",
                     d_tile if np.isfinite(d_tile) else -1.0,
                     "delay elements (-1 = never)"))
    return rows


def fig6a_selective_pingpong() -> list[tuple[str, float, str]]:
    """Fig 6a: send only a subset of the 1024-element buffer — the MDMP/MPI
    gap shrinks with the number of sent elements."""
    rows = []
    hw = cm.HECTOR_XE6
    for sent in (1024, 256, 32, 1):
        bulk, fine = cm.pingpong_times(1024, 0.0, hw, sent_elements=sent)
        rows.append((f"f6a_sent{sent}_mpi", bulk * 1e6, ""))
        rows.append((f"f6a_sent{sent}_mdmp", fine * 1e6,
                     f"gap={1e6 * (fine - bulk):.1f}us"))
    return rows


def fig6b_selective_delay() -> list[tuple[str, float, str]]:
    """Fig 6b: 1024 elements processed, 1 or 32 sent, sweeping delay —
    the paper's '16 adds hide one element / ~32 adds hide 32 elements'."""
    rows = []
    hw = cm.HECTOR_XE6
    for sent in (1, 32):
        d = cm.crossover_compute_per_element(1024, hw=hw,
                                             sent_elements=sent)
        rows.append((f"f6b_sent{sent}_crossover",
                     d if np.isfinite(d) else -1.0,
                     "delay elements (-1 = never)"))
        for delay in (0.0, 16.0, 64.0):
            bulk, fine = cm.pingpong_times(1024, delay, hw,
                                           sent_elements=sent)
            rows.append((f"f6b_sent{sent}_delay{int(delay)}_mpi",
                         bulk * 1e6, ""))
            rows.append((f"f6b_sent{sent}_delay{int(delay)}_mdmp",
                         fine * 1e6, f"x{fine / bulk:.2f}"))
    return rows


def halo_aggregation_model() -> list[tuple[str, float, str]]:
    """The aggregation knob (beyond the paper's figures, same alpha-beta
    machinery): predicted seconds-per-sweep of the k-aggregated deep-halo
    Jacobi schedule for a 128 x 514 local block, per machine.  k=1 is the
    paper's bulk schedule; the chosen-k row is what the managed runtime
    would pick (messages amortised k x, tile streamed once per k sweeps,
    redundant ghost trapezoid charged as flops)."""
    rows = []
    rows_local, cols = 128, 514
    for hw in (cm.HECTOR_XE6, cm.HELIOS_BULLX, cm.JUQUEEN_BGQ, cm.TPU_V5E):
        d = cm.decide_halo_aggregation(rows_local, cols, 8, hw=hw)
        for k in (1, 2, 4, 8):
            if k not in d.per_sweep_s:
                continue
            t = d.per_sweep_s[k]
            rows.append((f"halo_agg_{hw.name}_k{k}", t * 1e6,
                         f"x{d.bulk_sweep_s / t:.2f} vs bulk/sweep"))
        rows.append((f"halo_agg_{hw.name}_chosen", float(d.k),
                     f"k picked by cost model (pred "
                     f"x{d.predicted_speedup:.2f})"))
    return rows


def attention_schedule_model() -> list[tuple[str, float, str]]:
    """The attention schedule knob (PR 2 tentpole, same alpha-beta
    machinery): predicted seconds-per-layer for bulk sequence-gather vs
    ulysses a2a vs ring streaming on a long-context prefill point
    (S = 64k over tp = 8, 32 heads x 128, D = 4096, bf16), per machine.
    The chosen row is what the managed runtime picks; on machines with
    real link bandwidth the ring hides the KV transfer under the
    per-block flash while the gather schedules pay bytes ∝ S·B·D."""
    rows = []
    tp, s_local = 8, 65536 // 8
    for hw in (cm.HECTOR_XE6, cm.HELIOS_BULLX, cm.JUQUEEN_BGQ, cm.TPU_V5E):
        for causal in (False, True):
            tag = "causal" if causal else "full"
            d = cm.decide_attention_schedule(
                1, s_local, 32, 8, 128, 4096, tp, dtype_bytes=2,
                causal=causal, hw=hw)
            for sched, t in sorted(d.times_s.items()):
                rows.append((f"attn_sched_{hw.name}_{tag}_{sched}",
                             t * 1e6, f"x{d.bulk_s / t:.2f} vs bulk"))
            rows.append((f"attn_sched_{hw.name}_{tag}_chosen",
                         d.chosen_s * 1e6,
                         f"{d.schedule} picked by cost model (pred "
                         f"x{d.predicted_speedup:.2f})"))
    return rows


def pipeline_schedule_model() -> list[tuple[str, float, str]]:
    """The pipeline schedule knob (PR 4 tentpole, same alpha-beta
    machinery): predicted step seconds of gpipe vs 1f1b vs interleaved
    for a production point — 4 stages of an 8-layer-per-stage decoder,
    30 ms of full-batch forward per rank, a 2 GB boundary activation
    block, and an HBM stash cap that retires GPipe's O(batch) activation
    memory (the 1F1B memory claim).  The chosen row is what the managed
    runtime picks: on machines where per-message alpha dominates the
    fewest-tick 1f1b wins; where the bubble dominates the interleaved
    virtual chunks shave the ramp."""
    rows = []
    s, batch_fwd_s, batch_bytes = 4, 30e-3, 2.0e9
    for hw in (cm.HECTOR_XE6, cm.HELIOS_BULLX, cm.JUQUEEN_BGQ, cm.TPU_V5E):
        d = cm.decide_pipeline_schedule(
            s, batch_fwd_s, batch_bytes, n_layers=32,
            stash_cap_bytes=1.5e9, hw=hw)
        for variant in sorted(d.times_s):
            sched, m, v = variant.split(":")
            rows.append((f"pipe_sched_{hw.name}_{sched}_m{m}_v{v}",
                         d.times_s[variant] * 1e3,
                         f"x{d.bulk_s / d.times_s[variant]:.2f} vs best "
                         "surviving baseline (ms/step)"))
        rows.append((f"pipe_sched_{hw.name}_chosen", float(d.n_micro),
                     f"{d.schedule} M={d.n_micro} v={d.virtual} picked by "
                     f"cost model (bubble {d.bubble_frac:.2f}, stash "
                     f"{d.stash_bytes/1e9:.2f}GB <= cap)"))
    return rows


def serve_schedule_model() -> list[tuple[str, float, str]]:
    """The serving schedule knob (PR 3 tentpole, same alpha-beta
    machinery): modeled per-token latency of static waves vs continuous
    batching across scheduling quanta, for a 1.3B-param bf16 decoder
    serving 64 slots at a mixed 1k-mean/4k-max prompt, 256 new tokens.
    The chosen row is what the managed runtime picks: decode steps are
    HBM-bound (weights stream once per step), so the quantum C trades
    per-dispatch overhead against the C/2 slot-steps a completing request
    wastes before its boundary refill — and continuous batching's
    occupancy win over padded static waves dominates whenever prompt
    lengths are mixed."""
    rows = []
    for hw in (cm.HECTOR_XE6, cm.HELIOS_BULLX, cm.JUQUEEN_BGQ, cm.TPU_V5E):
        d = cm.decide_serve_schedule(
            1.3e9, 64, 1024, 256, max_prompt=4096, dtype_bytes=2, hw=hw)
        static_best = d.static_tok_s
        for variant in sorted(d.tok_s):
            mode, c = variant.split(":")
            if mode == "static" and d.tok_s[variant] != static_best:
                continue                  # one static row (best C) is enough
            rows.append((f"serve_sched_{hw.name}_{mode}_c{c}",
                         1e6 / max(d.tok_s[variant], 1e-9),
                         f"x{d.tok_s[variant] / static_best:.2f} vs static"
                         " (us/token)"))
        rows.append((f"serve_sched_{hw.name}_chosen", float(d.chunk),
                     f"{d.mode} picked by cost model (pred "
                     f"x{d.predicted_speedup:.2f} vs static; "
                     f"ttft {d.ttft_s * 1e3:.0f}ms)"))
    return rows


def moe_dispatch_model() -> list[tuple[str, float, str]]:
    """The MoE dispatch knob (PR 5 tentpole, same alpha-beta machinery):
    predicted seconds-per-layer for bulk a2a vs chunked-stream vs the
    dense fallback on the moonshot production point — 8192 local tokens,
    D = 2048, 64 experts top-6 with F = 1408, EP = 16, bf16 — per
    machine, declared cf = 1.25 vs a measured 4x-skewed routing.  The
    chosen row is what the managed runtime picks: on machines with real
    link bandwidth the stream hides the capacity-buffer wire under the
    grouped-GEMM compute; when instrumented skew inflates the capacity
    factor the a2a bytes balloon and the capacity-free dense fallback
    crosses over."""
    rows = []
    t_loc, d_model, e, k, f, ep = 8192, 2048, 64, 6, 1408, 16
    for hw in (cm.HECTOR_XE6, cm.HELIOS_BULLX, cm.JUQUEEN_BGQ, cm.TPU_V5E):
        for tag, imb in (("declared", None), ("skewed", 4.0)):
            d = cm.decide_moe_dispatch(
                t_loc, d_model, e, k, f, ep, mults=3, dtype_bytes=2,
                capacity_factor=1.25, measured_imbalance=imb, hw=hw)
            for variant in sorted(d.times_s):
                sched, g = variant.split(":")
                rows.append((f"moe_dispatch_{hw.name}_{tag}_{sched}_g{g}",
                             d.times_s[variant] * 1e6,
                             f"x{d.bulk_s / d.times_s[variant]:.2f} vs "
                             "bulk"))
            rows.append((f"moe_dispatch_{hw.name}_{tag}_chosen",
                         d.chosen_s * 1e6,
                         f"{d.schedule} g={d.g} cf={d.capacity_factor:g} "
                         f"picked by cost model (pred "
                         f"x{d.predicted_speedup:.2f}, C={d.capacity}, "
                         f"a2a={d.a2a_bytes/1e6:.0f}MB)"))
    return rows


def all_tables() -> list[tuple[str, float, str]]:
    rows = []
    rows += table1_stream_in_region()
    rows += table2_stream_outside_region()
    rows += fig5a_pingpong()
    rows += fig5b_delay_pingpong()
    rows += fig6a_selective_pingpong()
    rows += fig6b_selective_delay()
    rows += halo_aggregation_model()
    rows += attention_schedule_model()
    rows += pipeline_schedule_model()
    rows += serve_schedule_model()
    rows += moe_dispatch_model()
    return rows
