"""Full 10-architecture distributed-equivalence sweep (the heavyweight
version of tests/dist_suite/test_model_parallel.py):

    python scripts/validate_all.py [arch ...]

For every arch: single-device training == (2x2 bulk) == (2x2 interleaved
MDMP) == (2x2x2 multipod), loss + grad-norm + updated params; ~6 min.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import dataclasses, traceback
import jax, numpy as np
from repro import configs
from repro.models.model import Model
from repro.parallel.sharding import MeshCtx
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.train_loop import build_train_step
from repro.data.pipeline import DataConfig, SyntheticLMData

def run(cfg, mesh_shape, axes, mode, params0, batch_np):
    mesh = jax.make_mesh(mesh_shape, axes)
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode=mode)
    model = Model(cfg, ctx)
    step_fn, pshard, bshard = build_train_step(model, AdamWConfig(lr=1e-2), mesh, donate=False)
    params = jax.tree.map(jax.device_put, params0, pshard)
    opt = adamw_init(params, AdamWConfig())
    batch = {k: jax.device_put(v, bshard[k]) if k in bshard else v for k, v in batch_np.items()}
    p2, o2, m = step_fn(params, opt, batch)
    return float(m["loss"]), float(m["grad_norm"]), jax.tree.map(np.asarray, p2)

which = sys.argv[1:] or configs.list_archs()
for arch in which:
    cfg = dataclasses.replace(configs.get_reduced(arch), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    try:
        # init once on single device
        m1 = jax.make_mesh((1, 1), ("data", "model"))
        model0 = Model(cfg, MeshCtx.from_mesh(m1))
        params0 = jax.tree.map(np.asarray, model0.init(jax.random.key(0)))
        data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
        b = data.global_batch_at(0)
        rng = np.random.default_rng(0)
        if cfg.encoder is not None:
            b["frames"] = rng.normal(size=(4, cfg.encoder.n_frames, cfg.d_model)).astype(np.float32)
        if cfg.vision is not None:
            b["patches"] = rng.normal(size=(4, cfg.vision.n_patches, cfg.d_model)).astype(np.float32)

        l_ref, g_ref, p_ref = run(cfg, (1, 1), ("data", "model"), "bulk", params0, b)
        results = [f"ref={l_ref:.4f}"]
        for mesh_shape, axes, mode in [((2, 2), ("data", "model"), "bulk"),
                                       ((2, 2), ("data", "model"), "interleaved"),
                                       ((2, 2, 2), ("pod", "data", "model"), "bulk")]:
            l, g, p2 = run(cfg, mesh_shape, axes, mode, params0, b)
            np.testing.assert_allclose(l, l_ref, rtol=(1e-3 if cfg.moe is not None else 2e-4), err_msg=f"{arch} loss {axes} {mode} dist={l} ref={l_ref}")
            np.testing.assert_allclose(g, g_ref, rtol=2e-3,
                err_msg=f"{arch} gnorm dist={g} ref={g_ref}")
            for (k1, a), (k2, bb) in zip(
                sorted(jax.tree_util.tree_flatten_with_path(p_ref)[0], key=lambda t: str(t[0])),
                sorted(jax.tree_util.tree_flatten_with_path(p2)[0], key=lambda t: str(t[0]))):
                np.testing.assert_allclose(a, bb, rtol=2e-3, atol=2e-4,
                    err_msg=f"{arch} param {k1} {mesh_shape} {mode}")
            results.append(f"{'x'.join(map(str,mesh_shape))}/{mode[:3]} ok")
        print(f"{arch:22s} " + "  ".join(results))
    except Exception as e:
        print(f"{arch:22s} FAIL: {type(e).__name__}: {str(e)[:400]}")
        if len(which) == 1:
            traceback.print_exc()
