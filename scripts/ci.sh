#!/usr/bin/env bash
# CI gate: tier-1 tests + a benchmark smoke so perf rows can't silently rot.
#
#   scripts/ci.sh            # full tier-1 + benchmark smoke (REPS=2)
#   MDMP_BENCH_REPS=10 scripts/ci.sh   # heavier benchmark pass
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== mdmplint gate (static communication verifier) =="
# the live launch configs below must lint clean (exit 0, zero errors)...
lint_pipe="$(python -m repro.launch.lint --target train \
    --arch granite-34b --reduced --mesh 2x2x2 --pipeline 1f1b \
    --batch 8 --seq 32)"
echo "$lint_pipe" | grep -q "clean (0 diagnostics)" || {
    echo "FAIL: pipelined train config does not lint clean"; exit 1; }
lint_moe="$(python -m repro.launch.lint --target train \
    --arch moonshot-v1-16b-a3b --reduced --mesh 2x2 --batch 8 --seq 32)"
echo "$lint_moe" | grep -q "clean (0 diagnostics)" || {
    echo "FAIL: MoE train config does not lint clean"; exit 1; }
lint_serve="$(python -m repro.launch.lint --target serve \
    --arch mamba2-130m --reduced --slots 2 --prompt-len 12 \
    --new-tokens 8)"
echo "$lint_serve" | grep -q "clean (0 diagnostics)" || {
    echo "FAIL: serve config does not lint clean"; exit 1; }
# ...while every deliberately-broken corpus case must yield EXACTLY its
# golden diagnostic code and a non-zero exit
lint_case() {  # $1 = corpus case, $2 = expected code
    if out="$(python -m repro.launch.lint \
            --case "tests/lint_corpus/$1" 2>&1)"; then
        echo "FAIL: lint of broken corpus case $1 exited zero"; exit 1
    fi
    echo "$out" | grep -q "^$2 " || {
        echo "FAIL: corpus case $1 missing $2 (got: $out)"; exit 1; }
}
lint_case unknown_axis.json MDMP001
lint_case undeclared_collective.json MDMP101
lint_case bytes_drift.json MDMP102
lint_case nonbijective_permute.json MDMP201
lint_case ring_no_return.json MDMP202
lint_case wait_cycle.json MDMP301
lint_case overlap_race.json MDMP401
lint_case nondivisor_g.json MDMP501
lint_case bad_microbatch.json MDMP502
lint_case overcap_stash.json MDMP503
python -m repro.launch.lint --case tests/lint_corpus/clean.json || {
    echo "FAIL: clean corpus case did not lint clean"; exit 1; }
echo "mdmplint gate OK"

echo "== serve smoke (managed serving runtime, schedule=auto) =="
serve_out="$(python -m repro.launch.serve --arch mamba2-130m --reduced \
    --schedule auto --requests 6 --slots 2 --new-tokens 8 --max-seq 64 \
    --prompt-len 12 --verify strict)"
echo "$serve_out" | head -8
echo "$serve_out" | grep -q "tok/s" || {
    echo "FAIL: serve smoke produced no throughput line"; exit 1; }
echo "$serve_out" | grep -q "decision serve_schedule(" || {
    echo "FAIL: serve smoke missing the serve_schedule decision"; exit 1; }

echo "== overload smoke (SLO admission + cost-model-chosen preemption) =="
overload_out="$(python -m repro.launch.serve --arch mamba2-130m --reduced \
    --schedule continuous --chunk 8 --preempt auto \
    --fault-plan 'burst@2:16' --pages 12 --prompt-len 24 --new-tokens 16 \
    --max-seq 64 --requests 6 --max-queue 12)"
echo "$overload_out" | head -8
echo "$overload_out" | grep -q "overload: sheds" || {
    echo "FAIL: overload smoke produced no overload summary line"; exit 1; }
echo "$overload_out" | grep -q "decision preempt_policy(" || {
    echo "FAIL: overload smoke missing the preempt_policy decision"; exit 1; }

echo "== pipeline smoke (managed 1F1B/interleaved training, --pipeline auto) =="
pipe_out="$(XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m repro.launch.train --arch granite-34b --reduced --steps 2 \
    --pipeline auto --mesh 2x2x2 --batch 8 --seq 32 \
    --verify strict --ckpt /tmp/mdmp_ci_pipe_ckpt)"
echo "$pipe_out" | head -6
echo "$pipe_out" | grep -q "decision pipeline_schedule(" || {
    echo "FAIL: pipeline smoke missing the pipeline_schedule decision"
    exit 1; }
echo "$pipe_out" | grep -q "loss" || {
    echo "FAIL: pipeline smoke produced no training losses"; exit 1; }

echo "== moe smoke (managed expert dispatch, --moe-dispatch auto) =="
moe_out="$(XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m repro.launch.train --arch moonshot-v1-16b-a3b --reduced \
    --steps 2 --moe-dispatch auto --mesh 2x2 --batch 8 --seq 32 \
    --verify strict --ckpt /tmp/mdmp_ci_moe_ckpt)"
echo "$moe_out" | head -6
echo "$moe_out" | grep -q "decision moe_dispatch(" || {
    echo "FAIL: moe smoke missing the moe_dispatch decision"; exit 1; }
echo "$moe_out" | grep -q "loss" || {
    echo "FAIL: moe smoke produced no training losses"; exit 1; }

echo "== planner smoke (whole-program comm plan, --plan auto, pipelined) =="
plan_out="$(XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m repro.launch.train --arch granite-34b --reduced --steps 2 \
    --pipeline auto --plan auto --mesh 2x2x2 --batch 8 --seq 32 \
    --ckpt /tmp/mdmp_ci_plan_ckpt)"
echo "$plan_out" | head -8
echo "$plan_out" | grep -q "decision program_plan(" || {
    echo "FAIL: planner smoke missing the program_plan decision"; exit 1; }
echo "$plan_out" | grep -q "  trail  " || {
    echo "FAIL: planner smoke missing the per-op coordinated trail"
    exit 1; }
echo "$plan_out" | grep -q "loss" || {
    echo "FAIL: planner smoke produced no training losses"; exit 1; }

echo "== planner smoke (whole-program comm plan, --plan auto, MoE) =="
plan_moe_out="$(XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m repro.launch.train --arch moonshot-v1-16b-a3b --reduced \
    --steps 2 --moe-dispatch auto --plan auto --mesh 2x2 --batch 8 \
    --seq 32 --ckpt /tmp/mdmp_ci_plan_moe_ckpt)"
echo "$plan_moe_out" | head -8
echo "$plan_moe_out" | grep -q "decision program_plan(" || {
    echo "FAIL: MoE planner smoke missing the program_plan decision"
    exit 1; }
echo "$plan_moe_out" | grep -q "loss" || {
    echo "FAIL: MoE planner smoke produced no training losses"; exit 1; }

echo "== fault smoke (managed cadence + deterministic fault injection) =="
rm -rf /tmp/mdmp_ci_fault_ckpt
fault_out="$(python -m repro.launch.train --arch granite-34b --reduced \
    --steps 8 --batch 4 --seq 32 --ckpt-every auto --mtbf 2 \
    --fault-plan 'transient@4;slow@6:0.2' \
    --ckpt /tmp/mdmp_ci_fault_ckpt)"
echo "$fault_out" | tail -6
echo "$fault_out" | grep -q "decision ckpt_interval(" || {
    echo "FAIL: fault smoke missing the ckpt_interval decision"; exit 1; }
echo "$fault_out" | grep -q "faults injected=2 unfired=0 restarts=1" || {
    echo "FAIL: fault smoke did not inject+recover the planned faults"
    exit 1; }
echo "$fault_out" | grep -q "done at step 8" || {
    echo "FAIL: fault smoke did not run to completion"; exit 1; }

echo "== trace gate (mdmptrace: --trace export, calibration, --diff) =="
rm -f /tmp/mdmp_ci_trace_serve.json /tmp/mdmp_ci_trace_serve2.json \
    /tmp/mdmp_ci_trace_train.json
trace_serve="$(python -m repro.launch.serve --arch mamba2-130m --reduced \
    --schedule auto --requests 6 --slots 2 --new-tokens 8 --max-seq 64 \
    --prompt-len 12 --trace /tmp/mdmp_ci_trace_serve.json)"
echo "$trace_serve" | grep -q "calibration: .* decisions correlated" || {
    echo "FAIL: serve trace run printed no calibration report"; exit 1; }
rm -rf /tmp/mdmp_ci_trace_ckpt
trace_train="$(python -m repro.launch.train --arch granite-34b --reduced \
    --steps 4 --batch 4 --seq 32 --ckpt-every auto \
    --ckpt /tmp/mdmp_ci_trace_ckpt \
    --trace /tmp/mdmp_ci_trace_train.json)"
echo "$trace_train" | grep -q "calibration: .* decisions correlated" || {
    echo "FAIL: train trace run printed no calibration report"; exit 1; }
# both artifacts must be valid Chrome traces with the expected tracks,
# span events, decision instants, and an embedded calibration ledger
python - <<'EOF'
from repro.obs.export import load_trace, trace_tracks
for path, need in (
        ("/tmp/mdmp_ci_trace_serve.json", {"decisions", "serve"}),
        ("/tmp/mdmp_ci_trace_train.json", {"decisions", "compute",
                                           "ckpt"})):
    doc = load_trace(path)
    tracks = set(trace_tracks(doc).values())
    assert need <= tracks, f"{path}: tracks {tracks} missing {need}"
    evs = doc["traceEvents"]
    assert any(e["ph"] == "X" for e in evs), f"{path}: no spans"
    assert any(e["ph"] == "i" and e.get("s") == "p" for e in evs), \
        f"{path}: no decision instants"
    cal = doc["otherData"]["calibration"]
    assert cal["coverage"] >= 0.9, f"{path}: coverage {cal['coverage']}"
print("trace artifacts OK")
EOF
# a second identical serve run must diff clean under a generous bound
python -m repro.launch.serve --arch mamba2-130m --reduced \
    --schedule auto --requests 6 --slots 2 --new-tokens 8 --max-seq 64 \
    --prompt-len 12 --trace /tmp/mdmp_ci_trace_serve2.json > /dev/null
python -m repro.launch.trace --diff /tmp/mdmp_ci_trace_serve.json \
    /tmp/mdmp_ci_trace_serve2.json --threshold 4.0 || {
    echo "FAIL: identical serve configs diff past +400%"; exit 1; }
echo "trace gate OK"

echo "== benchmark smoke (python -m benchmarks.run) =="
out="$(MDMP_BENCH_REPS="${MDMP_BENCH_REPS:-2}" python -m benchmarks.run)"
echo "$out" | tail -40
# The CSV must contain the paper tables, the measured Jacobi k-sweep rows,
# and no measured-suite subprocess error.
echo "$out" | grep -q "^t1_db_triad_original," || {
    echo "FAIL: paper-table rows missing"; exit 1; }
echo "$out" | grep -q "jacobi_.*_aggregated_k" || {
    echo "FAIL: aggregated Jacobi k-sweep rows missing"; exit 1; }
echo "$out" | grep -q "halo_agg_tpu_v5e_chosen" || {
    echo "FAIL: halo aggregation model rows missing"; exit 1; }
# Ring-attention smoke: the bulk/ulysses/ring sweep must have run (short-S
# measured rows + modeled schedule table) and the decision trail must
# contain an attention entry with the winning schedule.
echo "$out" | grep -q "ring_attn_.*_ring," || {
    echo "FAIL: measured ring-attention sweep rows missing"; exit 1; }
echo "$out" | grep -q "attn_sched_tpu_v5e_causal_chosen" || {
    echo "FAIL: attention schedule model rows missing"; exit 1; }
echo "$out" | grep -q "ring_attn_decision_.*trail=attention_schedule" || {
    echo "FAIL: attention decision trail entry missing"; exit 1; }
# Pipeline smoke: the gpipe/1f1b/interleaved sweep must have run (loss and
# grads asserted allclose in-suite), the modeled schedule table must be
# present, and the decision trail must contain a pipeline_schedule entry
# with the tuner-measured winner.
echo "$out" | grep -q "pipeline_M.*_1f1b," || {
    echo "FAIL: measured pipeline schedule sweep rows missing"; exit 1; }
echo "$out" | grep -q "pipe_sched_tpu_v5e_chosen" || {
    echo "FAIL: pipeline schedule model rows missing"; exit 1; }
echo "$out" | grep -q "pipeline_decision_.*trail=pipeline_schedule" || {
    echo "FAIL: pipeline decision trail entry missing"; exit 1; }
# Serving smoke: the static-vs-continuous sweep must have run (measured
# rows with token-equality asserted in-suite), the modeled schedule table
# must be present, and the decision trail must contain a serve_schedule
# entry with the tuner-measured winner.
echo "$out" | grep -q "serve_cont_c.*tokens==static" || {
    echo "FAIL: measured continuous-batching sweep rows missing"; exit 1; }
echo "$out" | grep -q "serve_sched_tpu_v5e_chosen" || {
    echo "FAIL: serve schedule model rows missing"; exit 1; }
echo "$out" | grep -q "serve_decision_.*trail=serve_schedule" || {
    echo "FAIL: serve decision trail entry missing"; exit 1; }
# MoE smoke: the dispatch sweep must have run (schedules asserted
# allclose to the bulk oracle in-suite, capacity adaptation rows from the
# instrumented routing), the modeled schedule table must be present, and
# the decision trail must contain a moe_dispatch entry with the
# tuner-measured winner.
echo "$out" | grep -q "moe_dispatch_.*_capacity_adapt" || {
    echo "FAIL: instrumented capacity-adaptation rows missing"; exit 1; }
echo "$out" | grep -q "moe_dispatch_.*allclose=bulk" || {
    echo "FAIL: measured moe dispatch sweep rows missing"; exit 1; }
echo "$out" | grep -q "moe_dispatch_tpu_v5e_.*_chosen" || {
    echo "FAIL: moe dispatch model rows missing"; exit 1; }
echo "$out" | grep -q "moe_dispatch_decision_.*trail=moe_dispatch" || {
    echo "FAIL: moe dispatch decision trail entry missing"; exit 1; }
# Overload smoke: the bursty-trace comparison must have run (seed commit
# admission livelocks and is caught; managed watermark admission +
# preemption completes with outputs token-equal to the FIFO baseline and
# at least matches its SLO-goodput) and the decision trail must contain
# the chosen preemption policy.
echo "$out" | grep -q "overload_seed_commit,.*livelock caught" || {
    echo "FAIL: seed-admission livelock row missing"; exit 1; }
echo "$out" | grep -q "overload_fifo_goodput," || {
    echo "FAIL: no-preemption FIFO goodput row missing"; exit 1; }
echo "$out" | grep -q "overload_managed_goodput,.*tokens==fifo" || {
    echo "FAIL: managed overload goodput row missing"; exit 1; }
echo "$out" | grep -q "overload_decision_.*trail=preempt_policy" || {
    echo "FAIL: preemption decision trail entry missing"; exit 1; }
# Fault-tolerance smoke: the goodput comparison must have run (managed
# Young/Daly cadence vs the fixed-25 baseline under the same injected
# fault) and the decision trail must contain the chosen interval.
echo "$out" | grep -q "faults_goodput_fixed25," || {
    echo "FAIL: fixed-cadence goodput row missing"; exit 1; }
echo "$out" | grep -q "faults_goodput_managed,.*vs fixed25" || {
    echo "FAIL: managed-cadence goodput row missing"; exit 1; }
echo "$out" | grep -q "ckpt_decision_.*trail=ckpt_interval" || {
    echo "FAIL: checkpoint cadence decision trail entry missing"; exit 1; }
# Program-plan smoke: the contending two-region config must have run with
# both resolutions (program-plan outputs asserted allclose to the local
# oracle in-suite) and the coordinated trail row must be present.
echo "$out" | grep -q "plan_conflict_local," || {
    echo "FAIL: local-resolution conflict row missing"; exit 1; }
echo "$out" | grep -q "plan_conflict_program,.*allclose=local" || {
    echo "FAIL: program-plan conflict row missing"; exit 1; }
echo "$out" | grep -q "plan_conflict_decision,.*trail=program_plan(coordinated" || {
    echo "FAIL: program-plan decision trail entry missing"; exit 1; }
# Trace-overhead smoke: the mdmptrace tax must be measured (the <2%
# bound and bit-identical disabled path are asserted in the row text)
# and the machine-readable summary must have been written.
echo "$out" | grep -q "trace_overhead_enabled,.*bound 2%" || {
    echo "FAIL: trace overhead row missing"; exit 1; }
echo "$out" | grep -q "trace_disabled_identical,.*bit-identical=True" || {
    echo "FAIL: disabled tracer is not bit-identical"; exit 1; }
echo "$out" | grep -q "bench_summary,0.00,.*BENCH_summary.json" || {
    echo "FAIL: BENCH_summary.json row missing"; exit 1; }
echo "$out" | grep -q "measured_suite,0.00,ERROR" && {
    echo "FAIL: measured suite subprocess errored"; exit 1; }
echo "CI OK"
