"""End-to-end driver: train a ~110M-parameter decoder with the production
stack (managed collectives, FSDP layout, fault-tolerant loop, checkpoints).

    PYTHONPATH=src python examples/train_100m.py --steps 300

On a TPU slice this config does a few hundred steps in minutes; on this
CPU container use a small --steps (the final bench run uses ~12 and the
convergence curve is demonstrated by examples/quickstart.py at small
scale and by tests/test_system.py::test_loss_decreases).
"""

import argparse
import dataclasses

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import MeshCtx
from repro.train.train_loop import TrainLoop, TrainLoopConfig, \
    build_train_step

CONFIG_100M = ModelConfig(
    name="repro-110m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    mlp="swiglu",
    tie_embeddings=True,
    tp_multiple=1,
    remat=True,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/train100m_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--pipeline", default="none",
                    choices=["none", "gpipe", "1f1b", "interleaved",
                             "auto"],
                    help="run the pod axis as pipeline stages; 'auto' "
                         "lets the managed runtime pick the schedule "
                         "(cost model + decision trail)")
    args = ap.parse_args()

    cfg = CONFIG_100M
    print(f"model: {cfg.param_count()/1e6:.0f}M params")
    if args.pipeline != "none":
        mesh = jax.make_mesh((jax.device_count(), 1, 1),
                             ("pod", "data", "model"))
    else:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="auto")
    model = Model(cfg, ctx)
    opt_cfg = AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    from repro.core import managed
    managed.clear_decision_log()
    step_fn, pshard, bshard = build_train_step(
        model, opt_cfg, mesh, pipeline=args.pipeline,
        global_batch=args.batch, seq_len=args.seq)
    for rec in managed.decision_log():
        if rec.op == "pipeline_schedule":
            print(f"pipeline schedule: {rec.mode} M={rec.chunks} "
                  f"(bulk {rec.predicted_bulk_s*1e3:.2f}ms -> "
                  f"{rec.predicted_interleaved_s*1e3:.2f}ms)")
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch))
    loop = TrainLoop(step_fn, model, opt_cfg, data,
                     TrainLoopConfig(total_steps=args.steps, ckpt_every=50,
                                     ckpt_dir=args.ckpt, log_every=10),
                     pshard, bshard)
    params, opt, s0 = (loop.resume_or_init() if args.resume
                       else loop.init_state())
    out = loop.run(params, opt, s0)
    hist = out["history"]
    for h in hist[:: max(1, len(hist) // 12)]:
        print(f"  step {h['step']:4d} loss {h['loss']:.4f} "
              f"{h['time_s']:.2f}s")
    print(f"final loss {hist[-1]['loss']:.4f} at step {out['step']}")


if __name__ == "__main__":
    main()
