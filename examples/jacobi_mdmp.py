import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

"""The paper's running example: a 2-D Jacobi sweep with MDMP-managed halo
exchange, distributed over 8 (forced host) devices.

    PYTHONPATH=src python examples/jacobi_mdmp.py

Shows the full MDMP workflow from the paper's Figure 4:
  1. declare the communication (CommRegion directives),
  2. let the region instrument the computation (trace-time read/write
     analysis) and plan each message (alpha-beta model),
  3. run with the planned schedule — bulk (paper Fig 2) vs intermingled
     (paper Fig 3) — and check they agree.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import CommRegion, halo
from repro.core import cost_model as cm
from repro.kernels.stencil import jacobi_step_pallas
from repro.parallel.sharding import smap


def main() -> None:
    mesh = jax.make_mesh((8,), ("x",))
    m, n = 1024, 514                       # global grid, rows sharded
    rng = np.random.default_rng(0)
    u0 = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    f = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))

    # 1-2. declare + plan (the paper's #pragma commregion block)
    region = CommRegion("jacobi", axis_sizes={"x": 8})
    region.send("halo_up", axis="x", shape=(n,), dtype=np.float32)
    region.send("halo_down", axis="x", shape=(n,), dtype=np.float32)
    local = (m // 8, n)

    def shard_compute(u, ff):            # the per-shard stencil the halos
        return 0.25 * (u[:-2, 1:-1] + u[2:, 1:-1]      # must overlap with
                       + u[1:-1, :-2] + u[1:-1, 2:] - ff[1:-1, 1:-1])

    plan = region.plan(
        shard_compute,
        jax.ShapeDtypeStruct(local, jnp.float32),
        jax.ShapeDtypeStruct(local, jnp.float32),
        compute_time_s=5.0 * local[0] * local[1] / cm.TPU_V5E.peak_flops)
    print(plan.summary())

    # 3. run both schedules
    outs = {}
    for mode in ("bulk", "interleaved"):
        fn = jax.jit(smap(
            lambda u, ff, mode=mode: halo.jacobi_solve(u, ff, "x", 50, mode),
            mesh, in_specs=(P("x"), P("x")), out_specs=P("x")))
        out = fn(u0, f)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = fn(u0, f)
        jax.block_until_ready(out)
        outs[mode] = np.asarray(out)
        print(f"{mode:12s} 50 sweeps in {time.perf_counter() - t0:.3f}s")
    np.testing.assert_allclose(outs["bulk"], outs["interleaved"], rtol=1e-5)
    print("bulk (Fig 2) == intermingled (Fig 3): max diff",
          np.abs(outs["bulk"] - outs["interleaved"]).max())

    # bonus: the Pallas stencil kernel on a single shard (interpret mode)
    u_loc = u0[:m // 8 + 2]         # +2 boundary rows for the kernel
    out = jacobi_step_pallas(u_loc, f[:m // 8 + 2], blk_m=64,
                             blk_n=256,
                             interpret=True)
    print("pallas stencil kernel ok:", out.shape)


if __name__ == "__main__":
    main()
