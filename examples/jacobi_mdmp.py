import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

"""The paper's running example: a 2-D Jacobi sweep with MDMP-managed halo
exchange, distributed over 8 (forced host) devices.

    PYTHONPATH=src python examples/jacobi_mdmp.py

Shows the full MDMP workflow from the paper's Figure 4:
  1. declare the communication (CommRegion directives),
  2. let the region instrument the computation (trace-time read/write
     analysis) and plan each message (alpha-beta model) — including the
     AGGREGATION knob: how many sweeps one k-row halo slab should carry,
  3. run all three schedules — bulk (paper Fig 2), intermingled (paper
     Fig 3), and aggregated (k sweeps per exchange, the temporally-blocked
     deep-halo pipeline) — and check they agree.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import CommRegion, halo, managed
from repro.core import cost_model as cm
from repro.kernels.stencil import jacobi_multistep_pallas, jacobi_step_pallas
from repro.parallel.sharding import smap


def main() -> None:
    mesh = jax.make_mesh((8,), ("x",))
    m, n = 1024, 514                       # global grid, rows sharded
    iters = 48
    rng = np.random.default_rng(0)
    u0 = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    f = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))

    # 1-2. declare + plan (the paper's #pragma commregion block)
    region = CommRegion("jacobi", axis_sizes={"x": 8})
    region.send("halo_up", axis="x", shape=(n,), dtype=np.float32)
    region.send("halo_down", axis="x", shape=(n,), dtype=np.float32)
    region.halo("halo_agg", axis="x", rows_local=m // 8, cols=n,
                dtype=np.float32)
    local = (m // 8, n)

    def shard_compute(u, ff):            # the per-shard stencil the halos
        return 0.25 * (u[:-2, 1:-1] + u[2:, 1:-1]      # must overlap with
                       + u[1:-1, :-2] + u[1:-1, 2:] - ff[1:-1, 1:-1])

    plan = region.plan(
        shard_compute,
        jax.ShapeDtypeStruct(local, jnp.float32),
        jax.ShapeDtypeStruct(local, jnp.float32),
        compute_time_s=5.0 * local[0] * local[1] / cm.TPU_V5E.peak_flops)
    print(plan.summary())
    k = plan.k_for("halo_agg")
    print(f"cost model chose k={k}: one {k}-row halo slab per {k} sweeps "
          f"(messages / sweep drop 2 -> {2.0 / k:.3f})")
    print("decision trail:", managed.decision_log()[-1])

    # 3. run all three schedules (the aggregated one with the planned k)
    outs, times = {}, {}
    for mode, kw in (("bulk", {}), ("interleaved", {}),
                     (f"aggregated_k{k}", {"k": k})):
        run_mode = "aggregated" if mode.startswith("aggregated") else mode
        fn = jax.jit(smap(
            lambda u, ff, run_mode=run_mode, kw=kw: halo.jacobi_solve(
                u, ff, "x", iters, run_mode, **kw),
            mesh, in_specs=(P("x"), P("x")), out_specs=P("x")))
        out = fn(u0, f)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = fn(u0, f)
        jax.block_until_ready(out)
        times[mode] = time.perf_counter() - t0
        outs[mode] = np.asarray(out)
        print(f"{mode:16s} {iters} sweeps in {times[mode]:.3f}s")
    for mode, out in outs.items():
        np.testing.assert_allclose(outs["bulk"], out, rtol=1e-5, atol=1e-5)
    print("bulk (Fig 2) == intermingled (Fig 3) == aggregated: max diff",
          max(np.abs(outs["bulk"] - o).max() for o in outs.values()))

    # bonus: the Pallas stencil kernels on a single shard (interpret mode)
    u_loc = u0[:m // 8 + 2]         # +2 boundary rows for the kernel
    out = jacobi_step_pallas(u_loc, f[:m // 8 + 2], blk_m=64,
                             blk_n=256,
                             interpret=True)
    print("pallas stencil kernel ok:", out.shape)
    out_k = jacobi_multistep_pallas(u_loc, f[:m // 8 + 2], k=k, blk_m=64,
                                    interpret=True)
    print(f"pallas {k}-sweep temporally-blocked kernel ok:", out_k.shape)


if __name__ == "__main__":
    main()
