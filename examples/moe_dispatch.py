import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

"""Managed expert dispatch end to end — the PR 5 subsystem on 8 (forced
host) devices.

    PYTHONPATH=src python examples/moe_dispatch.py

Shows the full MDMP workflow applied to the most data-dependent
communication in the codebase, MoE token routing:
  1. declare the dispatch (CommRegion.moe) and let the region plan it
     from the alpha-beta model;
  2. run all three schedules — bulk a2a (the unmanaged baseline),
     chunked-stream (capacity chunks ppermute'd around the EP ring under
     the expert FFN), dense fallback (no dispatch at all) — and check
     they agree;
  3. instrument the routing (the paper's runtime read/write counters:
     token->expert histogram, drop rate, occupancy) and let the managed
     runtime re-pick the capacity factor from the measured imbalance —
     the iteration-(k)->(k+1) adaptation.
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import instrument, managed
from repro.core.region import CommRegion
from repro.models import moe
from repro.parallel.sharding import MeshCtx, smap


def main() -> None:
    tp, E, K, D, F = 8, 8, 2, 64, 128
    b, S = 2, 256
    mesh = jax.make_mesh((1, tp), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    base = ModelConfig(name="moe-demo", family="moe", n_layers=1,
                       d_model=D, n_heads=2, n_kv_heads=2, d_ff=0,
                       vocab_size=64, tp_multiple=1, dtype="float32",
                       moe=MoEConfig(n_experts=E, top_k=K, d_ff_expert=F,
                                     capacity_factor=2.0, impl="ep_a2a"))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, S, D)).astype(np.float32))
    params = {
        "w_router": jnp.asarray(rng.normal(size=(D, E))
                                .astype(np.float32) * 0.5),
        "w1": jnp.asarray(rng.normal(size=(E, D, F)).astype(np.float32)
                          * 0.1),
        "w1_gate": jnp.asarray(rng.normal(size=(E, D, F))
                               .astype(np.float32) * 0.1),
        "w2": jnp.asarray(rng.normal(size=(E, F, D)).astype(np.float32)
                          * 0.1),
    }
    pspec = {"w_router": P(None, None), "w1": P("model", None, None),
             "w1_gate": P("model", None, None),
             "w2": P("model", None, None)}
    t_loc = b * S // tp

    # 1. declare + plan (the paper's Figure-4 workflow)
    region = CommRegion("moe", axis_sizes={"model": tp})
    region.moe("dispatch", axis="model", tokens_local=t_loc, d_model=D,
               n_experts=E, top_k=K, d_ff_expert=F, dtype=jnp.float32,
               capacity_factor=base.moe.capacity_factor)
    plan = region.plan(lambda a: a * 2, np.zeros(4, np.float32))
    print(plan.summary())

    # 2. the three schedules agree
    outs, times = {}, {}
    for disp, g in (("bulk", 1), ("stream", 2), ("dense", 1)):
        cfg = dataclasses.replace(base, moe=dataclasses.replace(
            base.moe, dispatch=disp, dispatch_g=g))
        fn = jax.jit(smap(
            lambda xx, pp, cfg=cfg: moe.moe_block_ep(xx, pp, cfg, ctx)[0],
            mesh, in_specs=(P(None, "model", None), pspec),
            out_specs=P(None, "model", None)))
        out = fn(x, params)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, params))
        outs[disp], times[disp] = np.asarray(out), time.perf_counter() - t0
        print(f"  {disp:8s} {times[disp]*1e3:7.2f}ms")
    for disp in ("stream", "dense"):
        np.testing.assert_allclose(outs[disp], outs["bulk"], rtol=2e-4,
                                   atol=2e-5)
    print("  all three dispatch schedules allclose")

    # 3. instrument the routing, adapt the capacity factor
    logits = np.asarray(x.reshape(-1, D) @ np.asarray(params["w_router"]))
    top_idx = np.argsort(-logits, axis=1)[:, :K]
    from repro.core import cost_model as cm
    rec = instrument.capture_routing(
        "demo", top_idx, E,
        cm.moe_capacity(b * S, K, E, base.moe.capacity_factor))
    managed.clear_decision_log()
    d = managed.resolve_moe_dispatch(
        "model", tp, t_loc, D, E, K, F, dtype_bytes=4,
        capacity_factor=base.moe.capacity_factor,
        measured_imbalance=rec.imbalance, measured_drop_rate=rec.drop_rate)
    trail = managed.decision_log()[-1]
    print(f"routing instrumented: imbalance={rec.imbalance:.2f} "
          f"drop={rec.drop_rate:.2f} occupancy={rec.occupancy:.2f}")
    print(f"re-resolved: cf {base.moe.capacity_factor:g} -> "
          f"{d.capacity_factor:g}, schedule={d.schedule} g={d.g} "
          f"(trail: {trail.op}({trail.mode} g={trail.chunks}))")


if __name__ == "__main__":
    main()
