"""Batched serving example — the managed serving runtime end to end.

Submits a queue of mixed-length requests to the ServeEngine (paged KV
cache + continuous batching; repro/serve) instead of hand-rolling a
prefill/decode loop, prints each request's greedy completion, and shows
the MDMP serve-schedule decision the managed runtime made for the queue.

    PYTHONPATH=src python examples/serve_batched.py [arch]
"""

import sys

import jax
import numpy as np

from repro import configs
from repro.core import managed
from repro.models.model import Model
from repro.parallel.sharding import MeshCtx, infer_shardings
from repro.serve.engine import ServeEngine


def main() -> None:
    arch = sys.argv[1] if len(sys.argv) > 1 else "mamba2-130m"
    cfg = configs.get_reduced(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="auto")
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))

    engine = ServeEngine(model, mesh, params, slots=2, max_seq=64,
                         page_size=8, schedule="auto")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size - 1, size=p).astype(np.int32)
               for p in (8, 3, 12, 5)]
    rids = [engine.submit(p, 16) for p in prompts]
    out = engine.run()

    for i, rid in enumerate(rids):
        print(f"request {rid}: prompt={prompts[i].tolist()} "
              f"-> {out[rid].tolist()}")
    s = engine.metrics.summary()
    print(f"{s['useful_tok_s']:.1f} useful tok/s over {s['quanta']} quanta, "
          f"occupancy {s['occupancy']:.2f}")
    for rec in managed.decision_log():
        if rec.op == "serve_schedule":
            print(f"managed decision: serve_schedule({rec.mode}, "
                  f"C={rec.chunks})")


if __name__ == "__main__":
    main()
