"""Batched serving example: greedy decoding with the TP-2D decode flow
(sequence-sharded KV cache + distributed LSE merge).

    PYTHONPATH=src python examples/serve_batched.py [arch]
"""

import sys

import jax
import numpy as np

from repro import configs
from repro.configs.base import ShapeConfig
from repro.models.model import Model
from repro.parallel.sharding import MeshCtx, infer_shardings
from repro.train.serve_loop import Generator


def main() -> None:
    arch = sys.argv[1] if len(sys.argv) > 1 else "mamba2-130m"
    cfg = configs.get_reduced(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="auto")
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))

    shape = ShapeConfig("serve", seq_len=64, global_batch=4, kind="decode")
    gen = Generator(model, mesh, shape, params)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size - 1, size=(4, 8)).astype(
        np.int32)
    out = gen.generate(prompts, n_new=16)
    for i, row in enumerate(out):
        print(f"request {i}: prompt={prompts[i].tolist()} "
              f"-> {row.tolist()}")


if __name__ == "__main__":
    main()
