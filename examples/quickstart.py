"""Quickstart: train a tiny decoder with the full MDMP stack on CPU.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates: config -> Model -> shard_map train step (every collective a
managed MDMP op) -> fault-tolerant TrainLoop with checkpoints -> greedy
decode from the trained weights.
"""

import sys

import jax
import numpy as np

from repro import configs
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import MeshCtx
from repro.train.serve_loop import Generator
from repro.train.train_loop import TrainLoop, TrainLoopConfig, \
    build_train_step


def main() -> None:
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 30
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="auto")
    cfg = configs.get_reduced("granite-34b")
    model = Model(cfg, ctx)

    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=steps)
    step_fn, pshard, bshard = build_train_step(model, opt_cfg, mesh)
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=128, global_batch=8))
    loop = TrainLoop(step_fn, model, opt_cfg, data,
                     TrainLoopConfig(total_steps=steps, ckpt_every=10,
                                     ckpt_dir="/tmp/quickstart_ckpt"),
                     pshard, bshard)
    params, opt, s0 = loop.resume_or_init()
    out = loop.run(params, opt, s0)
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} over {steps} steps "
          f"({out['restarts']} restarts, {len(out['stragglers'])} "
          f"stragglers)")

    gen = Generator(model, mesh,
                    ShapeConfig("qs", seq_len=64, global_batch=2,
                                kind="decode"), out["params"])
    prompt = np.array([[5, 6, 7, 8]] * 2, np.int32)
    print("greedy continuation:", gen.generate(prompt, n_new=8)[0].tolist())


if __name__ == "__main__":
    main()
