"""Rendering for mdmplint diagnostics — one format for the CLI, the
launcher preflight, and the CI greps.

The non-verbose line format is stable on purpose::

    MDMP101 error   undeclared-collective: <message> [<file>:<line>]

CI asserts on the ``MDMPxxx`` prefix; humans read the rest.  Verbose
mode adds the declared-op / traced-op side-by-side and the fix hint
under each line (``--verify strict`` failures print this form so the
fix is one click away).
"""

from __future__ import annotations

from typing import Sequence

from repro.analysis.diagnostics import Diagnostic


def render(diags: Sequence[Diagnostic], verbose: bool = False) -> str:
    """Render the diagnostics block (empty string when clean)."""
    return "\n".join(d.render(verbose=verbose) for d in diags)


def summary(diags: Sequence[Diagnostic], name: str = "program") -> str:
    """The one-line verdict the launchers print."""
    errors = sum(1 for d in diags if d.severity == "error")
    warnings = len(diags) - errors
    if not diags:
        return f"mdmplint: {name} clean (0 diagnostics)"
    return (f"mdmplint: {name} {errors} error(s), "
            f"{warnings} warning(s)")


def exit_code(diags: Sequence[Diagnostic]) -> int:
    """Process exit status: 1 iff any error-severity diagnostic."""
    return 1 if any(d.severity == "error" for d in diags) else 0
