"""The mdmplint pass pipeline — five families over one CommGraph.

Each pass is a pure function ``CommGraph -> list[Diagnostic]``; the
pipeline (``run_all``) concatenates them errors-first.  The passes only
read the graph — building it (graph.py) is where the three truth
sources were reconciled into one shape, so every pass runs identically
on a launcher preflight and on a corpus JSON case.
"""

from __future__ import annotations

from typing import Sequence

from repro.analysis.diagnostics import Diagnostic, make
from repro.analysis.graph import CommGraph

#: declared kinds that name a collective family directly — only these
#: must match the traced primitive family (MDMP104); subsystem kinds
#: (halo/attention/pipeline/moe/serve/preempt/ckpt) lower to whatever
#: mix of primitives their chosen schedule emits.
_DIRECT_KINDS = frozenset({"send", "recv", "all_gather", "all_reduce",
                           "reduce_scatter", "all_to_all", "collective"})

#: traced-vs-declared bytes tolerance — matches ir.crosscheck_collectives
#: (schedules legitimately move up to ~4x the declared payload: ring
#: round-trips, grad + activation traffic on one axis).
_DRIFT_TOL = 4.0


def _op_ref(op) -> str:
    src = op.meta.get("source") or op.meta.get("site") or ""
    trips = op.meta.get("trips", 1)
    t = f" x{trips}" if trips and trips != 1 else ""
    at = f" @ {src}" if src else ""
    return (f"{op.op_name} axis={op.axis} {op.nbytes}B{t} "
            f"kind={op.kind} label={op.label}{at}")


def _traced_bytes(op) -> int:
    return int(op.nbytes) * max(1, int(op.meta.get("trips", 1)))


# -- pass 0: declaration validity -----------------------------------------

def check_axes(g: CommGraph) -> list[Diagnostic]:
    """MDMP001 — every axis referenced must be a mesh axis the graph
    knows; an unknown axis prices as size-1 and is never scheduled."""
    out = []
    known = sorted(g.axis_sizes)
    for op in list(g.declared) + list(g.traced):
        if op.axis not in g.axis_sizes:
            out.append(make(
                "MDMP001",
                f"{op.label!r} names axis {op.axis!r}, not one of "
                f"{known}",
                label=op.label, axis=op.axis,
                site=op.meta.get("site") or op.meta.get("source"),
                spec_ref=_op_ref(op),
                hint=f"declare on one of {known} or add the axis to the "
                     f"mesh"))
    for p in g.permutes:
        if p.axis not in g.axis_sizes:
            out.append(make(
                "MDMP001",
                f"permute {p.label!r} names axis {p.axis!r}, not one of "
                f"{known}",
                label=p.label, axis=p.axis, site=p.site,
                hint=f"permute over one of {known}"))
    return out


# -- pass 1: declared-vs-traced drift -------------------------------------

def check_drift(g: CommGraph) -> list[Diagnostic]:
    """MDMP101/102/103/104 — the declarations are a specification the
    traced program can silently violate; reconcile them per axis."""
    out = []
    if not g.traced:
        return out                    # nothing traced — nothing to drift
    decl_by_axis: dict[str, int] = {}
    for op in g.declared:
        decl_by_axis[op.axis] = decl_by_axis.get(op.axis, 0) + op.nbytes
    traced_by_axis: dict[str, int] = {}
    for op in g.traced:
        traced_by_axis[op.axis] = (traced_by_axis.get(op.axis, 0)
                                   + _traced_bytes(op))
    for axis in sorted(traced_by_axis):
        tb, db = traced_by_axis[axis], decl_by_axis.get(axis, 0)
        ops = [op for op in g.traced if op.axis == axis]
        if db == 0:
            out.append(make(
                "MDMP101",
                f"{tb}B traced on axis {axis!r} but nothing declared",
                axis=axis, label=ops[0].label,
                site=ops[0].meta.get("source"),
                op_ref="; ".join(_op_ref(o) for o in ops[:3]),
                hint="declare the collective on the owning CommRegion "
                     "(region.collective/attention/moe/... on this axis)"))
        elif tb > _DRIFT_TOL * db:
            out.append(make(
                "MDMP102",
                f"axis {axis!r} moves {tb}B traced vs {db}B declared "
                f"(> {_DRIFT_TOL:.0f}x tolerance)",
                axis=axis, label=ops[0].label,
                site=ops[0].meta.get("source"),
                spec_ref="; ".join(_op_ref(o) for o in g.declared
                                   if o.axis == axis)[:200],
                op_ref="; ".join(_op_ref(o) for o in ops[:3]),
                hint="update the declaration's shape/dtype to what the "
                     "program actually sends"))
    for axis in sorted(decl_by_axis):
        if decl_by_axis[axis] > 0 and axis not in traced_by_axis:
            specs = [op for op in g.declared if op.axis == axis]
            out.append(make(
                "MDMP103",
                f"{decl_by_axis[axis]}B declared on axis {axis!r}, "
                f"none traced (stale declaration)",
                axis=axis, label=specs[0].label,
                site=specs[0].meta.get("site"),
                spec_ref="; ".join(_op_ref(o) for o in specs[:3]),
                hint="drop the declaration or trace the region that "
                     "exercises it"))
    # family mismatch: a DIRECT collective declaration on an axis whose
    # trace carries traffic, but none of the declared family
    for op in g.declared:
        if op.kind not in _DIRECT_KINDS or op.axis not in traced_by_axis:
            continue
        fams = {t.op_name for t in g.traced if t.axis == op.axis}
        if op.op_name not in fams:
            out.append(make(
                "MDMP104",
                f"{op.label!r} declares {op.op_name} on axis "
                f"{op.axis!r} but the trace only carries "
                f"{sorted(fams)}",
                axis=op.axis, label=op.label,
                site=op.meta.get("site"), spec_ref=_op_ref(op),
                op_ref="; ".join(_op_ref(t) for t in g.traced
                                 if t.axis == op.axis)[:200],
                hint="declare the family the program emits (kind/"
                     "collective argument)"))
    return out


# -- pass 2: permute validity ---------------------------------------------

def check_permutes(g: CommGraph) -> list[Diagnostic]:
    """MDMP201/202 — every constructed permutation must be a bijection
    on its support; ring permutes must return home after axis_size
    applications; paired stream shifts must compose to the identity."""
    out = []
    for p in g.permutes:
        n = int(p.axis_size)
        srcs = [a for a, _ in p.perm]
        dsts = [b for _, b in p.perm]
        bad = (len(set(srcs)) != len(srcs)
               or len(set(dsts)) != len(dsts)
               or any(not (0 <= v < n) for v in srcs + dsts))
        if not bad and p.ring and len(p.perm) != n:
            bad = True                # a ring must cover the whole axis
        if bad:
            out.append(make(
                "MDMP201",
                f"permute {p.label!r} on axis {p.axis!r} (n={n}) is not "
                f"a bijection: perm={list(p.perm)}",
                label=p.label, axis=p.axis, site=p.site,
                op_ref=f"perm={list(p.perm)}",
                hint="each rank must appear exactly once as source and "
                     "once as destination (in range 0..n-1)"))
            continue
        if p.ring:
            # a ring must be ONE n-cycle: starting anywhere, the data
            # visits every rank and is first home after exactly n hops —
            # shorter sub-cycles (e.g. pair swaps) satisfy f^n == id but
            # never deliver to the ranks outside their orbit
            f = {a: b for a, b in p.perm}
            if _orbit_len(f, 0, n) != n:
                out.append(make(
                    "MDMP202",
                    f"ring permute {p.label!r} on axis {p.axis!r} does "
                    f"not complete a full cycle: orbit of rank 0 has "
                    f"length {_orbit_len(f, 0, n)}, not {n}",
                    label=p.label, axis=p.axis, site=p.site,
                    op_ref=f"perm={list(p.perm)}",
                    hint="a composed ring must be a single n-cycle "
                         "(use one uniform shift coprime to n)"))
        if p.pair is not None:
            fwd, ret = p.pair
            if (fwd + ret) % n != 0:
                out.append(make(
                    "MDMP202",
                    f"stream permute {p.label!r}: forward shift {fwd} "
                    f"and return shift {ret} do not compose to the "
                    f"identity on axis {p.axis!r} (n={n})",
                    label=p.label, axis=p.axis, site=p.site,
                    op_ref=f"fwd_shift={fwd} ret_shift={ret}",
                    hint="the return permute must invert the forward "
                         "one: ret_shift == -fwd_shift (mod n)"))
    return out


def _orbit_len(f: dict, start: int, n: int) -> int:
    i, steps = f[start], 1
    while i != start and steps <= n:
        i, steps = f[i], steps + 1
    return steps


# -- pass 3: ordering / deadlock ------------------------------------------

def check_ordering(g: CommGraph) -> list[Diagnostic]:
    """MDMP301 — happens-before graph: explicit wait edges plus the
    wire-serialization order inside each contention set (same axis,
    overlapping readiness windows, earlier window transmits first).  A
    cycle is a deadlock: two regions each waiting on the other's
    serialized wire."""
    edges: dict[str, set[str]] = {}
    why: dict[tuple[str, str], str] = {}

    def add(a: str, b: str, reason: str) -> None:
        if a == b:
            return
        edges.setdefault(a, set()).add(b)
        why.setdefault((a, b), reason)

    for w in g.waits:
        add(w.src, w.dst, w.reason or "declared wait")
    ops = list(g.declared)
    for i, a in enumerate(ops):
        for b in ops[i + 1:]:
            if not a.overlaps(b):
                continue
            if a.window[0] < b.window[0]:
                add(a.label, b.label,
                    f"serialized wire on axis {a.axis!r}")
            elif b.window[0] < a.window[0]:
                add(b.label, a.label,
                    f"serialized wire on axis {a.axis!r}")
    cycle = _find_cycle(edges)
    if cycle is None:
        return []
    path = " -> ".join(cycle)
    reasons = "; ".join(
        f"{a}->{b}: {why.get((a, b), '?')}"
        for a, b in zip(cycle, cycle[1:]))
    return [make(
        "MDMP301",
        f"wait-for cycle {path}",
        label=cycle[0], op_ref=reasons,
        hint="break the cycle: reorder the windows so the serialized "
             "wire and the declared waits agree on one direction")]


def _find_cycle(edges: dict[str, set]) -> list | None:
    WHITE, GREY, BLACK = 0, 1, 2
    color: dict[str, int] = {}
    stack: list[str] = []

    def dfs(u: str):
        color[u] = GREY
        stack.append(u)
        for v in sorted(edges.get(u, ())):
            c = color.get(v, WHITE)
            if c == GREY:
                i = stack.index(v)
                return stack[i:] + [v]
            if c == WHITE:
                got = dfs(v)
                if got:
                    return got
        stack.pop()
        color[u] = BLACK
        return None

    for node in sorted(edges):
        if color.get(node, WHITE) == WHITE:
            got = dfs(node)
            if got:
                return got
    return None


# -- pass 4: overlap races -------------------------------------------------

def check_overlap(g: CommGraph) -> list[Diagnostic]:
    """MDMP401/402 — a buffer marked in flight over (t0, t1) must not be
    touched by compute inside that window (the stale-ghost-read class),
    and two in-flight claims on one buffer must not overlap (donation /
    aliasing hazards)."""
    out = []
    for f in g.inflight:
        for a in g.accesses:
            if a.buffer != f.buffer:
                continue
            if f.t0 < a.time < f.t1:
                code = "MDMP401" if a.access == "read" else "MDMP402"
                what = ("reads stale" if a.access == "read"
                        else "writes into")
                out.append(make(
                    code,
                    f"{a.label or 'compute'} {what} buffer "
                    f"{f.buffer!r} at t={a.time:.2f} while "
                    f"{f.label or 'a transfer'} holds it in flight "
                    f"over ({f.t0:.2f}, {f.t1:.2f})",
                    label=a.label or f.label,
                    op_ref=f"in-flight ({f.t0:.2f}, {f.t1:.2f}) by "
                           f"{f.label or '?'}",
                    hint="move the access outside the readiness window "
                         "or double-buffer the operand"))
    flights = sorted(g.inflight, key=lambda f: (f.buffer, f.t0))
    for i, f in enumerate(flights):
        for h in flights[i + 1:]:
            if h.buffer != f.buffer:
                break
            if h.t0 < f.t1 and f.t0 < h.t1:
                out.append(make(
                    "MDMP402",
                    f"buffer {f.buffer!r} claimed in flight twice: "
                    f"{f.label or '?'} ({f.t0:.2f}, {f.t1:.2f}) and "
                    f"{h.label or '?'} ({h.t0:.2f}, {h.t1:.2f})",
                    label=f.label or h.label,
                    op_ref=f"{f.label}: ({f.t0:.2f},{f.t1:.2f}); "
                           f"{h.label}: ({h.t0:.2f},{h.t1:.2f})",
                    hint="donated/aliased operands need disjoint "
                         "in-flight windows — stage through a copy"))
    return out


# -- pass 5: plan feasibility ----------------------------------------------

def check_feasibility(g: CommGraph) -> list[Diagnostic]:
    """MDMP501/502/503/504 — forced knobs the executor would silently
    degrade (clamped stream chunks, indivisible microbatches, stash over
    capacity, halo k past the block) become hard lint errors."""
    from repro.core import cost_model
    out = []
    for op in g.declared:
        knob = g.knob(op)
        if knob is None:
            continue
        m = op.meta
        if op.kind == "moe" and knob.get("mode") == "stream":
            gch = int(knob.get("chunks", 1))
            cap = cost_model.moe_capacity(
                int(m.get("tokens_local", 0)), int(m.get("top_k", 1)),
                int(m.get("n_experts", 1)),
                float(m.get("capacity_factor", 1.25)))
            if gch < 1 or cap % gch != 0:
                out.append(make(
                    "MDMP501",
                    f"{op.label!r}: stream chunks g={gch} does not "
                    f"divide the per-expert capacity C={cap} — the "
                    f"executor would silently clamp to g=1 (bulk)",
                    label=op.label, axis=op.axis,
                    site=m.get("site"), spec_ref=_op_ref(op),
                    op_ref=f"knob={knob}",
                    hint=f"pick g from the divisors of {cap} (or adjust "
                         f"capacity_factor so C is divisible)"))
        elif op.kind == "pipeline":
            mm = int(knob.get("chunks", 1))
            sched = knob.get("mode", "gpipe")
            v = int(knob.get("virtual", 1))
            s = int(g.axis_sizes.get(op.axis, op.axis_size))
            lb = int(m.get("local_batch", 0))
            if lb and mm >= 1 and lb % mm != 0:
                out.append(make(
                    "MDMP502",
                    f"{op.label!r}: microbatches M={mm} does not "
                    f"divide the local batch {lb}",
                    label=op.label, axis=op.axis, site=m.get("site"),
                    spec_ref=_op_ref(op), op_ref=f"knob={knob}",
                    hint=f"pick M from the divisors of {lb}"))
            if sched == "interleaved" and (v < 2 or mm % max(1, s)):
                out.append(make(
                    "MDMP502",
                    f"{op.label!r}: interleaved needs virtual >= 2 and "
                    f"M % S == 0 (got M={mm}, S={s}, v={v}) — "
                    f"build_schedule would raise at launch",
                    label=op.label, axis=op.axis, site=m.get("site"),
                    spec_ref=_op_ref(op), op_ref=f"knob={knob}",
                    hint="choose M a multiple of the stage count"))
            n_layers = int(m.get("n_layers", 0))
            if sched == "interleaved" and n_layers and v * s > n_layers:
                out.append(make(
                    "MDMP502",
                    f"{op.label!r}: v*S = {v * s} virtual stages exceed "
                    f"{n_layers} layers",
                    label=op.label, axis=op.axis, site=m.get("site"),
                    spec_ref=_op_ref(op), op_ref=f"knob={knob}",
                    hint="lower the virtual factor"))
            bb = int(m.get("batch_bytes", 0))
            cap = g.stash_cap_bytes or int(getattr(g.hw, "hbm_bytes", 0)
                                           or 0)
            if bb and mm >= 1 and cap:
                slots = cost_model.pipeline_stash_slots(
                    sched, mm, max(1, s), v)
                stash = slots * (bb // max(1, mm))
                if stash > cap:
                    out.append(make(
                        "MDMP503",
                        f"{op.label!r}: {sched} stash {slots} slots x "
                        f"{bb // max(1, mm)}B = {stash}B exceeds the "
                        f"{cap}B cap — the runtime would spill or OOM",
                        label=op.label, axis=op.axis, site=m.get("site"),
                        spec_ref=_op_ref(op),
                        op_ref=f"knob={knob} stash={stash}B cap={cap}B",
                        hint="raise M (smaller microbatches), switch to "
                             "1f1b (stash capped at 2S), or shrink the "
                             "boundary activation"))
        elif op.kind == "halo" and knob.get("mode") == "aggregated":
            k = int(knob.get("chunks", 1))
            rows = int(m.get("rows_local", 0))
            if rows and k > rows:
                out.append(make(
                    "MDMP504",
                    f"{op.label!r}: aggregation k={k} exceeds the "
                    f"{rows}-row local block",
                    label=op.label, axis=op.axis, site=m.get("site"),
                    spec_ref=_op_ref(op), op_ref=f"knob={knob}",
                    hint=f"clamp k to <= {rows}"))
    return out


PASSES = (check_axes, check_drift, check_permutes, check_ordering,
          check_overlap, check_feasibility)


def run_all(g: CommGraph,
            passes: Sequence = PASSES) -> list[Diagnostic]:
    """Run the pipeline; errors first, then warnings, stable within."""
    diags: list[Diagnostic] = []
    for p in passes:
        diags.extend(p(g))
    return sorted(diags, key=lambda d: (d.severity != "error", d.code))
