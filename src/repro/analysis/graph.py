"""The checkable comm-graph — mdmplint's one program representation.

``CommGraph`` lifts the repo's three truth sources into a single object
the pass pipeline (passes.py) runs over:

  1. *declared* — CommRegion declarations lowered to CommOps
     (``plan/ir.lower_specs`` / ``lower_region``), with declaration-site
     provenance in ``meta["site"]``;
  2. *traced* — jaxpr collectives the instrumentation extracted
     (``instrument._walk`` -> ``lower_collectives``), with trip counts
     and eqn provenance in ``meta["trips"]`` / ``meta["source"]``;
  3. *plan* — the installed ``ProgramPlan`` knobs (duck-typed
     ``knob_for(op_name, axis)``), so feasibility is checked against the
     knobs the executor will actually run.

Permute sites, wait edges, buffer accesses and in-flight claims are
derived from the declared ops + chosen knobs (``derive_permutes``) or
supplied directly (corpus JSON via ``from_corpus``) — the same graph
shape either way, so the lint corpus exercises exactly the production
passes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from repro.plan.ir import CommOp


@dataclasses.dataclass(frozen=True)
class PermuteSite:
    """One ppermute call site with its constructed permutation."""
    label: str
    axis: str
    axis_size: int
    perm: tuple                  # ((src, dst), ...) — may be partial
    ring: bool = False           # composed ring: f^axis_size must be id
    pair: tuple | None = None    # (fwd_shift, ret_shift) for paired a2a
    site: Any = None


@dataclasses.dataclass(frozen=True)
class WaitEdge:
    """``dst`` waits for ``src`` (happens-before edge src -> dst)."""
    src: str
    dst: str
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class InFlight:
    """A buffer an OverlapAccount marks in flight over (t0, t1)."""
    buffer: str
    t0: float
    t1: float
    label: str = ""


@dataclasses.dataclass(frozen=True)
class BufferAccess:
    """A compute read/write of a named buffer at normalised step time."""
    buffer: str
    time: float
    access: str                  # "read" | "write"
    label: str = ""


class _KnobTable:
    """Duck-typed ProgramPlan stand-in for corpus-supplied knob dicts."""

    def __init__(self, knobs: dict[str, dict]):
        self.knobs = dict(knobs)

    def knob_for(self, op_name: str, axis: str):
        return self.knobs.get(f"{op_name}|{axis}")


@dataclasses.dataclass
class CommGraph:
    name: str
    axis_sizes: dict[str, int]
    declared: list = dataclasses.field(default_factory=list)
    traced: list = dataclasses.field(default_factory=list)
    plan: Any = None             # knob_for(op_name, axis) -> dict | None
    permutes: list = dataclasses.field(default_factory=list)
    waits: list = dataclasses.field(default_factory=list)
    inflight: list = dataclasses.field(default_factory=list)
    accesses: list = dataclasses.field(default_factory=list)
    stash_cap_bytes: int | None = None
    hw: Any = None

    def knob(self, op: CommOp) -> dict | None:
        if self.plan is None:
            return None
        return self.plan.knob_for(op.op_name, op.axis)


def ring_perm(n: int, shift: int = 1) -> tuple:
    """The repo's canonical ring permutation (managed._ring_perm)."""
    return tuple((i, (i + shift) % n) for i in range(n))


def derive_permutes(ops: Sequence[CommOp], axis_sizes: dict[str, int],
                    plan: Any = None) -> list[PermuteSite]:
    """Reconstruct every permutation the executors would build for the
    declared ops under the chosen plan knobs — ring attention KV and
    dk/dv rings, pipeline fwd/bwd tick handoffs, MoE stream chunk
    round-trips.  This is the analyzer's pass-2 input when the program
    comes from declarations rather than a corpus file."""
    sites: list[PermuteSite] = []
    for op in ops:
        n = int(axis_sizes.get(op.axis, op.axis_size) or op.axis_size)
        if n <= 1:
            continue
        knob = plan.knob_for(op.op_name, op.axis) if plan is not None \
            else None
        mode = (knob or {}).get("mode")
        site = op.meta.get("site")
        if op.kind == "attention" and mode in (None, "ring"):
            # ring attention streams KV (fwd) and dk/dv (bwd) around the
            # axis one shift-1 hop per step, n steps = home again
            sites.append(PermuteSite(
                label=f"{op.label}.kv_ring", axis=op.axis, axis_size=n,
                perm=ring_perm(n), ring=True, site=site))
            sites.append(PermuteSite(
                label=f"{op.label}.dkv_ring", axis=op.axis, axis_size=n,
                perm=ring_perm(n), ring=True, site=site))
        elif op.kind == "pipeline":
            # pipeline ticks hand activations to stage+1 (fwd) and
            # gradients to stage-1 (bwd); interleaved chunk wraps ride
            # the same ring permutes
            sites.append(PermuteSite(
                label=f"{op.label}.fwd_tick", axis=op.axis, axis_size=n,
                perm=ring_perm(n, 1), ring=True, site=site))
            sites.append(PermuteSite(
                label=f"{op.label}.bwd_tick", axis=op.axis, axis_size=n,
                perm=ring_perm(n, -1), ring=True, site=site))
        elif op.kind == "moe" and mode == "stream":
            # expert stream step s issues shift s+1 forward and returns
            # results with shift -s — each forward/return pair must
            # compose to the identity
            for s in range(1, n):
                sites.append(PermuteSite(
                    label=f"{op.label}.stream{s}", axis=op.axis,
                    axis_size=n, perm=ring_perm(n, s), ring=False,
                    pair=(s, -s), site=site))
    return sites


def from_ops(name: str, *, axis_sizes: dict[str, int],
             declared: Sequence[CommOp] = (),
             traced: Sequence[CommOp] = (),
             plan: Any = None, hw: Any = None,
             stash_cap_bytes: int | None = None,
             derive: bool = True) -> CommGraph:
    """Build the graph from lowered CommOps — the launcher-preflight
    path.  ``derive=True`` reconstructs the permute sites from the
    declarations + knobs."""
    if hw is None:
        from repro.core import managed
        hw = managed.get_config().hw
    g = CommGraph(name=name, axis_sizes=dict(axis_sizes),
                  declared=list(declared), traced=list(traced),
                  plan=plan, stash_cap_bytes=stash_cap_bytes, hw=hw)
    if derive:
        g.permutes = derive_permutes(g.declared, g.axis_sizes, plan)
    return g


def attach_trace(graph: CommGraph, spans: Sequence[Any], *,
                 replace: bool = True) -> CommGraph:
    """Swap the graph's *declared* overlap story for the *measured* one.

    Declared ``inflight``/``accesses`` rows encode when the program
    claims transfers hold buffers and compute touches them.  A runtime
    trace knows when they actually did: every span carrying a
    ``buffer=`` attr is a real in-flight window, and ``reads=``/
    ``writes=`` attrs are real compute touches (pinned at the span
    midpoint).  This rebuilds pass 4's inputs from those spans, so
    MDMP401/402 fire on races that happened rather than races that were
    declared — the trace feedback edge into the static verifier.

    ``replace=False`` appends instead, checking measured windows
    against the declared access story (and vice versa).
    """
    from repro.obs.export import measured_windows
    windows, touches = measured_windows(spans)
    inflight = [] if replace else list(graph.inflight)
    accesses = [] if replace else list(graph.accesses)
    inflight += [InFlight(buffer=b, t0=t0, t1=t1, label=label)
                 for (b, t0, t1, label) in windows]
    accesses += [BufferAccess(buffer=b, time=t, access=acc, label=label)
                 for (b, t, acc, label) in touches]
    return dataclasses.replace(graph, inflight=inflight, accesses=accesses)


def from_corpus(case: dict, hw: Any = None) -> CommGraph:
    """Build the graph from a lint-corpus JSON case (tests/lint_corpus).

    Schema::

        {"name": ..., "axis_sizes": {...}, "stash_cap_bytes": ...,
         "declared": [CommOp dicts], "traced": [CommOp dicts],
         "permutes": [{label, axis, axis_size, perm, ring, pair?}],
         "waits": [{src, dst, reason?}],
         "inflight": [{buffer, t0, t1, label?}],
         "accesses": [{buffer, time, access, label?}],
         "knobs": {"op_name|axis": {mode, chunks, ...}}}
    """
    if hw is None:
        from repro.core import managed
        hw = managed.get_config().hw
    axis_sizes = dict(case.get("axis_sizes", {}))
    declared = [CommOp.from_dict(d) for d in case.get("declared", ())]
    traced = [CommOp.from_dict(d) for d in case.get("traced", ())]
    plan = _KnobTable(case.get("knobs", {})) if case.get("knobs") else None
    g = CommGraph(
        name=case.get("name", "corpus"), axis_sizes=axis_sizes,
        declared=declared, traced=traced, plan=plan,
        stash_cap_bytes=case.get("stash_cap_bytes"), hw=hw)
    g.permutes = [PermuteSite(
        label=p["label"], axis=p["axis"],
        axis_size=int(p.get("axis_size",
                            axis_sizes.get(p["axis"], 1))),
        perm=tuple((int(a), int(b)) for a, b in p.get("perm", ())),
        ring=bool(p.get("ring", False)),
        pair=tuple(p["pair"]) if p.get("pair") else None,
        site=p.get("site")) for p in case.get("permutes", ())]
    if case.get("derive_permutes"):
        g.permutes += derive_permutes(declared, axis_sizes, plan)
    g.waits = [WaitEdge(w["src"], w["dst"], w.get("reason", ""))
               for w in case.get("waits", ())]
    g.inflight = [InFlight(f["buffer"], float(f["t0"]), float(f["t1"]),
                           f.get("label", ""))
                  for f in case.get("inflight", ())]
    g.accesses = [BufferAccess(a["buffer"], float(a["time"]),
                               a["access"], a.get("label", ""))
                  for a in case.get("accesses", ())]
    return g
