"""mdmplint — the static communication verifier (the sixth managed
subsystem, cross-cutting the other five).

MDMP's premise is that declared communications are a *specification*
the traced program and the installed plan must satisfy.  This package
lifts the three truth sources — CommRegion declarations
(core/region.py), traced-jaxpr collectives (core/instrument.py ->
plan/ir.lower_collectives), and the installed ProgramPlan
(plan/planner.py) — into one checkable ``CommGraph`` (graph.py) and
runs a pass pipeline over it (passes.py):

  1. declared-vs-traced drift      MDMP101/102/103/104
  2. permute validity              MDMP201/202
  3. ordering / deadlock           MDMP301
  4. overlap races                 MDMP401/402
  5. plan feasibility              MDMP501/502/503/504
  0. declaration validity          MDMP001 (axes)

Entry points: ``python -m repro.launch.lint`` (CLI), and
``preflight()`` — the ``--verify {off,warn,strict}`` hook both
launchers run before committing to a schedule.
"""

from __future__ import annotations

from typing import Callable, Sequence

from repro.analysis.diagnostics import CODES, Diagnostic, Site, make
from repro.analysis.graph import (BufferAccess, CommGraph, InFlight,
                                  PermuteSite, WaitEdge, attach_trace,
                                  derive_permutes, from_corpus, from_ops,
                                  ring_perm)
from repro.analysis.passes import (PASSES, check_axes, check_drift,
                                   check_feasibility, check_ordering,
                                   check_overlap, check_permutes,
                                   run_all)
from repro.analysis.report import exit_code, render, summary


class LintError(SystemExit):
    """Raised by strict preflight on error diagnostics (exit status 1)."""

    def __init__(self, diags: Sequence[Diagnostic]):
        self.diags = list(diags)
        super().__init__(1)


def preflight(graph: CommGraph, mode: str = "warn", *,
              out: Callable[[str], None] = print) -> list[Diagnostic]:
    """Run the verifier as a launcher preflight.

    ``off``   — skip entirely (returns []).
    ``warn``  — print findings, log a DecisionRecord(op="lint") so
                suppressed warnings land in the decision trail, continue.
    ``strict``— print findings with the declared/traced side-by-side and
                fix hints; raise ``LintError`` (exit 1) on any error.
    """
    if mode == "off":
        return []
    from repro.obs.tracer import get_tracer
    with get_tracer().span("lint.preflight", op="lint", track="lint",
                           graph=graph.name):
        diags = run_all(graph)
    errors = sum(1 for d in diags if d.severity == "error")
    if diags:
        out(render(diags, verbose=(mode == "strict")))
    out(summary(diags, graph.name))
    if mode == "warn":
        from repro.core import managed
        managed.log_decision(managed.DecisionRecord(
            op="lint", axis=graph.name, nbytes=errors, mode=mode,
            chunks=len(diags), predicted_bulk_s=0.0,
            predicted_interleaved_s=0.0))
    if mode == "strict" and errors:
        raise LintError(diags)
    return diags


__all__ = [
    "CODES", "Diagnostic", "Site", "make",
    "BufferAccess", "CommGraph", "InFlight", "PermuteSite", "WaitEdge",
    "attach_trace", "derive_permutes", "from_corpus", "from_ops",
    "ring_perm",
    "PASSES", "check_axes", "check_drift", "check_feasibility",
    "check_ordering", "check_overlap", "check_permutes", "run_all",
    "exit_code", "render", "summary",
    "LintError", "preflight",
]
