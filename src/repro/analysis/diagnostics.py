"""Typed diagnostics for the static communication verifier (mdmplint).

Every finding the analyzer emits is a ``Diagnostic`` — a frozen record
with a registry code (``MDMP...``), a severity, the program site it
anchors to, the declared-side and traced-side renderings it reconciles,
and a fix hint.  The registry below is the single source of truth the CI
greps, the EXPERIMENTS.md table, and ``launch/lint.py`` all enumerate.

Code families (hundreds digit = pass family):

  * MDMP0xx — declaration validity (axes, spec well-formedness)
  * MDMP1xx — declared-vs-traced drift
  * MDMP2xx — permute validity (bijection, ring closure)
  * MDMP3xx — ordering / deadlock (wait-for cycles)
  * MDMP4xx — overlap races (in-flight buffer hazards)
  * MDMP5xx — plan feasibility (knobs the executor would silently clamp)
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Site:
    """Repo-relative program location a diagnostic points at."""
    file: str = ""
    line: int = 0

    def __str__(self) -> str:
        if not self.file:
            return "<unknown site>"
        return f"{self.file}:{self.line}" if self.line else self.file

    @classmethod
    def of(cls, obj) -> "Site":
        """Coerce the provenance shapes the graph carries: a (file, line)
        tuple (CommSpec.site), a "file:line" string (CollectiveRecord
        .source), or None."""
        if obj is None:
            return cls()
        if isinstance(obj, Site):
            return obj
        if isinstance(obj, str):
            if ":" in obj:
                f, _, ln = obj.rpartition(":")
                try:
                    return cls(f, int(ln))
                except ValueError:
                    return cls(obj, 0)
            return cls(obj, 0)
        try:
            f, ln = obj
            return cls(str(f), int(ln))
        except Exception:
            return cls()


#: code -> (severity, title).  Severity is fixed per code — a corpus
#: golden file asserting "MDMP501" asserts the severity too.
CODES: dict[str, tuple[str, str]] = {
    "MDMP001": ("error", "unknown-axis"),
    "MDMP101": ("error", "undeclared-collective"),
    "MDMP102": ("error", "bytes-drift"),
    "MDMP103": ("warning", "stale-declaration"),
    "MDMP104": ("warning", "kind-mismatch"),
    "MDMP201": ("error", "non-bijective-permute"),
    "MDMP202": ("error", "ring-no-return"),
    "MDMP301": ("error", "wait-cycle"),
    "MDMP401": ("error", "stale-read-in-flight"),
    "MDMP402": ("error", "write-races-in-flight"),
    "MDMP501": ("error", "non-divisor-stream-chunks"),
    "MDMP502": ("error", "microbatch-indivisible"),
    "MDMP503": ("error", "stash-over-cap"),
    "MDMP504": ("error", "halo-k-exceeds-block"),
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One verifier finding."""
    code: str                    # registry key, e.g. "MDMP101"
    severity: str                # "error" | "warning"
    title: str                   # registry short name
    message: str                 # one-line human statement
    label: str = ""              # CommOp/spec label it anchors to
    axis: str = ""
    site: Site = dataclasses.field(default_factory=Site)
    spec_ref: str = ""           # declared-side rendering (side-by-side)
    op_ref: str = ""             # traced/plan-side rendering
    hint: str = ""               # how to fix

    def render(self, verbose: bool = False) -> str:
        head = f"{self.code} {self.severity:7s} {self.title}"
        where = f" [{self.site}]" if self.site.file else ""
        line = f"{head}: {self.message}{where}"
        if not verbose:
            return line
        parts = [line]
        if self.spec_ref:
            parts.append(f"    declared | {self.spec_ref}")
        if self.op_ref:
            parts.append(f"    traced   | {self.op_ref}")
        if self.hint:
            parts.append(f"    fix      | {self.hint}")
        return "\n".join(parts)


def make(code: str, message: str, **kw) -> Diagnostic:
    """Build a Diagnostic with the registry's severity/title for ``code``."""
    sev, title = CODES[code]
    if "site" in kw:
        kw["site"] = Site.of(kw["site"])
    return Diagnostic(code=code, severity=sev, title=title,
                      message=message, **kw)
