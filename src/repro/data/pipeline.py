"""Deterministic, resumable synthetic LM data pipeline.

Production posture without external data dependencies: batches are a pure
function of (seed, step), so
  * every host materialises exactly its shard (no cross-host data traffic),
  * resuming from step k reproduces the uninterrupted stream bit-for-bit
    (checkpoint/restart tests rely on this),
  * elastic restarts on a different mesh re-slice the same global stream.

The token stream is a stationary Markov-ish mixture so the LM loss has
learnable structure (quickstart/train_100m show it falling).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_patterns: int = 64          # learnable repeated n-gram patterns
    pattern_len: int = 16


class SyntheticLMData:
    """state = just the step counter (plus config); see module docstring."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = max(cfg.vocab_size - 1, 2)
        self._patterns = rng.integers(
            0, v, size=(cfg.n_patterns, cfg.pattern_len), dtype=np.int32)

    def global_batch_at(self, step: int) -> dict:
        """Full global batch for ``step`` (tokens + next-token labels)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        b, s = cfg.global_batch, cfg.seq_len
        n_pat = (s + cfg.pattern_len - 1) // cfg.pattern_len + 1
        idx = rng.integers(0, cfg.n_patterns, size=(b, n_pat))
        stream = self._patterns[idx].reshape(b, -1)[:, :s + 1]
        noise = rng.random((b, s + 1)) < 0.05
        rand_tok = rng.integers(0, max(cfg.vocab_size - 1, 2),
                                size=(b, s + 1), dtype=np.int32)
        stream = np.where(noise, rand_tok, stream).astype(np.int32)
        return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}

    def shard_at(self, step: int, shard: int, n_shards: int) -> dict:
        """This host's batch-dim shard of the global batch (pure function of
        (seed, step, shard) — no host ever builds another host's data)."""
        g = self.global_batch_at(step)
        b = self.cfg.global_batch
        assert b % n_shards == 0
        lo = shard * (b // n_shards)
        hi = lo + b // n_shards
        return {k: v[lo:hi] for k, v in g.items()}

    # -- checkpointable state ------------------------------------------------

    def state_dict(self, step: int) -> dict:
        return {"seed": self.cfg.seed, "step": int(step)}

    @staticmethod
    def resume(cfg: DataConfig, state: dict) -> tuple["SyntheticLMData", int]:
        assert state["seed"] == cfg.seed, "data seed mismatch on resume"
        return SyntheticLMData(cfg), int(state["step"])
