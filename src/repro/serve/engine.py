"""Serving engine — the jitted step loop over the paged cache.

One dispatched call advances every decode slot by up to C tokens
(``lax.scan`` over ``Model.decode_step_paged``): slots still inside their
prompt consume prompt tokens (chunked prefill), slots past it feed their
own last sample back (decode).  C — the scheduling quantum — is the
managed knob: it amortises the per-dispatch overhead (the alpha of this
decision) against scheduling granularity (admission + retirement only
happen at quantum boundaries), and is chosen by
``managed.resolve_serve_schedule`` from the serve cost model, then
corrected online from serve/metrics.py's measured step latencies —
MDMP's iteration-(k)->(k+1) loop on the serving path.

The cache is the paged pool of serve/kv_cache.py: per-layer page pools
sharded over the cache axes, one host-side page table, pages recycled
through the free list as requests retire.  Works for every token-only
decoder family (dense / moe / ssm / hybrid — SSM state is slot-indexed
and masked, so "paging" degenerates to slot reuse there).

Overload is a managed condition, not a crash.  Admission is OPTIMISTIC
(watermark mode commits only the prompt's pages; decode growth claims
pages on demand), and when the pool exhausts mid-decode
(``PagePoolExhausted``) the engine preempts: pick a victim, then either
SWAP its page chain to host (D2H in ``overlap.drain_chunk_bytes``-metered
row slices, restored on re-admission) or DROP it for prefill-replay
(``scheduler.continuation`` — the drain() idiom), or stall the growing
slot one quantum — whichever ``managed.resolve_preempt`` prices cheapest
from the measured step seconds and PCIe bandwidth.  Greedy decoding makes
both eviction paths token-equal to the no-overload run.  The ``burst``
and ``pool_squeeze`` fault kinds drive this machinery deterministically
under test.
"""

from __future__ import annotations

import math
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import cost_model, managed, overlap
from repro.core.faults import FaultPlan
from repro.models.model import Model
from repro.obs.calibrate import Recalibrator
from repro.obs.tracer import get_tracer
from repro.parallel.sharding import smap, spec_pspecs
from repro.serve.kv_cache import (PagedCacheConfig, PagePoolExhausted,
                                  PageTable)
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (Request, RequestRejected, ServeScheduler)

Array = jax.Array


def build_paged_step(model: Model, mesh: Mesh, cache_pspecs: Any,
                     chunk: int):
    """Jitted quantum: (params, cache, table [B, Pmax], tokens [B, C],
    n_in [B], pos0 [B], steps [B]) -> (sampled [B, C], new cache).

    Inner scan step t feeds slot b ``tokens[b, t]`` while t < n_in[b]
    (prompt/chain seed) and its own previous sample afterwards; slots
    with t >= steps[b] are inactive (no cache write, no position
    advance)."""
    pspecs = spec_pspecs(model.param_specs())

    def body(params, cache, table, tokens, n_in, pos0, steps):
        def inner(carry, xs):
            cache_c, pos, last = carry
            t, tok_col = xs
            tok = jnp.where(t < n_in, tok_col, last)
            act = t < steps
            nxt, cache_c = model.decode_step_paged(
                params, cache_c, table, tok, pos, act)
            pos = pos + act.astype(jnp.int32)
            last = jnp.where(act, nxt, last)
            return (cache_c, pos, last), nxt

        init = (cache, pos0, tokens[:, 0])
        (cache, _, _), outs = lax.scan(
            inner, init, (jnp.arange(chunk), tokens.T))
        return outs.T, cache

    sharded = smap(
        body, mesh,
        in_specs=(pspecs, cache_pspecs, P(None, None), P(None, None),
                  P(None), P(None), P(None)),
        out_specs=(P(None, None), cache_pspecs))
    return jax.jit(sharded, donate_argnums=(1,))


class ServeEngine:
    """Continuous-batching serving loop over the paged cache."""

    def __init__(self, model: Model, mesh: Mesh, params: Any, *,
                 slots: int = 4, max_seq: int = 256, page_size: int = 8,
                 n_pages: int | None = None, schedule: str = "auto",
                 chunk: int | None = None,
                 metrics: ServeMetrics | None = None, tuner: Any = None,
                 fault_plan: FaultPlan | None = None,
                 admission: str = "watermark", watermark: int = 0,
                 preempt: str = "auto",
                 slo_ttft_s: float | None = None,
                 max_queue: int | None = None, burst_new: int = 8):
        from repro.models import attention
        assert preempt in ("auto", "swap", "recompute", "none"), preempt
        self.model = model
        self.mesh = mesh
        self.params = params
        self.slots = slots
        n_sh = attention.cache_shards(model.ctx)
        pages_per_seq = max(1, math.ceil(max_seq / page_size))
        if n_pages is None:
            n_pages = slots * pages_per_seq
        n_pages = ((n_pages + n_sh - 1) // n_sh) * n_sh
        self.cache_cfg = PagedCacheConfig(
            slots=slots, page_size=page_size, n_pages=n_pages,
            max_pages_per_seq=pages_per_seq)
        self.pt = PageTable(self.cache_cfg)
        self.metrics = metrics or ServeMetrics()
        self._n_params = model.cfg.param_count()
        self._dtype_bytes = jnp.dtype(model.cfg.dtype).itemsize
        self.scheduler = ServeScheduler(
            slots, schedule=schedule, chunk=chunk, tuner=tuner,
            cache_cfg=self.cache_cfg, admission=admission,
            watermark=watermark, slo_ttft_s=slo_ttft_s,
            max_queue=max_queue,
            model_step_s=cost_model.serve_step_time(
                self._n_params, slots, dtype_bytes=self._dtype_bytes))
        self._schedule = schedule
        self._preempt = preempt
        self._burst_new = int(burst_new)
        # KV state is pageable for attention-cache families; SSM slot
        # state is not a page chain, so those evict by recompute only
        self._swappable = model.cfg.family in ("dense", "moe")
        self._cache_sds, self._cache_pspecs = model.paged_cache_specs(
            slots, n_pages, page_size)
        # bytes per pool page, summed across pool leaves (each leaf is
        # layer-stacked, so a page's footprint spans every layer)
        self._page_bytes = sum(
            int(np.prod(s.shape)) // s.shape[ax]
            * np.dtype(s.dtype).itemsize
            for s, ax in zip(jax.tree.leaves(self._cache_sds),
                             self._pool_page_axes())
            if ax is not None)
        self._steps: dict[int, Any] = {}      # chunk -> jitted quantum
        self._rid = 0
        # the online-correction trigger (obs.Recalibrator): fire once as
        # soon as 3 quanta are measured (the historical warmup retune),
        # then again whenever the per-step seconds drift >25% off the
        # value the schedule was last resolved against
        self.recal = Recalibrator(threshold=0.25, warmup=3)
        self._variant_q0 = 0      # quanta index of the variant's window
        self.fault_plan = fault_plan
        self._quantum_idx = 0     # lifetime quantum counter (fault clock)
        self.results: dict[int, np.ndarray] = {}
        #: rid -> (n_pages, host page rows per pool leaf, consumed,
        #: last_out, generated) for swapped-out victims awaiting re-admit
        self._swapped: dict[int, tuple] = {}
        #: rid -> tokens generated before a recompute eviction (stitched
        #: in front of the continuation's output at retirement)
        self._gen_prefix: dict[int, list[int]] = {}
        #: rids evicted since the last dispatched quantum; admission holds
        #: them at the queue head so eviction cannot chase re-admission
        self._hold: set[int] = set()
        self.cache = self._empty_cache()

    # -- device state --------------------------------------------------------

    def _empty_cache(self) -> Any:
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self._cache_pspecs)
        return jax.tree.map(
            lambda sds, sh: jax.device_put(
                jnp.zeros(sds.shape, sds.dtype), sh),
            self._cache_sds, shardings)

    def _step_fn(self, chunk: int):
        fn = self._steps.get(chunk)
        if fn is None:
            fn = build_paged_step(self.model, self.mesh,
                                  self._cache_pspecs, chunk)
            self._steps[chunk] = fn
        return fn

    def warmup(self, chunk: int) -> None:
        """Compile the quantum function outside the measured loop (a
        zero-step quantum touches no state)."""
        zeros = np.zeros(self.slots, np.int32)
        out, self.cache = self._step_fn(chunk)(
            self.params, self.cache, jnp.asarray(self.pt.table),
            jnp.zeros((self.slots, chunk), jnp.int32),
            jnp.asarray(np.ones(self.slots, np.int32)),
            jnp.asarray(zeros), jnp.asarray(zeros))
        jax.block_until_ready(out)

    # -- queue ---------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int,
               ttft_slo_s: float | None = None) -> int:
        rid = self._rid
        self._rid += 1
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32).ravel(),
                      max_new=int(max_new), ttft_slo_s=ttft_slo_s)
        self.submit_request(req)
        return rid

    def submit_request(self, req: Request) -> None:
        """Submit a pre-built request, preserving its rid — the failover
        path: a drained replica's requests re-admit here with their
        generated prefix folded into the prompt.  Infeasible requests
        raise the typed ``RequestRejected`` and shed ones ``RequestShed``
        (scheduler.submit) — the rid is consumed either way."""
        self._rid = max(self._rid, req.rid + 1)
        self.scheduler.submit(req, self.metrics)

    def drain(self) -> list[tuple[Request, list[int]]]:
        """Evacuate a dead replica: free every in-flight request's page
        chain and hand back [(request, generated_prefix)] rebuilt for a
        survivor (scheduler.drain).  Finished requests retire into
        ``self.results``; the caller stitches prefix + survivor output
        for the rest.  Swapped-out host state is dropped — the original
        request is still queued and replays from scratch elsewhere."""
        out = self.scheduler.drain(self.pt, self.results)
        self._swapped.clear()
        self.scheduler.restore_pages.clear()
        for rid, pre in list(self._gen_prefix.items()):
            if rid in self.results:
                self.results[rid] = np.concatenate(
                    [np.asarray(pre, np.int32), self.results[rid]])
                del self._gen_prefix[rid]
        return [(req, self._gen_prefix.pop(req.rid, []) + prefix)
                for req, prefix in out]

    # -- overload faults -----------------------------------------------------

    def _inject_burst(self, n: int) -> None:
        """A ``burst@q:n`` event: n synthetic arrivals at this quantum
        boundary, prompts seeded from the quantum index so the flood is
        identical across runs.  Shed/rejected arrivals are recorded by
        admission control and dropped — overload degrades, never kills."""
        rng = np.random.default_rng(0xB0 + 997 * self._quantum_idx)
        for _ in range(max(0, n)):
            plen = int(rng.integers(4, 17))
            prompt = rng.integers(1, 1000, size=plen).astype(np.int32)
            try:
                self.submit(prompt, self._burst_new)
            except RequestRejected:
                pass

    def _apply_overload_events(self) -> None:
        if self.fault_plan is None:
            return
        for ev in self.fault_plan.serve_overload(self._quantum_idx):
            if ev.kind == "burst":
                self._inject_burst(int(ev.arg))
            else:                             # pool_squeeze@q:frac
                self.pt.squeeze(float(ev.arg))

    # -- preemption (the optimistic-admission backstop) ----------------------

    def _pool_page_axes(self) -> list[int | None]:
        """Per cache leaf: the axis indexed by pool page ids, or None for
        non-pool state (SSM slot state).  Pool leaves are [Np, page, KV,
        hd] or, layer-stacked, [L, Np, page, KV, hd]."""
        npg = self.cache_cfg.n_pages
        pg = self.cache_cfg.page_size
        axes: list[int | None] = []
        for leaf in jax.tree.leaves(self._cache_sds):
            shp = tuple(leaf.shape)
            if len(shp) == 4 and shp[0] == npg and shp[1] == pg:
                axes.append(0)
            elif len(shp) == 5 and shp[1] == npg and shp[2] == pg:
                axes.append(1)
            else:
                axes.append(None)
        return axes

    def _swap_chunk_rows(self, row_bytes: int) -> int:
        """Rows per metered transfer slice: the checkpoint drain's chunk
        meter applied to eviction traffic."""
        step = self.scheduler.step_s_hint(self.metrics) or 1e-3
        bw = self.metrics.swap_bw_estimate() or cost_model.PCIE_BW
        cb = overlap.drain_chunk_bytes(step, bw)
        return max(1, cb // max(1, row_bytes))

    def _swap_out(self, slot: int) -> None:
        """Evict ``slot`` by draining its resident KV pages to host in
        row-sliced chunks; the original request requeues at the front and
        restores (``_swap_in``) once admission finds its pages again."""
        sch, pt = self.scheduler, self.pt
        rs = sch.active[slot]
        keep = pt.cfg.pages_needed(rs.consumed)
        ids = np.asarray(pt.chain(slot)[:keep], np.int32)
        axes = self._pool_page_axes()
        leaves = jax.tree.leaves(self.cache)
        t0 = time.perf_counter()
        host: list[np.ndarray | None] = []
        nbytes = 0
        with get_tracer().span("serve.swap_out", op="preempt_policy",
                               axis="serve", track="serve",
                               buffer="kv_pages", slot=slot) as sp:
            for leaf, ax in zip(leaves, axes):
                if ax is None:
                    host.append(None)
                    continue
                row_bytes = (int(np.prod(leaf.shape)) // leaf.shape[ax]
                             * leaf.dtype.itemsize)
                rpc = self._swap_chunk_rows(row_bytes)
                parts = [np.asarray(jnp.take(leaf,
                                             jnp.asarray(ids[i:i + rpc]),
                                             axis=ax))
                         for i in range(0, len(ids), rpc)]
                empty = leaf.shape[:ax] + (0,) + leaf.shape[ax + 1:]
                rows = (np.concatenate(parts, axis=ax) if parts else
                        np.zeros(empty, leaf.dtype))
                host.append(rows)
                nbytes += rows.nbytes
            if sp is not None:
                sp.note(nbytes=nbytes)
        self.metrics.note_swap(nbytes, time.perf_counter() - t0)
        rs = sch.preempt(slot, pt)
        self._swapped[rs.req.rid] = (len(ids), host, rs.consumed,
                                     rs.last_out, list(rs.generated))
        sch.restore_pages[rs.req.rid] = keep
        sch.requeue_front(rs.req)
        self._hold.add(rs.req.rid)
        self.metrics.on_preempt(rs.req.rid, "swap")

    def _swap_in(self, rs) -> None:
        """Restore a swapped victim into its new slot: reallocate a page
        chain for its consumed positions and push the host rows back
        (H2D, same chunk meter), then resume decoding mid-chain."""
        data = self._swapped.pop(rs.req.rid, None)
        if data is None:
            return
        n_ids, host, consumed, last_out, generated = data
        pt = self.pt
        pt.ensure(rs.slot, consumed)
        new_ids = np.asarray(pt.chain(rs.slot)[:n_ids], np.int32)
        leaves, treedef = jax.tree.flatten(self.cache)
        pleaves = jax.tree.leaves(self._cache_pspecs)
        axes = self._pool_page_axes()
        t0 = time.perf_counter()
        nbytes = 0
        out_leaves = []
        with get_tracer().span("serve.swap_in", op="preempt_policy",
                               axis="serve", track="serve",
                               buffer="kv_pages", slot=rs.slot) as sp:
            for leaf, ps, rows, ax in zip(leaves, pleaves, host, axes):
                if rows is None or ax is None or not len(new_ids):
                    out_leaves.append(leaf)
                    continue
                row_bytes = (int(np.prod(leaf.shape)) // leaf.shape[ax]
                             * leaf.dtype.itemsize)
                rpc = self._swap_chunk_rows(row_bytes)
                pre = (slice(None),) * ax
                for i in range(0, len(new_ids), rpc):
                    leaf = leaf.at[pre + (new_ids[i:i + rpc],)].set(
                        jnp.asarray(rows[pre + (slice(i, i + rpc),)]))
                leaf = jax.device_put(leaf, NamedSharding(self.mesh, ps))
                out_leaves.append(leaf)
                nbytes += rows.nbytes
            self.cache = jax.tree.unflatten(treedef, out_leaves)
            jax.block_until_ready(self.cache)
            if sp is not None:
                sp.note(nbytes=nbytes)
        self.metrics.note_swap(nbytes, time.perf_counter() - t0)
        rs.consumed = consumed
        rs.last_out = last_out
        rs.generated = list(generated)
        self.scheduler.restore_pages.pop(rs.req.rid, None)

    def _drop_recompute(self, slot: int) -> None:
        """Evict ``slot`` by releasing its pages outright; the request
        requeues as a prompt+generated continuation whose prefill REPLAYS
        the lost KV (greedy decoding keeps the token chain bit-equal)."""
        sch = self.scheduler
        with get_tracer().span("serve.recompute_evict",
                               op="preempt_policy", axis="serve",
                               track="serve", buffer="kv_pages",
                               slot=slot):
            rs = sch.preempt(slot, self.pt)
            rid = rs.req.rid
            cont = sch.continuation(rs)
            if cont is None:                  # already finished: retire
                self._retire(rid, rs.generated)
                return
            if rs.generated:
                self._gen_prefix[rid] = (self._gen_prefix.get(rid, [])
                                         + list(rs.generated))
            sch.requeue_front(cont)
            self._hold.add(rid)
        self.metrics.on_preempt(rid, "recompute")

    def _retire(self, rid: int, generated: list[int]) -> None:
        pre = self._gen_prefix.pop(rid, [])
        self.results[rid] = np.asarray(list(pre) + list(generated),
                                       np.int32)

    def _cap_to_resident(self, plan, stalled: list[int]) -> int:
        """The WAIT policy: clamp each stalled slot's quantum steps to
        the positions its already-allocated chain can hold.  Returns the
        batch's total steps after clamping."""
        for s in stalled:
            rs = self.scheduler.active[s]
            fit = (self.pt.pages_held(s) * self.cache_cfg.page_size
                   - rs.consumed)
            plan.steps[s] = max(0, min(int(plan.steps[s]), fit))
        return int(plan.steps.sum())

    def _handle_exhaustion(self, plan, stalled: list[int]) -> bool:
        """React to ``PagePoolExhausted`` on this quantum's page growth.
        Returns True when a victim was evicted (the caller re-admits and
        re-plans), False when ``plan.steps`` were capped in place and the
        clamped quantum should dispatch (wait)."""
        sch, pt = self.scheduler, self.pt
        can_wait = self._cap_to_resident(plan, stalled) > 0
        if self._preempt == "none":
            # the unmanaged baseline: no eviction machinery — stall while
            # anything progresses, die when nothing can
            if not can_wait:
                raise RuntimeError(
                    "serve queue stalled: page pool exhausted and "
                    f"preemption is disabled ({self.cache_cfg})")
            return False
        victim = sch.select_victim(pt, prefer_not=stalled[0])
        if victim is None or len(sch.active) == 1:
            # no victim — or evicting the SOLE slot, which can never
            # help: its continuation needs at least the pages it holds
            # now, so eviction would only trade a stall for a thrash
            if can_wait:
                return False
            raise RuntimeError(
                "serve queue stalled: page pool exhausted with no "
                f"evictable victim ({self.cache_cfg})")
        vrs = sch.active[victim]
        victim_pages = pt.pages_held(victim)
        step = sch.step_s_hint(self.metrics)
        # soonest a retirement frees pages naturally — only meaningful
        # when the clamped batch still progresses toward one
        wait_s = None
        if can_wait and step is not None:
            rem = [rs.req.total_steps - rs.consumed
                   for s, rs in sch.active.items() if s not in stalled]
            if rem:
                wait_s = min(rem) * step
        policy = None if self._preempt == "auto" else self._preempt
        if policy is None and sch.tuner is not None:
            entry = sch.tuner.decide_preempt(
                sch.axis_name, self.slots, self._page_bytes,
                self._n_params, victim_pages=victim_pages,
                replay_tokens=vrs.consumed,
                dtype_str=self.model.cfg.dtype,
                dtype_bytes=self._dtype_bytes, step_s=step)
            self._preempt_key = entry.key
            if len(entry.measured_s) >= 2:
                policy = entry.mode
        d = managed.resolve_preempt(
            sch.axis_name, victim_pages, self._page_bytes, vrs.consumed,
            self._n_params, batch_slots=self.slots,
            dtype_bytes=self._dtype_bytes, measured_step_s=step,
            measured_pcie_bw=self.metrics.swap_bw_estimate(),
            wait_s=wait_s, allow_swap=self._swappable, policy=policy)
        if d.policy == "wait":
            return False
        t0 = time.perf_counter()
        if d.policy == "swap":
            self._swap_out(victim)
        else:
            self._drop_recompute(victim)
        if sch.tuner is not None and getattr(self, "_preempt_key", None):
            # feed the measured eviction cost back (the replay part of a
            # recompute is charged from the measured step rate)
            cost = time.perf_counter() - t0
            if d.policy == "recompute" and step is not None:
                cost += vrs.consumed * step
            sch.tuner.record(self._preempt_key, d.policy, 1, cost)
        return True

    # -- the step loop -------------------------------------------------------

    def run(self) -> dict[int, np.ndarray]:
        """Serve the queue to completion; returns rid -> generated tokens.
        The schedule decision (and any online correction) is visible in
        ``managed.decision_log()`` as ``op="serve_schedule"`` records,
        and every pool-exhaustion event as ``op="preempt_policy"``."""
        sch = self.scheduler
        if not sch.has_work() and not (
                self.fault_plan and self.fault_plan.unfired()):
            return {}
        sch.decide(self._n_params, self._dtype_bytes,
                   dtype_str=self.model.cfg.dtype)
        if sch.chunk is None:       # queue was empty (pure fault drive)
            return self.results
        self.warmup(sch.chunk)
        # compilation is over: TTFT measures serving from here, and the
        # running variant's measurement window starts empty
        self.metrics.rebase_pending()
        self._variant_q0 = len(self.metrics.quanta)
        results = self.results
        while sch.has_work():
            self._apply_overload_events()
            for rs in sch.admit(self.pt, hold=self._hold):
                if rs.req.rid in self._swapped:
                    self._swap_in(rs)
            plan = sch.plan_quantum(sch.chunk)
            if int(plan.steps.sum()) == 0:
                # admit() ran just above with an empty batch and still
                # produced nothing: the head request can never fit
                raise RuntimeError(
                    "serve queue stalled: request exceeds the page pool "
                    f"({self.cache_cfg})")
            stalled = []
            for slot in sorted(sch.active):
                rs = sch.active[slot]
                try:
                    self.pt.ensure(slot,
                                   rs.consumed + int(plan.steps[slot]))
                except PagePoolExhausted:
                    stalled.append(slot)
            if stalled and self._handle_exhaustion(plan, stalled):
                continue              # victim evicted: re-admit, re-plan
            if int(plan.steps.sum()) == 0:
                continue              # whole batch stalled this quantum
            if self.fault_plan is not None:
                # the fault clock ticks on dispatched quanta; a
                # replica_death here leaves finished work in self.results
                # and in-flight state intact for drain()
                self.fault_plan.serve_quantum(self._quantum_idx)
            self._quantum_idx += 1
            useful = int(plan.steps.sum())
            t0 = time.perf_counter()
            # scale = useful slot-steps: dur/scale is measured seconds
            # per token, the unit resolve_serve_schedule predicts
            with get_tracer().span(
                    "serve.quantum", op="serve_schedule", axis="serve",
                    track="serve", chunk=plan.chunk, scale=useful,
                    quantum=self._quantum_idx - 1, reads="kv_pages"):
                out, self.cache = self._step_fn(plan.chunk)(
                    self.params, self.cache, jnp.asarray(self.pt.table),
                    jnp.asarray(plan.tokens), jnp.asarray(plan.n_in),
                    jnp.asarray(plan.pos), jnp.asarray(plan.steps))
                out_np = np.asarray(out)
            wall = time.perf_counter() - t0
            self._hold.clear()    # a quantum dispatched: evictees may
            # re-enter admission on the next planning round
            self.metrics.note_quantum(wall, plan.chunk, useful,
                                      self.slots)
            self.recal.note(wall / max(1, plan.chunk))
            for rs in sch.complete_quantum(plan, out_np, self.pt,
                                           self.metrics):
                self._retire(rs.req.rid, rs.generated)
            prev = (sch.mode, sch.chunk)
            self._maybe_retune()
            if sch.has_work() and (sch.mode, sch.chunk) != prev:
                # the correction changed the schedule: compile the new
                # quantum OUTSIDE the measured loop, keep the compile out
                # of still-queued requests' TTFT, and start a fresh
                # measurement window for the new variant
                self.warmup(sch.chunk)
                self.metrics.rebase_pending()
                self._variant_q0 = len(self.metrics.quanta)
        return results

    def _maybe_retune(self) -> None:
        """The iteration-(k)->(k+1) correction: once enough quanta are
        measured, re-resolve the schedule with the observed step/dispatch
        seconds, and feed the running variant's measured seconds-per-token
        to the tuner (so a persisted winner survives restarts).  The
        variant is only credited with quanta from its OWN measurement
        window (``_variant_q0``) — cumulative throughput would attribute
        the previous variant's behaviour to the current one."""
        sch = self.scheduler
        tok_s = self.metrics.useful_tokens_per_s(since=self._variant_q0)
        if sch.tuner is not None and sch.tuner_key and tok_s > 0:
            sch.tuner.record(sch.tuner_key, sch.mode, sch.chunk,
                             1.0 / tok_s)
        if self._schedule != "auto" or not self.recal.should_retune():
            return
        sch.decide(self._n_params, self._dtype_bytes,
                   dtype_str=self.model.cfg.dtype,
                   measured_step_s=self.metrics.step_s_estimate(),
                   measured_dispatch_s=self.metrics.dispatch_s_estimate())
        # rebase on the measurement EWMA at resolve time; the next
        # retune needs a further >threshold sustained drift from here
        self.recal.rebase()
