"""Serving engine — the jitted step loop over the paged cache.

One dispatched call advances every decode slot by up to C tokens
(``lax.scan`` over ``Model.decode_step_paged``): slots still inside their
prompt consume prompt tokens (chunked prefill), slots past it feed their
own last sample back (decode).  C — the scheduling quantum — is the
managed knob: it amortises the per-dispatch overhead (the alpha of this
decision) against scheduling granularity (admission + retirement only
happen at quantum boundaries), and is chosen by
``managed.resolve_serve_schedule`` from the serve cost model, then
corrected online from serve/metrics.py's measured step latencies —
MDMP's iteration-(k)->(k+1) loop on the serving path.

The cache is the paged pool of serve/kv_cache.py: per-layer page pools
sharded over the cache axes, one host-side page table, pages recycled
through the free list as requests retire.  Works for every token-only
decoder family (dense / moe / ssm / hybrid — SSM state is slot-indexed
and masked, so "paging" degenerates to slot reuse there).
"""

from __future__ import annotations

import math
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.faults import FaultPlan
from repro.models.model import Model
from repro.parallel.sharding import smap, spec_pspecs
from repro.serve.kv_cache import PagedCacheConfig, PageTable
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Request, ServeScheduler

Array = jax.Array


def build_paged_step(model: Model, mesh: Mesh, cache_pspecs: Any,
                     chunk: int):
    """Jitted quantum: (params, cache, table [B, Pmax], tokens [B, C],
    n_in [B], pos0 [B], steps [B]) -> (sampled [B, C], new cache).

    Inner scan step t feeds slot b ``tokens[b, t]`` while t < n_in[b]
    (prompt/chain seed) and its own previous sample afterwards; slots
    with t >= steps[b] are inactive (no cache write, no position
    advance)."""
    pspecs = spec_pspecs(model.param_specs())

    def body(params, cache, table, tokens, n_in, pos0, steps):
        def inner(carry, xs):
            cache_c, pos, last = carry
            t, tok_col = xs
            tok = jnp.where(t < n_in, tok_col, last)
            act = t < steps
            nxt, cache_c = model.decode_step_paged(
                params, cache_c, table, tok, pos, act)
            pos = pos + act.astype(jnp.int32)
            last = jnp.where(act, nxt, last)
            return (cache_c, pos, last), nxt

        init = (cache, pos0, tokens[:, 0])
        (cache, _, _), outs = lax.scan(
            inner, init, (jnp.arange(chunk), tokens.T))
        return outs.T, cache

    sharded = smap(
        body, mesh,
        in_specs=(pspecs, cache_pspecs, P(None, None), P(None, None),
                  P(None), P(None), P(None)),
        out_specs=(P(None, None), cache_pspecs))
    return jax.jit(sharded, donate_argnums=(1,))


class ServeEngine:
    """Continuous-batching serving loop over the paged cache."""

    def __init__(self, model: Model, mesh: Mesh, params: Any, *,
                 slots: int = 4, max_seq: int = 256, page_size: int = 8,
                 n_pages: int | None = None, schedule: str = "auto",
                 chunk: int | None = None,
                 metrics: ServeMetrics | None = None, tuner: Any = None,
                 fault_plan: FaultPlan | None = None):
        from repro.models import attention
        self.model = model
        self.mesh = mesh
        self.params = params
        self.slots = slots
        n_sh = attention.cache_shards(model.ctx)
        pages_per_seq = max(1, math.ceil(max_seq / page_size))
        if n_pages is None:
            n_pages = slots * pages_per_seq
        n_pages = ((n_pages + n_sh - 1) // n_sh) * n_sh
        self.cache_cfg = PagedCacheConfig(
            slots=slots, page_size=page_size, n_pages=n_pages,
            max_pages_per_seq=pages_per_seq)
        self.pt = PageTable(self.cache_cfg)
        self.metrics = metrics or ServeMetrics()
        self.scheduler = ServeScheduler(slots, schedule=schedule,
                                        chunk=chunk, tuner=tuner)
        self._schedule = schedule
        self._n_params = model.cfg.param_count()
        self._dtype_bytes = jnp.dtype(model.cfg.dtype).itemsize
        self._cache_sds, self._cache_pspecs = model.paged_cache_specs(
            slots, n_pages, page_size)
        self._steps: dict[int, Any] = {}      # chunk -> jitted quantum
        self._rid = 0
        self._retuned = False
        self._variant_q0 = 0      # quanta index of the variant's window
        self.fault_plan = fault_plan
        self._quantum_idx = 0     # lifetime quantum counter (fault clock)
        self.results: dict[int, np.ndarray] = {}
        self.cache = self._empty_cache()

    # -- device state --------------------------------------------------------

    def _empty_cache(self) -> Any:
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self._cache_pspecs)
        return jax.tree.map(
            lambda sds, sh: jax.device_put(
                jnp.zeros(sds.shape, sds.dtype), sh),
            self._cache_sds, shardings)

    def _step_fn(self, chunk: int):
        fn = self._steps.get(chunk)
        if fn is None:
            fn = build_paged_step(self.model, self.mesh,
                                  self._cache_pspecs, chunk)
            self._steps[chunk] = fn
        return fn

    def warmup(self, chunk: int) -> None:
        """Compile the quantum function outside the measured loop (a
        zero-step quantum touches no state)."""
        zeros = np.zeros(self.slots, np.int32)
        out, self.cache = self._step_fn(chunk)(
            self.params, self.cache, jnp.asarray(self.pt.table),
            jnp.zeros((self.slots, chunk), jnp.int32),
            jnp.asarray(np.ones(self.slots, np.int32)),
            jnp.asarray(zeros), jnp.asarray(zeros))
        jax.block_until_ready(out)

    # -- queue ---------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        rid = self._rid
        self._rid += 1
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32).ravel(),
                      max_new=int(max_new))
        self.submit_request(req)
        return rid

    def submit_request(self, req: Request) -> None:
        """Submit a pre-built request, preserving its rid — the failover
        path: a drained replica's requests re-admit here with their
        generated prefix folded into the prompt."""
        assert len(req.prompt) + req.max_new <= \
            self.cache_cfg.max_pages_per_seq * self.cache_cfg.page_size, \
            f"request {req.rid} exceeds max_seq"
        self._rid = max(self._rid, req.rid + 1)
        self.scheduler.submit(req, self.metrics)

    def drain(self) -> list[tuple[Request, list[int]]]:
        """Evacuate a dead replica: free every in-flight request's page
        chain and hand back [(request, generated_prefix)] rebuilt for a
        survivor (scheduler.drain).  Finished requests stay in
        ``self.results``; the caller stitches prefix + survivor output
        for the rest."""
        return self.scheduler.drain(self.pt)

    # -- the step loop -------------------------------------------------------

    def run(self) -> dict[int, np.ndarray]:
        """Serve the queue to completion; returns rid -> generated tokens.
        The schedule decision (and any online correction) is visible in
        ``managed.decision_log()`` as ``op="serve_schedule"`` records."""
        sch = self.scheduler
        if not sch.has_work():
            return {}
        sch.decide(self._n_params, self._dtype_bytes,
                   dtype_str=self.model.cfg.dtype)
        self.warmup(sch.chunk)
        # compilation is over: TTFT measures serving from here, and the
        # running variant's measurement window starts empty
        self.metrics.rebase_pending()
        self._variant_q0 = len(self.metrics.quanta)
        results = self.results
        while sch.has_work():
            sch.admit(self.pt)
            plan = sch.plan_quantum(sch.chunk)
            if int(plan.steps.sum()) == 0:
                # admit() ran just above with an empty batch and still
                # produced nothing: the head request can never fit
                raise RuntimeError(
                    "serve queue stalled: request exceeds the page pool "
                    f"({self.cache_cfg})")
            for slot, rs in sch.active.items():
                self.pt.ensure(slot,
                               rs.consumed + int(plan.steps[slot]))
            if self.fault_plan is not None:
                # the fault clock ticks on dispatched quanta; a
                # replica_death here leaves finished work in self.results
                # and in-flight state intact for drain()
                self.fault_plan.serve_quantum(self._quantum_idx)
            self._quantum_idx += 1
            t0 = time.perf_counter()
            out, self.cache = self._step_fn(plan.chunk)(
                self.params, self.cache, jnp.asarray(self.pt.table),
                jnp.asarray(plan.tokens), jnp.asarray(plan.n_in),
                jnp.asarray(plan.pos), jnp.asarray(plan.steps))
            out_np = np.asarray(out)
            wall = time.perf_counter() - t0
            self.metrics.note_quantum(wall, plan.chunk,
                                      int(plan.steps.sum()), self.slots)
            for rs in sch.complete_quantum(plan, out_np, self.pt,
                                           self.metrics):
                results[rs.req.rid] = np.asarray(rs.generated, np.int32)
            prev = (sch.mode, sch.chunk)
            self._maybe_retune()
            if sch.has_work() and (sch.mode, sch.chunk) != prev:
                # the correction changed the schedule: compile the new
                # quantum OUTSIDE the measured loop, keep the compile out
                # of still-queued requests' TTFT, and start a fresh
                # measurement window for the new variant
                self.warmup(sch.chunk)
                self.metrics.rebase_pending()
                self._variant_q0 = len(self.metrics.quanta)
        return results

    def _maybe_retune(self) -> None:
        """The iteration-(k)->(k+1) correction: once enough quanta are
        measured, re-resolve the schedule with the observed step/dispatch
        seconds, and feed the running variant's measured seconds-per-token
        to the tuner (so a persisted winner survives restarts).  The
        variant is only credited with quanta from its OWN measurement
        window (``_variant_q0``) — cumulative throughput would attribute
        the previous variant's behaviour to the current one."""
        sch = self.scheduler
        tok_s = self.metrics.useful_tokens_per_s(since=self._variant_q0)
        if sch.tuner is not None and sch.tuner_key and tok_s > 0:
            sch.tuner.record(sch.tuner_key, sch.mode, sch.chunk,
                             1.0 / tok_s)
        if self._schedule != "auto" or self._retuned \
                or len(self.metrics.quanta) < 3:
            return
        self._retuned = True
        sch.decide(self._n_params, self._dtype_bytes,
                   dtype_str=self.model.cfg.dtype,
                   measured_step_s=self.metrics.step_s_estimate(),
                   measured_dispatch_s=self.metrics.dispatch_s_estimate())
