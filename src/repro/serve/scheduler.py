"""Continuous-batching scheduler — admission, preemption, shedding.

Pure host logic (numpy only): the engine owns the device arrays, the
scheduler decides WHAT each quantum does.  Every engine step advances
each active slot by one token — a slot still consuming its prompt is
"chunked prefill", a slot past it is decoding — so the prefill:decode
mix of a step is exactly the mix of slot phases, and the scheduler
controls it through admission.

The request lifecycle under load:

  submit      — feasibility first: a request whose page need exceeds the
                whole pool (or the table width) can never run and is
                rejected with the typed ``RequestRejected`` instead of
                livelocking admission; a full pending queue
                (``max_queue``) or a cost-model TTFT estimate beyond the
                request's SLO sheds it with ``RequestShed`` —
                backpressure and graceful degradation, never a crash.
  admit       — watermark-based OPTIMISTIC admission: only the prompt's
                pages are committed up front (decode pages are claimed
                on demand as positions cross page boundaries), so
                occupancy rises well above the old upfront
                prompt+max_new reservation.  ``admission="commit"``
                keeps the conservative reservation (the seed baseline).
  preempt     — the backstop for optimistic admission: when the pool
                exhausts mid-decode (``PagePoolExhausted``), the engine
                picks a victim (most pages held, then least progress)
                and either swaps its page chain to host, drops it for
                prefill-replay (``continuation`` — the drain() idiom),
                or stalls the growing slot for a quantum — the policy
                is a managed decision (``managed.resolve_preempt``,
                ``DecisionRecord(op="preempt_policy")``).
  retire      — finished requests return slot + pages to the free lists
                at quantum boundaries (continuous mode refills them
                immediately).

The batching knobs (mode + scheduling quantum C) come from
``managed.resolve_serve_schedule``: seeded from the alpha-beta serve
model, re-resolved mid-run with the measured step/dispatch seconds from
serve/metrics.py, optionally pinned by a ``ScheduleTuner`` measured
winner.  Every resolve lands in the MDMP decision log.

  static      — admit a wave, run it to completion, admit the next wave
                (the unmanaged baseline = the seed Generator's behaviour:
                every request pads to the wave's longest).
  continuous  — refill freed slots from the queue at every quantum
                boundary; pages released by finished requests are reused
                immediately (kv_cache.py free list).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np

from repro.core import managed
from repro.serve.kv_cache import PageTable
from repro.serve.metrics import ServeMetrics


class RequestRejected(RuntimeError):
    """The request can NEVER be served by this pool/table geometry —
    rejected at submit() instead of livelocking admission forever."""


class RequestShed(RequestRejected):
    """The request was shed by admission control: the pending queue is
    full (backpressure) or the queue-wait estimate exceeds its TTFT SLO.
    Typed so callers degrade gracefully — overload never crashes."""


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    prompt: np.ndarray            # [P] int32
    max_new: int
    ttft_slo_s: float | None = None   # per-request TTFT target

    @property
    def total_steps(self) -> int:
        """Engine steps to finish: feed P prompt tokens, sample max_new
        (the P-th input's output is the first generated token)."""
        return len(self.prompt) + self.max_new - 1


@dataclasses.dataclass
class RequestState:
    req: Request
    slot: int
    consumed: int = 0             # engine steps done (= cache positions)
    last_out: int = 0             # last sampled token (chain seed)
    generated: list[int] = dataclasses.field(default_factory=list)
    committed: int = 0            # pages committed at admission

    @property
    def done(self) -> bool:
        return self.consumed >= self.req.total_steps


@dataclasses.dataclass(frozen=True)
class QuantumPlan:
    """Device inputs for one dispatched quantum of C engine steps."""
    tokens: np.ndarray            # [slots, C] int32 input-token buffer
    n_in: np.ndarray              # [slots] provided input tokens (>= 1)
    pos: np.ndarray               # [slots] starting positions
    steps: np.ndarray             # [slots] valid steps this quantum
    chunk: int


class ServeScheduler:
    def __init__(self, slots: int, *, schedule: str = "auto",
                 chunk: int | None = None, tuner: Any = None,
                 axis_name: str = "serve", cache_cfg: Any = None,
                 admission: str = "watermark", watermark: int = 0,
                 slo_ttft_s: float | None = None,
                 max_queue: int | None = None,
                 model_step_s: float | None = None):
        assert schedule in ("auto", "static", "continuous"), schedule
        assert admission in ("watermark", "commit"), admission
        self.slots = slots
        self.schedule = schedule
        self._pinned_chunk = chunk
        self.tuner = tuner
        self.axis_name = axis_name
        self.cache_cfg = cache_cfg
        self.admission = admission
        self.watermark = int(watermark)
        self.slo_ttft_s = slo_ttft_s
        self.max_queue = max_queue
        self.model_step_s = model_step_s
        self.pending: deque[Request] = deque()
        self.active: dict[int, RequestState] = {}
        self._free_slots = list(range(slots - 1, -1, -1))
        self._committed_pages = 0
        #: rid -> pages an evicted (swapped) request needs back before
        #: re-admission (set by the engine's swap path)
        self.restore_pages: dict[int, int] = {}
        self.mode: str | None = None
        self.chunk: int | None = None
        self.decision = None
        self.tuner_key: str | None = None

    # -- the managed decision ------------------------------------------------

    def decide(self, n_params: int, dtype_bytes: int, *,
               dtype_str: str = "bfloat16",
               measured_step_s: float | None = None,
               measured_dispatch_s: float | None = None) -> None:
        """(Re-)resolve the batching mode and quantum from the queue's
        statistics — seeded from the cost model, corrected by measured
        step latencies, logged in the MDMP decision trail."""
        reqs = list(self.pending) + [s.req for s in self.active.values()]
        if not reqs:
            return
        prompts = [len(r.prompt) for r in reqs]
        news = [r.max_new for r in reqs]
        pin_mode = None if self.schedule == "auto" else self.schedule
        pin_chunk = self._pinned_chunk
        if self.tuner is not None:
            entry = self.tuner.decide_serve(
                self.slots, int(np.mean(prompts)), int(np.mean(news)),
                int(n_params), dtype_str=dtype_str,
                dtype_bytes=dtype_bytes, max_prompt=int(np.max(prompts)))
            self.tuner_key = entry.key
            if pin_mode is None and len(entry.measured_s) >= 2:
                # a measured COMPARISON (>= 2 variants trialled) overrides
                # the model seed; one measurement is just the status quo
                # and must not lock out the online correction
                pin_mode = entry.mode
                if pin_chunk is None:
                    pin_chunk = entry.chunks
        self.decision = managed.resolve_serve_schedule(
            self.axis_name, self.slots, float(np.mean(prompts)),
            float(np.mean(news)), float(n_params),
            dtype_bytes=dtype_bytes, max_prompt=float(np.max(prompts)),
            measured_step_s=measured_step_s,
            measured_dispatch_s=measured_dispatch_s,
            schedule=pin_mode, chunk=pin_chunk)
        self.mode = self.decision.mode
        self.chunk = self.decision.chunk

    # -- queue wait / SLO estimates ------------------------------------------

    def step_s_hint(self, metrics: ServeMetrics | None = None
                    ) -> float | None:
        """Best available per-engine-step seconds: measured if any quanta
        have run, else the roofline seed the engine installed."""
        step = metrics.step_s_estimate() if metrics is not None else None
        return step if step is not None else self.model_step_s

    def estimate_queue_wait_s(self, metrics: ServeMetrics | None = None
                              ) -> float | None:
        """Head-of-line wait for a NEW request: the backlog's remaining
        engine steps spread over the slots at the current step rate —
        the instrumented queue statistic the shed decision prices."""
        step = self.step_s_hint(metrics)
        if step is None:
            return None
        backlog = sum(rs.req.total_steps - rs.consumed
                      for rs in self.active.values())
        backlog += sum(r.total_steps for r in self.pending)
        return backlog * step / max(1, self.slots)

    def estimate_ttft_s(self, req: Request,
                        metrics: ServeMetrics | None = None
                        ) -> float | None:
        wait = self.estimate_queue_wait_s(metrics)
        step = self.step_s_hint(metrics)
        if wait is None or step is None:
            return None
        return wait + len(req.prompt) * step

    # -- queue ---------------------------------------------------------------

    def submit(self, req: Request, metrics: ServeMetrics | None = None
               ) -> None:
        """Admission control at the queue door: feasibility (typed
        ``RequestRejected``), backpressure and SLO shedding (typed
        ``RequestShed``) — then enqueue."""
        assert len(req.prompt) >= 1 and req.max_new >= 1, req
        cfg = self.cache_cfg
        if cfg is not None:
            need = cfg.pages_needed(req.total_steps)
            if need > cfg.max_pages_per_seq:
                raise RequestRejected(
                    f"request {req.rid} needs {need} pages "
                    f"> {cfg.max_pages_per_seq}-page table (max_seq)")
            if need > cfg.n_pages:
                raise RequestRejected(
                    f"request {req.rid} needs {need} pages > the whole "
                    f"{cfg.n_pages}-page pool — it can never be admitted")
        if self.max_queue is not None \
                and len(self.pending) >= self.max_queue:
            if metrics is not None:
                metrics.on_shed(req.rid, "queue_full")
            raise RequestShed(
                f"request {req.rid} shed: pending queue at max_queue="
                f"{self.max_queue}")
        slo = req.ttft_slo_s if req.ttft_slo_s is not None \
            else self.slo_ttft_s
        if slo is not None:
            est = self.estimate_ttft_s(req, metrics)
            if est is not None and est > slo:
                if metrics is not None:
                    metrics.on_shed(req.rid, "slo")
                raise RequestShed(
                    f"request {req.rid} shed: estimated TTFT "
                    f"{est * 1e3:.1f}ms > SLO {slo * 1e3:.1f}ms")
        self.pending.append(req)
        if metrics is not None:
            metrics.on_submit(req.rid, len(req.prompt), req.max_new)

    def has_work(self) -> bool:
        return bool(self.pending or self.active)

    # -- admission -----------------------------------------------------------

    def admit(self, pt: PageTable,
              hold: frozenset[int] | set[int] = frozenset()
              ) -> list[RequestState]:
        """Move queued requests into free slots (page-budget permitting).
        Static mode only admits into an EMPTY batch — the wave barrier.

        Watermark admission commits only the pages the head request needs
        to START (its prompt — or its restored chain for a swapped-out
        victim); decode growth is claimed on demand, the preemption path
        is the backstop.  Commit admission reserves prompt+max_new up
        front (the conservative seed behaviour, kept as a baseline).

        ``hold`` rids stop admission at the head of the queue: a freshly
        evicted victim must not re-enter the batch before the quantum
        that its pages were freed FOR has dispatched, or eviction and
        re-admission chase each other without progress."""
        if self.mode == "static" and self.active:
            return []
        newly: list[RequestState] = []
        while self.pending and self._free_slots:
            req = self.pending[0]
            if req.rid in hold:
                break                     # evicted this round: not yet
            if self.admission == "commit":
                need = pt.cfg.pages_needed(len(req.prompt) + req.max_new)
                if self._committed_pages + need > pt.usable_pages:
                    break                 # no page budget: wait for frees
            else:
                need = max(pt.cfg.pages_needed(len(req.prompt)),
                           self.restore_pages.get(req.rid, 0))
                if pt.free_pages < need + self.watermark:
                    break                 # below the watermark: wait
            self.pending.popleft()
            slot = self._free_slots.pop()
            rs = RequestState(req=req, slot=slot, committed=need)
            self.active[slot] = rs
            self._committed_pages += need
            newly.append(rs)
        return newly

    # -- preemption ----------------------------------------------------------

    def select_victim(self, pt: PageTable,
                      prefer_not: int | None = None) -> int | None:
        """Pick the slot to evict when the pool exhausts: most pages held
        first (frees the most), then least progress (cheapest to replay),
        then lowest slot — deterministic.  ``prefer_not`` (the slot that
        needs to grow) only loses its immunity when it is the sole
        candidate."""
        cands = [(pt.pages_held(s), -rs.consumed, -s)
                 for s, rs in self.active.items()
                 if pt.pages_held(s) > 0 and s != prefer_not]
        if not cands and prefer_not in self.active \
                and pt.pages_held(prefer_not) > 0:
            return prefer_not
        if not cands:
            return None
        return -max(cands)[2]

    def preempt(self, slot: int, pt: PageTable) -> RequestState:
        """Evict ``slot``: release its page chain, free the slot, and
        hand its state back to the engine (which swaps or rebuilds it).
        The victim is NOT requeued here — the policy decides how."""
        rs = self.active.pop(slot)
        pt.release(slot)
        self._free_slots.append(slot)
        self._committed_pages -= rs.committed
        return rs

    def requeue_front(self, req: Request) -> None:
        """Put a preempted request at the head of the queue so it
        re-admits as soon as its pages are available again."""
        self.pending.appendleft(req)

    @staticmethod
    def continuation(rs: RequestState) -> Request | None:
        """Rebuild an evicted request as a prompt+generated continuation
        (prefill REPLAYS the progress; greedy decoding continues the
        exact chain — total_steps is conserved: (P+g)+(N-g)-1 = P+N-1).
        Returns None when the request is already finished
        (``generated == max_new``): rebuilding it would need max_new=0,
        which submit rejects — retire it instead."""
        if len(rs.generated) >= rs.req.max_new:
            return None
        if not rs.generated:
            return rs.req
        return Request(
            rid=rs.req.rid,
            prompt=np.concatenate(
                [rs.req.prompt, np.asarray(rs.generated, np.int32)]),
            max_new=rs.req.max_new - len(rs.generated),
            ttft_slo_s=rs.req.ttft_slo_s)

    # -- failover ------------------------------------------------------------

    def drain(self, pt: PageTable,
              results: dict[int, np.ndarray] | None = None
              ) -> list[tuple[Request, list[int]]]:
        """Evacuate this (dead) replica's work for re-admission elsewhere.

        Every in-flight request's page chain returns to the free list and
        the request is rebuilt as a continuation (``continuation``);
        a request whose generated prefix already equals max_new is
        RETIRED into ``results`` instead of rebuilt (the max_new=0 rebuild
        used to trip submit's assert on re-admission).  Pending requests
        pass through unchanged.  Returns [(request, generated_prefix)] in
        admission order; the caller stitches prefix + survivor output.
        """
        out: list[tuple[Request, list[int]]] = []
        for slot, rs in sorted(self.active.items()):
            pt.release(slot)
            req = self.continuation(rs)
            if req is None:
                if results is not None:
                    results[rs.req.rid] = np.asarray(rs.generated,
                                                     np.int32)
                continue
            out.append((req, list(rs.generated)))
        out.extend((req, []) for req in self.pending)
        self.active.clear()
        self.pending.clear()
        self._free_slots = list(range(self.slots - 1, -1, -1))
        self._committed_pages = 0
        return out

    # -- quantum planning / retirement ---------------------------------------

    def plan_quantum(self, chunk: int) -> QuantumPlan:
        c = max(1, int(chunk))
        tokens = np.zeros((self.slots, c), np.int32)
        n_in = np.ones(self.slots, np.int32)
        pos = np.zeros(self.slots, np.int32)
        steps = np.zeros(self.slots, np.int32)
        for slot, rs in self.active.items():
            p = len(rs.req.prompt)
            steps[slot] = min(c, rs.req.total_steps - rs.consumed)
            pos[slot] = rs.consumed
            if rs.consumed < p:           # chunked prefill: prompt inputs
                n = min(int(steps[slot]), p - rs.consumed)
                n_in[slot] = n
                tokens[slot, :n] = rs.req.prompt[rs.consumed:rs.consumed + n]
            else:                         # decoding: chain from last sample
                n_in[slot] = 1
                tokens[slot, 0] = rs.last_out
        return QuantumPlan(tokens=tokens, n_in=n_in, pos=pos, steps=steps,
                           chunk=c)

    def complete_quantum(self, plan: QuantumPlan, out: np.ndarray,
                         pt: PageTable, metrics: ServeMetrics
                         ) -> list[RequestState]:
        """Fold the quantum's sampled tokens back into request state;
        retire finished requests (slots + pages return to the free
        lists)."""
        finished: list[RequestState] = []
        for slot, rs in list(self.active.items()):
            n = int(plan.steps[slot])
            if n == 0:
                continue
            p = len(rs.req.prompt)
            before = len(rs.generated)
            for t in range(n):
                g = rs.consumed + t       # global engine-step index
                if g >= p - 1 and len(rs.generated) < rs.req.max_new:
                    rs.generated.append(int(out[slot, t]))
            delta = len(rs.generated) - before
            if delta:
                if before == 0:
                    metrics.on_first_token(rs.req.rid)
                metrics.on_generated(rs.req.rid, delta)
            rs.last_out = int(out[slot, n - 1])
            rs.consumed += n
            if rs.done:
                metrics.on_done(rs.req.rid)
                finished.append(rs)
                del self.active[slot]
                self._free_slots.append(slot)
                self._committed_pages -= rs.committed
                pt.release(slot)
        return finished
