"""Continuous-batching scheduler — admission, quantum planning, retirement.

Pure host logic (numpy only): the engine owns the device arrays, the
scheduler decides WHAT each quantum does.  Every engine step advances
each active slot by one token — a slot still consuming its prompt is
"chunked prefill" (its inputs come from the prompt), a slot past the
prompt is decoding (its input is its own last sample) — so the
prefill:decode mix of a step is exactly the mix of slot phases, and the
scheduler controls it through admission.

The managed knobs (batching mode + scheduling quantum C) come from
``managed.resolve_serve_schedule``: seeded from the alpha-beta serve
model, re-resolved mid-run with the measured step/dispatch seconds from
serve/metrics.py, optionally pinned by a ``ScheduleTuner`` measured
winner.  Every resolve lands in the MDMP decision log
(``DecisionRecord(op="serve_schedule")``).

  static      — admit a wave, run it to completion, admit the next wave
                (the unmanaged baseline = the seed Generator's behaviour:
                every request pads to the wave's longest).
  continuous  — refill freed slots from the queue at every quantum
                boundary; pages released by finished requests are reused
                immediately (kv_cache.py free list).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np

from repro.core import managed
from repro.serve.kv_cache import PageTable
from repro.serve.metrics import ServeMetrics


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    prompt: np.ndarray            # [P] int32
    max_new: int

    @property
    def total_steps(self) -> int:
        """Engine steps to finish: feed P prompt tokens, sample max_new
        (the P-th input's output is the first generated token)."""
        return len(self.prompt) + self.max_new - 1


@dataclasses.dataclass
class RequestState:
    req: Request
    slot: int
    consumed: int = 0             # engine steps done (= cache positions)
    last_out: int = 0             # last sampled token (chain seed)
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.consumed >= self.req.total_steps


@dataclasses.dataclass(frozen=True)
class QuantumPlan:
    """Device inputs for one dispatched quantum of C engine steps."""
    tokens: np.ndarray            # [slots, C] int32 input-token buffer
    n_in: np.ndarray              # [slots] provided input tokens (>= 1)
    pos: np.ndarray               # [slots] starting positions
    steps: np.ndarray             # [slots] valid steps this quantum
    chunk: int


class ServeScheduler:
    def __init__(self, slots: int, *, schedule: str = "auto",
                 chunk: int | None = None, tuner: Any = None,
                 axis_name: str = "serve"):
        assert schedule in ("auto", "static", "continuous"), schedule
        self.slots = slots
        self.schedule = schedule
        self._pinned_chunk = chunk
        self.tuner = tuner
        self.axis_name = axis_name
        self.pending: deque[Request] = deque()
        self.active: dict[int, RequestState] = {}
        self._free_slots = list(range(slots - 1, -1, -1))
        self._committed_pages = 0
        self.mode: str | None = None
        self.chunk: int | None = None
        self.decision = None
        self.tuner_key: str | None = None

    # -- the managed decision ------------------------------------------------

    def decide(self, n_params: int, dtype_bytes: int, *,
               dtype_str: str = "bfloat16",
               measured_step_s: float | None = None,
               measured_dispatch_s: float | None = None) -> None:
        """(Re-)resolve the batching mode and quantum from the queue's
        statistics — seeded from the cost model, corrected by measured
        step latencies, logged in the MDMP decision trail."""
        reqs = list(self.pending) + [s.req for s in self.active.values()]
        if not reqs:
            return
        prompts = [len(r.prompt) for r in reqs]
        news = [r.max_new for r in reqs]
        pin_mode = None if self.schedule == "auto" else self.schedule
        pin_chunk = self._pinned_chunk
        if self.tuner is not None:
            entry = self.tuner.decide_serve(
                self.slots, int(np.mean(prompts)), int(np.mean(news)),
                int(n_params), dtype_str=dtype_str,
                dtype_bytes=dtype_bytes, max_prompt=int(np.max(prompts)))
            self.tuner_key = entry.key
            if pin_mode is None and len(entry.measured_s) >= 2:
                # a measured COMPARISON (>= 2 variants trialled) overrides
                # the model seed; one measurement is just the status quo
                # and must not lock out the online correction
                pin_mode = entry.mode
                if pin_chunk is None:
                    pin_chunk = entry.chunks
        self.decision = managed.resolve_serve_schedule(
            self.axis_name, self.slots, float(np.mean(prompts)),
            float(np.mean(news)), float(n_params),
            dtype_bytes=dtype_bytes, max_prompt=float(np.max(prompts)),
            measured_step_s=measured_step_s,
            measured_dispatch_s=measured_dispatch_s,
            schedule=pin_mode, chunk=pin_chunk)
        self.mode = self.decision.mode
        self.chunk = self.decision.chunk

    # -- queue ---------------------------------------------------------------

    def submit(self, req: Request, metrics: ServeMetrics | None = None
               ) -> None:
        assert len(req.prompt) >= 1 and req.max_new >= 1, req
        self.pending.append(req)
        if metrics is not None:
            metrics.on_submit(req.rid, len(req.prompt), req.max_new)

    def has_work(self) -> bool:
        return bool(self.pending or self.active)

    # -- admission -----------------------------------------------------------

    def admit(self, pt: PageTable) -> list[RequestState]:
        """Move queued requests into free slots (page-budget permitting).
        Static mode only admits into an EMPTY batch — the wave barrier."""
        if self.mode == "static" and self.active:
            return []
        newly: list[RequestState] = []
        while self.pending and self._free_slots:
            req = self.pending[0]
            need = pt.cfg.pages_needed(len(req.prompt) + req.max_new)
            if self._committed_pages + need > pt.cfg.n_pages:
                break                     # no page budget: wait for frees
            self.pending.popleft()
            slot = self._free_slots.pop()
            rs = RequestState(req=req, slot=slot)
            self.active[slot] = rs
            self._committed_pages += need
            newly.append(rs)
        return newly

    # -- failover ------------------------------------------------------------

    def drain(self, pt: PageTable) -> list[tuple[Request, list[int]]]:
        """Evacuate this (dead) replica's work for re-admission elsewhere.

        Every in-flight request's page chain returns to the free list and
        the request is rebuilt for a survivor: prompt' = prompt + the
        tokens already generated here, max_new' = the remainder — so the
        survivor's prefill REPLAYS the dead replica's progress and greedy
        decoding continues the exact chain (total_steps is conserved:
        (P + g) + (N - g) - 1 = P + N - 1).  Pending requests pass
        through unchanged.  Returns [(request, generated_prefix)] in
        admission order; the caller stitches prefix + survivor output.
        """
        out: list[tuple[Request, list[int]]] = []
        for slot, rs in sorted(self.active.items()):
            pt.release(slot)
            prefix = list(rs.generated)
            if prefix:
                req = Request(
                    rid=rs.req.rid,
                    prompt=np.concatenate(
                        [rs.req.prompt,
                         np.asarray(prefix, np.int32)]),
                    max_new=rs.req.max_new - len(prefix))
            else:
                req = rs.req
            out.append((req, prefix))
        out.extend((req, []) for req in self.pending)
        self.active.clear()
        self.pending.clear()
        self._free_slots = list(range(self.slots - 1, -1, -1))
        self._committed_pages = 0
        return out

    # -- quantum planning / retirement ---------------------------------------

    def plan_quantum(self, chunk: int) -> QuantumPlan:
        c = max(1, int(chunk))
        tokens = np.zeros((self.slots, c), np.int32)
        n_in = np.ones(self.slots, np.int32)
        pos = np.zeros(self.slots, np.int32)
        steps = np.zeros(self.slots, np.int32)
        for slot, rs in self.active.items():
            p = len(rs.req.prompt)
            steps[slot] = min(c, rs.req.total_steps - rs.consumed)
            pos[slot] = rs.consumed
            if rs.consumed < p:           # chunked prefill: prompt inputs
                n = min(int(steps[slot]), p - rs.consumed)
                n_in[slot] = n
                tokens[slot, :n] = rs.req.prompt[rs.consumed:rs.consumed + n]
            else:                         # decoding: chain from last sample
                n_in[slot] = 1
                tokens[slot, 0] = rs.last_out
        return QuantumPlan(tokens=tokens, n_in=n_in, pos=pos, steps=steps,
                           chunk=c)

    def complete_quantum(self, plan: QuantumPlan, out: np.ndarray,
                         pt: PageTable, metrics: ServeMetrics
                         ) -> list[RequestState]:
        """Fold the quantum's sampled tokens back into request state;
        retire finished requests (slots + pages return to the free
        lists)."""
        finished: list[RequestState] = []
        for slot, rs in list(self.active.items()):
            n = int(plan.steps[slot])
            if n == 0:
                continue
            p = len(rs.req.prompt)
            before = len(rs.generated)
            for t in range(n):
                g = rs.consumed + t       # global engine-step index
                if g >= p - 1 and len(rs.generated) < rs.req.max_new:
                    rs.generated.append(int(out[slot, t]))
            delta = len(rs.generated) - before
            if delta:
                if before == 0:
                    metrics.on_first_token(rs.req.rid)
                metrics.on_generated(rs.req.rid, delta)
            rs.last_out = int(out[slot, n - 1])
            rs.consumed += n
            if rs.done:
                metrics.on_done(rs.req.rid)
                finished.append(rs)
                del self.active[slot]
                self._free_slots.append(slot)
                self._committed_pages -= pt.cfg.pages_needed(
                    p + rs.req.max_new)
                pt.release(slot)
        return finished
