"""Managed serving runtime: continuous batching over a paged KV cache.

The MDMP loop applied to serving: the scheduler's batching decisions are
the declared "messages", serve/metrics.py's step-latency counters are the
runtime instrumentation, and core/cost_model.py::decide_serve_schedule
turns iteration-k measurements into the iteration-(k+1) schedule.
"""

from repro.serve.engine import ServeEngine                    # noqa: F401
from repro.serve.kv_cache import PagedCacheConfig, PageTable  # noqa: F401
from repro.serve.metrics import ServeMetrics                  # noqa: F401
from repro.serve.scheduler import Request, ServeScheduler     # noqa: F401
