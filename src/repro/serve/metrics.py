"""Serving instrumentation — the runtime counters the scheduler plans from.

MDMP's contract is that iteration k's measured behaviour schedules
iteration k+1.  For serving the "iteration" is one dispatched quantum of
C engine steps: every quantum records its wall clock and how many
slot-steps did useful work, and the per-request traces record TTFT/TPOT.
``step_s_estimate`` / ``dispatch_s_estimate`` invert the quantum model
``wall = dispatch + C * step`` from those records; the scheduler feeds
them back into ``cost_model.decide_serve_schedule`` (via
``managed.resolve_serve_schedule(measured_*)``) to correct the modeled
roofline terms online.

The overload path adds three more instruments, all feeding the preempt/
shed decisions the same way: ``sheds`` (typed admission rejections and
their reasons), ``preempts`` (the victim/policy sequence — the
determinism tests compare it across runs), and ``swaps`` (measured D2H/
H2D bytes and seconds, whose ratio is the MEASURED PCIe bandwidth
``swap_bw_estimate`` that re-prices the swap-vs-recompute decision).
``p99_ttft_s`` / ``slo_met_tokens`` are the robustness headline numbers
(benchmarks/measured.py::bench_overload).
"""

from __future__ import annotations

import dataclasses
import math
import time

from repro.obs.registry import MetricsRegistry


@dataclasses.dataclass
class RequestTrace:
    rid: int
    submit_s: float
    n_prompt: int
    n_new: int
    first_token_s: float | None = None
    done_s: float | None = None
    generated: int = 0


@dataclasses.dataclass(frozen=True)
class QuantumRecord:
    wall_s: float
    chunk: int               # C — engine steps dispatched per slot
    useful_steps: int        # sum over slots of steps that advanced a slot
    slots: int


class ServeMetrics:
    """Counters and estimators ride the unified ``obs.MetricsRegistry``
    (one registry per ServeMetrics); the record lists (``quanta``,
    ``sheds``, ``preempts``) stay — the determinism tests compare their
    sequences, and the variant-window estimators slice them."""

    def __init__(self, registry: MetricsRegistry | None = None):
        self._t0 = time.perf_counter()
        self.reg = registry if registry is not None else MetricsRegistry()
        self.quanta: list[QuantumRecord] = []
        self.traces: dict[int, RequestTrace] = {}
        self.sheds: list[tuple[int, str]] = []      # (rid, reason)
        self.preempts: list[tuple[int, str]] = []   # (rid, policy)
        self._swap_bytes = self.reg.counter("serve.swap_bytes")
        self._swap_s = self.reg.counter("serve.swap_s")
        # "the min is the noise-robust estimator on a shared host"
        self._step_min = self.reg.extremum("serve.step_s", kind="min")
        self._quantum_wall = self.reg.histogram("serve.quantum_wall_s")

    # registry-backed counters, exposed under their historical names
    @property
    def swap_bytes(self) -> int:
        return int(self._swap_bytes.value)

    @property
    def swap_s(self) -> float:
        return float(self._swap_s.value)

    def now(self) -> float:
        return time.perf_counter() - self._t0

    # -- recording -----------------------------------------------------------

    def on_submit(self, rid: int, n_prompt: int, n_new: int) -> None:
        self.traces[rid] = RequestTrace(rid=rid, submit_s=self.now(),
                                        n_prompt=n_prompt, n_new=n_new)

    def on_first_token(self, rid: int) -> None:
        t = self.traces[rid]
        if t.first_token_s is None:
            t.first_token_s = self.now()

    def on_generated(self, rid: int, n: int = 1) -> None:
        self.traces[rid].generated += n

    def on_done(self, rid: int) -> None:
        self.traces[rid].done_s = self.now()

    def on_shed(self, rid: int, reason: str) -> None:
        """An admission rejection (queue_full / slo / infeasible)."""
        self.sheds.append((rid, reason))
        self.reg.counter(f"serve.shed.{reason}").add()

    def on_preempt(self, rid: int, policy: str) -> None:
        """A preemption event — the (victim, policy) sequence is the
        determinism contract of the overload fault kinds."""
        self.preempts.append((rid, policy))
        self.reg.counter(f"serve.preempt.{policy}").add()

    def note_swap(self, nbytes: int, seconds: float) -> None:
        """One swap transfer leg (D2H or H2D) — accumulates the measured
        PCIe bandwidth that re-prices decide_preempt online."""
        self._swap_bytes.add(int(nbytes))
        self._swap_s.add(float(seconds))

    def note_quantum(self, wall_s: float, chunk: int, useful_steps: int,
                     slots: int) -> None:
        self.quanta.append(QuantumRecord(wall_s, chunk, useful_steps,
                                         slots))
        self._step_min.observe(wall_s / max(1, chunk))
        self._quantum_wall.observe(wall_s)

    def rebase_pending(self) -> None:
        """Move not-yet-served requests' submit times to 'now' — called
        after jit warmup so TTFT measures scheduling, not compilation."""
        now = self.now()
        for t in self.traces.values():
            if t.first_token_s is None:
                t.submit_s = max(t.submit_s, now)

    # -- estimates fed back into the cost model ------------------------------

    def step_s_estimate(self) -> float | None:
        """Per-engine-step seconds (whole batch): running min over quanta
        of wall/C (an ``obs.registry.Extremum``) — the min is the
        noise-robust estimator on a shared host and absorbs the least
        dispatch overhead."""
        return self._step_min.value

    def dispatch_s_estimate(self) -> float | None:
        """Per-quantum overhead left after charging C * step_s."""
        step = self.step_s_estimate()
        if step is None or len(self.quanta) < 2:
            return None
        rest = sorted(max(0.0, q.wall_s - q.chunk * step)
                      for q in self.quanta)
        return rest[len(rest) // 2]

    def swap_bw_estimate(self) -> float | None:
        """Measured swap bandwidth (bytes/s over all transfer legs) —
        the PCIe term of the swap-vs-recompute decision, measured."""
        if self.swap_bytes <= 0 or self.swap_s <= 0:
            return None
        return self.swap_bytes / self.swap_s

    # -- aggregates ----------------------------------------------------------

    def useful_tokens_per_s(self, since: int = 0) -> float:
        """Useful slot-steps per wall second over ``quanta[since:]`` —
        pass the index where the current schedule variant started so a
        variant is only credited with its own quanta."""
        window = self.quanta[since:]
        wall = sum(q.wall_s for q in window)
        if wall <= 0:
            return 0.0
        return sum(q.useful_steps for q in window) / wall

    def occupancy(self) -> float:
        denom = sum(q.chunk * q.slots for q in self.quanta)
        if denom <= 0:
            return 0.0
        return sum(q.useful_steps for q in self.quanta) / denom

    def ttft_s(self) -> list[float]:
        return [t.first_token_s - t.submit_s for t in self.traces.values()
                if t.first_token_s is not None]

    def p99_ttft_s(self) -> float:
        xs = sorted(self.ttft_s())
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, max(0, math.ceil(0.99 * len(xs)) - 1))]

    def tpot_s(self) -> list[float]:
        out = []
        for t in self.traces.values():
            if t.done_s is not None and t.first_token_s is not None \
                    and t.generated > 1:
                out.append((t.done_s - t.first_token_s)
                           / (t.generated - 1))
        return out

    def slo_met_tokens(self, slo_ttft_s: float) -> int:
        """Tokens generated by COMPLETED requests whose TTFT met the SLO
        — the numerator of SLO-goodput (met tokens / wall second)."""
        tot = 0
        for t in self.traces.values():
            if t.done_s is not None and t.first_token_s is not None \
                    and (t.first_token_s - t.submit_s) <= slo_ttft_s:
                tot += t.generated
        return tot

    def summary(self) -> dict:
        ttft = self.ttft_s()
        tpot = self.tpot_s()
        return {
            "quanta": len(self.quanta),
            "useful_tok_s": self.useful_tokens_per_s(),
            "occupancy": self.occupancy(),
            "mean_ttft_s": sum(ttft) / len(ttft) if ttft else 0.0,
            "p99_ttft_s": self.p99_ttft_s(),
            "mean_tpot_s": sum(tpot) / len(tpot) if tpot else 0.0,
            "step_s": self.step_s_estimate() or 0.0,
            "dispatch_s": self.dispatch_s_estimate() or 0.0,
            "sheds": len(self.sheds),
            "preempts": len(self.preempts),
            "swap_bytes": self.swap_bytes,
        }
