"""Paged KV cache bookkeeping — host-side page tables + free-list.

The device side is a per-layer page POOL ([n_pages, page, KV, hd];
models/model.py::paged_cache_specs shards the page dim over the cache
axes).  This module owns the host side: which pool pages belong to which
decode slot, in order.  Allocation is on-demand (a page is claimed the
first time a slot's position crosses a page boundary) and completed
sequences return their whole chain to the free list, so pool memory
tracks the tokens actually resident — the contiguous decode cache it
replaces reserved ``slots * max_seq`` up front regardless of occupancy.

Unused table entries keep page id 0: the attention engines mask every
position beyond ``lens`` (kernels/paged_attention.py), so a dangling id
only has to be in range for the gather, never correct.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    slots: int                 # decode slots (batch rows)
    page_size: int             # tokens per page
    n_pages: int               # pool pages (global, across cache shards)
    max_pages_per_seq: int     # table width (= ceil(max_seq / page_size))

    def pages_needed(self, n_tokens: int) -> int:
        return max(0, math.ceil(n_tokens / self.page_size))


class PageTable:
    """Free-list page allocator + per-slot page chains."""

    def __init__(self, cfg: PagedCacheConfig):
        self.cfg = cfg
        # pop() hands out low page ids first (keeps early traffic on the
        # first cache shards — nice for eyeballing dumps, not load-bearing)
        self._free = list(range(cfg.n_pages - 1, -1, -1))
        self._owned: list[list[int]] = [[] for _ in range(cfg.slots)]
        self.table = np.zeros((cfg.slots, cfg.max_pages_per_seq), np.int32)
        self.high_water = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.cfg.n_pages - len(self._free)

    def pages_held(self, slot: int) -> int:
        return len(self._owned[slot])

    def can_fit(self, n_tokens: int) -> bool:
        return self.cfg.pages_needed(n_tokens) <= len(self._free)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow slot's chain to cover ``n_tokens`` positions."""
        need = self.cfg.pages_needed(n_tokens)
        assert need <= self.cfg.max_pages_per_seq, (
            f"slot {slot}: {n_tokens} tokens exceed the "
            f"{self.cfg.max_pages_per_seq}-page table")
        chain = self._owned[slot]
        while len(chain) < need:
            assert self._free, "page pool exhausted (admission bug)"
            pid = self._free.pop()
            self.table[slot, len(chain)] = pid
            chain.append(pid)
        self.high_water = max(self.high_water, self.pages_in_use)

    def release(self, slot: int) -> int:
        """Return slot's whole chain to the free list."""
        chain = self._owned[slot]
        n = len(chain)
        self._free.extend(reversed(chain))
        self._owned[slot] = []
        self.table[slot, :] = 0
        return n
