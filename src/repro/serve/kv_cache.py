"""Paged KV cache bookkeeping — host-side page tables + free-list.

The device side is a per-layer page POOL ([n_pages, page, KV, hd];
models/model.py::paged_cache_specs shards the page dim over the cache
axes).  This module owns the host side: which pool pages belong to which
decode slot, in order.  Allocation is on-demand (a page is claimed the
first time a slot's position crosses a page boundary) and completed
sequences return their whole chain to the free list, so pool memory
tracks the tokens actually resident — the contiguous decode cache it
replaces reserved ``slots * max_seq`` up front regardless of occupancy.

Running out of pages is an OVERLOAD condition, not a programming error:
``ensure`` raises the typed ``PagePoolExhausted`` and the engine reacts
(preempt a victim, or stall the growing slot for a quantum) instead of
dying on an assert.  ``squeeze`` shrinks the usable pool at runtime (the
``pool_squeeze`` fault kind — a co-tenant claiming HBM), quarantining
free pages now and collecting the remainder as chains release.

Unused table entries keep page id 0: the attention engines mask every
position beyond ``lens`` (kernels/paged_attention.py), so a dangling id
only has to be in range for the gather, never correct.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


class PagePoolExhausted(RuntimeError):
    """The free list cannot cover a requested chain growth — an overload
    signal the engine handles (preemption / stall), never a crash."""

    def __init__(self, slot: int, need: int, free: int):
        super().__init__(
            f"page pool exhausted: slot {slot} needs {need} more "
            f"page(s), {free} free")
        self.slot = slot
        self.need = need
        self.free = free


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    slots: int                 # decode slots (batch rows)
    page_size: int             # tokens per page
    n_pages: int               # pool pages (global, across cache shards)
    max_pages_per_seq: int     # table width (= ceil(max_seq / page_size))

    def pages_needed(self, n_tokens: int) -> int:
        return max(0, math.ceil(n_tokens / self.page_size))


class PageTable:
    """Free-list page allocator + per-slot page chains."""

    def __init__(self, cfg: PagedCacheConfig):
        self.cfg = cfg
        # pop() hands out low page ids first (keeps early traffic on the
        # first cache shards — nice for eyeballing dumps, not load-bearing)
        self._free = list(range(cfg.n_pages - 1, -1, -1))
        self._owned: list[list[int]] = [[] for _ in range(cfg.slots)]
        self.table = np.zeros((cfg.slots, cfg.max_pages_per_seq), np.int32)
        self.high_water = 0
        self._quarantined: list[int] = []   # squeezed-out pages
        self._squeeze_debt = 0              # pages still owed to a squeeze

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def usable_pages(self) -> int:
        """Pool capacity after any squeeze (allocated + free)."""
        return self.cfg.n_pages - len(self._quarantined) \
            - self._squeeze_debt

    @property
    def pages_in_use(self) -> int:
        return self.cfg.n_pages - len(self._free) - len(self._quarantined)

    def pages_held(self, slot: int) -> int:
        return len(self._owned[slot])

    def chain(self, slot: int) -> tuple[int, ...]:
        """Slot's page chain, in position order (the swap path reads the
        pool rows through this)."""
        return tuple(self._owned[slot])

    def can_fit(self, n_tokens: int) -> bool:
        return self.cfg.pages_needed(n_tokens) <= len(self._free)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow slot's chain to cover ``n_tokens`` positions.  Raises
        ``PagePoolExhausted`` (typed, recoverable) when the free list
        cannot cover the growth — the engine's preemption trigger."""
        need = self.cfg.pages_needed(n_tokens)
        assert need <= self.cfg.max_pages_per_seq, (
            f"slot {slot}: {n_tokens} tokens exceed the "
            f"{self.cfg.max_pages_per_seq}-page table")
        chain = self._owned[slot]
        if need - len(chain) > len(self._free):
            raise PagePoolExhausted(slot, need - len(chain),
                                    len(self._free))
        while len(chain) < need:
            pid = self._free.pop()
            self.table[slot, len(chain)] = pid
            chain.append(pid)
        self.high_water = max(self.high_water, self.pages_in_use)

    def release(self, slot: int) -> int:
        """Return slot's whole chain to the free list (less any pages a
        pending squeeze is still owed)."""
        chain = self._owned[slot]
        n = len(chain)
        back = list(reversed(chain))
        if self._squeeze_debt:
            take = min(self._squeeze_debt, len(back))
            self._quarantined.extend(back[:take])
            self._squeeze_debt -= take
            back = back[take:]
        self._free.extend(back)
        self._owned[slot] = []
        self.table[slot, :] = 0
        return n

    def squeeze(self, keep_frac: float) -> int:
        """Shrink the usable pool to ``keep_frac`` of its configured size
        (the ``pool_squeeze`` fault kind).  Free pages are quarantined
        immediately; if the free list is short, the deficit is collected
        from future releases.  Returns the number of pages removed from
        service (immediately or as debt)."""
        keep = max(0, min(1.0, float(keep_frac)))
        target = int(math.floor(self.cfg.n_pages * keep))
        remove = self.usable_pages - target
        if remove <= 0:
            return 0
        take = min(remove, len(self._free))
        # quarantine the pages that would be handed out LAST (the front
        # of the pop()-from-the-end free list) so near-term allocation
        # order is unchanged — determinism for the fault tests
        self._quarantined.extend(self._free[:take])
        del self._free[:take]
        self._squeeze_debt += remove - take
        return remove
