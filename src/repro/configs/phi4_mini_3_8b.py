"""Phi-4-mini-3.8B [dense]: 32L, d_model 3072, 24H GQA(kv=8), d_ff 8192,
vocab 200064, RoPE + SwiGLU.  [arXiv:2412.08905]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,           # padded to 32 for TP16
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    mlp="swiglu",
    rope_theta=10000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=256, tp_multiple=1)
