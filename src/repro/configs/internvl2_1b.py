"""InternVL2-1B [vlm]: InternLM2-backbone 24L, d_model 896, 14H GQA(kv=2),
d_ff 4864, vocab 151655.  InternViT frontend is a STUB per assignment:
input_specs provides precomputed patch embeddings.  [arXiv:2404.16821]"""

import dataclasses

from repro.configs.base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,            # padded to 16 for TP16
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    mlp="swiglu",
    rope_theta=1_000_000.0,
    vision=VisionConfig(n_patches=256),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=256, tp_multiple=1, vision=VisionConfig(n_patches=4))
