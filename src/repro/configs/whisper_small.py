"""Whisper-small [audio]: enc-dec, 12L each, d_model 768, 12H MHA,
d_ff 3072, vocab 51865.  Conv frontend is a STUB per assignment:
input_specs provides precomputed frame embeddings.  [arXiv:2212.04356]"""

import dataclasses

from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,           # decoder layers
    d_model=768,
    n_heads=12,            # padded to 16 for TP16
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp="gelu",
    encoder=EncoderConfig(n_layers=12, n_frames=1500),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=256, tp_multiple=1,
        encoder=EncoderConfig(n_layers=2, n_frames=16))
