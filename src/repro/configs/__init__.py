"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Each module defines ``CONFIG`` (exact published dims from the assignment)
and ``reduced()`` (tiny same-family variant for CPU smoke tests).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (SHAPES, EncoderConfig, ModelConfig, MoEConfig,
                                ShapeConfig, SSMConfig, VisionConfig,
                                shape_applicable)

ARCH_IDS = [
    "nemotron-4-340b",
    "granite-34b",
    "starcoder2-7b",
    "phi4-mini-3.8b",
    "mamba2-130m",
    "hymba-1.5b",
    "grok-1-314b",
    "moonshot-v1-16b-a3b",
    "whisper-small",
    "internvl2-1b",
]


def _module(arch_id: str):
    return importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _module(arch_id).CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    return _module(arch_id).reduced()


def list_archs() -> list[str]:
    return list(ARCH_IDS)


__all__ = ["ARCH_IDS", "SHAPES", "EncoderConfig", "ModelConfig", "MoEConfig",
           "ShapeConfig", "SSMConfig", "VisionConfig", "get_config",
           "get_reduced", "list_archs", "shape_applicable"]
