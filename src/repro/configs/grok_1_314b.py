"""Grok-1-314B [moe]: 64L, d_model 6144, 48H GQA(kv=8), MoE 8 experts top-2
with expert d_ff 32768, vocab 131072.  [hf:xai-org/grok-1]

8 experts on a TP16 axis -> expert-TP path (each expert's FFN sharded over
the model axis, capacity-limited local dispatch); see DESIGN.md §3.3.
"""

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=0,
    vocab_size=131072,
    mlp="geglu",  # gated GeLU expert FFN -> ~314B
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768, impl="expert_tp"),
    moment_dtype="bfloat16",
    accum_steps=4,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        vocab_size=256, accum_steps=1, moment_dtype="float32", tp_multiple=1,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, impl="expert_tp"))
