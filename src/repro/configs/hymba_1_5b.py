"""Hymba-1.5B [hybrid]: 32L, d_model 1600, 25H GQA(kv=5) in parallel with
mamba heads, d_ff 5504, vocab 32001, d_state 16, sliding-window attention
except 3 global layers.  [arXiv:2411.13676]"""

import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,           # padded to 32 for TP16
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    mlp="swiglu",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=64, chunk=256,
                  parallel_with_attn=True),
    sliding_window=1024,
    full_attn_layers=(0, 15, 31),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=256, sliding_window=16, full_attn_layers=(0,),
        tp_multiple=1,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=16, chunk=32,
                      parallel_with_attn=True))
