"""Granite-34B-Code [dense]: 88L, d_model 6144, 48H MQA(kv=1), d_ff 24576,
vocab 49152, llama-style arch.  [arXiv:2405.04324]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp="gelu",   # GPT-BigCode-style 2-matrix MLP -> ~34B
    rope_theta=10000.0,
    accum_steps=2,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=256,
        vocab_size=256, accum_steps=1, tp_multiple=1)
