"""Nemotron-4-340B [dense]: 96L, d_model 18432, 96H GQA(kv=8), d_ff 73728,
vocab 256000, squared-ReLU MLP, no-bias GQA.  [arXiv:2402.16819]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp="relu2",
    rope_theta=10000.0,
    # 340B-scale memory posture on a 256-chip pod: bf16 Adam moments +
    # deep gradient accumulation (DESIGN.md §3.1).
    moment_dtype="bfloat16",
    accum_steps=8,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=256, accum_steps=1, moment_dtype="float32", tp_multiple=1)
