"""Moonlight-16B-A3B [moe]: 48L, d_model 2048, 16H GQA(kv=16), MoE 64
experts top-6 with expert d_ff 1408, vocab 163840.
[hf:moonshotai/Moonlight-16B-A3B]

64 experts % TP16 == 0 -> expert-parallel all_to_all dispatch path.
"""

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=163840,
    mlp="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, impl="ep_a2a"),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        vocab_size=256, tp_multiple=1,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, impl="ep_a2a"))
