"""StarCoder2-7B [dense]: 32L, d_model 4608, 36H GQA(kv=4), d_ff 18432,
vocab 49152, RoPE.  [arXiv:2402.19173]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,           # padded to 48 for TP16 (DESIGN.md §3.3)
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    mlp="gelu",
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=256, tp_multiple=1)
