"""Model/config system.  One ``ModelConfig`` covers every assigned family
(dense / moe / ssm / hybrid / audio / vlm); per-arch files instantiate the
exact published dimensions and provide ``reduced()`` smoke-test variants.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.parallel.sharding import pad_to_multiple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # EP via all_to_all when n_experts % tp == 0, else expert-TP dense path
    impl: str = "auto"      # auto | ep_a2a | expert_tp
    # -- managed dispatch schedule (PR 5): how routed tokens cross the EP
    # axis.  "bulk" = one all_to_all into capacity buffers (the unmanaged
    # baseline); "stream" = capacity chunks ppermute'd around the EP ring
    # under the expert FFN; "dense" = no dispatch (every rank runs its
    # local experts on the full token set, reduce-scattered back); "auto"
    # = core/cost_model.decide_moe_dispatch picks (schedule, g,
    # capacity_factor) and logs the DecisionRecord -------------------------
    dispatch: str = "bulk"  # bulk | stream | dense | auto
    dispatch_g: int = 0     # stream chunk count (0 = cost-model pick)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    chunk: int = 256
    # Hymba-style hybrid: SSM output fused with attention in parallel heads
    parallel_with_attn: bool = False


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (conv frontend stubbed per assignment:
    input_specs provides precomputed frame embeddings)."""
    n_layers: int
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    """InternVL-style ViT frontend stub: precomputed patch embeddings are
    prepended to the token stream."""
    n_patches: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    mlp: str = "swiglu"              # swiglu | relu2 | gelu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    sliding_window: int = 0          # 0 = full attention
    # Hybrid archs: indices of layers using *full* attention (others SWA)
    full_attn_layers: tuple[int, ...] = ()
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # -- attention comm strategy: "megatron" (AG-matmul rings), "ulysses"
    # (a2a head/seq switch), "ring" (context parallelism: KV streamed
    # around 'model' under flash compute — O(S_loc) activation memory), or
    # "auto" (the managed runtime picks per call site from the cost model
    # and logs the DecisionRecord; EXPERIMENTS.md §Attention-schedules) ---
    attn_impl: str = "megatron"
    # -- training memory knobs ------------------------------------------------
    remat: bool = True
    accum_steps: int = 1             # gradient accumulation microbatches
    moment_dtype: str = "float32"    # bf16 for the 100B+ archs (DESIGN.md)
    # -- padding for TP divisibility (derived; see padded_* properties) -------
    tp_multiple: int = 16

    # ---- derived ----
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    @property
    def padded_heads(self) -> int:
        return pad_to_multiple(self.n_heads, self.tp_multiple) \
            if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, 128)

    @property
    def padded_ff(self) -> int:
        return pad_to_multiple(self.d_ff, self.tp_multiple) if self.d_ff else 0

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return pad_to_multiple(self.d_inner // self.ssm.headdim,
                               self.tp_multiple)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """May this arch run the long_500k shape?  SSM state is O(1);
        hybrid = SSM + sliding-window (few global layers, O(S) decode)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True   # every assigned arch decodes (whisper via its decoder)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), used for the
        6·N·D MODEL_FLOPS roofline term."""
        d = self.d_model
        n = 0
        n += self.padded_vocab * d                      # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * d                  # unembed
        per_layer = 0
        if self.family != "ssm":
            hd = self.head_dim
            per_layer += d * self.padded_heads * hd      # Wq
            per_layer += 2 * d * self.n_kv_heads * hd    # Wk, Wv
            per_layer += self.padded_heads * hd * d      # Wo
        mults = 3 if self.mlp in ("swiglu", "geglu") else 2
        if self.moe is not None:
            e = self.moe
            per_layer += d * e.n_experts                 # router
            per_layer += e.n_experts * mults * d * e.d_ff_expert
        elif self.d_ff:
            per_layer += mults * d * self.padded_ff
        if self.ssm is not None:
            di = self.ssm_heads * self.ssm.headdim
            per_layer += d * 2 * di                      # in_proj (x, z)
            per_layer += d * 2 * self.ssm.d_state        # B, C proj
            per_layer += d * self.ssm_heads              # dt proj
            per_layer += di * d                          # out_proj
        n += self.n_layers * per_layer
        if self.encoder is not None:
            # encoder blocks (attn + mlp) + decoder cross-attention
            hd = self.head_dim
            enc_layer = (d * self.padded_heads * hd * 2
                         + 2 * d * self.n_kv_heads * hd
                         + mults * d * self.padded_ff)
            n += self.encoder.n_layers * enc_layer
            n += self.n_layers * (d * self.padded_heads * hd * 2
                                  + 2 * d * self.n_kv_heads * hd)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        mults = 3 if self.mlp in ("swiglu", "geglu") else 2
        expert_params = self.n_layers * e.n_experts * mults * \
            self.d_model * e.d_ff_expert
        active_expert = expert_params * e.top_k / e.n_experts
        return self.param_count() - expert_params + int(active_expert)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every arch pairs with these four.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell, with the skip reason
    (DESIGN.md §3.3)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k decode state is "
                       "O(seq)-quadratic; skipped per assignment rules")
    return True, ""
