"""Mamba2-130M [ssm]: 24L, d_model 768, attention-free SSD,
vocab 50280, d_state 128.  [arXiv:2405.21060]"""

import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, chunk=256),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab_size=256, tp_multiple=1,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=16, chunk=32))
