"""Serving: prefill + decode step builders and a batched generation loop.

Prefill runs the SP flow (sequence-sharded); decode runs the TP-2D flow
with the KV cache sequence-sharded over (data x model) [x pod].  The two
use the SAME parameter layout — no weight resharding between phases
(DESIGN.md §3.1); only the cache is resharded once per sequence
(prefill layout [B(data), S(model)] -> decode layout [B replicated,
S(data x model)]), the standard prefill/decode disaggregation transfer.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import attention
from repro.models.model import Model
from repro.parallel.sharding import MeshCtx, smap, spec_pspecs

Array = jax.Array


def build_decode_step(model: Model, mesh: Mesh, shape: ShapeConfig
                      ) -> tuple[Callable, Any, Any]:
    """Returns (jitted step, cache ShapeDtypeStructs, cache NamedShardings).

    step(params, cache, token [B], pos []) -> (next_token [B], new cache)
    """
    ctx = model.ctx
    pspecs = spec_pspecs(model.param_specs())
    cache_sds, cache_pspecs = model.decode_cache_specs(shape)

    def body(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    sharded = smap(body, mesh,
                   in_specs=(pspecs, cache_pspecs, P(), P()),
                   out_specs=(P(), cache_pspecs))
    jitted = jax.jit(sharded, donate_argnums=(1,))
    cache_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                   cache_pspecs)
    return jitted, cache_sds, cache_shardings


def build_prefill_step(model: Model, mesh: Mesh) -> Callable:
    """step(params, batch) -> (last-token logits [B, V_loc], prefill cache)."""
    ctx = model.ctx
    cfg = model.cfg
    pspecs = spec_pspecs(model.param_specs())
    batch_axes = ctx.batch_axes
    batch_pspec = {"tokens": P(batch_axes, None)}
    if cfg.encoder is not None:
        batch_pspec["frames"] = P(batch_axes, None, None)
    if cfg.vision is not None:
        batch_pspec["patches"] = P(batch_axes, None, None)

    def body(params, batch):
        return model.prefill_sp(params, batch)

    # prefill cache layout: kv stacks [L?][B_loc, S_loc, KV, hd]
    kv_spec = P(batch_axes, "model", None, None)
    if model.scan_layers:
        kv_tree = P(None, *kv_spec) if cfg.n_layers else None
    else:
        kv_tree = [kv_spec for _ in range(cfg.n_layers)]

    def out_specs():
        cache_spec = {
            "kv": _kv_out_spec(model, kv_spec),
            "ssm": _ssm_out_spec(model),
            "enc_out": (P(batch_axes, "model", None)
                        if cfg.encoder is not None else P()),
        }
        return (P(batch_axes, "model"), cache_spec)

    sharded = smap(body, mesh, in_specs=(pspecs, batch_pspec),
                   out_specs=out_specs())
    return jax.jit(sharded)


def _kv_out_spec(model: Model, kv_spec: P):
    cfg = model.cfg
    if cfg.family == "ssm" or not cfg.n_heads:
        return None
    pair = (kv_spec, kv_spec)
    if model.scan_layers:
        stacked = P(None, *kv_spec)
        return (stacked, stacked)
    return [pair for _ in range(cfg.n_layers)]


def _ssm_out_spec(model: Model):
    cfg = model.cfg
    ctx = model.ctx
    if cfg.family not in ("ssm", "hybrid"):
        return None
    ba = ctx.batch_axes
    h_spec = P(ba, "model", None, None)          # [B, H_loc, P, N]
    conv_spec = P(ba, None, None)                # [B, K-1, C_loc(mixed)]
    pair = (h_spec, conv_spec)
    if model.scan_layers:
        return (P(None, *h_spec), P(None, *conv_spec))
    return [pair for _ in range(cfg.n_layers)]


# ---------------------------------------------------------------------------
# Generation driver (CPU-scale; powers the serving example + tests)
# ---------------------------------------------------------------------------


class Generator:
    """Greedy-generation facade over the two decode engines:

      * ``engine="contiguous"`` (default) — the original static-batch loop
        over ``Model.decode_step`` and the contiguous [B, S_max] cache.
        This is the numerical ORACLE for the serving runtime's tests.
      * ``engine="paged"`` — delegates to the serving runtime
        (repro/serve): paged KV cache, per-slot positions, static-wave
        scheduling so the contract (same tokens) is identical.  Extra
        ``ServeEngine`` knobs ride through ``engine_kwargs``.
    """

    def __init__(self, model: Model, mesh: Mesh, shape: ShapeConfig,
                 params: Any, engine: str = "contiguous",
                 **engine_kwargs: Any):
        assert engine in ("contiguous", "paged"), engine
        self.model = model
        self.mesh = mesh
        self.shape = shape
        self.params = params
        self.engine = engine
        self.engine_kwargs = engine_kwargs
        if engine == "contiguous":
            self.decode_fn, self.cache_sds, self.cache_shardings = \
                build_decode_step(model, mesh, shape)
        else:
            self.decode_fn = self.cache_sds = self.cache_shardings = None

    def empty_cache(self) -> Any:
        assert self.engine == "contiguous", (
            "empty_cache is the contiguous decode cache; the paged engine "
            "owns its pool via repro.serve.ServeEngine")
        return jax.tree.map(
            lambda sds, sh: jax.device_put(
                jnp.zeros(sds.shape, sds.dtype), sh),
            self.cache_sds, self.cache_shardings)

    def generate(self, prompt_tokens: np.ndarray, n_new: int,
                 start_pos: int = 0) -> np.ndarray:
        """Greedy generation: feeds the prompt token-by-token through the
        decode path (prompt prefill via decode — exercises cache writes),
        then samples ``n_new`` tokens."""
        if self.engine == "paged":
            return self._generate_paged(prompt_tokens, n_new)
        cache = self.empty_cache()
        b = prompt_tokens.shape[0]
        out = []
        tok = jnp.asarray(prompt_tokens[:, 0].astype(np.int32))
        pos = start_pos
        for i in range(prompt_tokens.shape[1] + n_new - 1):
            nxt, cache = self.decode_fn(self.params, cache, tok,
                                        jnp.int32(pos))
            pos += 1
            if i + 1 < prompt_tokens.shape[1]:
                tok = jnp.asarray(prompt_tokens[:, i + 1].astype(np.int32))
            else:
                tok = nxt
                out.append(np.asarray(nxt))
        return np.stack(out, axis=1) if out else np.zeros((b, 0), np.int32)

    def _generate_paged(self, prompt_tokens: np.ndarray,
                        n_new: int) -> np.ndarray:
        from repro.serve.engine import ServeEngine
        b = prompt_tokens.shape[0]
        kwargs = dict(slots=b, max_seq=self.shape.seq_len,
                      schedule="static")
        kwargs.update(self.engine_kwargs)
        eng = ServeEngine(self.model, self.mesh, self.params, **kwargs)
        rids = [eng.submit(prompt_tokens[i], n_new) for i in range(b)]
        results = eng.run()
        return np.stack([results[r] for r in rids], axis=0)
