from repro.train.train_loop import TrainLoop, TrainLoopConfig, build_train_step

__all__ = ["TrainLoop", "TrainLoopConfig", "build_train_step"]
