"""Fault-tolerant training loop + the shard_map train step builder.

The train step is ONE shard_map over the full mesh: every collective in
forward, backward, and optimizer is an MDMP managed op.  Gradient flow:

  * FSDP-sharded params: the fsdp_gather transpose reduce-scatters each
    layer's gradient inside the backward scan step — MDMP's as-ready
    "send on last write" (core/overlap.py);
  * replicated params (+ the pod axis): explicit psums over exactly the
    mesh axes absent from each param's PartitionSpec, with optional int8
    error-feedback compression on the thin cross-pod link.

Fault tolerance (DESIGN.md §4): periodic async checkpoints, automatic
restore-and-retry on step failure (with injectable faults for tests),
straggler detection via step-time EWMA, elastic resume on a different mesh.
"""

from __future__ import annotations

import dataclasses
import json
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import checkpoint as ckpt_lib
from repro.core import managed, overlap
from repro.core import tuner as tuner_lib
from repro.core.faults import FaultPlan
from repro.data.pipeline import SyntheticLMData
from repro.models import layers as model_layers
from repro.models import transformer
from repro.models.model import Model
from repro.obs.calibrate import Recalibrator
from repro.obs.tracer import get_tracer
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel import compression
from repro.parallel import pipeline as pipe
from repro.parallel.sharding import MeshCtx, ParamSpec, smap, spec_pspecs

Array = jax.Array


# ---------------------------------------------------------------------------
# Gradient post-processing: reduce over the axes a param is NOT sharded on
# ---------------------------------------------------------------------------


def _missing_axes(pspec: P, all_axes: tuple[str, ...]) -> tuple[str, ...]:
    present: set[str] = set()
    for entry in pspec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            present.update(entry)
        else:
            present.add(entry)
    return tuple(ax for ax in all_axes if ax not in present)


def sync_grads(grads: Any, spec_tree: Any, ctx: MeshCtx, *,
               compress_pod: bool = False, error_state: Any = None
               ) -> tuple[Any, Any]:
    """psum each grad over the mesh axes absent from its PartitionSpec.
    FSDP/TP-sharded dims were already reduced by collective transposes.
    The pod-axis reduction optionally uses int8 error-feedback compression
    (the thin inter-pod pipe)."""
    pspecs = spec_pspecs(spec_tree)
    flat_g, tdef = jax.tree.flatten(grads)
    flat_s = jax.tree.leaves(pspecs)
    flat_err = (jax.tree.leaves(error_state)
                if error_state is not None else [None] * len(flat_g))
    out_g, out_err = [], []
    for g, ps, err in zip(flat_g, flat_s, flat_err):
        axes = _missing_axes(ps, ctx.all_axes)
        for ax in axes:
            if ax == "pod" and compress_pod and g.size > 4096:
                g, err = compression.compressed_psum(g, ax, err)
            else:
                g = managed.managed_all_reduce(g, ax)
        out_g.append(g)
        out_err.append(err if err is not None else jnp.zeros((), g.dtype))
    return (jax.tree.unflatten(tdef, out_g),
            jax.tree.unflatten(tdef, out_err))


def _replication_factor(pspec: P, ctx: MeshCtx) -> int:
    n = 1
    for ax in _missing_axes(pspec, ctx.all_axes):
        n *= ctx.axis_sizes[ax]
    return n


# ---------------------------------------------------------------------------
# Train step builder
# ---------------------------------------------------------------------------


def build_train_step(model: Model, opt_cfg: AdamWConfig, mesh: Mesh, *,
                     compress_pod: bool = False, donate: bool = True,
                     pipeline: str = "none",
                     pipe_microbatches: int | None = None,
                     global_batch: int | None = None,
                     seq_len: int | None = None
                     ) -> tuple[Callable, Any, Any]:
    """Returns (jitted step, param NamedShardings, batch NamedShardings).

    step(params, opt_state, batch) -> (params, opt_state, metrics)

    ``pipeline`` turns the pod axis into pipeline STAGES instead of
    hierarchical DP: "gpipe" | "1f1b" | "interleaved" pin a schedule,
    "auto" lets the managed runtime pick (cost model + decision log,
    ``managed.resolve_pipeline_schedule``); the batch then replicates
    across pods and streams through the stages as ``pipe_microbatches``
    microbatches (default: the decision's M).  ``global_batch``/
    ``seq_len`` feed the cost model's compute/bytes estimates.
    """
    cfg = model.cfg
    ctx = model.ctx
    spec_tree = model.param_specs()
    pspecs = spec_pspecs(spec_tree)
    use_pipe = pipeline != "none"
    if use_pipe:
        assert ctx.has_pod, (
            f"pipeline={pipeline!r} needs a 'pod' mesh axis (stages); "
            f"got axes {tuple(ctx.axis_sizes)}")
    batch_axes = ("data",) if use_pipe else ctx.batch_axes
    batch_pspec = {"tokens": P(batch_axes, None),
                   "labels": P(batch_axes, None)}
    if cfg.encoder is not None:
        batch_pspec["frames"] = P(batch_axes, None, None)
    if cfg.vision is not None:
        batch_pspec["patches"] = P(batch_axes, None, None)
    accum = max(1, cfg.accum_steps)

    n_devices = 1
    for n in ctx.axis_sizes.values():
        n_devices *= n

    sched = None
    if use_pipe:
        assert model.scan_layers and cfg.moe is None \
            and cfg.encoder is None and cfg.vision is None and accum == 1, \
            "pipeline training needs a uniform scanned decoder stack"
        n_stage = ctx.pods
        # cost-model inputs: one rank's full-batch forward compute
        # (~2 flops/param/token over its layer share) and the boundary
        # activation block
        gb = global_batch if global_batch is not None else 8
        sl = seq_len if seq_len is not None else 128
        b_loc = max(1, gb // max(1, ctx.dp))
        tokens_loc = b_loc * sl
        batch_fwd_s = (2.0 * cfg.param_count() / n_stage * tokens_loc
                       / managed.get_config().hw.peak_flops)
        batch_bytes = (b_loc * (sl // max(1, ctx.tp)) * cfg.d_model
                       * jnp.dtype(cfg.dtype).itemsize)
        # M must tile the local batch: restrict the candidates (and any
        # explicit M) to divisors of b_loc up front, not at trace time
        cand_micro = tuple(m for m in (1, 2, 4, 8, 16, 32, 64)
                           if b_loc % m == 0)
        if pipe_microbatches is not None:
            assert b_loc % pipe_microbatches == 0, (
                f"--microbatches {pipe_microbatches} must divide the "
                f"local batch {b_loc}")
        decision = managed.resolve_pipeline_schedule(
            "pod", n_stage, batch_fwd_s, batch_bytes,
            n_layers=cfg.n_layers, candidate_micro=cand_micro,
            mode=ctx.mdmp_mode,
            schedule=None if pipeline == "auto" else pipeline,
            n_micro=pipe_microbatches)
        sched = pipe.build_schedule(decision.schedule, decision.n_micro,
                                    n_stage, decision.virtual)

    def pipe_loss_and_grads(params, batch):
        """Loss + grads through the managed pipeline over the pod axis.
        Grads come back per-stage partial (each rank only differentiates
        its own chunks); sync_grads' pod psum assembles the full tree."""
        n_virtual = sched.n_stage * sched.virtual
        m = sched.n_micro
        tokens, labels_b = batch["tokens"], batch["labels"]
        b_loc, sl = tokens.shape
        assert b_loc % m == 0, (b_loc, m)
        toks = tokens.reshape(m, b_loc // m, sl)
        labels_s = labels_b.reshape(m, b_loc // m, sl)
        proto = jax.ShapeDtypeStruct(
            (b_loc // m, sl // max(1, ctx.tp), cfg.d_model),
            jnp.dtype(cfg.dtype))

        def chunk_fn(p, q, mb, x):
            x = lax.cond(
                q == 0,
                lambda op: model._assemble_input_sp(
                    p, {"tokens": toks[mb]}).astype(op.dtype),
                lambda op: op, x)
            cp, per = pipe.slice_chunk_params(p["layers"], cfg.n_layers,
                                              n_virtual, q)

            def layer_fn(xc, lp):
                y, _, _, _ = transformer.block_sp(
                    xc, lp, cfg, ctx, causal=True,
                    window=cfg.sliding_window, collect_kv=False)
                return y

            return pipe.masked_chunk_apply(layer_fn, cp, per, x)

        def loss_fn(p, y, mb):
            x = model_layers.rms_norm(y, p["final_ln"], cfg.norm_eps)
            loss_sum, count = model_layers.lm_loss_sp(
                x, model._unembed(p), labels_s[mb], cfg, ctx)
            for ax in ("data", "model"):
                if ax in ctx.axis_sizes:
                    loss_sum = managed.managed_all_reduce(loss_sum, ax)
                    count = managed.managed_all_reduce(count, ax)
            return loss_sum / jnp.maximum(count, 1.0)

        # the loss psums over data+model replicate it there; the backward
        # seed divides their product away (same correction as micro())
        n_md = ctx.dp * ctx.tp
        return pipe.pipeline_value_and_grad(
            chunk_fn, loss_fn, params, proto, sched, "pod", mean=True,
            grad_seed_scale=1.0 / n_md, reduce_grads=False)

    def body(params, opt_state, batch):
        def micro(p, mb):
            # The psum'd loss is REPLICATED on every rank; shard_map
            # transposes then accumulate each rank's cotangent, so the raw
            # grad is n_devices x too large.  Differentiate loss/N and
            # report the true loss via aux.
            loss, metrics = model.loss_sp(p, mb)
            return loss / n_devices, loss

        if use_pipe:
            loss, grads = pipe_loss_and_grads(params, batch)
        elif accum > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])
            stacked = jax.tree.map(split, batch)
            mb0 = jax.tree.map(lambda x: x[0], stacked)
            (_, loss0), g0 = jax.value_and_grad(micro, has_aux=True)(
                params, mb0)

            def acc_body(carry, mb):
                loss_a, g_a = carry
                (_, l), g = jax.value_and_grad(micro, has_aux=True)(
                    params, mb)
                return (loss_a + l,
                        jax.tree.map(jnp.add, g_a, g)), None

            rest = jax.tree.map(lambda x: x[1:], stacked)
            (loss_sum, grads), _ = lax.scan(acc_body, (loss0, g0), rest)
            loss = loss_sum / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        else:
            (_, loss), grads = jax.value_and_grad(micro, has_aux=True)(
                params, batch)

        grads, _ = sync_grads(grads, spec_tree, ctx,
                              compress_pod=compress_pod)
        # replication-aware global grad norm
        flat_g = jax.tree.leaves(grads)
        flat_s = jax.tree.leaves(pspecs)
        ssq = jnp.float32(0.0)
        for g, ps in zip(flat_g, flat_s):
            rep = _replication_factor(ps, ctx)
            ssq = ssq + jnp.sum(jnp.square(g.astype(jnp.float32))) / rep
        for ax in ctx.all_axes:
            ssq = managed.managed_all_reduce(ssq, ax)
        gnorm = jnp.sqrt(ssq)

        params2, opt2, metrics = adamw_update(
            params, grads, opt_state, opt_cfg, gnorm=gnorm)
        metrics["loss"] = loss
        return params2, opt2, metrics

    opt_pspecs = {"mu": pspecs, "nu": pspecs, "step": P()}
    out_metrics_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
    sharded = smap(body, mesh,
                   in_specs=(pspecs, opt_pspecs, batch_pspec),
                   out_specs=(pspecs, opt_pspecs, out_metrics_spec))
    jitted = jax.jit(sharded, donate_argnums=(0, 1) if donate else ())

    param_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    batch_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                   batch_pspec)
    return jitted, param_shardings, batch_shardings


# ---------------------------------------------------------------------------
# Fault-tolerant loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    max_retries: int = 3
    straggler_factor: float = 3.0       # step > factor * EWMA -> straggler
    ewma: float = 0.9
    managed_cadence: bool = False       # Young/Daly-chosen ckpt interval
    mtbf_s: float = 1800.0              # assumed mean time between failures


class TrainLoop:
    """Drives (step fn, data, checkpoints) with restart-on-failure.

    ``fault_hook(step)`` (tests) may raise to simulate a node failure, and
    ``fault_plan`` injects the deterministic fault taxonomy of
    core/faults.py; the loop restores the latest readable checkpoint and
    retries.  Step times feed a straggler detector (on real pods this
    triggers re-balancing / host replacement; here it logs and counts).

    With ``managed_cadence`` the checkpoint interval is a managed knob:
    ``managed.resolve_checkpoint`` re-resolves the Young/Daly optimum
    between steps from the EWMA step time and checkpoint/metrics.py's
    measured write bandwidth / snapshot cost, logging each pick as a
    ``DecisionRecord(op="ckpt_interval")``.  A ``tuner`` persists the
    winner (it rides along inside the checkpoint's ``extra``), and on an
    elastic resume — checkpoint written on a different mesh — every
    persisted tuner winner is replayed onto the new topology in one
    ``tuner.replan_for_mesh`` pass (``self.replayed`` keeps the trail).
    """

    def __init__(self, step_fn: Callable, model: Model, opt_cfg: AdamWConfig,
                 data: SyntheticLMData, loop_cfg: TrainLoopConfig,
                 param_shardings: Any, batch_shardings: Any,
                 fault_hook: Callable[[int], None] | None = None, *,
                 tuner: tuner_lib.ScheduleTuner | None = None,
                 fault_plan: FaultPlan | None = None):
        self.step_fn = step_fn
        self.model = model
        self.opt_cfg = opt_cfg
        self.data = data
        self.cfg = loop_cfg
        self.param_shardings = param_shardings
        self.batch_shardings = batch_shardings
        self.tuner = tuner
        self.fault_plan = fault_plan
        hooks = [h for h in (
            fault_hook,
            fault_plan.train_hook(ckpt_dir=loop_cfg.ckpt_dir)
            if fault_plan is not None else None) if h is not None]
        self.fault_hook = (
            (lambda step: [h(step) for h in hooks]) if hooks else None)
        self.ckpt_metrics = ckpt_lib.CheckpointMetrics()
        self.mgr = ckpt_lib.CheckpointManager(loop_cfg.ckpt_dir,
                                              keep=loop_cfg.keep,
                                              metrics=self.ckpt_metrics)
        self.ckpt_interval = max(1, loop_cfg.ckpt_every)
        # the step-time EWMA + the cadence re-resolution trigger, now the
        # shared obs.Recalibrator policy (warmup=1: resolve from the very
        # first post-warmup measurement, then on >25% sustained drift —
        # exactly the trigger the loop used to hand-roll inline)
        self.recal = Recalibrator(threshold=0.25, warmup=1,
                                  alpha=loop_cfg.ewma)
        self.ckpt_decisions: list = []       # CheckpointDecision trail
        self.replayed: list[dict] = []       # elastic replan records
        self._resolved_step_s: float | None = None
        self._mesh_axis = "mesh"
        self._mesh_size = 1
        for n in model.ctx.axis_sizes.values():
            self._mesh_size *= int(n)
        self.stragglers: list[int] = []
        self.restarts = 0
        self.history: list[dict] = []

    # -- state management ----------------------------------------------------

    def init_state(self, seed: int = 0) -> tuple[Any, Any, int]:
        params = self.model.init(jax.random.key(seed))
        params = jax.tree.map(jax.device_put, params, self.param_shardings)
        opt = adamw_init(params, self.opt_cfg)
        return params, opt, 0

    def resume_or_init(self, seed: int = 0) -> tuple[Any, Any, int]:
        params, opt, _ = self.init_state(seed)
        like = {"params": params, "opt": opt}
        t0 = time.monotonic()
        hit = ckpt_lib.restore_latest(
            self.cfg.ckpt_dir, like,
            shardings={"params": self.param_shardings,
                       "opt": {"mu": self.param_shardings,
                               "nu": self.param_shardings,
                               "step": None}})
        if hit is None:
            return params, opt, 0
        tree, extra, ck_step = hit
        self.ckpt_metrics.note_restore(ck_step, time.monotonic() - t0)
        step = int(extra.get("step", ck_step))
        if "data" in extra:
            # the data pipeline resumes WITH the model: dropping its state
            # used to replay batches the optimizer had already consumed
            self.data, _ = SyntheticLMData.resume(self.data.cfg,
                                                  extra["data"])
        if self.tuner is not None and "tuner" in extra:
            self.tuner.load_entries(extra["tuner"])
            mesh_now = self._mesh_dict()
            mesh_then = {k: int(v)
                         for k, v in extra.get("mesh", mesh_now).items()}
            if mesh_then != mesh_now:
                # elastic resume: N-way winners replayed onto M ranks
                sizes = dict(mesh_now)
                sizes[self._mesh_axis] = self._mesh_size
                self.replayed += tuner_lib.replan_for_mesh(
                    self.tuner, sizes,
                    step_s=self._resolved_step_s or 0.1,
                    mtbf_s=self.cfg.mtbf_s)
        return tree["params"], tree["opt"], step

    def _mesh_dict(self) -> dict[str, int]:
        return {k: int(v) for k, v in self.model.ctx.axis_sizes.items()}

    def _batch(self, step: int) -> Any:
        g = self.data.global_batch_at(step)
        return {k: jax.device_put(v, self.batch_shardings[k])
                if k in self.batch_shardings else v for k, v in g.items()}

    # -- managed checkpoint cadence -------------------------------------------

    def _resolve_cadence(self, step_s: float, snapshot_bytes: int) -> None:
        """Re-resolve the Young/Daly interval from live measurements: the
        EWMA step time plus checkpoint/metrics.py's measured write
        bandwidth, snapshot cost and restore time.  Logged as a
        DecisionRecord(op="ckpt_interval"); the winner persists via the
        tuner (riding along inside the next checkpoint)."""
        m = self.ckpt_metrics
        d = managed.resolve_checkpoint(
            self._mesh_axis, step_s, snapshot_bytes,
            mtbf_s=self.cfg.mtbf_s,
            measured_write_bw=m.write_bw_estimate(),
            measured_ckpt_cost_s=m.ckpt_cost_s_estimate(),
            measured_restore_s=m.restore_s_estimate())
        self.ckpt_interval = max(1, int(d.interval))
        self.ckpt_decisions.append(d)
        self._resolved_step_s = step_s
        self.recal.rebase(step_s)
        # re-meter the async drain's D2H chunking to the current step time
        self.mgr.drain_chunk_bytes = overlap.drain_chunk_bytes(
            step_s, d.write_bw)
        if self.tuner is not None:
            entry = self.tuner.decide_ckpt(
                self._mesh_axis, self._mesh_size, snapshot_bytes, step_s,
                mtbf_s=self.cfg.mtbf_s, write_bw=m.write_bw_estimate(),
                ckpt_cost_s=m.ckpt_cost_s_estimate(),
                restore_s=m.restore_s_estimate())
            cost = m.ckpt_cost_s_estimate()
            if cost is not None:
                # realized overhead of the cadence we actually ran
                tau = self.ckpt_interval * step_s
                overhead = (cost / tau
                            + (0.5 * tau + (m.restore_s_estimate() or 0.0))
                            / self.cfg.mtbf_s)
                self.tuner.record(entry.key, d.mode, self.ckpt_interval,
                                  overhead)

    def _save(self, step: int, params: Any, opt: Any) -> None:
        extra = {"step": step, "data": self.data.state_dict(step),
                 "mesh": self._mesh_dict()}
        if self.tuner is not None:
            extra["tuner"] = json.loads(self.tuner.to_json())
        self.mgr.save_async(step, {"params": params, "opt": opt},
                            extra=extra)

    # -- the loop --------------------------------------------------------------

    def run(self, params: Any, opt: Any, start_step: int = 0) -> dict:
        cfg = self.cfg
        tr = get_tracer()
        step = start_step
        retries = 0
        warmup_until = start_step + 2
        last_saved = start_step
        steps_executed = 0
        wall_t0 = time.monotonic()
        snapshot_bytes = sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree.leaves({"params": params, "opt": opt})
            if hasattr(leaf, "size"))
        while step < cfg.total_steps:
            batch = self._batch(step)
            t0 = time.monotonic()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                with tr.span("train.step", track="compute", step=step):
                    params, opt, metrics = self.step_fn(params, opt,
                                                        batch)
                    # float() blocks on the device — the span measures
                    # the realized step, not the dispatch
                    loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}")
            except Exception as e:          # noqa: BLE001 — restart path
                retries += 1
                self.restarts += 1
                if retries > cfg.max_retries:
                    raise
                self.mgr.wait()
                params, opt, step = self.resume_or_init()
                last_saved = step
                # the EWMA window must restart: the first post-restore
                # steps re-compile/re-warm, and judging them against the
                # pre-fault EWMA flags every recovery as a straggler
                warmup_until = step + 2
                continue
            retries = 0
            steps_executed += 1
            dt = time.monotonic() - t0
            in_warmup = step < warmup_until
            ewma_t = self.recal.value
            if (not in_warmup and ewma_t is not None
                    and dt > cfg.straggler_factor * ewma_t):
                self.stragglers.append(step)
            if not in_warmup:
                # (re)compile steps feed neither EWMA nor straggler
                self.recal.note(dt)
            self.history.append({"step": step, "loss": loss,
                                 "time_s": dt})
            if cfg.managed_cadence and self.recal.should_retune():
                self._resolve_cadence(self.recal.value, snapshot_bytes)
            step += 1
            if step - last_saved >= self.ckpt_interval \
                    or step == cfg.total_steps:
                # scale = the train seconds this cadence amortizes one
                # checkpoint over, so dur/scale is the measured overhead
                # fraction — the unit resolve_checkpoint predicts
                with tr.span("ckpt.save", op="ckpt_interval",
                             axis=self._mesh_axis, track="ckpt",
                             nbytes=snapshot_bytes,
                             scale=self.ckpt_interval
                             * max(self.recal.value or dt, 1e-9)):
                    self._save(step, params, opt)
                last_saved = step
        self.mgr.wait()
        return {"params": params, "opt": opt, "step": step,
                "history": self.history, "stragglers": self.stragglers,
                "restarts": self.restarts,
                "steps_executed": steps_executed,
                "wall_s": time.monotonic() - wall_t0,
                "ckpt_interval": self.ckpt_interval,
                "replayed": self.replayed}
