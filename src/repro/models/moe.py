"""Mixture-of-Experts — two layout regimes x three managed dispatch
schedules (DESIGN.md §3.3, PR 5 tentpole).

Layouts:

``ep_a2a``   (moonshot: 64 experts % TP16 == 0): experts sharded by expert
  id over the ``model`` axis; capacity-limited token dispatch crosses the
  axis.  Tokens stay in their local sequence shard: no sequence gather
  needed at all.

``expert_tp`` (grok: 8 experts on a TP16 axis): every expert's FFN is
  sharded over the ``model`` axis like a dense MLP; dispatch is local
  against the sequence-gathered activations and the down-projection
  returns to sequence shards through a reduce-scatter ring.

Dispatch schedules (``cfg.moe.dispatch``, managed end-to-end):

``bulk``     one managed all_to_all of the [E, C, D] capacity buffers each
             way around the expert FFN — the unmanaged baseline and the
             numerical oracle.
``stream``   the capacity buffers split into g chunks and streamed around
             the EP axis (``managed.managed_expert_stream``): each ring
             block's ppermute is issued before the previous block's
             expert FFN, hiding the wire under compute like PR 2's ring.
``dense``    no dispatch: every rank runs its LOCAL experts on the full
             token set gate-masked and reduce-scatters — capacity-free
             (never drops a token), wins when the t*D token bytes
             undercut the 2*E*C*D a2a bytes.
``auto``     ``core/cost_model.decide_moe_dispatch`` picks (schedule, g,
             capacity_factor) per call site and logs the DecisionRecord
             (the managed-runtime role), re-resolved online from
             ``instrument.capture_routing`` statistics.

Dispatch is index-based (sort + gather, GShard capacity semantics) — the
one-hot [T, E, C] dispatch tensor would be terabytes at 32k-token
microbatches.  Capacity is ``moe.dispatch.capacity_for`` (rounds UP — the
seed floored, dropping tokens even at capacity_factor=1.0 balanced).  The
expert FFN itself runs through ``kernels/grouped_matmul.py``: the
per-expert valid counts (from ``dispatch_indices``' keep mask) ride in
scalar-prefetch SMEM so padded capacity rows cost no FLOPs.  Both paths
add a Switch-style load-balancing aux loss.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import managed
from repro.core.overlap import fsdp_gather
from repro.kernels import grouped_matmul
from repro.models import layers
from repro.moe.dispatch import (capacity_for, combine_from_buffers,
                                dispatch_indices, expert_counts,
                                gather_to_buffers)
from repro.parallel.sharding import MeshCtx

Array = jax.Array

__all__ = ["moe_block", "moe_block_ep", "moe_block_expert_tp",
           "moe_block_decode", "capacity_for", "dispatch_indices",
           "expert_counts", "gather_to_buffers", "combine_from_buffers"]


def _router(x: Array, w_router: Array, n_experts: int, top_k: int
            ) -> tuple[Array, Array, Array]:
    """x: [T, D] -> (top-k gate weights [T, K] renormalised,
    top-k expert ids [T, K], aux loss)."""
    logits = jnp.dot(x.astype(jnp.float32), w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = lax.top_k(probs, top_k)                # [T, K]
    gates = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True),
                                1e-9)
    # Switch-style load-balance aux loss
    mask = jnp.sum(jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32),
                   axis=1)
    me = jnp.mean(mask, axis=0)
    pe = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(me * pe)
    return gates, top_idx, aux


def _expert_ffn(h: Array, w1: Array, w1_gate: Array | None, w2: Array,
                mlp: str, valid: Array) -> Array:
    """Batched expert FFN over capacity groups.  h: [G, C, D] (G a
    multiple of the expert count); w1 (+w1_gate): [E, D, F]; w2:
    [E, F, D]; ``valid`` [G] = per-group kept-row counts — the
    grouped-expert GEMM skips padded capacity rows."""
    return grouped_matmul.grouped_expert_ffn(
        h, w1, w1_gate, w2, valid, mlp=mlp)


def _resolve_dispatch(cfg: ModelConfig, ctx: MeshCtx, tokens_local: int,
                      axis_size: int, layout: str
                      ) -> tuple[str, int, float]:
    """Route the dispatch knob through the managed runtime (logged as a
    DecisionRecord(op="moe_dispatch") per call site — once per traced
    layer like attn_impl="auto").  An explicit ``cfg.moe.dispatch`` wins
    over the ambient mdmp mode; "auto" lets the cost model pick
    (schedule, g, capacity_factor) from the static shapes, priced for
    THIS layout's wire (ep a2a vs expert_tp sequence AG/RS)."""
    e = cfg.moe
    decision = managed.resolve_moe_dispatch(
        "model", axis_size, tokens_local, cfg.d_model, e.n_experts,
        e.top_k, e.d_ff_expert,
        mults=3 if layers.gated(cfg.mlp) else 2,
        dtype_bytes=jnp.dtype(cfg.dtype).itemsize,
        capacity_factor=e.capacity_factor, layout=layout,
        mode=ctx.mdmp_mode,
        schedule=None if e.dispatch == "auto" else e.dispatch,
        g=e.dispatch_g or None)
    return decision.schedule, decision.g, decision.capacity_factor


def _gathered_ffn_weights(params: dict, cfg: ModelConfig, ctx: MeshCtx
                          ) -> tuple[Array, Array | None, Array]:
    w1 = fsdp_gather(params["w1"], "data", axis=1, mode=ctx.mdmp_mode)
    w1g = (fsdp_gather(params["w1_gate"], "data", axis=1,
                       mode=ctx.mdmp_mode)
           if layers.gated(cfg.mlp) else None)
    w2 = fsdp_gather(params["w2"], "data", axis=2, mode=ctx.mdmp_mode)
    return w1, w1g, w2


# ---------------------------------------------------------------------------
# ep_a2a: expert-parallel dispatch across the 'model' axis
# ---------------------------------------------------------------------------


def _dense_fallback_ep(x2: Array, gates: Array, top_idx: Array, w1: Array,
                       w1g: Array | None, w2: Array, cfg: ModelConfig,
                       ctx: MeshCtx, n_experts: int) -> Array:
    """The no-dispatch schedule: all-gather the t*D tokens, run this
    rank's E_loc experts on the FULL token set gate-masked, reduce-scatter
    the outputs back to sequence shards.  Capacity-free — no token is
    ever dropped — at the price of E (not top_k) expert rows per token."""
    tp = ctx.tp
    e_loc = n_experts // tp
    ge = _scatter_gates(gates, top_idx, n_experts)          # [t, E]
    x_full = managed.managed_all_gather(x2, "model", mode=ctx.mdmp_mode)
    ge_full = managed.managed_all_gather(ge.astype(x2.dtype), "model",
                                         mode=ctx.mdmp_mode)
    u = jnp.einsum("td,edf->etf", x_full, w1)
    if layers.gated(cfg.mlp):
        g = jnp.einsum("td,edf->etf", x_full, w1g)
        act = layers.activation(cfg.mlp, u, g)
    else:
        act = layers.activation(cfg.mlp, u, None)
    o = jnp.einsum("etf,efd->etd", act, w2)                 # [E_loc, T, D]
    eidx = lax.axis_index("model") * e_loc
    g_loc = lax.dynamic_slice_in_dim(ge_full, eidx, e_loc, axis=1)
    y_part = jnp.einsum("etd,te->td", o, g_loc.astype(o.dtype))
    return managed.managed_reduce_scatter(y_part, "model",
                                          mode=ctx.mdmp_mode)


def moe_block_ep(x: Array, params: dict, cfg: ModelConfig, ctx: MeshCtx
                 ) -> tuple[Array, Array]:
    """x: [B, S_loc, D] -> (y, aux_loss).  Experts sharded by id over
    'model'; tokens routed across the axis under the managed dispatch
    schedule (bulk a2a / chunked-stream / dense fallback)."""
    e_cfg = cfg.moe
    b, s_loc, d = x.shape
    t = b * s_loc
    tp = ctx.tp
    e = e_cfg.n_experts
    schedule, g, cf = _resolve_dispatch(cfg, ctx, t, tp, "ep_a2a")
    cap = capacity_for(t, e_cfg, cf)

    x2 = x.reshape(t, d)
    gates, top_idx, aux = _router(x2, params["w_router"], e, e_cfg.top_k)
    w1, w1g, w2 = _gathered_ffn_weights(params, cfg, ctx)

    if schedule == "dense":
        # capacity-free on ANY axis size (tp=1 included): the dense
        # contract is "never drops a token", which the capacity path
        # below cannot honor at starved capacity factors
        y2 = _dense_fallback_ep(x2, gates, top_idx, w1, w1g, w2, cfg, ctx,
                                e)
        return y2.reshape(b, s_loc, d).astype(x.dtype), aux

    dest, tok, keep, order = dispatch_indices(top_idx, e, cap)
    buffers = gather_to_buffers(x2, dest, tok, keep, e, cap)
    counts = expert_counts(top_idx, e, cap)

    if schedule == "stream" and tp > 1:
        def expert_fn(blk, valid):
            return _expert_ffn(blk, w1, w1g, w2, cfg.mlp, valid=valid)

        back = managed.managed_expert_stream(buffers, counts, "model",
                                             expert_fn, g=g)
    else:
        # tokens cross the EP axis: [E, C, D] -> [E_loc, tp*C, D]; the
        # per-expert kept counts ride along so the grouped GEMM can skip
        # the padded capacity rows on the receiving side
        recv = managed.managed_all_to_all(
            buffers, "model", split_axis=0, concat_axis=1,
            mode=ctx.mdmp_mode)
        cnt_recv = (lax.all_to_all(counts, "model", 0, 0, tiled=True)
                    if tp > 1 else counts)
        e_loc = e // tp
        hg = recv.reshape(e_loc, tp, cap, d).reshape(e_loc * tp, cap, d)
        vg = cnt_recv.reshape(tp, e_loc).T.reshape(e_loc * tp)
        out_g = _expert_ffn(hg, w1, w1g, w2, cfg.mlp, valid=vg)
        out = out_g.reshape(e_loc, tp * cap, d)
        # route results back and combine with gate weights
        back = managed.managed_all_to_all(
            out, "model", split_axis=1, concat_axis=0, mode=ctx.mdmp_mode)
    y2 = combine_from_buffers(back, dest, tok, keep, gates, order, t)
    return y2.reshape(b, s_loc, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# expert_tp: each expert TP-sharded over 'model' (expert count < TP)
# ---------------------------------------------------------------------------


def moe_block_expert_tp(x: Array, params: dict, cfg: ModelConfig,
                        ctx: MeshCtx) -> tuple[Array, Array]:
    """x: [B, S_loc, D] -> (y, aux_loss).  All ranks hold an ff-shard of
    every expert; dispatch happens on the sequence-gathered activations so
    all ranks agree on token order, and the down-projection reduce-scatters
    straight back to sequence shards (MDMP ring).  The dispatch knob maps
    onto this layout's actual wire: "stream" rides the sequence AG/RS as
    chunked rings, "dense" skips the capacity buffers entirely (every
    expert's ff-shard on every token, gate-masked — capacity-free)."""
    e_cfg = cfg.moe
    b, s_loc, d = x.shape
    schedule, g, cf = _resolve_dispatch(cfg, ctx, b * s_loc, ctx.tp,
                                        "expert_tp")
    seq_mode = "interleaved" if schedule == "stream" else ctx.mdmp_mode
    seq_chunks = g if schedule == "stream" else None

    # gather the sequence (all ranks see identical tokens)
    x_full2 = managed.managed_all_gather(layers.to_ring(x), "model",
                                         mode=seq_mode, chunks=seq_chunks)
    t = x_full2.shape[0]
    e = e_cfg.n_experts
    cap = capacity_for(t, e_cfg, cf)

    gates, top_idx, aux = _router(x_full2, params["w_router"], e,
                                  e_cfg.top_k)
    w1, w1g, w2 = _gathered_ffn_weights(params, cfg, ctx)

    if schedule == "dense":
        ge = _scatter_gates(gates, top_idx, e)               # [T, E]
        u = jnp.einsum("td,edf->etf", x_full2, w1)           # F_loc cols
        if layers.gated(cfg.mlp):
            gg = jnp.einsum("td,edf->etf", x_full2, w1g)
            act = layers.activation(cfg.mlp, u, gg)
        else:
            act = layers.activation(cfg.mlp, u, None)
        part = jnp.einsum("etf,efd->etd", act, w2)           # partial (F)
        y_part = jnp.einsum("etd,te->td", part, ge.astype(part.dtype))
    else:
        dest, tok, keep, order = dispatch_indices(top_idx, e, cap)
        buffers = gather_to_buffers(x_full2, dest, tok, keep, e, cap)
        counts = expert_counts(top_idx, e, cap)
        part = _expert_ffn(buffers, w1, w1g, w2, cfg.mlp, valid=counts)
        y_part = combine_from_buffers(part, dest, tok, keep, gates, order,
                                      t)

    # combine back to token-major, then one ring both sums the ff-partials
    # and scatters the sequence (psum+scatter ring).
    y2 = managed.managed_reduce_scatter(y_part, "model", mode=seq_mode,
                                        chunks=seq_chunks)
    return layers.from_ring(y2, b).astype(x.dtype), aux


def moe_block(x: Array, params: dict, cfg: ModelConfig, ctx: MeshCtx
              ) -> tuple[Array, Array]:
    impl = cfg.moe.impl
    if impl == "auto":
        impl = "ep_a2a" if cfg.moe.n_experts % ctx.tp == 0 else "expert_tp"
    if impl == "ep_a2a" and cfg.moe.n_experts % ctx.tp == 0:
        return moe_block_ep(x, params, cfg, ctx)
    return moe_block_expert_tp(x, params, cfg, ctx)


# ---------------------------------------------------------------------------
# Decode flow: single token, batch replicated
# ---------------------------------------------------------------------------


def moe_block_decode(x: Array, params: dict, cfg: ModelConfig,
                     ctx: MeshCtx) -> Array:
    """x: [B, D_loc(data)] -> [B, D_loc(data)].  Batch is replicated, so
    every rank routes identically; expert weights stay stationary and only
    activation-sized reductions cross the links.

    ep_a2a layout: this rank holds [E_loc] whole experts — compute their
    contributions (gate-masked) and psum over 'model'.
    expert_tp layout: this rank holds an F-shard of every expert — dense
    masked compute, psum over 'model' sums the ff partials.
    Both contract the FSDP dim with psum('data')."""
    e_cfg = cfg.moe
    e = e_cfg.n_experts

    x_full = managed.managed_all_gather(
        x.T, "data", mode=ctx.mdmp_mode).T          # [B, D]
    gates, top_idx, _ = _router(x_full, params["w_router"], e, e_cfg.top_k)
    gate_full = _scatter_gates(gates, top_idx, e)              # [B, E]

    impl = e_cfg.impl
    if impl == "auto":
        impl = "ep_a2a" if e % ctx.tp == 0 else "expert_tp"

    u = jnp.einsum("bd,edf->ebf", x, params["w1"])
    if layers.gated(cfg.mlp):
        g = jnp.einsum("bd,edf->ebf", x, params["w1_gate"])
        ug = managed.managed_all_reduce(
            jnp.concatenate([u, g], axis=-1), "data", mode=ctx.mdmp_mode)
        uu, g = jnp.split(ug, 2, axis=-1)
        act = layers.activation(cfg.mlp, uu, g)
    else:
        u = managed.managed_all_reduce(u, "data", mode=ctx.mdmp_mode)
        act = layers.activation(cfg.mlp, u, None)
    part = jnp.einsum("ebf,efd->ebd", act, params["w2"])

    if impl == "ep_a2a" and e % ctx.tp == 0:
        e_loc = e // ctx.tp
        eidx = lax.axis_index("model") * e_loc
        g_use = lax.dynamic_slice_in_dim(gate_full, eidx, e_loc, axis=1)
    else:
        g_use = gate_full
    y = jnp.einsum("ebd,be->bd", part, g_use.astype(part.dtype))
    y = managed.managed_all_reduce(y, "model", mode=ctx.mdmp_mode)
    return y.astype(x.dtype)


def _scatter_gates(gates: Array, top_idx: Array, n_experts: int) -> Array:
    """[T, K] gate weights + ids -> dense [T, E]."""
    oh = jax.nn.one_hot(top_idx, n_experts, dtype=gates.dtype)   # [T,K,E]
    return jnp.einsum("tk,tke->te", gates, oh)
