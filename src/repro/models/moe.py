"""Mixture-of-Experts — two dispatch regimes (DESIGN.md §3.3):

``ep_a2a``   (moonshot: 64 experts % TP16 == 0): experts sharded by expert
  id over the ``model`` axis; capacity-limited token dispatch crosses the
  axis via MDMP managed all_to_all (chunked/interleaved schedulable — the
  paper's "send tokens for expert e as soon as routed").  Tokens stay in
  their local sequence shard: no sequence gather needed at all.

``expert_tp`` (grok: 8 experts on a TP16 axis): every expert's FFN is
  sharded over the ``model`` axis like a dense MLP; dispatch is local
  against the sequence-gathered activations and the down-projection
  returns to sequence shards through a reduce-scatter ring.

Dispatch is index-based (sort + gather, GShard capacity semantics) — the
one-hot [T, E, C] dispatch tensor would be terabytes at 32k-token
microbatches.  Both paths add a Switch-style load-balancing aux loss.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import managed
from repro.core.overlap import fsdp_gather
from repro.models import layers
from repro.parallel.sharding import MeshCtx

Array = jax.Array


def _router(x: Array, w_router: Array, n_experts: int, top_k: int
            ) -> tuple[Array, Array, Array]:
    """x: [T, D] -> (top-k gate weights [T, K] renormalised,
    top-k expert ids [T, K], aux loss)."""
    logits = jnp.dot(x.astype(jnp.float32), w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = lax.top_k(probs, top_k)                # [T, K]
    gates = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True),
                                1e-9)
    # Switch-style load-balance aux loss
    mask = jnp.sum(jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32),
                   axis=1)
    me = jnp.mean(mask, axis=0)
    pe = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(me * pe)
    return gates, top_idx, aux


def dispatch_indices(top_idx: Array, n_experts: int, capacity: int
                     ) -> tuple[Array, Array, Array, Array]:
    """Capacity-limited dispatch bookkeeping (index-based).

    top_idx: [T, K] expert ids.  Returns
      dest  [T*K] slot in the [E*C] buffer (or E*C for dropped entries),
      tok   [T*K] source token of each (t, k) entry in expert-sorted order,
      keep  [T*K] 1.0 where the entry fit under capacity,
      order [T*K] the expert-major argsort permuting flat (t, k) entries
            into the order of the three arrays above (combine_from_buffers
            uses it to align the gate weights).
    """
    t, k = top_idx.shape
    flat_e = top_idx.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)            # expert-major order
    sorted_e = flat_e[order]
    tok = order // k
    # position of each entry within its expert's buffer
    pos = jnp.arange(t * k) - jnp.searchsorted(sorted_e,
                                               sorted_e, side="left")
    keep = (pos < capacity).astype(jnp.float32)
    dest = jnp.where(pos < capacity, sorted_e * capacity + pos,
                     n_experts * capacity)               # overflow bucket
    return dest, tok, keep, order


def gather_to_buffers(x2: Array, dest: Array, tok: Array, keep: Array,
                      n_experts: int, capacity: int) -> Array:
    """x2: [T, D] -> expert buffers [E, C, D] (dropped tokens zeroed)."""
    d = x2.shape[-1]
    rows = x2[tok] * keep[:, None].astype(x2.dtype)
    buf = jnp.zeros((n_experts * capacity + 1, d), x2.dtype)
    buf = buf.at[dest].set(rows, mode="drop")
    return buf[:-1].reshape(n_experts, capacity, d)


def combine_from_buffers(out: Array, dest: Array, tok: Array, keep: Array,
                         gates: Array, order: Array, t: int) -> Array:
    """out: [E, C, D] -> y [T, D], weighting by the (t, k) gate.
    dest/tok/keep are in expert-sorted order; ``order`` permutes the flat
    [T*K] gate entries into that order."""
    e, c, d = out.shape
    flat = jnp.concatenate([out.reshape(e * c, d),
                            jnp.zeros((1, d), out.dtype)])
    k = gates.shape[1]
    g = gates.reshape(t * k)[order]
    rows = flat[dest] * (g * keep)[:, None].astype(out.dtype)
    y = jnp.zeros((t, d), out.dtype)
    return y.at[tok].add(rows)


def _expert_ffn(h: Array, w1: Array, w1_gate: Array | None, w2: Array,
                mlp: str) -> Array:
    """Batched expert FFN.  h: [E, C, D]; w1 (+w1_gate): [E, D, F];
    w2: [E, F, D]."""
    u = jnp.einsum("ecd,edf->ecf", h, w1)
    if layers.gated(mlp):
        g = jnp.einsum("ecd,edf->ecf", h, w1_gate)
        act = layers.activation(mlp, u, g)
    else:
        act = layers.activation(mlp, u, None)
    return jnp.einsum("ecf,efd->ecd", act, w2)


# ---------------------------------------------------------------------------
# ep_a2a: expert-parallel all_to_all dispatch
# ---------------------------------------------------------------------------


def moe_block_ep(x: Array, params: dict, cfg: ModelConfig, ctx: MeshCtx
                 ) -> tuple[Array, Array]:
    """x: [B, S_loc, D] -> (y, aux_loss).  Experts sharded by id over
    'model'; tokens routed across the axis with managed all_to_all."""
    e_cfg = cfg.moe
    b, s_loc, d = x.shape
    t = b * s_loc
    tp = ctx.tp
    e = e_cfg.n_experts
    cap = max(1, int(t * e_cfg.top_k / e * e_cfg.capacity_factor))

    x2 = x.reshape(t, d)
    gates, top_idx, aux = _router(x2, params["w_router"], e, e_cfg.top_k)
    dest, tok, keep, order = dispatch_indices(top_idx, e, cap)
    buffers = gather_to_buffers(x2, dest, tok, keep, e, cap)

    # tokens cross the EP axis: [E, C, D] -> [E_loc, tp*C, D]
    recv = managed.managed_all_to_all(
        buffers, "model", split_axis=0, concat_axis=1, mode=ctx.mdmp_mode)

    w1 = fsdp_gather(params["w1"], "data", axis=1, mode=ctx.mdmp_mode)
    w1g = (fsdp_gather(params["w1_gate"], "data", axis=1,
                       mode=ctx.mdmp_mode)
           if layers.gated(cfg.mlp) else None)
    w2 = fsdp_gather(params["w2"], "data", axis=2, mode=ctx.mdmp_mode)
    out = _expert_ffn(recv, w1, w1g, w2, cfg.mlp)

    # route results back and combine with gate weights
    back = managed.managed_all_to_all(
        out, "model", split_axis=1, concat_axis=0, mode=ctx.mdmp_mode)
    y2 = combine_from_buffers(back, dest, tok, keep, gates, order, t)
    return y2.reshape(b, s_loc, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# expert_tp: each expert TP-sharded over 'model' (expert count < TP)
# ---------------------------------------------------------------------------


def moe_block_expert_tp(x: Array, params: dict, cfg: ModelConfig,
                        ctx: MeshCtx) -> tuple[Array, Array]:
    """x: [B, S_loc, D] -> (y, aux_loss).  All ranks hold an ff-shard of
    every expert; dispatch happens on the sequence-gathered activations so
    all ranks agree on token order, and the down-projection reduce-scatters
    straight back to sequence shards (MDMP ring)."""
    e_cfg = cfg.moe
    b, s_loc, d = x.shape

    # gather the sequence (all ranks see identical tokens)
    x_full2 = managed.managed_all_gather(layers.to_ring(x), "model",
                                         mode=ctx.mdmp_mode)  # [S*B, D]
    t = x_full2.shape[0]
    e = e_cfg.n_experts
    cap = max(1, int(t * e_cfg.top_k / e * e_cfg.capacity_factor))

    gates, top_idx, aux = _router(x_full2, params["w_router"], e,
                                  e_cfg.top_k)
    dest, tok, keep, order = dispatch_indices(top_idx, e, cap)
    buffers = gather_to_buffers(x_full2, dest, tok, keep, e, cap)

    w1 = fsdp_gather(params["w1"], "data", axis=1, mode=ctx.mdmp_mode)
    w1g = (fsdp_gather(params["w1_gate"], "data", axis=1,
                       mode=ctx.mdmp_mode)
           if layers.gated(cfg.mlp) else None)
    w2 = fsdp_gather(params["w2"], "data", axis=2, mode=ctx.mdmp_mode)
    u = jnp.einsum("ecd,edf->ecf", buffers, w1)          # F_loc columns
    if layers.gated(cfg.mlp):
        g = jnp.einsum("ecd,edf->ecf", buffers, w1g)
        act = layers.activation(cfg.mlp, u, g)
    else:
        act = layers.activation(cfg.mlp, u, None)
    part = jnp.einsum("ecf,efd->ecd", act, w2)           # partial over F

    # combine back to token-major, then one ring both sums the ff-partials
    # and scatters the sequence (psum+scatter ring).
    y_part = combine_from_buffers(part, dest, tok, keep, gates, order, t)
    y2 = managed.managed_reduce_scatter(y_part, "model", mode=ctx.mdmp_mode)
    return layers.from_ring(y2, b).astype(x.dtype), aux


def moe_block(x: Array, params: dict, cfg: ModelConfig, ctx: MeshCtx
              ) -> tuple[Array, Array]:
    impl = cfg.moe.impl
    if impl == "auto":
        impl = "ep_a2a" if cfg.moe.n_experts % ctx.tp == 0 else "expert_tp"
    if impl == "ep_a2a" and cfg.moe.n_experts % ctx.tp == 0:
        return moe_block_ep(x, params, cfg, ctx)
    return moe_block_expert_tp(x, params, cfg, ctx)


# ---------------------------------------------------------------------------
# Decode flow: single token, batch replicated
# ---------------------------------------------------------------------------


def moe_block_decode(x: Array, params: dict, cfg: ModelConfig,
                     ctx: MeshCtx) -> Array:
    """x: [B, D_loc(data)] -> [B, D_loc(data)].  Batch is replicated, so
    every rank routes identically; expert weights stay stationary and only
    activation-sized reductions cross the links.

    ep_a2a layout: this rank holds [E_loc] whole experts — compute their
    contributions (gate-masked) and psum over 'model'.
    expert_tp layout: this rank holds an F-shard of every expert — dense
    masked compute, psum over 'model' sums the ff partials.
    Both contract the FSDP dim with psum('data')."""
    e_cfg = cfg.moe
    e = e_cfg.n_experts

    x_full = managed.managed_all_gather(
        x.T, "data", mode=ctx.mdmp_mode).T          # [B, D]
    gates, top_idx, _ = _router(x_full, params["w_router"], e, e_cfg.top_k)
    gate_full = _scatter_gates(gates, top_idx, e)              # [B, E]

    impl = e_cfg.impl
    if impl == "auto":
        impl = "ep_a2a" if e % ctx.tp == 0 else "expert_tp"

    u = jnp.einsum("bd,edf->ebf", x, params["w1"])
    if layers.gated(cfg.mlp):
        g = jnp.einsum("bd,edf->ebf", x, params["w1_gate"])
        ug = managed.managed_all_reduce(
            jnp.concatenate([u, g], axis=-1), "data", mode=ctx.mdmp_mode)
        uu, g = jnp.split(ug, 2, axis=-1)
        act = layers.activation(cfg.mlp, uu, g)
    else:
        u = managed.managed_all_reduce(u, "data", mode=ctx.mdmp_mode)
        act = layers.activation(cfg.mlp, u, None)
    part = jnp.einsum("ebf,efd->ebd", act, params["w2"])

    if impl == "ep_a2a" and e % ctx.tp == 0:
        e_loc = e // ctx.tp
        eidx = lax.axis_index("model") * e_loc
        g_use = lax.dynamic_slice_in_dim(gate_full, eidx, e_loc, axis=1)
    else:
        g_use = gate_full
    y = jnp.einsum("ebd,be->bd", part, g_use.astype(part.dtype))
    y = managed.managed_all_reduce(y, "model", mode=ctx.mdmp_mode)
    return y.astype(x.dtype)


def _scatter_gates(gates: Array, top_idx: Array, n_experts: int) -> Array:
    """[T, K] gate weights + ids -> dense [T, E]."""
    oh = jax.nn.one_hot(top_idx, n_experts, dtype=gates.dtype)   # [T,K,E]
    return jnp.einsum("tk,tke->te", gates, oh)
