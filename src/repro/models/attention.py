"""Attention — SP flow (train/prefill) and TP-2D decode flow.

SP flow (x sequence-sharded over ``model``):
  * fused QKV all-gather-matmul ring: ONE ring gathers the sequence while
    computing Q (this rank's heads) and K/V (replicated kv weights —
    GQA kv_heads < TP, DESIGN.md §3.3) — MDMP intermingling;
  * blockwise (flash) attention over full sequence for local heads;
  * output projection as matmul-reduce-scatter back to sequence shards.

Decode flow (batch replicated; KV cache sharded over data × model on the
sequence dim):
  * q/k/v via weight-stationary psum('data') contractions;
  * all-gather q heads over 'model' (tiny), partial attention on the local
    cache slice, LSE merge via pmax+psum over BOTH cache axes
    (flash-decoding, distributed);
  * o-projection row-parallel with psum('model').
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import managed
from repro.core.overlap import fsdp_gather
from repro.models import layers
from repro.parallel.sharding import MeshCtx

Array = jax.Array


def padded_kv_heads(cfg: ModelConfig) -> int:
    """Smallest kv-head count >= n_kv_heads that divides padded_heads."""
    h = cfg.padded_heads
    kv = max(1, cfg.n_kv_heads)
    while h % kv:
        kv += 1
    return kv


# ---------------------------------------------------------------------------
# Core attention math (reference path; the Pallas flash kernel plugs in via
# kernels/flash_attention/ops.py for the same signature)
# ---------------------------------------------------------------------------


def attend(q: Array, k: Array, v: Array, *, causal: bool,
           window: int = 0, q_offset: int = 0,
           use_kernel: bool = True) -> Array:
    """q: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd]; GQA via head grouping.
    ``q_offset``: global position of q[0] relative to k[0] (SP/decode).
    ``window`` > 0: sliding-window attention."""
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        if kernel_ops.flash_attention_applicable(q, k, v):
            return kernel_ops.flash_attention(
                q, k, v, causal=causal, window=window, q_offset=q_offset)
    return attend_ref(q, k, v, causal=causal, window=window,
                      q_offset=q_offset)


def attend_ref(q: Array, k: Array, v: Array, *, causal: bool,
               window: int = 0, q_offset: int = 0) -> Array:
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    groups = h // kvh
    qg = q.reshape(b, sq, kvh, groups, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _local_kv_slice(k: Array, v: Array, cfg: ModelConfig, ctx: MeshCtx
                    ) -> tuple[Array, Array, int]:
    """Slice replicated kv heads to the range this rank's q heads use.

    q heads are contiguous per rank ([r*h_loc, (r+1)*h_loc)); with group
    size g = Hp / KVp the kv range is [(r*h_loc)//g, ...) of uniform size
    (KVp | tp or tp | KVp — guaranteed by padded_kv_heads + tp powers of
    two).
    """
    tp = ctx.tp
    hp = cfg.padded_heads
    kvp = padded_kv_heads(cfg)
    h_loc = hp // tp
    g = hp // kvp                       # q heads per kv head
    kv_count = max(1, h_loc // g)
    assert h_loc % max(min(g, h_loc), 1) == 0, (h_loc, g)
    if kv_count == kvp:
        return k, v, kvp
    r = lax.axis_index("model")
    lo = (r * h_loc) // g
    k = lax.dynamic_slice_in_dim(k, lo, kv_count, axis=2)
    v = lax.dynamic_slice_in_dim(v, lo, kv_count, axis=2)
    return k, v, kv_count


# ---------------------------------------------------------------------------
# SP flow (training / prefill)
# ---------------------------------------------------------------------------


def attention_sp(x: Array, params: dict, cfg: ModelConfig, ctx: MeshCtx, *,
                 causal: bool = True, window: int = 0,
                 positions_offset: int = 0,
                 return_kv: bool = False) -> Any:
    """x: [B, S_loc, D] -> [B, S_loc, D].  When ``return_kv`` (prefill),
    also returns this rank's (k, v) sequence slice for the cache."""
    b, s_loc, d = x.shape
    tp = ctx.tp
    h_loc = cfg.padded_heads // tp
    kvh = padded_kv_heads(cfg)
    hd = cfg.head_dim

    # w_q: [D(data), H(model)*hd]; w_kv: [D(data), 2*KVp*hd] (replicated
    # over model — GQA kv_heads < TP); one ring computes both.
    wq = fsdp_gather(params["w_q"], "data", mode=ctx.mdmp_mode)
    wkv = fsdp_gather(params["w_kv"], "data", mode=ctx.mdmp_mode)
    wo = fsdp_gather(params["w_o"], "data", axis=1, mode=ctx.mdmp_mode)

    x2 = layers.to_ring(x)
    q2, kv2 = managed.all_gather_matmul_multi(x2, [wq, wkv], "model",
                                              mode=ctx.mdmp_mode)
    s_full = q2.shape[0] // b
    q = layers.from_ring(q2, b).reshape(b, s_full, h_loc, hd)
    kv = layers.from_ring(kv2, b)
    k, v = jnp.split(kv, 2, axis=-1)
    k = k.reshape(b, s_full, kvh, hd)
    v = v.reshape(b, s_full, kvh, hd)

    if not cfg.attention_free and cfg.rope_theta > 0:
        pos = positions_offset + jnp.arange(s_full)
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k = layers.apply_rope(k, pos, cfg.rope_theta)

    # GQA under TP: this rank's contiguous q heads attend a contiguous kv
    # slice (kv weights are replicated; slice to the local group range).
    # The cache (return_kv) keeps ALL kv heads — decode needs every head.
    k_att, v_att, _ = _local_kv_slice(k, v, cfg, ctx)
    o = attend(q, k_att, v_att, causal=causal, window=window)
    o2 = layers.to_ring(o.reshape(b, s_full, h_loc * hd))
    y2 = managed.matmul_reduce_scatter(o2, wo, "model", mode=ctx.mdmp_mode)
    y = layers.from_ring(y2.astype(x.dtype), b)
    if return_kv:
        # This rank keeps its own sequence slice of the (replicated) kv.
        r = lax.axis_index("model")
        k_slice = lax.dynamic_slice_in_dim(k, r * s_loc, s_loc, axis=1)
        v_slice = lax.dynamic_slice_in_dim(v, r * s_loc, s_loc, axis=1)
        return y, (k_slice, v_slice)
    return y


def attention_sp_ulysses(x: Array, params: dict, cfg: ModelConfig,
                         ctx: MeshCtx, *, causal: bool = True,
                         window: int = 0,
                         return_kv: bool = False) -> Any:
    """Ulysses-style attention (beyond-paper §Perf option): instead of
    all-gathering the SEQUENCE for the qkv matmuls (bytes ∝ S·B·D), gather
    the q/o WEIGHTS over 'model' (bytes ∝ D·H·hd) and switch
    seq-sharding <-> head-sharding with a managed all_to_all
    (bytes ∝ S·B·D / tp).  For long-context prefill the activation term
    dominates, so this cuts attention comm ~tp-fold.  Numerically
    identical to attention_sp (tests assert it).
    """
    b, s_loc, d = x.shape
    tp = ctx.tp
    hp = cfg.padded_heads
    h_loc = hp // tp
    kvh = padded_kv_heads(cfg)
    hd = cfg.head_dim

    # full q/o weights: FSDP gather (data) + TP gather (model, columns)
    wq = fsdp_gather(params["w_q"], "data", mode=ctx.mdmp_mode)
    wq = fsdp_gather(wq, "model", axis=1, mode=ctx.mdmp_mode)  # [D, H*hd]
    wkv = fsdp_gather(params["w_kv"], "data", mode=ctx.mdmp_mode)
    wo = fsdp_gather(params["w_o"], "data", axis=1, mode=ctx.mdmp_mode)
    wo = fsdp_gather(wo, "model", axis=0, mode=ctx.mdmp_mode)  # [H*hd, D]

    # local-seq projections with FULL heads
    q = jnp.dot(x, wq).reshape(b, s_loc, hp, hd)
    kv = jnp.dot(x, wkv)
    k, v = jnp.split(kv, 2, axis=-1)
    k = k.reshape(b, s_loc, kvh, hd)
    v = v.reshape(b, s_loc, kvh, hd)

    r = lax.axis_index("model")
    if cfg.rope_theta > 0:
        pos = r * s_loc + jnp.arange(s_loc)
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k = layers.apply_rope(k, pos, cfg.rope_theta)

    # head<->seq switch: [B, S_loc, H, hd] -> [B, S, H_loc, hd]
    qt = managed.managed_all_to_all(
        q.transpose(1, 0, 2, 3), "model", 2, 0,
        ctx.mdmp_mode)                                   # [S, B, H_loc, hd]
    qt = qt.transpose(1, 0, 2, 3)
    # kv heads are few: plain seq all-gather (tiny)
    kg = layers.from_ring(managed.managed_all_gather(
        layers.to_ring(k.reshape(b, s_loc, kvh * hd)), "model",
        ctx.mdmp_mode), b).reshape(b, s_loc * tp, kvh, hd)
    vg = layers.from_ring(managed.managed_all_gather(
        layers.to_ring(v.reshape(b, s_loc, kvh * hd)), "model",
        ctx.mdmp_mode), b).reshape(b, s_loc * tp, kvh, hd)

    k_att, v_att, _ = _local_kv_slice(kg, vg, cfg, ctx)
    o = attend(qt, k_att, v_att, causal=causal, window=window)

    # switch back: [B, S, H_loc, hd] -> [B, S_loc, H, hd]
    ot = managed.managed_all_to_all(
        o.transpose(1, 0, 2, 3), "model", 0, 2, ctx.mdmp_mode)
    ot = ot.transpose(1, 0, 2, 3).reshape(b, s_loc, hp * hd)
    y = jnp.dot(ot, wo).astype(x.dtype)                  # no psum needed
    if return_kv:
        return y, (k, v)   # this rank's seq slice, all kv heads
    return y


def attention_sp_ring(x: Array, params: dict, cfg: ModelConfig,
                      ctx: MeshCtx, *, causal: bool = True,
                      window: int = 0, return_kv: bool = False) -> Any:
    """Ring attention / context parallelism (MDMP Figure-3 on the
    transformer path): q stays sequence-sharded with FULL heads, KV blocks
    stream around 'model' via the managed ring collective while the flash
    kernel consumes the block that already arrived — O(S_loc) activation
    memory vs the O(S) gathers of attention_sp / attention_sp_ulysses.

    Projections mirror ulysses (weights gathered over 'model', bytes ∝
    D·H·hd) but NO head<->seq switch is needed: every rank keeps all heads
    on its own sequence block, so GQA needs no kv slicing — the flash
    head-grouping consumes all KVp heads directly.  Numerically identical
    to attention_sp (tests assert it)."""
    b, s_loc, d = x.shape
    hp = cfg.padded_heads
    kvh = padded_kv_heads(cfg)
    hd = cfg.head_dim

    wq = fsdp_gather(params["w_q"], "data", mode=ctx.mdmp_mode)
    wq = fsdp_gather(wq, "model", axis=1, mode=ctx.mdmp_mode)  # [D, H*hd]
    wkv = fsdp_gather(params["w_kv"], "data", mode=ctx.mdmp_mode)
    wo = fsdp_gather(params["w_o"], "data", axis=1, mode=ctx.mdmp_mode)
    wo = fsdp_gather(wo, "model", axis=0, mode=ctx.mdmp_mode)  # [H*hd, D]

    q = jnp.dot(x, wq).reshape(b, s_loc, hp, hd)
    kv = jnp.dot(x, wkv)
    k, v = jnp.split(kv, 2, axis=-1)
    k = k.reshape(b, s_loc, kvh, hd)
    v = v.reshape(b, s_loc, kvh, hd)

    if cfg.rope_theta > 0:
        pos = lax.axis_index("model") * s_loc + jnp.arange(s_loc)
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k = layers.apply_rope(k, pos, cfg.rope_theta)

    o = managed.managed_ring_attention(q, k, v, "model", causal, window,
                                       ctx.mdmp_mode)
    y = jnp.dot(o.reshape(b, s_loc, hp * hd), wo).astype(x.dtype)
    if return_kv:
        return y, (k, v)   # this rank's seq slice, all kv heads (decode
    return y               # needs every head — same contract as ulysses)


#: schedule name (cost model / tuner / plan) -> SP attention implementation
SP_SCHEDULES = {
    "bulk": attention_sp,          # megatron AG-matmul rings
    "ulysses": attention_sp_ulysses,
    "ring": attention_sp_ring,
}


def attention_sp_auto(x: Array, params: dict, cfg: ModelConfig,
                      ctx: MeshCtx, *, causal: bool = True,
                      window: int = 0, return_kv: bool = False) -> Any:
    """The managed dispatcher (cfg.attn_impl='auto'): pick bulk gather vs
    ulysses a2a vs ring streaming per call site from the cost model, log
    the DecisionRecord, and run the winner.  Shapes are static at trace
    time, so the decision costs nothing at runtime."""
    b, s_loc, _ = x.shape
    decision = managed.resolve_attention_schedule(
        "model", ctx.tp, b, s_loc, cfg.padded_heads, padded_kv_heads(cfg),
        cfg.head_dim, cfg.d_model,
        dtype_bytes=jnp.dtype(cfg.dtype).itemsize, causal=causal,
        mode=ctx.mdmp_mode)
    fn = SP_SCHEDULES[decision.schedule]
    return fn(x, params, cfg, ctx, causal=causal, window=window,
              return_kv=return_kv)


# ---------------------------------------------------------------------------
# Decode flow
# ---------------------------------------------------------------------------


def cache_axes(ctx: MeshCtx) -> tuple[str, ...]:
    """Mesh axes the KV-cache sequence dim is sharded over."""
    return (("pod", "data", "model") if ctx.has_pod else ("data", "model"))


def cache_shards(ctx: MeshCtx) -> int:
    n = 1
    for ax in cache_axes(ctx):
        n *= ctx.axis_sizes[ax]
    return n


def _cache_rank(ctx: MeshCtx) -> Array:
    """Linear rank of this device along the cache sharding axes."""
    r = jnp.int32(0)
    for ax in cache_axes(ctx):
        r = r * ctx.axis_sizes[ax] + lax.axis_index(ax)
    return r


def attention_decode(x: Array, kv_cache: tuple[Array, Array], pos: Array,
                     params: dict, cfg: ModelConfig, ctx: MeshCtx, *,
                     window: int = 0) -> tuple[Array, tuple[Array, Array]]:
    """One-token decode attention.

    x:        [B, D_loc(data)] (batch replicated over the mesh)
    kv_cache: (k, v) each [B, S_shard, KV, hd] — sequence sharded over
              cache_axes(ctx); for SWA layers S_shard covers the window.
    pos:      [] int32 — global position being written/attended.
    Returns (y [B, D_loc(data)], updated cache).
    """
    b = x.shape[0]
    tp = ctx.tp
    h = cfg.padded_heads
    h_loc = h // tp
    kvh = padded_kv_heads(cfg)
    hd = cfg.head_dim
    k_cache, v_cache = kv_cache
    s_shard = k_cache.shape[1]

    # qkv: weight-stationary contraction over the FSDP dim.
    qkv = managed.managed_all_reduce(
        jnp.concatenate([jnp.dot(x, params["w_q"]),
                         jnp.dot(x, params["w_kv"])], axis=-1),
        "data", mode=ctx.mdmp_mode)
    q, knew, vnew = jnp.split(qkv, [h_loc * hd, h_loc * hd + kvh * hd],
                              axis=-1)
    q = q.reshape(b, h_loc, hd)
    knew = knew.reshape(b, kvh, hd)
    vnew = vnew.reshape(b, kvh, hd)

    if cfg.rope_theta > 0:
        posv = pos[None] if pos.ndim == 0 else pos
        q = layers.apply_rope(q[:, None], posv, cfg.rope_theta)[:, 0]
        knew = layers.apply_rope(knew[:, None], posv, cfg.rope_theta)[:, 0]

    # Cache write: the shard owning ``pos`` (ring-buffer slot for SWA).
    n_shards = cache_shards(ctx)
    slot_global = pos if window <= 0 else pos % (s_shard * n_shards)
    owner = slot_global // s_shard
    slot = slot_global % s_shard
    me = _cache_rank(ctx)
    is_mine = (owner == me)
    k_upd = lax.dynamic_update_slice_in_dim(k_cache, knew[:, None], slot,
                                            axis=1)
    v_upd = lax.dynamic_update_slice_in_dim(v_cache, vnew[:, None], slot,
                                            axis=1)
    k_cache = jnp.where(is_mine, k_upd, k_cache)
    v_cache = jnp.where(is_mine, v_upd, v_cache)

    # All heads everywhere (tiny), partial attention on the local slice.
    q_all = managed.managed_all_gather(
        q.transpose(1, 0, 2), "model", mode=ctx.mdmp_mode)  # [H, B, hd]
    q_all = q_all.transpose(1, 0, 2)                        # [B, H, hd]

    groups = h // kvh
    qg = q_all.reshape(b, kvh, groups, hd)
    scale = 1.0 / math.sqrt(hd)
    # accumulate in f32 WITHOUT materialising an f32 copy of the cache
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale

    # validity: global slot index of each local cache slot <= pos
    slot_ids = me * s_shard + jnp.arange(s_shard)            # [Ss]
    if window > 0:
        # ring buffer: slot holds position p iff p % ring == slot_global
        ring = s_shard * n_shards
        base = (pos + 1) - ring
        cand = jnp.where(slot_ids <= pos % ring,
                         (pos // ring) * ring + slot_ids,
                         (pos // ring - 1) * ring + slot_ids)
        valid = (cand >= jnp.maximum(0, pos + 1 - window)) & (cand <= pos)
    else:
        valid = slot_ids <= pos
    logits = jnp.where(valid[None, None, None], logits, -jnp.inf)

    m_loc = jnp.max(logits, axis=-1)                          # [B,KV,G]
    m_glob = lax.pmax(m_loc, cache_axes(ctx))
    p = jnp.exp(logits - m_glob[..., None])
    p = jnp.where(valid[None, None, None], p, 0.0)
    l_loc = jnp.sum(p, axis=-1)
    o_loc = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
    l_glob = l_loc
    o_glob = o_loc
    for ax in cache_axes(ctx):
        l_glob = managed.managed_all_reduce(l_glob, ax)
        o_glob = managed.managed_all_reduce(o_glob, ax)
    o = (o_glob / jnp.maximum(l_glob[..., None], 1e-30))
    o = o.reshape(b, h, hd).astype(x.dtype)

    # o-projection: my model-axis head block, row-parallel psum('model').
    r_m = lax.axis_index("model")
    o_my = lax.dynamic_slice_in_dim(o, r_m * h_loc, h_loc, axis=1)
    y = managed.managed_all_reduce(
        jnp.dot(o_my.reshape(b, h_loc * hd), params["w_o"]), "model",
        mode=ctx.mdmp_mode)
    return y.astype(x.dtype), (k_cache, v_cache)


def attention_decode_paged(x: Array, pool: tuple[Array, Array],
                           table: Array, pos: Array, active: Array,
                           params: dict, cfg: ModelConfig, ctx: MeshCtx, *,
                           window: int = 0
                           ) -> tuple[Array, tuple[Array, Array]]:
    """One-token decode attention against a PAGED KV cache (the serving
    runtime's cache; kernels/paged_attention.py).

    x:      [B, D_loc(data)] — every slot decodes its own token.
    pool:   (k_pages, v_pages), each [Np_loc, page, KV, hd]; the pool's
            page dim is sharded over cache_axes(ctx) (rank r owns global
            page ids [r*Np_loc, (r+1)*Np_loc)).
    table:  [B, n_pages_max] int32 GLOBAL page ids per slot (replicated).
    pos:    [B] int32 per-slot positions being written/attended — unlike
            the contiguous flow the batch rows sit at DIFFERENT positions
            (continuous batching mixes prefilling and decoding slots).
    active: [B] bool — inactive slots neither write the cache nor count;
            their outputs are garbage the engine discards.
    Returns (y [B, D_loc(data)], updated pool).
    """
    b = x.shape[0]
    tp = ctx.tp
    h = cfg.padded_heads
    h_loc = h // tp
    kvh = padded_kv_heads(cfg)
    hd = cfg.head_dim
    k_pages, v_pages = pool
    np_loc, page = k_pages.shape[0], k_pages.shape[1]

    qkv = managed.managed_all_reduce(
        jnp.concatenate([jnp.dot(x, params["w_q"]),
                         jnp.dot(x, params["w_kv"])], axis=-1),
        "data", mode=ctx.mdmp_mode)
    q, knew, vnew = jnp.split(qkv, [h_loc * hd, h_loc * hd + kvh * hd],
                              axis=-1)
    q = q.reshape(b, h_loc, hd)
    knew = knew.reshape(b, kvh, hd)
    vnew = vnew.reshape(b, kvh, hd)

    if cfg.rope_theta > 0:
        q = layers.apply_rope_slots(q, pos, cfg.rope_theta)
        knew = layers.apply_rope_slots(knew, pos, cfg.rope_theta)

    # Cache write: slot b's position ``pos[b]`` lives in pool page
    # table[b, pos[b] // page], row pos[b] % page.  Only the owning rank
    # writes; inactive or foreign writes are routed to an out-of-range
    # local index and dropped by the scatter.
    me = _cache_rank(ctx)
    gpid = jnp.take_along_axis(table.astype(jnp.int32),
                               (pos // page)[:, None], axis=1)[:, 0]
    lp = gpid - me * np_loc
    writable = active & (lp >= 0) & (lp < np_loc)
    lp_safe = jnp.where(writable, lp, np_loc)
    row = pos % page
    k_pages = k_pages.at[lp_safe, row].set(knew.astype(k_pages.dtype),
                                           mode="drop")
    v_pages = v_pages.at[lp_safe, row].set(vnew.astype(v_pages.dtype),
                                           mode="drop")

    # All heads everywhere (tiny), paged partials on the local pool slice,
    # then the distributed flash-decoding LSE merge over the cache axes.
    q_all = managed.managed_all_gather(
        q.transpose(1, 0, 2), "model", mode=ctx.mdmp_mode)  # [H, B, hd]
    q_all = q_all.transpose(1, 0, 2)                        # [B, H, hd]
    lens = jnp.where(active, pos + 1, 0).astype(jnp.int32)

    from repro.kernels import paged_attention as paged
    n_sh = cache_shards(ctx)
    if n_sh == 1 and paged.paged_kernel_enabled():
        o = paged.paged_attention(q_all, k_pages, v_pages, table, lens,
                                  window=window)
    else:
        m, l, acc = paged.paged_attention_partials_jnp(
            q_all, k_pages, v_pages, table, lens, window=window,
            pool_offset=me * np_loc)
        m_glob = lax.pmax(m, cache_axes(ctx))
        w = jnp.exp(m - m_glob)
        l = l * w
        acc = acc * w[..., None]
        for ax in cache_axes(ctx):
            l = managed.managed_all_reduce(l, ax)
            acc = managed.managed_all_reduce(acc, ax)
        o = (acc / jnp.maximum(l[..., None], 1e-30))[:, 0]
    o = o.reshape(b, h, hd).astype(x.dtype)

    r_m = lax.axis_index("model")
    o_my = lax.dynamic_slice_in_dim(o, r_m * h_loc, h_loc, axis=1)
    y = managed.managed_all_reduce(
        jnp.dot(o_my.reshape(b, h_loc * hd), params["w_o"]), "model",
        mode=ctx.mdmp_mode)
    return y.astype(x.dtype), (k_pages, v_pages)
