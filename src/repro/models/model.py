"""Model factory: ModelConfig -> parameter specs, init, and the three
entry points (all called INSIDE shard_map over the production mesh):

  * ``loss_sp(params, batch)``           training loss (SP flow)
  * ``prefill_sp(params, batch)``        prefill -> (last-token logits, cache)
  * ``decode_step(params, cache, ...)``  one-token decode (TP-2D flow)

Parameters are stored in ONE layout shared by train and serve
(DESIGN.md §3.1); decode contracts FSDP dims in place instead of gathering.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import managed
from repro.models import attention, layers, moe, ssm, transformer
from repro.parallel.sharding import (LOGICAL_RULES, MeshCtx, ParamSpec,
                                     pad_to_multiple)

Array = jax.Array
PS = ParamSpec


def _gated_mult(cfg: ModelConfig) -> int:
    return 2 if layers.gated(cfg.mlp) else 1


class Model:
    def __init__(self, cfg: ModelConfig, ctx: MeshCtx):
        self.cfg = cfg
        self.ctx = ctx
        assert cfg.padded_heads % max(ctx.tp, 1) == 0 or cfg.n_heads == 0, \
            (cfg.name, cfg.padded_heads, ctx.tp)

    # ------------------------------------------------------------------
    # Parameter specs
    # ------------------------------------------------------------------

    def _attn_specs(self, cross: bool = False) -> dict:
        cfg = self.cfg
        hd = cfg.head_dim
        hp = cfg.padded_heads
        kvp = attention.padded_kv_heads(cfg)
        sfx = "_x" if cross else ""
        d = cfg.d_model
        specs = {
            f"w_q{sfx}": PS((d, hp * hd), ("embed", "heads")),
            f"w_kv{sfx}": PS((d, 2 * kvp * hd), ("embed", "null")),
            f"w_o{sfx}": PS((hp * hd, d), ("heads", "embed")),
        }
        return specs

    def _mlp_specs(self) -> dict:
        cfg = self.cfg
        d, ff = cfg.d_model, cfg.padded_ff
        specs = {
            "w_up": PS((d, ff), ("embed", "ff")),
            "w_down": PS((ff, d), ("ff", "embed")),
        }
        if _gated_mult(cfg) == 2:
            # separate gate matrix: a fused [up|gate] would split on the
            # LOCAL shard and misalign with the global column order
            # (breaks elastic resume across mesh shapes)
            specs["w_gate"] = PS((d, ff), ("embed", "ff"))
        return specs

    def _moe_specs(self) -> dict:
        cfg = self.cfg
        e = cfg.moe
        d, f = cfg.d_model, e.d_ff_expert
        ep = (e.impl == "ep_a2a" or
              (e.impl == "auto" and e.n_experts % self.ctx.tp == 0))
        e_ax = "experts" if ep else "null"
        f_ax = "expert_ff" if ep else "ff"
        specs = {
            "w_router": PS((d, e.n_experts), ("embed_nofsdp", "null")),
            "w1": PS((e.n_experts, d, f), (e_ax, "embed", f_ax)),
            "w2": PS((e.n_experts, f, d), (e_ax, f_ax, "embed")),
        }
        if _gated_mult(cfg) == 2:
            specs["w1_gate"] = PS((e.n_experts, d, f),
                                  (e_ax, "embed", f_ax))
        return specs

    def _ssm_specs(self) -> dict:
        cfg = self.cfg
        s = cfg.ssm
        d = cfg.d_model
        h = cfg.ssm_heads
        di = h * s.headdim
        n = s.d_state
        return {
            "w_z": PS((d, di), ("embed", "inner")),
            "w_x": PS((d, di), ("embed", "inner")),
            "w_bc": PS((d, 2 * n), ("embed", "null")),
            "w_dt": PS((d, h), ("embed", "ssm_heads")),
            "conv_x": PS((s.d_conv, di), ("conv", "inner")),
            "conv_bc": PS((s.d_conv, 2 * n), ("conv", "null")),
            "a_log": PS((h,), ("ssm_heads",)),
            "dt_bias": PS((h,), ("ssm_heads",)),
            "d_skip": PS((h,), ("ssm_heads",)),
            "norm_w": PS((di,), ("inner",)),
            "w_out": PS((di, d), ("inner", "embed")),
        }

    def _layer_specs(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        ln = lambda: PS((d,), ("embed_nofsdp",))
        if cfg.family == "ssm":
            return {"ln1": ln(), **self._ssm_specs()}
        specs = {"ln1": ln(), "ln2": ln(), **self._attn_specs()}
        if cfg.family == "moe":
            specs.update(self._moe_specs())
        else:
            specs.update(self._mlp_specs())
        if cfg.family == "hybrid":
            specs["ssm"] = self._ssm_specs()
        if cfg.encoder is not None:
            specs["ln_x"] = ln()
            specs.update(self._attn_specs(cross=True))
        return specs

    def param_specs(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        v = cfg.padded_vocab
        specs: dict[str, Any] = {
            "embed": PS((v, d), ("vocab", "embed")),
            "final_ln": PS((d,), ("embed_nofsdp",)),
        }
        if not cfg.tie_embeddings:
            specs["unembed"] = PS((d, v), ("embed", "vocab"))
        layer = self._layer_specs()
        if self.scan_layers:
            specs["layers"] = jax.tree.map(
                lambda s: PS((cfg.n_layers,) + s.shape,
                             ("layers",) + s.logical),
                layer, is_leaf=lambda x: isinstance(x, PS))
        else:
            specs["layers"] = [jax.tree.map(lambda s: s, layer,
                                            is_leaf=lambda x: isinstance(x, PS))
                               for _ in range(cfg.n_layers)]
        if cfg.encoder is not None:
            enc_layer = {"ln1": PS((d,), ("embed_nofsdp",)),
                         "ln2": PS((d,), ("embed_nofsdp",)),
                         **self._attn_specs(), **self._mlp_specs()}
            specs["encoder"] = {
                "layers": jax.tree.map(
                    lambda s: PS((cfg.encoder.n_layers,) + s.shape,
                                 ("layers",) + s.logical),
                    enc_layer, is_leaf=lambda x: isinstance(x, PS)),
                "final_ln": PS((d,), ("embed_nofsdp",)),
            }
        if cfg.vision is not None:
            specs["vision_adapter"] = PS((d, d), ("embed_nofsdp", "null"))
        return specs

    @property
    def scan_layers(self) -> bool:
        return self.cfg.family != "hybrid"

    # ------------------------------------------------------------------
    # Init (global arrays — for CPU-scale configs; dry-run uses specs only)
    # ------------------------------------------------------------------

    def init(self, key: Array) -> dict:
        cfg = self.cfg
        specs = self.param_specs()
        leaves, treedef = jax.tree.flatten(
            specs, is_leaf=lambda x: isinstance(x, PS))
        keys = jax.random.split(key, len(leaves))
        dtype = jnp.dtype(cfg.dtype)

        def one(k, spec: PS):
            shape = spec.shape
            non_layer = [l for l in spec.logical if l != "layers"]
            if len(non_layer) <= 1:
                # norm scales / per-head scalars: zeros (fixed up below)
                return jnp.zeros(shape, dtype)
            fan_in = shape[-2]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(k, shape, jnp.float32)
                    * scale).astype(dtype)

        out = jax.tree.unflatten(treedef, [one(k, s) for k, s in
                                           zip(keys, leaves)])
        # SSM-specific non-zero inits (A in [1, e], dt_bias ~ softplus-inv)
        def fix_ssm(tree):
            if isinstance(tree, dict):
                for name, vdict in tree.items():
                    if isinstance(vdict, dict):
                        fix_ssm(vdict)
                if "a_log" in tree:
                    tree["a_log"] = jnp.zeros_like(tree["a_log"]) + \
                        jnp.asarray(0.5, dtype)
                    tree["dt_bias"] = jnp.zeros_like(tree["dt_bias"]) + \
                        jnp.asarray(0.1, dtype)
                    tree["d_skip"] = jnp.ones_like(tree["d_skip"])
            elif isinstance(tree, list):
                for t in tree:
                    fix_ssm(t)
        fix_ssm(out)
        return out

    # ------------------------------------------------------------------
    # Forward (SP flow)
    # ------------------------------------------------------------------

    def _assemble_input_sp(self, params: dict, batch: dict) -> Array:
        """Embed tokens (and splice modality-stub embeddings)."""
        cfg, ctx = self.cfg, self.ctx
        x = layers.embed_sp(batch["tokens"], params["embed"], cfg, ctx)
        if cfg.vision is not None and "patches" in batch:
            # splice projected patch embeddings into positions [0, P)
            patches = batch["patches"]                    # [B, P, D]
            b, s_loc, d = x.shape
            s = batch["tokens"].shape[1]
            pad = jnp.zeros((b, s - patches.shape[1], d), x.dtype)
            patch_full = jnp.concatenate(
                [jnp.dot(patches, params["vision_adapter"]).astype(x.dtype),
                 pad], axis=1)
            r = lax.axis_index("model")
            mine = lax.dynamic_slice_in_dim(patch_full, r * s_loc, s_loc,
                                            axis=1)
            pos = r * s_loc + jnp.arange(s_loc)
            is_patch = (pos < patches.shape[1])[None, :, None]
            x = jnp.where(is_patch, mine, x)
        return x

    def _encoder_sp(self, params: dict, frames: Array) -> Array:
        """Whisper encoder on stub frame embeddings [B, F, D] ->
        enc_out [B, F_loc, D]."""
        cfg, ctx = self.cfg, self.ctx
        b, f, d = frames.shape
        pos = jnp.arange(f)
        x = frames + _sinusoidal(pos, d)[None].astype(frames.dtype)
        # pad frames to a TP multiple, then shard over 'model' (SP)
        f_pad = pad_to_multiple(f, ctx.tp)
        if f_pad != f:
            x = jnp.pad(x, ((0, 0), (0, f_pad - f), (0, 0)))
        r = lax.axis_index("model")
        f_loc = f_pad // ctx.tp
        x = lax.dynamic_slice_in_dim(x, r * f_loc, f_loc, axis=1)
        x, _, _, _ = transformer.stack_sp(
            x, params["encoder"]["layers"], cfg, ctx, causal=False)
        return layers.rms_norm(x, params["encoder"]["final_ln"],
                               cfg.norm_eps)

    def loss_sp(self, params: dict, batch: dict) -> tuple[Array, dict]:
        """Training loss.  batch: tokens [B_loc, S], labels [B_loc, S]
        (+ frames/patches stubs).  Returns (loss, metrics)."""
        cfg, ctx = self.cfg, self.ctx
        x = self._assemble_input_sp(params, batch)
        enc_out = None
        if cfg.encoder is not None:
            enc_out = self._encoder_sp(params, batch["frames"])
        x, aux, _, _ = transformer.stack_sp(
            x, params["layers"], cfg, ctx, causal=True, enc_out=enc_out)
        x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
        unembed = self._unembed(params)
        loss_sum, count = layers.lm_loss_sp(x, unembed, batch["labels"],
                                            cfg, ctx)
        axes = ctx.all_axes
        total = loss_sum
        cnt = count
        for ax in axes:
            total = managed.managed_all_reduce(total, ax)
            cnt = managed.managed_all_reduce(cnt, ax)
        loss = total / jnp.maximum(cnt, 1.0)
        if cfg.moe is not None:
            # aux is a local-token mean: average it across ranks (expert_tp
            # computes it on replicated tokens — the pmean is then a no-op
            # on the model axis; ep_a2a tokens are fully sharded).
            n_dev = 1
            for ax in axes:
                aux = managed.managed_all_reduce(aux, ax)
                n_dev *= ctx.axis_sizes[ax]
            loss = loss + 0.01 * (aux / n_dev) / cfg.n_layers
        return loss, {"loss": loss, "tokens": cnt}

    def _unembed(self, params: dict) -> Array:
        if self.cfg.tie_embeddings:
            # embed: [V_loc(model), D_loc(data)] -> unembed [D_loc, V_loc]
            return params["embed"].T
        return params["unembed"]

    # ------------------------------------------------------------------
    # Prefill (SP flow, collects cache in prefill layout)
    # ------------------------------------------------------------------

    def prefill_sp(self, params: dict, batch: dict) -> tuple[Array, Any]:
        """Prefill: returns (logits of the LAST position [B, V_loc(model)],
        cache in prefill layout).  Dry-run cells lower this as-is."""
        cfg, ctx = self.cfg, self.ctx
        x = self._assemble_input_sp(params, batch)
        enc_out = None
        if cfg.encoder is not None:
            enc_out = self._encoder_sp(params, batch["frames"])
        x, _, kvs, states = transformer.stack_sp(
            x, params["layers"], cfg, ctx, causal=True, collect_kv=True,
            enc_out=enc_out, remat=False)
        x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
        # The final global position lives on the LAST model rank's shard:
        # masked psum broadcasts its hidden state to every rank.
        last_loc = x[:, -1, :].astype(jnp.float32)          # [B_loc, D]
        is_last = (lax.axis_index("model") == ctx.tp - 1).astype(jnp.float32)
        last = managed.managed_all_reduce(last_loc * is_last, "model")
        w = self._unembed(params)
        from repro.core.overlap import fsdp_gather
        wg = fsdp_gather(w, "data", axis=0, mode=ctx.mdmp_mode)
        logits = jnp.dot(last, wg.astype(jnp.float32))      # [B, V_loc(mdl)]
        cache = {"kv": kvs, "ssm": states, "enc_out": enc_out}
        return logits, cache

    # ------------------------------------------------------------------
    # Decode (TP-2D flow)
    # ------------------------------------------------------------------

    def decode_step(self, params: dict, cache: Any, token: Array,
                    pos: Array) -> tuple[Array, Any]:
        """One greedy decode step.  token: [B] int32 (replicated);
        pos: [] int32.  Returns (next_token [B], new cache)."""
        cfg, ctx = self.cfg, self.ctx
        # embed_decode contracts vocab over 'model' and returns the
        # decode-layout [B, D_loc(data)] residual directly.
        x = layers.embed_decode(token, params["embed"], cfg, ctx)
        d_loc = cfg.d_model // ctx.dp
        r_d = lax.axis_index("data")

        stacked = params["layers"]
        x, new_cache = transformer.stack_decode(x, stacked, cache, pos,
                                                cfg, ctx)
        ln = lax.dynamic_slice_in_dim(params["final_ln"], r_d * d_loc,
                                      d_loc, axis=0)
        x = layers.rms_norm_sharded(x, ln, cfg.norm_eps, "data")
        if cfg.tie_embeddings:
            # embed [V_loc(model), D_loc(data)]: logits = x @ embed.T
            logits = managed.managed_all_reduce(
                jnp.dot(x, params["embed"].T), "data", mode=ctx.mdmp_mode)
        else:
            logits = layers.logits_decode(x, params["unembed"], ctx)
        nxt = layers.greedy_sample(logits, ctx)
        return nxt, new_cache

    def decode_step_paged(self, params: dict, cache: Any, table: Array,
                          token: Array, pos: Array, active: Array
                          ) -> tuple[Array, Any]:
        """One greedy decode step against the PAGED cache (the serving
        runtime's flow).  token: [B] int32 (replicated); table: [B, Pmax]
        int32 global page ids; pos: [B] int32 per-slot positions; active:
        [B] bool.  Returns (next_token [B], new cache) — outputs of
        inactive slots are garbage the engine discards, and their cache
        state does not advance."""
        cfg, ctx = self.cfg, self.ctx
        x = layers.embed_decode(token, params["embed"], cfg, ctx)
        d_loc = cfg.d_model // ctx.dp
        r_d = lax.axis_index("data")

        x, new_cache = transformer.stack_decode_paged(
            x, params["layers"], cache, table, pos, active, cfg, ctx)
        ln = lax.dynamic_slice_in_dim(params["final_ln"], r_d * d_loc,
                                      d_loc, axis=0)
        x = layers.rms_norm_sharded(x, ln, cfg.norm_eps, "data")
        if cfg.tie_embeddings:
            logits = managed.managed_all_reduce(
                jnp.dot(x, params["embed"].T), "data", mode=ctx.mdmp_mode)
        else:
            logits = layers.logits_decode(x, params["unembed"], ctx)
        nxt = layers.greedy_sample(logits, ctx)
        return nxt, new_cache

    # ------------------------------------------------------------------
    # Decode-cache construction (decode layout; used by serve + dry-run)
    # ------------------------------------------------------------------

    def decode_cache_specs(self, shape: ShapeConfig) -> tuple[Any, Any]:
        """Returns (ShapeDtypeStruct pytree, PartitionSpec pytree) for the
        decode-layout cache of this (arch, shape) cell."""
        cfg, ctx = self.cfg, self.ctx
        b = shape.global_batch                   # replicated in decode flow
        n_sh = attention.cache_shards(ctx)
        sax = (("pod", "data", "model") if ctx.has_pod else
               ("data", "model"))
        dt = jnp.dtype(cfg.dtype)
        kvp = attention.padded_kv_heads(cfg) if cfg.n_heads else 0
        hd = cfg.head_dim if cfg.n_heads else 0

        def kv_entry(s_total):
            s_pad = pad_to_multiple(s_total, n_sh)
            shp = (b, s_pad, kvp, hd)
            spec = P(None, sax, None, None)
            return (jax.ShapeDtypeStruct(shp, dt), spec)

        def ssm_entry():
            s = cfg.ssm
            h_loc_total = cfg.ssm_heads          # global; sharded by model
            di = cfg.ssm_heads * s.headdim
            hshp = (b, h_loc_total, s.headdim, s.d_state)
            hspec = P(None, "model", None, None)
            cshp = (b, s.d_conv - 1, di + 2 * s.d_state)
            # conv channels: x-part sharded over model, bc replicated —
            # stored separately to shard cleanly
            cx = (jax.ShapeDtypeStruct((b, s.d_conv - 1, di), dt),
                  P(None, None, "model"))
            cbc = (jax.ShapeDtypeStruct((b, s.d_conv - 1, 2 * s.d_state),
                                        dt), P(None, None, None))
            return ((jax.ShapeDtypeStruct(hshp, jnp.float32), hspec),
                    cx, cbc)

        def layer_entry(i):
            entry = {}
            if cfg.family != "ssm" and cfg.n_heads:
                w = transformer.layer_window(cfg, i)
                s_total = min(shape.seq_len, w) if w else shape.seq_len
                s_total = max(s_total, n_sh)
                entry["k"] = kv_entry(s_total)
                entry["v"] = kv_entry(s_total)
            if cfg.family in ("ssm", "hybrid"):
                h_e, cx, cbc = ssm_entry()
                entry["ssm_h"] = h_e
                entry["ssm_conv_x"] = cx
                entry["ssm_conv_bc"] = cbc
            if cfg.encoder is not None:
                f = pad_to_multiple(cfg.encoder.n_frames, n_sh)
                entry["xk"] = kv_entry(f)
                entry["xv"] = kv_entry(f)
            return entry

        if self.scan_layers:
            entry = layer_entry(0)
            out_sds = jax.tree.map(
                lambda e: jax.ShapeDtypeStruct(
                    (cfg.n_layers,) + e[0].shape, e[0].dtype),
                entry, is_leaf=lambda x: isinstance(x, tuple))
            out_specs = jax.tree.map(
                lambda e: P(None, *e[1]), entry,
                is_leaf=lambda x: isinstance(x, tuple))
            return out_sds, out_specs
        sds, specs = [], []
        for i in range(cfg.n_layers):
            e = layer_entry(i)
            sds.append(jax.tree.map(lambda t: t[0], e,
                                    is_leaf=lambda x: isinstance(x, tuple)))
            specs.append(jax.tree.map(lambda t: t[1], e,
                                      is_leaf=lambda x: isinstance(x, tuple)))
        return sds, specs


    # ------------------------------------------------------------------
    # Paged-cache construction (serving runtime; repro/serve)
    # ------------------------------------------------------------------

    def paged_cache_specs(self, slots: int, n_pages: int, page_size: int
                          ) -> tuple[Any, Any]:
        """Returns (ShapeDtypeStruct pytree, PartitionSpec pytree) for the
        paged serving cache: per-layer page POOLS [n_pages, page, KV, hd]
        (the page dim sharded over the cache axes — rank r owns global
        page ids [r*Np_loc, (r+1)*Np_loc)) plus slot-indexed SSM states.
        Unlike ``decode_cache_specs`` nothing scales with max_seq: memory
        is pages actually allocated, and completed sequences recycle their
        pages through the free list (serve/kv_cache.py)."""
        cfg, ctx = self.cfg, self.ctx
        n_sh = attention.cache_shards(ctx)
        assert n_pages % n_sh == 0, (n_pages, n_sh)
        assert cfg.encoder is None and cfg.vision is None, \
            "paged serving supports token-only decoders"
        sax = (("pod", "data", "model") if ctx.has_pod else
               ("data", "model"))
        dt = jnp.dtype(cfg.dtype)
        kvp = attention.padded_kv_heads(cfg) if cfg.n_heads else 0
        hd = cfg.head_dim if cfg.n_heads else 0

        def pool_entry():
            shp = (n_pages, page_size, kvp, hd)
            return (jax.ShapeDtypeStruct(shp, dt), P(sax, None, None, None))

        def ssm_entry():
            s = cfg.ssm
            di = cfg.ssm_heads * s.headdim
            hshp = (slots, cfg.ssm_heads, s.headdim, s.d_state)
            cx = (jax.ShapeDtypeStruct((slots, s.d_conv - 1, di), dt),
                  P(None, None, "model"))
            cbc = (jax.ShapeDtypeStruct((slots, s.d_conv - 1,
                                         2 * s.d_state), dt),
                   P(None, None, None))
            return ((jax.ShapeDtypeStruct(hshp, jnp.float32),
                     P(None, "model", None, None)), cx, cbc)

        def layer_entry(i):
            entry = {}
            if cfg.family != "ssm" and cfg.n_heads:
                entry["kp"] = pool_entry()
                entry["vp"] = pool_entry()
            if cfg.family in ("ssm", "hybrid"):
                h_e, cx, cbc = ssm_entry()
                entry["ssm_h"] = h_e
                entry["ssm_conv_x"] = cx
                entry["ssm_conv_bc"] = cbc
            return entry

        if self.scan_layers:
            entry = layer_entry(0)
            out_sds = jax.tree.map(
                lambda e: jax.ShapeDtypeStruct(
                    (cfg.n_layers,) + e[0].shape, e[0].dtype),
                entry, is_leaf=lambda x: isinstance(x, tuple))
            out_specs = jax.tree.map(
                lambda e: P(None, *e[1]), entry,
                is_leaf=lambda x: isinstance(x, tuple))
            return out_sds, out_specs
        sds, specs = [], []
        for i in range(cfg.n_layers):
            e = layer_entry(i)
            sds.append(jax.tree.map(lambda t: t[0], e,
                                    is_leaf=lambda x: isinstance(x, tuple)))
            specs.append(jax.tree.map(lambda t: t[1], e,
                                      is_leaf=lambda x: isinstance(x, tuple)))
        return sds, specs


def _sinusoidal(positions: Array, d: int) -> Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(10000.0) / max(half - 1, 1)))
    ang = positions[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
