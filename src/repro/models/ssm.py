"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in the explicit
shard_map world.

TP: SSD heads are sharded over the ``model`` axis (head count padded to a
TP multiple, DESIGN.md §3.3).  The fused input projection is computed with
the MDMP all-gather-matmul ring (sequence gathered while the projection
matmul runs); the output projection returns to sequence shards via
matmul-reduce-scatter.  The scan itself is chunk-parallel within a shard
(the SSD dual form: quadratic-in-chunk attention-like blocks + an
inter-chunk state recurrence) and communication-free — the paper's
technique applies to the projections and gradient reduction only
(DESIGN.md §3.3 arch-applicability).

Decode: O(1) state update per token (conv ring buffer + SSM state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import managed
from repro.core.overlap import fsdp_gather
from repro.models import layers
from repro.parallel.sharding import MeshCtx

Array = jax.Array


def ssd_dims(cfg: ModelConfig, ctx: MeshCtx) -> dict:
    s = cfg.ssm
    h = cfg.ssm_heads
    h_loc = h // ctx.tp
    p = s.headdim
    return dict(h=h, h_loc=h_loc, p=p, n=s.d_state, conv=s.d_conv,
                chunk=s.chunk, d_inner_loc=h_loc * p)


# ---------------------------------------------------------------------------
# Chunked SSD scan (per shard-local heads, full sequence)
# ---------------------------------------------------------------------------


def ssd_scan(x: Array, dt: Array, a: Array, b_mat: Array, c_mat: Array,
             d_skip: Array, chunk: int,
             h0: Array | None = None) -> tuple[Array, Array]:
    """SSD chunked dual form.

    x:     [B, S, H, P]     inputs per head
    dt:    [B, S, H]        softplus-activated step sizes
    a:     [H]              negative decay rates (A = -exp(a_log))
    b_mat: [B, S, N]        input maps (shared across heads, n_groups=1)
    c_mat: [B, S, N]        output maps
    d_skip:[H]              skip connection
    h0:    [B, H, P, N]     initial state (decode/chunked prefill)
    Returns (y [B, S, H, P], final_state [B, H, P, N]).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    nc = max(1, s // chunk)
    q = s // nc
    f32 = jnp.float32

    xc = x.reshape(bsz, nc, q, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, q, h).astype(f32)
    bc = b_mat.reshape(bsz, nc, q, n).astype(f32)
    cc = c_mat.reshape(bsz, nc, q, n).astype(f32)

    da = dtc * a[None, None, None, :]                   # [B,NC,Q,H] (<=0)
    cum = jnp.cumsum(da, axis=2)                        # within-chunk cumsum
    seg_end = cum[:, :, -1, :]                          # [B,NC,H]

    # --- intra-chunk (attention-like, lower-triangular decay mask) --------
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,NC,Q,Q,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    lmask = jnp.where(tri[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)          # [B,NC,Q,Q]
    w = cb[..., None] * lmask * dtc[:, :, None, :, :]   # [B,NC,Q,Q,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # --- chunk states ------------------------------------------------------
    decay_to_end = jnp.exp(seg_end[:, :, None, :] - cum)      # [B,NC,Q,H]
    sc = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                    bc, decay_to_end * dtc, xc)               # [B,NC,H,P,N]

    # --- inter-chunk recurrence (sequential scan over chunks) --------------
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), f32)

    def body(hprev, inputs):
        s_c, g = inputs                                  # g: [B,H] decay
        hnew = hprev * jnp.exp(g)[:, :, None, None] + s_c
        return hnew, hprev

    sc_t = jnp.moveaxis(sc, 1, 0)                        # [NC,B,H,P,N]
    g_t = jnp.moveaxis(seg_end, 1, 0)                    # [NC,B,H]
    h_final, h_before = lax.scan(body, h0.astype(f32), (sc_t, g_t))
    h_before = jnp.moveaxis(h_before, 0, 1)              # [B,NC,H,P,N]

    # --- inter-chunk contribution ------------------------------------------
    yc_in = jnp.einsum("bcqn,bchpn->bcqhp", cc, h_before)
    y_inter = yc_in * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + x.astype(f32) * d_skip[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssd_decode_step(xt: Array, dt: Array, a: Array, bt: Array, ct: Array,
                    d_skip: Array, h_state: Array) -> tuple[Array, Array]:
    """One-token SSD update.  xt: [B,H,P], dt: [B,H], bt/ct: [B,N],
    h_state: [B,H,P,N] -> (y [B,H,P], new state)."""
    f32 = jnp.float32
    xt_, dt_, bt_, ct_ = (t.astype(f32) for t in (xt, dt, bt, ct))
    da = jnp.exp(dt_ * a[None, :])                       # [B,H]
    upd = jnp.einsum("bhp,bn->bhpn", xt_ * dt_[..., None], bt_)
    hnew = h_state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", hnew, ct_)
    y = y + xt_ * d_skip[None, :, None]
    return y.astype(xt.dtype), hnew


# ---------------------------------------------------------------------------
# Depthwise causal conv over sequence (pre-SSD, on x|B|C channels)
# ---------------------------------------------------------------------------


def causal_conv(u: Array, w: Array, state: Array | None = None
                ) -> tuple[Array, Array]:
    """u: [B, S, C]; w: [K, C] depthwise kernel.  Returns (out [B,S,C],
    new conv state [B, K-1, C])."""
    bsz, s, c = u.shape
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((bsz, k - 1, c), u.dtype)
    up = jnp.concatenate([state, u], axis=1)            # [B, S+K-1, C]
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(k):
        out = out + up[:, i:i + s].astype(jnp.float32) * \
            w[i][None, None].astype(jnp.float32)
    new_state = up[:, s:]
    return jax.nn.silu(out).astype(u.dtype), new_state


def conv_step(ut: Array, w: Array, state: Array) -> tuple[Array, Array]:
    """One-token depthwise conv.  ut: [B, C]; state: [B, K-1, C]."""
    k = w.shape[0]
    window = jnp.concatenate([state, ut[:, None]], axis=1)  # [B, K, C]
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    return jax.nn.silu(out).astype(ut.dtype), window[:, 1:]


# ---------------------------------------------------------------------------
# Full Mamba-2 mixer (SP flow and decode flow)
# ---------------------------------------------------------------------------


def mamba_mixer_sp(x: Array, params: dict, cfg: ModelConfig, ctx: MeshCtx,
                   *, return_state: bool = False):
    """x: [B, S_loc, D] -> [B, S_loc, D].  Heads sharded over 'model';
    the in-projection ring gathers the sequence (MDMP)."""
    b = x.shape[0]
    dims = ssd_dims(cfg, ctx)
    h_loc, p, n = dims["h_loc"], dims["p"], dims["n"]

    # w_z/w_x: [D, di] heads sharded over model; w_bc: [D, 2N] replicated
    # over model; w_dt: [D, H] heads sharded.  ONE MDMP ring for all four.
    w_z = fsdp_gather(params["w_z"], "data", mode=ctx.mdmp_mode)
    w_x = fsdp_gather(params["w_x"], "data", mode=ctx.mdmp_mode)
    w_bc = fsdp_gather(params["w_bc"], "data", mode=ctx.mdmp_mode)
    w_dt = fsdp_gather(params["w_dt"], "data", mode=ctx.mdmp_mode)
    w_out = fsdp_gather(params["w_out"], "data", axis=1, mode=ctx.mdmp_mode)

    x2 = layers.to_ring(x)
    z2, xs2, bc2, dt2 = managed.all_gather_matmul_multi(
        x2, [w_z, w_x, w_bc, w_dt], "model", mode=ctx.mdmp_mode)
    z = layers.from_ring(z2, b)                          # [B, S, di]
    xs = layers.from_ring(xs2, b)                        # [B, S, di]
    bc = layers.from_ring(bc2, b)                        # [B, S, 2N]
    dt = layers.from_ring(dt2, b)                        # [B, S, H_loc]
    s_full = z.shape[1]
    di = h_loc * p

    conv_w = jnp.concatenate([params["conv_x"], params["conv_bc"]],
                             axis=-1)
    xbc = jnp.concatenate([xs, bc], axis=-1)
    xbc, conv_tail = causal_conv(xbc, conv_w)
    xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)

    a = -jnp.exp(params["a_log"].astype(jnp.float32))    # [H_loc]
    dt_act = jax.nn.softplus(dt.astype(jnp.float32)
                             + params["dt_bias"][None, None])
    y, h_final = ssd_scan(xs.reshape(b, s_full, h_loc, p), dt_act, a,
                          bmat, cmat, params["d_skip"], dims["chunk"])
    y = y.reshape(b, s_full, di)
    # gated norm over the FULL d_inner (heads are sharded over 'model' —
    # only the scalar sum-of-squares crosses the axis)
    y = layers.rms_norm_sharded(
        y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
        params["norm_w"], cfg.norm_eps, "model")

    y2 = managed.matmul_reduce_scatter(layers.to_ring(y), w_out, "model",
                                       mode=ctx.mdmp_mode)
    out = layers.from_ring(y2.astype(x.dtype), b)
    if return_state:
        # decode continues from the final SSM state + pre-conv tail
        return out, (h_final, conv_tail)
    return out


def mamba_mixer_decode(x: Array, state: tuple, params: dict,
                       cfg: ModelConfig, ctx: MeshCtx):
    """One-token mixer.  x: [B, D_loc(data)] (decode flow);
    state = (h_state [B,H_loc,P,N], conv_state [B,K-1,C]).
    Weight-stationary: in-projection contracts the FSDP dim with
    psum('data'); out-projection psum('model')."""
    dims = ssd_dims(cfg, ctx)
    h_loc, p, n = dims["h_loc"], dims["p"], dims["n"]
    di = h_loc * p
    h_state, conv_state = state

    zxbcdt = managed.managed_all_reduce(
        jnp.concatenate([jnp.dot(x, params["w_z"]),
                         jnp.dot(x, params["w_x"]),
                         jnp.dot(x, params["w_bc"]),
                         jnp.dot(x, params["w_dt"])], axis=-1),
        "data", mode=ctx.mdmp_mode)
    z, xs, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    conv_w = jnp.concatenate([params["conv_x"], params["conv_bc"]],
                             axis=-1)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    xbc, conv_state = conv_step(xbc, conv_w, conv_state)
    xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)

    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    dt_act = jax.nn.softplus(dt.astype(jnp.float32)
                             + params["dt_bias"][None])
    bsz = x.shape[0]
    y, h_state = ssd_decode_step(
        xs.reshape(bsz, h_loc, p), dt_act, a, bmat, cmat,
        params["d_skip"], h_state)
    y = y.reshape(bsz, di)
    y = layers.rms_norm_sharded(
        y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
        params["norm_w"], cfg.norm_eps, "model")
    out = managed.managed_all_reduce(
        jnp.dot(y, params["w_out"]), "model", mode=ctx.mdmp_mode)
    return out.astype(x.dtype), (h_state, conv_state)
