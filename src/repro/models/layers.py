"""Shared layers — norms, MLPs, embeddings, RoPE — in the explicit
shard_map world.

Layout conventions (training / prefill — the "SP flow"):
  * residual stream  x:  [B, S_loc, D]   (sequence sharded over ``model``)
  * ring-op layout   x2: [S_loc * B, D]  (S-major rows so ring all-gather
                         along axis 0 yields rank-ordered full sequence)
  * weights arrive as LOCAL shards; the FSDP (``data``) dimension is
    gathered on use via mdmp.fsdp_gather (whose autodiff transpose is the
    as-ready reduce-scatter of the gradient — the paper's send-on-last-
    write applied to gradients).

Decode flow ("TP-2D"): batch replicated, alternating psum axes; see
attention.py and model.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import managed
from repro.core.overlap import fsdp_gather
from repro.parallel.sharding import MeshCtx

Array = jax.Array


# ---------------------------------------------------------------------------
# Layout shuffles between [B, S_loc, D] and the S-major ring layout
# ---------------------------------------------------------------------------


def to_ring(x: Array) -> Array:
    """[B, S_loc, D] -> [S_loc*B, D] (S-major)."""
    b, s, d = x.shape
    return x.transpose(1, 0, 2).reshape(s * b, d)


def from_ring(x2: Array, batch: int) -> Array:
    """[S*B, D] -> [B, S, D]."""
    sb, d = x2.shape
    s = sb // batch
    return x2.reshape(s, batch, d).transpose(1, 0, 2)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rms_norm_sharded(x: Array, scale_loc: Array, eps: float,
                     axis_name: str) -> Array:
    """RMSNorm over a feature dim sharded across ``axis_name`` (decode
    flow): only the scalar sum-of-squares crosses the link."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    ssq = jnp.sum(xf * xf, axis=-1, keepdims=True)
    d_total = x.shape[-1] * lax.psum(1, axis_name)
    var = managed.managed_all_reduce(ssq, axis_name) / d_total
    out = xf * lax.rsqrt(var + eps)
    return (out * (1.0 + scale_loc.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------


def activation(name: str, u: Array, g: Array | None) -> Array:
    """Gated (u = gate, g = linear) or plain activation."""
    if name == "swiglu":
        return jax.nn.silu(u) * g
    if name == "geglu":
        return jax.nn.gelu(u) * g
    if name == "relu2":
        r = jax.nn.relu(u)
        return r * r
    if name == "gelu":
        return jax.nn.gelu(u)
    raise ValueError(name)


def gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


def mlp_block_sp(x: Array, params: dict, cfg: ModelConfig,
                 ctx: MeshCtx) -> Array:
    """Dense MLP, SP flow: AG-matmul up (+gate fused into one ring), local
    activation, matmul-RS down.  x: [B, S_loc, D] -> same."""
    b = x.shape[0]
    w_up = fsdp_gather(params["w_up"], "data", mode=ctx.mdmp_mode)
    w_down = fsdp_gather(params["w_down"], "data", axis=1,
                         mode=ctx.mdmp_mode)
    x2 = to_ring(x)
    if gated(cfg.mlp):
        w_gate = fsdp_gather(params["w_gate"], "data", mode=ctx.mdmp_mode)
        # ONE ring gathers the sequence while computing up AND gate columns.
        u, g = managed.all_gather_matmul_multi(x2, [w_up, w_gate], "model",
                                               mode=ctx.mdmp_mode)
        h = activation(cfg.mlp, u, g)
    else:
        u2 = managed.all_gather_matmul(x2, w_up, "model",
                                       mode=ctx.mdmp_mode)
        h = activation(cfg.mlp, u2, None)
    y2 = managed.matmul_reduce_scatter(h, w_down, "model",
                                       mode=ctx.mdmp_mode)
    return from_ring(y2.astype(x.dtype), b)


def mlp_block_decode(x: Array, params: dict, cfg: ModelConfig,
                     ctx: MeshCtx) -> Array:
    """Dense MLP, decode flow (TP-2D): x [B, D_loc(data)] -> same.
    Weight-stationary: contract the FSDP dim with psum('data'), come back
    with psum('model')."""
    if gated(cfg.mlp):
        ug = managed.managed_all_reduce(
            jnp.concatenate([jnp.dot(x, params["w_up"]),
                             jnp.dot(x, params["w_gate"])], axis=-1),
            "data", mode=ctx.mdmp_mode)
        uu, g = jnp.split(ug, 2, axis=-1)
        h = activation(cfg.mlp, uu, g)
    else:
        u = managed.managed_all_reduce(
            jnp.dot(x, params["w_up"]), "data", mode=ctx.mdmp_mode)
        h = activation(cfg.mlp, u, None)
    y = managed.managed_all_reduce(
        jnp.dot(h, params["w_down"]), "model", mode=ctx.mdmp_mode)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding + loss (vocab-parallel over the ``model`` axis)
# ---------------------------------------------------------------------------


def embed_sp(tokens: Array, table_loc: Array, cfg: ModelConfig,
             ctx: MeshCtx) -> Array:
    """Vocab-parallel embedding lookup fused with the sequence scatter:
    one-hot(tokens) @ table is a matmul whose contraction dim (vocab) is
    sharded over ``model`` — exactly matmul_reduce_scatter's shape.  Each
    ring step materialises the one-hot block for one sequence shard only.

    tokens: [B, S] (replicated over model) -> x [B, S_loc, D].
    """
    b, s = tokens.shape
    v_loc = table_loc.shape[0]
    # table_loc: [V_loc(model), D_loc(data)] — FSDP-gather columns on use.
    if table_loc.shape[-1] != cfg.d_model:
        table = fsdp_gather(table_loc, "data", axis=1, mode=ctx.mdmp_mode)
    else:
        table = table_loc
    vidx = lax.axis_index("model") * v_loc
    tok2 = tokens.transpose(1, 0).reshape(s * b)          # S-major
    onehot = jax.nn.one_hot(tok2 - vidx, v_loc, dtype=table.dtype)
    x2 = managed.matmul_reduce_scatter(onehot, table, "model",
                                       mode=ctx.mdmp_mode)
    return from_ring(x2, b)


def embed_decode(tokens: Array, table_loc: Array, cfg: ModelConfig,
                 ctx: MeshCtx) -> Array:
    """Decode-flow lookup: tokens [B] (replicated) -> x [B, D_loc(data)].
    table_loc: [V_loc(model), D_loc(data)]."""
    v_loc = table_loc.shape[0]
    vidx = lax.axis_index("model") * v_loc
    onehot = jax.nn.one_hot(tokens - vidx, v_loc, dtype=table_loc.dtype)
    partial = jnp.dot(onehot, table_loc)
    return managed.managed_all_reduce(partial, "model", mode=ctx.mdmp_mode)


def lm_loss_sp(x: Array, unembed_loc: Array, tokens: Array, cfg: ModelConfig,
               ctx: MeshCtx, *, chunk: int = 512) -> tuple[Array, Array]:
    """Cross-entropy over vocab-parallel logits, chunked over the sequence
    so the [*, V_loc] logits tensor never fully materialises.

    The final hidden is first gathered over 'model' (one MDMP ring) so that
    every rank holds every position — the vocab-parallel reductions then
    cross the model axis with position-replicated stats (mixing seq shards
    with vocab shards in one psum would corrupt rows).

    x: [B, S_loc, D]; unembed_loc: [D_loc(data), V_loc(model)];
    tokens: [B, S] labels.  Returns (sum_loss_local / tp, count / tp) —
    caller psums over ALL axes (the /tp cancels the model-axis
    replication).
    """
    b, s_loc, d = x.shape
    w = fsdp_gather(unembed_loc, "data", mode=ctx.mdmp_mode)   # [D, V_loc]
    v_loc = w.shape[1]
    vidx = lax.axis_index("model") * v_loc

    x_full = from_ring(
        managed.managed_all_gather(to_ring(x), "model",
                                   mode=ctx.mdmp_mode), b)     # [B, S, D]
    s = x_full.shape[1]
    labels_all = tokens                                        # [B, S]

    n_chunks = max(1, s // max(chunk, 1))
    chunk = s // n_chunks

    def body(carry, i):
        loss_sum, count = carry
        xs = lax.dynamic_slice_in_dim(x_full, i * chunk, chunk, axis=1)
        lbl = lax.dynamic_slice_in_dim(labels_all, i * chunk, chunk, axis=1)
        # bf16 operands with f32 accumulation: halves the CE read traffic
        # (the memory-term hillclimb, EXPERIMENTS.md §Perf N-H3) at
        # standard mixed-precision numerics
        logits = jnp.dot(xs, w, preferred_element_type=jnp.float32)
        logits = logits.astype(jnp.float32)
        # vocab-parallel logsumexp: stats cross the model axis, logits don't
        # (the max is a constant shift — stop_gradient keeps it out of AD)
        lmax = lax.pmax(
            lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True)),
            "model")
        lse = jnp.log(managed.managed_all_reduce(
            jnp.sum(jnp.exp(logits - lmax), axis=-1, keepdims=True),
            "model")) + lmax
        onehot = jax.nn.one_hot(lbl - vidx, v_loc, dtype=jnp.float32)
        tgt = managed.managed_all_reduce(
            jnp.sum(logits * onehot, axis=-1, keepdims=True), "model")
        nll = (lse - tgt)[..., 0]
        valid = (lbl >= 0).astype(jnp.float32)
        loss_sum = loss_sum + jnp.sum(nll * valid)
        count = count + jnp.sum(valid)
        return (loss_sum, count), None

    (loss_sum, count), _ = lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), jnp.arange(n_chunks))
    tp = ctx.tp
    return loss_sum / tp, count / tp


def logits_decode(x: Array, unembed_loc: Array, ctx: MeshCtx) -> Array:
    """Decode-flow logits: x [B, D_loc(data)] @ W_un [D_loc, V_loc(model)]
    -> psum('data') -> [B, V_loc(model)]."""
    partial = jnp.dot(x, unembed_loc)
    return managed.managed_all_reduce(partial, "data", mode=ctx.mdmp_mode)


def greedy_sample(logits_loc: Array, ctx: MeshCtx) -> Array:
    """Greedy decode across vocab-parallel logits [B, V_loc(model)]."""
    v_loc = logits_loc.shape[-1]
    vidx = lax.axis_index("model") * v_loc
    local_max = jnp.max(logits_loc, axis=-1)
    local_arg = jnp.argmax(logits_loc, axis=-1) + vidx
    gmax = lax.pmax(local_max, "model")
    cand = jnp.where(local_max >= gmax, local_arg, jnp.iinfo(jnp.int32).max)
    return lax.pmin(cand.astype(jnp.int32), "model")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope_slots(x: Array, positions: Array, theta: float) -> Array:
    """Per-slot RoPE for the serving decode flow: every batch row sits at
    its OWN position.  x: [B, H, hd]; positions: [B] int32.  The batch
    axis plays apply_rope's position axis, so this is exactly the same
    rotation — no second copy of the formula to keep in sync."""
    return apply_rope(x[None], positions, theta)[0]


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [B, S, H, hd]; positions: [S] (global positions)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(angles)[None, :, None, :]             # [1, S, 1, hd/2]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)
