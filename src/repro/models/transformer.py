"""Block assembly + scan-over-layers for every assigned family.

SP flow blocks take/return [B, S_loc, D]; decode blocks [B, D_loc(data)].
Layers are stacked on a leading ``layers`` dim and run under ``lax.scan``
(keeps HLO size independent of depth — essential for 96-layer dry-runs),
with ``jax.checkpoint`` around the block body for training remat.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import managed
from repro.models import attention, layers, moe, ssm
from repro.parallel.sharding import MeshCtx

Array = jax.Array


# ---------------------------------------------------------------------------
# SP-flow blocks (train / prefill)
# ---------------------------------------------------------------------------


def block_sp(x: Array, p: dict, cfg: ModelConfig, ctx: MeshCtx, *,
             causal: bool, window, collect_kv: bool):
    """One decoder block.  ``window`` may be a traced scalar (hybrid archs
    scan over per-layer window sizes).  Returns (x, aux_loss, kv|None,
    ssm_state|None)."""
    aux = jnp.float32(0.0)
    kv = None
    sstate = None

    if cfg.family == "ssm":
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        if collect_kv:
            y, sstate = ssm.mamba_mixer_sp(h, p, cfg, ctx, return_state=True)
        else:
            y = ssm.mamba_mixer_sp(h, p, cfg, ctx)
        return x + y, aux, kv, sstate

    attn_fn = {
        "ulysses": attention.attention_sp_ulysses,
        "ring": attention.attention_sp_ring,
        "auto": attention.attention_sp_auto,   # cost-model-chosen schedule
    }.get(cfg.attn_impl, attention.attention_sp)
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        att = attn_fn(h, p, cfg, ctx, causal=causal,
                      window=window, return_kv=collect_kv)
        if collect_kv:
            att, kv = att
            y_ssm, sstate = ssm.mamba_mixer_sp(h, p["ssm"], cfg, ctx,
                                               return_state=True)
        else:
            y_ssm = ssm.mamba_mixer_sp(h, p["ssm"], cfg, ctx)
        x = x + 0.5 * (att + y_ssm)
    else:
        att = attn_fn(h, p, cfg, ctx, causal=causal,
                      window=window, return_kv=collect_kv)
        if collect_kv:
            att, kv = att
        x = x + att

    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe.moe_block(h2, p, cfg, ctx)
    else:
        y = layers.mlp_block_sp(h2, p, cfg, ctx)
    return x + y, aux, kv, sstate


def cross_block_sp(x: Array, p: dict, enc_out: Array, cfg: ModelConfig,
                   ctx: MeshCtx) -> Array:
    """Whisper decoder cross-attention sub-block.  enc_out: [B, F_loc, D]
    (frame-sharded over 'model')."""
    b = x.shape[0]
    h = layers.rms_norm(x, p["ln_x"], cfg.norm_eps)
    tp = ctx.tp
    h_loc = cfg.padded_heads // tp
    kvh = attention.padded_kv_heads(cfg)
    hd = cfg.head_dim

    from repro.core.overlap import fsdp_gather
    wq = fsdp_gather(p["w_q_x"], "data", mode=ctx.mdmp_mode)
    wkv = fsdp_gather(p["w_kv_x"], "data", mode=ctx.mdmp_mode)
    wo = fsdp_gather(p["w_o_x"], "data", axis=1, mode=ctx.mdmp_mode)

    q2 = managed.all_gather_matmul(layers.to_ring(h), wq, "model",
                                   mode=ctx.mdmp_mode)
    kv2 = managed.all_gather_matmul(layers.to_ring(enc_out), wkv, "model",
                                    mode=ctx.mdmp_mode)
    s_full = q2.shape[0] // b
    f_full = kv2.shape[0] // b
    q = layers.from_ring(q2, b).reshape(b, s_full, h_loc, hd)
    k, v = jnp.split(layers.from_ring(kv2, b), 2, axis=-1)
    k = k.reshape(b, f_full, kvh, hd)
    v = v.reshape(b, f_full, kvh, hd)
    k, v, _ = attention._local_kv_slice(k, v, cfg, ctx)
    o = attention.attend(q, k, v, causal=False)
    y2 = managed.matmul_reduce_scatter(
        layers.to_ring(o.reshape(b, s_full, h_loc * hd)), wo, "model",
        mode=ctx.mdmp_mode)
    return x + layers.from_ring(y2.astype(x.dtype), b)


def stack_sp(x: Array, stacked: dict, cfg: ModelConfig, ctx: MeshCtx, *,
             causal: bool = True, collect_kv: bool = False,
             enc_out: Array | None = None, remat: bool | None = None):
    """Run the block over layers.  ``stacked`` is a leaf-stacked pytree
    (scanned) or a per-layer list (unrolled — hybrid archs, whose per-layer
    cache shapes and static windows preclude a uniform scan).
    Returns (x, aux_sum, kv_stack|None, ssm_states|None)."""
    remat = cfg.remat if remat is None else remat
    if isinstance(stacked, (list, tuple)):
        return _stack_sp_unrolled(x, stacked, cfg, ctx, causal=causal,
                                  collect_kv=collect_kv, enc_out=enc_out,
                                  remat=remat)
    window = cfg.sliding_window   # uniform across scanned layers

    def body(carry, p):
        xc = carry
        if enc_out is not None:
            # whisper decoder: self-attn block + cross-attn sub-block
            xc, aux, kv, st = block_sp(xc, p, cfg, ctx, causal=causal,
                                       window=window, collect_kv=collect_kv)
            xc = cross_block_sp(xc, p, enc_out, cfg, ctx)
        else:
            xc, aux, kv, st = block_sp(xc, p, cfg, ctx, causal=causal,
                                       window=window, collect_kv=collect_kv)
        outs = (aux, kv, st)
        return xc, outs

    fn = jax.checkpoint(body, prevent_cse=False) if remat else body
    x, (auxs, kvs, states) = lax.scan(fn, x, stacked)
    aux = jnp.sum(auxs)
    return x, aux, kvs, states


def _stack_sp_unrolled(x: Array, per_layer: list, cfg: ModelConfig,
                       ctx: MeshCtx, *, causal: bool, collect_kv: bool,
                       enc_out: Array | None, remat: bool):
    aux = jnp.float32(0.0)
    kvs, states = [], []
    for i, p in enumerate(per_layer):
        window = layer_window(cfg, i)

        def run(xc, p, window=window):
            out = block_sp(xc, p, cfg, ctx, causal=causal, window=window,
                           collect_kv=collect_kv)
            if enc_out is not None:
                xc2, a, kv, st = out
                xc2 = cross_block_sp(xc2, p, enc_out, cfg, ctx)
                return xc2, a, kv, st
            return out

        # prevent_cse=True is LOAD-BEARING here: in an unrolled python
        # loop XLA CSE merges the bwd recompute back into the fwd,
        # silently reinstating every saved activation (measured: 313 GiB
        # -> remat'd on the hymba train cell).  Scan bodies (stack_sp
        # scanned path) are CSE-immune, so they keep prevent_cse=False.
        fn = jax.checkpoint(run, prevent_cse=True) if remat else run
        x, a, kv, st = fn(x, p)
        aux = aux + a
        kvs.append(kv)
        states.append(st)
    kv_out = kvs if collect_kv else None
    st_out = states if collect_kv else None
    return x, aux, kv_out, st_out


def layer_window(cfg: ModelConfig, i: int) -> int:
    """Static per-layer window (0 = full attention)."""
    if cfg.sliding_window and cfg.family == "hybrid":
        return 0 if i in cfg.full_attn_layers else cfg.sliding_window
    return cfg.sliding_window


# ---------------------------------------------------------------------------
# Decode-flow blocks
# ---------------------------------------------------------------------------


def _ln_loc(scale: Array, ctx: MeshCtx) -> Array:
    """Replicated [D] norm scale -> this data-rank's [D_loc] slice
    (decode-flow residual is D-sharded over 'data')."""
    d_loc = scale.shape[0] // ctx.dp
    return lax.dynamic_slice_in_dim(scale, lax.axis_index("data") * d_loc,
                                    d_loc, axis=0)


def _ssm_decode(x, p, state, cfg, ctx):
    cs = jnp.concatenate([state["ssm_conv_x"], state["ssm_conv_bc"]],
                         axis=-1)
    y, (hs, cs2) = ssm.mamba_mixer_decode(x, (state["ssm_h"], cs), p, cfg,
                                          ctx)
    di = state["ssm_conv_x"].shape[-1]
    return y, hs, cs2[..., :di], cs2[..., di:]


def block_decode(x: Array, p: dict, state: dict, pos: Array,
                 cfg: ModelConfig, ctx: MeshCtx, *, window) -> tuple:
    """One-token decode block.  state: per-layer slice of the decode cache
    pytree.  Returns (x, new_state)."""
    new_state = dict(state)

    if cfg.family == "ssm":
        h = layers.rms_norm_sharded(x, _ln_loc(p["ln1"], ctx), cfg.norm_eps,
                                    "data")
        y, hs, cx, cbc = _ssm_decode(h, p, state, cfg, ctx)
        new_state["ssm_h"] = hs
        new_state["ssm_conv_x"], new_state["ssm_conv_bc"] = cx, cbc
        return x + y, new_state

    h = layers.rms_norm_sharded(x, _ln_loc(p["ln1"], ctx), cfg.norm_eps,
                                "data")
    att, (k_c, v_c) = attention.attention_decode(
        h, (state["k"], state["v"]), pos, p, cfg, ctx, window=window)
    new_state["k"], new_state["v"] = k_c, v_c
    if cfg.family == "hybrid":
        y_ssm, hs, cx, cbc = _ssm_decode(h, p["ssm"], state, cfg, ctx)
        new_state["ssm_h"] = hs
        new_state["ssm_conv_x"], new_state["ssm_conv_bc"] = cx, cbc
        x = x + 0.5 * (att + y_ssm)
    else:
        x = x + att

    if cfg.encoder is not None:
        x = cross_block_decode(x, p, (state["xk"], state["xv"]), cfg, ctx)

    h2 = layers.rms_norm_sharded(x, _ln_loc(p["ln2"], ctx), cfg.norm_eps,
                                 "data")
    if cfg.family == "moe":
        y = moe.moe_block_decode(h2, p, cfg, ctx)
    else:
        y = layers.mlp_block_decode(h2, p, cfg, ctx)
    return x + y, new_state


def cross_block_decode(x: Array, p: dict, enc_kv: tuple, cfg: ModelConfig,
                       ctx: MeshCtx) -> Array:
    """Whisper decode cross-attention against the precomputed encoder KV
    (frame-sharded over the cache axes; LSE merge, no cache write)."""
    import math
    b = x.shape[0]
    tp = ctx.tp
    h_ = cfg.padded_heads
    h_loc = h_ // tp
    kvh = attention.padded_kv_heads(cfg)
    hd = cfg.head_dim
    k_enc, v_enc = enc_kv

    hx = layers.rms_norm_sharded(x, _ln_loc(p["ln_x"], ctx), cfg.norm_eps,
                                 "data")
    q = managed.managed_all_reduce(jnp.dot(hx, p["w_q_x"]), "data",
                                   mode=ctx.mdmp_mode)
    q = q.reshape(b, h_loc, hd)
    q_all = managed.managed_all_gather(q.transpose(1, 0, 2), "model",
                                       mode=ctx.mdmp_mode).transpose(1, 0, 2)
    groups = h_ // kvh
    qg = q_all.reshape(b, kvh, groups, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_enc,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    m_loc = jnp.max(logits, axis=-1)
    m_glob = lax.pmax(m_loc, attention.cache_axes(ctx))
    pr = jnp.exp(logits - m_glob[..., None])
    l_loc = jnp.sum(pr, axis=-1)
    o_loc = jnp.einsum("bkgs,bskd->bkgd", pr.astype(v_enc.dtype), v_enc,
                       preferred_element_type=jnp.float32)
    l_g, o_g = l_loc, o_loc
    for ax in attention.cache_axes(ctx):
        l_g = managed.managed_all_reduce(l_g, ax)
        o_g = managed.managed_all_reduce(o_g, ax)
    o = (o_g / jnp.maximum(l_g[..., None], 1e-30)).reshape(b, h_, hd)
    r_m = lax.axis_index("model")
    o_my = lax.dynamic_slice_in_dim(o.astype(x.dtype), r_m * h_loc, h_loc,
                                    axis=1)
    y = managed.managed_all_reduce(
        jnp.dot(o_my.reshape(b, h_loc * hd), p["w_o_x"]), "model",
        mode=ctx.mdmp_mode)
    return x + y.astype(x.dtype)


def _mask_state(new: Any, old: Any, active: Array) -> Any:
    """Keep ``old`` state leaves for inactive slots (leading dim = B)."""
    def sel(n, o):
        act = active.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(act, n, o)
    return jax.tree.map(sel, new, old)


_SSM_KEYS = ("ssm_h", "ssm_conv_x", "ssm_conv_bc")


def _ssm_state_paged(state: dict, pos: Array, active: Array) -> dict:
    """Slot-reuse hygiene: a slot stepping at pos 0 is starting a NEW
    request, so its carried SSM state (from the slot's previous occupant)
    is replaced with the zero init.  KV pages need no reset — attention
    masks every position beyond the slot's lens."""
    fresh = active & (pos == 0)
    def z(leaf):
        f = fresh.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.where(f, jnp.zeros_like(leaf), leaf)
    return {k: z(state[k]) for k in _SSM_KEYS}


def block_decode_paged(x: Array, p: dict, state: dict, table: Array,
                       pos: Array, active: Array, cfg: ModelConfig,
                       ctx: MeshCtx, *, window) -> tuple:
    """One-token decode block against the PAGED cache (serving runtime).
    ``state`` holds ("kp", "vp") page pools instead of ("k", "v") slabs;
    ``pos``/``active`` are per-slot [B] (continuous batching mixes slots
    at different positions).  SSM states are slot-indexed as before but
    masked so inactive slots don't advance and reused slots start from
    the zero init.  Returns (x, new_state)."""
    new_state = dict(state)

    if cfg.family == "ssm":
        h = layers.rms_norm_sharded(x, _ln_loc(p["ln1"], ctx), cfg.norm_eps,
                                    "data")
        ssm_in = _ssm_state_paged(state, pos, active)
        y, hs, cx, cbc = _ssm_decode(h, p, ssm_in, cfg, ctx)
        upd = _mask_state({"ssm_h": hs, "ssm_conv_x": cx,
                           "ssm_conv_bc": cbc},
                          {k: state[k] for k in _SSM_KEYS}, active)
        new_state.update(upd)
        return x + y, new_state

    h = layers.rms_norm_sharded(x, _ln_loc(p["ln1"], ctx), cfg.norm_eps,
                                "data")
    att, (kp, vp) = attention.attention_decode_paged(
        h, (state["kp"], state["vp"]), table, pos, active, p, cfg, ctx,
        window=window)
    new_state["kp"], new_state["vp"] = kp, vp
    if cfg.family == "hybrid":
        ssm_in = _ssm_state_paged(state, pos, active)
        y_ssm, hs, cx, cbc = _ssm_decode(h, p["ssm"], ssm_in, cfg, ctx)
        upd = _mask_state({"ssm_h": hs, "ssm_conv_x": cx,
                           "ssm_conv_bc": cbc},
                          {k: state[k] for k in _SSM_KEYS}, active)
        new_state.update(upd)
        x = x + 0.5 * (att + y_ssm)
    else:
        x = x + att

    h2 = layers.rms_norm_sharded(x, _ln_loc(p["ln2"], ctx), cfg.norm_eps,
                                 "data")
    if cfg.family == "moe":
        y = moe.moe_block_decode(h2, p, cfg, ctx)
    else:
        y = layers.mlp_block_decode(h2, p, cfg, ctx)
    return x + y, new_state


def stack_decode_paged(x: Array, stacked: dict, cache, table: Array,
                       pos: Array, active: Array, cfg: ModelConfig,
                       ctx: MeshCtx) -> tuple[Array, Any]:
    """Paged-cache decode over layers; mirrors ``stack_decode`` (scanned
    when cache leaves carry a leading [L], unrolled for hybrid archs)."""
    if isinstance(stacked, (list, tuple)):
        new_cache = []
        for i, (p, state) in enumerate(zip(stacked, cache)):
            x, st = block_decode_paged(x, p, state, table, pos, active,
                                       cfg, ctx, window=layer_window(cfg, i))
            new_cache.append(st)
        return x, new_cache

    window = cfg.sliding_window   # uniform across scanned layers

    def body(carry, xs):
        xc = carry
        p, state = xs
        xc, new_state = block_decode_paged(xc, p, state, table, pos,
                                           active, cfg, ctx, window=window)
        return xc, new_state

    x, new_cache = lax.scan(body, x, (stacked, cache))
    return x, new_cache


def stack_decode(x: Array, stacked: dict, cache, pos: Array,
                 cfg: ModelConfig, ctx: MeshCtx) -> tuple[Array, Any]:
    """Decode blocks over layers.  Scanned (cache leaves [L, ...]) or
    unrolled (per-layer cache list — hybrid archs whose SWA/global cache
    shapes differ)."""
    if isinstance(stacked, (list, tuple)):
        new_cache = []
        for i, (p, state) in enumerate(zip(stacked, cache)):
            x, st = block_decode(x, p, state, pos, cfg, ctx,
                                 window=layer_window(cfg, i))
            new_cache.append(st)
        return x, new_cache

    window = cfg.sliding_window   # uniform across scanned layers

    def body(carry, xs):
        xc = carry
        p, state = xs
        xc, new_state = block_decode(xc, p, state, pos, cfg, ctx,
                                     window=window)
        return xc, new_state

    x, new_cache = lax.scan(body, x, (stacked, cache))
    return x, new_cache
