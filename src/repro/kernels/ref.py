"""Pure-jnp oracles for every kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                        window: int = 0, q_offset: int = 0) -> Array:
    """Dense attention oracle.  q: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd]
    (GQA: head h attends kv head h * KV // H)."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    groups = h // kvh
    qg = q.reshape(b, sq, kvh, groups, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def jacobi_step_ref(u: Array, f: Array) -> Array:
    """5-point Jacobi sweep on the interior of u ([M, N], Dirichlet
    boundary rows/cols held fixed)."""
    new = 0.25 * (u[:-2, 1:-1] + u[2:, 1:-1] + u[1:-1, :-2] + u[1:-1, 2:]
                  - f[1:-1, 1:-1])
    return u.at[1:-1, 1:-1].set(new.astype(u.dtype))


def jacobi_multistep_ref(u: Array, f: Array, k: int) -> Array:
    """k unit Jacobi sweeps — the bulk oracle for the temporally-blocked
    kernel (kernels/stencil.py::jacobi_multistep_pallas)."""
    for _ in range(k):
        u = jacobi_step_ref(u, f)
    return u
