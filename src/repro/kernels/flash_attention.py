"""Blockwise (flash) attention — Pallas TPU kernel.

Online-softmax attention that never materialises the [Sq, Skv] logits.
Grid: (batch, q_heads, q_blocks, kv_blocks) with the kv dimension iterated
innermost; running max / sum / output accumulators live in VMEM scratch and
persist across kv steps (the standard TPU flash pattern).  GQA is handled
in the k/v BlockSpec index maps (head h reads kv head h * KV // H) — no
head-expansion copies.  Causal + sliding-window masking is applied
per-block; fully-masked blocks still run (grid is static) but their
contribution is zero.

Two kernel entry points:

  * ``flash_attention_pallas``       — self-contained attention over a full
    KV operand (init -> accumulate -> normalise in one pallas_call).
  * ``flash_attention_carry_pallas`` — the STREAMED variant for ring
    attention (managed.managed_ring_attention): the (m, l, acc) online-
    softmax state is carried IN and OUT instead of initialised/normalised,
    so one call consumes one KV block as it arrives off the ring and the
    next call continues where it left off.  q/k global offsets are traced
    SMEM scalars (they depend on lax.axis_index inside shard_map).
    ``merge_partials``/``finalize_partials`` are the LSE-merge combinators
    shared by this kernel, the pure-jnp engine (kernels/ops.py), and the
    distributed tests — merging partials over ANY kv split is exact up to
    float reduction order.

VMEM budget per step (bf16, blk_q = blk_kv = 512, hd = 256):
q/k/v blocks 3 * 512*256*2 = 768 KB + f32 accumulators 512*256*4 = 512 KB
— comfortably inside the ~128 MB/core VMEM with double buffering; block
sizes are MXU-aligned multiples of 128 (tuned in ops.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, q_offset: int, blk_q: int,
                  blk_kv: int, n_kv_blocks: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)               # [blk_q, hd]
    k = k_ref[...].astype(jnp.float32)               # [blk_kv, hd]
    v = v_ref[...].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [blk_q, blk_kv]

    qpos = q_offset + qi * blk_q + lax.broadcasted_iota(
        jnp.int32, (blk_q, blk_kv), 0)
    kpos = ki * blk_kv + lax.broadcasted_iota(
        jnp.int32, (blk_q, blk_kv), 1)
    mask = jnp.ones((blk_q, blk_kv), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                              # [blk_q, 1]
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           causal: bool = True, window: int = 0,
                           q_offset: int = 0, blk_q: int = 128,
                           blk_kv: int = 128,
                           interpret: bool = False) -> Array:
    """q: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd] -> [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    blk_q = min(blk_q, sq)
    blk_kv = min(blk_kv, skv)
    assert sq % blk_q == 0 and skv % blk_kv == 0, (sq, skv, blk_q, blk_kv)
    nq = sq // blk_q
    nk = skv // blk_kv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, q_offset=q_offset,
        blk_q=blk_q, blk_kv=blk_kv, n_kv_blocks=nk, scale=scale)

    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, blk_q, None, hd),
                         lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((None, blk_kv, None, hd),
                         lambda b_, h_, qi, ki, kvh=kvh, h=h:
                         (b_, ki, h_ * kvh // h, 0)),
            pl.BlockSpec((None, blk_kv, None, hd),
                         lambda b_, h_, qi, ki, kvh=kvh, h=h:
                         (b_, ki, h_ * kvh // h, 0)),
        ],
        out_specs=pl.BlockSpec((None, blk_q, None, hd),
                               lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pl_scratch((blk_q, 1), jnp.float32),
            pl_scratch((blk_q, 1), jnp.float32),
            pl_scratch((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out


def pl_scratch(shape, dtype):
    from jax.experimental import pallas as pl_mod
    try:
        return pl_mod.VMEM(shape, dtype)          # newer API
    except AttributeError:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)


# ---------------------------------------------------------------------------
# Online-softmax partials: init / merge / finalize
#
# Public carry layout (matches q): m, l: [B, Sq, H] f32; acc: [B, Sq, H, hd]
# f32.  ``out = acc / l`` and ``lse = m + log(l)`` only at finalize — every
# intermediate stays unnormalised so partials from disjoint KV ranges
# combine with one LSE merge.
# ---------------------------------------------------------------------------


def init_partials(b: int, sq: int, h: int, hd: int
                  ) -> tuple[Array, Array, Array]:
    """Empty carry: max = -inf (finite sentinel), sum = 0, acc = 0."""
    m = jnp.full((b, sq, h), NEG_INF, jnp.float32)
    l = jnp.zeros((b, sq, h), jnp.float32)
    acc = jnp.zeros((b, sq, h, hd), jnp.float32)
    return m, l, acc


def merge_partials(p1: tuple[Array, Array, Array],
                   p2: tuple[Array, Array, Array]
                   ) -> tuple[Array, Array, Array]:
    """LSE-merge two flash partials over disjoint KV ranges.  Commutative
    and associative up to float rounding; an empty carry (init_partials)
    is the identity."""
    m1, l1, a1 = p1
    m2, l2, a2 = p2
    m = jnp.maximum(m1, m2)
    w1 = jnp.exp(m1 - m)
    w2 = jnp.exp(m2 - m)
    l = w1 * l1 + w2 * l2
    acc = w1[..., None] * a1 + w2[..., None] * a2
    return m, l, acc


def finalize_partials(m: Array, l: Array, acc: Array,
                      out_dtype=jnp.float32) -> tuple[Array, Array]:
    """(m, l, acc) -> (out [B, Sq, H, hd], lse [B, Sq, H])."""
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).astype(out_dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


# ---------------------------------------------------------------------------
# Carry-in / carry-out kernel (ring-attention step)
# ---------------------------------------------------------------------------


def _flash_kernel_carry(off_ref, q_ref, k_ref, v_ref, m_in, l_in, acc_in,
                        m_out, l_out, acc_out, m_scr, l_scr, acc_scr, *,
                        causal: bool, window: int, blk_q: int, blk_kv: int,
                        n_kv_blocks: int, scale: float):
    """Same online-softmax update as _flash_kernel, but the running state
    enters/leaves through refs instead of being initialised/normalised, and
    the q/k global offsets come from SMEM (traced per-rank values)."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    q_offset = off_ref[0, 0]
    k_offset = off_ref[0, 1]

    @pl.when(ki == 0)
    def _load_carry():
        m_scr[...] = m_in[...]
        l_scr[...] = l_in[...]
        acc_scr[...] = acc_in[...]

    q = q_ref[...].astype(jnp.float32)               # [blk_q, hd]
    k = k_ref[...].astype(jnp.float32)               # [blk_kv, hd]
    v = v_ref[...].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [blk_q, blk_kv]

    qpos = q_offset + qi * blk_q + lax.broadcasted_iota(
        jnp.int32, (blk_q, blk_kv), 0)
    kpos = k_offset + ki * blk_kv + lax.broadcasted_iota(
        jnp.int32, (blk_q, blk_kv), 1)
    mask = jnp.ones((blk_q, blk_kv), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                              # [blk_q, 1]
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _store_carry():
        m_out[...] = m_scr[...]
        l_out[...] = l_scr[...]
        acc_out[...] = acc_scr[...]


def flash_attention_carry_pallas(q: Array, k: Array, v: Array,
                                 m: Array, l: Array, acc: Array, *,
                                 causal: bool = True, window: int = 0,
                                 q_offset=0, k_offset=0,
                                 blk_q: int = 128, blk_kv: int = 128,
                                 interpret: bool = False
                                 ) -> tuple[Array, Array, Array]:
    """One streamed flash step: fold the KV block [B, Skv, KV, hd] into the
    carry (m, l, acc) for q [B, Sq, H, hd].  ``q_offset``/``k_offset`` are
    the GLOBAL positions of q[0]/k[0] and may be traced int32 scalars
    (ring ranks derive them from lax.axis_index) — they ride in SMEM."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    blk_q = min(blk_q, sq)
    blk_kv = min(blk_kv, skv)
    assert sq % blk_q == 0 and skv % blk_kv == 0, (sq, skv, blk_q, blk_kv)
    nq = sq // blk_q
    nk = skv // blk_kv
    scale = 1.0 / math.sqrt(hd)

    offs = jnp.stack([jnp.asarray(q_offset, jnp.int32),
                      jnp.asarray(k_offset, jnp.int32)]).reshape(1, 2)
    m4 = m[..., None]                     # [B, Sq, H, 1] (2-D VMEM blocks)
    l4 = l[..., None]

    kernel = functools.partial(
        _flash_kernel_carry, causal=causal, window=window,
        blk_q=blk_q, blk_kv=blk_kv, n_kv_blocks=nk, scale=scale)

    grid = (b, h, nq, nk)
    kv_spec = pl.BlockSpec((None, blk_kv, None, hd),
                           lambda b_, h_, qi, ki, kvh=kvh, h=h:
                           (b_, ki, h_ * kvh // h, 0))
    ml_spec = pl.BlockSpec((None, blk_q, None, 1),
                           lambda b_, h_, qi, ki: (b_, qi, h_, 0))
    acc_spec = pl.BlockSpec((None, blk_q, None, hd),
                            lambda b_, h_, qi, ki: (b_, qi, h_, 0))
    m_new, l_new, acc_new = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # (q_offset, k_offset)
            pl.BlockSpec((None, blk_q, None, hd),
                         lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            kv_spec,
            kv_spec,
            ml_spec, ml_spec, acc_spec,
        ],
        out_specs=[ml_spec, ml_spec, acc_spec],
        out_shape=[
            jax.ShapeDtypeStruct(m4.shape, jnp.float32),
            jax.ShapeDtypeStruct(l4.shape, jnp.float32),
            jax.ShapeDtypeStruct(acc.shape, jnp.float32),
        ],
        scratch_shapes=[
            pl_scratch((blk_q, 1), jnp.float32),
            pl_scratch((blk_q, 1), jnp.float32),
            pl_scratch((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(offs, q, k, v, m4, l4, acc)
    return m_new[..., 0], l_new[..., 0], acc_new
