"""Blockwise (flash) attention — Pallas TPU kernel.

Online-softmax attention that never materialises the [Sq, Skv] logits.
Grid: (batch, q_heads, q_blocks, kv_blocks) with the kv dimension iterated
innermost; running max / sum / output accumulators live in VMEM scratch and
persist across kv steps (the standard TPU flash pattern).  GQA is handled
in the k/v BlockSpec index maps (head h reads kv head h * KV // H) — no
head-expansion copies.  Causal + sliding-window masking is applied
per-block; fully-masked blocks still run (grid is static) but their
contribution is zero.

VMEM budget per step (bf16, blk_q = blk_kv = 512, hd = 256):
q/k/v blocks 3 * 512*256*2 = 768 KB + f32 accumulators 512*256*4 = 512 KB
— comfortably inside the ~128 MB/core VMEM with double buffering; block
sizes are MXU-aligned multiples of 128 (tuned in ops.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, q_offset: int, blk_q: int,
                  blk_kv: int, n_kv_blocks: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)               # [blk_q, hd]
    k = k_ref[...].astype(jnp.float32)               # [blk_kv, hd]
    v = v_ref[...].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [blk_q, blk_kv]

    qpos = q_offset + qi * blk_q + lax.broadcasted_iota(
        jnp.int32, (blk_q, blk_kv), 0)
    kpos = ki * blk_kv + lax.broadcasted_iota(
        jnp.int32, (blk_q, blk_kv), 1)
    mask = jnp.ones((blk_q, blk_kv), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                              # [blk_q, 1]
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           causal: bool = True, window: int = 0,
                           q_offset: int = 0, blk_q: int = 128,
                           blk_kv: int = 128,
                           interpret: bool = False) -> Array:
    """q: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd] -> [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    blk_q = min(blk_q, sq)
    blk_kv = min(blk_kv, skv)
    assert sq % blk_q == 0 and skv % blk_kv == 0, (sq, skv, blk_q, blk_kv)
    nq = sq // blk_q
    nk = skv // blk_kv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, q_offset=q_offset,
        blk_q=blk_q, blk_kv=blk_kv, n_kv_blocks=nk, scale=scale)

    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, blk_q, None, hd),
                         lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((None, blk_kv, None, hd),
                         lambda b_, h_, qi, ki, kvh=kvh, h=h:
                         (b_, ki, h_ * kvh // h, 0)),
            pl.BlockSpec((None, blk_kv, None, hd),
                         lambda b_, h_, qi, ki, kvh=kvh, h=h:
                         (b_, ki, h_ * kvh // h, 0)),
        ],
        out_specs=pl.BlockSpec((None, blk_q, None, hd),
                               lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pl_scratch((blk_q, 1), jnp.float32),
            pl_scratch((blk_q, 1), jnp.float32),
            pl_scratch((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out


def pl_scratch(shape, dtype):
    from jax.experimental import pallas as pl_mod
    try:
        return pl_mod.VMEM(shape, dtype)          # newer API
    except AttributeError:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
