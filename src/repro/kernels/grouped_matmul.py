"""Grouped-expert GEMM — Pallas TPU kernel + jnp oracle engine.

The MoE capacity buffers are [G, C, D] groups of padded rows (G = E
experts, or E_loc x tp (expert, source-rank) groups after the EP
all_to_all); only the first ``valid[g]`` rows of each group hold real
tokens — the rest are zero padding sized by the capacity factor.  A plain
einsum burns FLOPs on every padded row; this kernel walks the groups with
a scalar-prefetched per-group valid count (from ``dispatch_indices``'
keep mask) so capacity blocks past the valid rows are skipped outright —
padded rows cost no FLOPs.

Two engines with identical math (engine-matched on the shared pattern):

  * ``grouped_expert_ffn`` with the Pallas path — grid (G, C/blk); the
    valid counts ride in scalar-prefetch SMEM
    (``pltpu.PrefetchScalarGridSpec``, same mechanism as
    kernels/paged_attention.py's page tables); blocks whose first row is
    past ``valid[g]`` write zeros without touching the MXU, partial
    blocks mask rows before the dot so the zero rows contribute exact
    zeros.
  * the jnp engine — rows masked by the same predicate, then the batched
    einsum; bit-exact against the kernel (both contract D in f32 with
    the same activation ops) including the padded capacity rows.

The Pallas path carries a custom VJP whose backward recomputes through
the jnp engine (the padded-row saving is a forward-schedule property;
the backward reuses the masked operands, O(G x C x D) residuals).
"""

from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _act(mlp: str, u: Array, g: Array | None) -> Array:
    """models/layers.py::activation, replicated here so the kernel layer
    does not import the model layer (same jnp primitives — engine match
    relies on it)."""
    if mlp == "swiglu":
        return jax.nn.silu(u) * g
    if mlp == "geglu":
        return jax.nn.gelu(u) * g
    if mlp == "relu2":
        r = jax.nn.relu(u)
        return r * r
    if mlp == "gelu":
        return jax.nn.gelu(u)
    raise ValueError(mlp)


def gated(mlp: str) -> bool:
    return mlp in ("swiglu", "geglu")


def _mask_rows(h: Array, valid: Array) -> Array:
    """Zero rows >= valid[g] (h: [G, C, D]; valid: [G])."""
    rows = jnp.arange(h.shape[1])
    live = rows[None, :, None] < valid[:, None, None]
    return jnp.where(live, h, jnp.zeros((), h.dtype))


# ---------------------------------------------------------------------------
# jnp engine (the oracle; also the backward of the Pallas path)
# ---------------------------------------------------------------------------


def grouped_expert_ffn_jnp(h: Array, w1: Array, w1_gate: Array | None,
                           w2: Array, valid: Array, mlp: str) -> Array:
    """h: [G, C, D] capacity groups; valid: [G] rows kept per group;
    w1 (+w1_gate): [E, D, F]; w2: [E, F, D] with G % E == 0 (group g uses
    expert g // (G/E) — the (expert, source-rank) grouping of the EP
    all_to_all).  Returns [G, C, D] in h's dtype; rows >= valid are
    exactly zero."""
    e = w1.shape[0]
    gpe = h.shape[0] // e
    hm = _mask_rows(h, valid)

    def per_expert(w):
        return jnp.repeat(w, gpe, axis=0) if gpe > 1 else w

    u = jnp.einsum("gcd,gdf->gcf", hm, per_expert(w1),
                   preferred_element_type=jnp.float32)
    if gated(mlp):
        g = jnp.einsum("gcd,gdf->gcf", hm, per_expert(w1_gate),
                       preferred_element_type=jnp.float32)
        act = _act(mlp, u, g)
    else:
        act = _act(mlp, u, None)
    out = jnp.einsum("gcf,gfd->gcd", act, per_expert(w2).astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(h.dtype)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------


def _gemm_kernel(valid_ref, h_ref, *wo_refs, blk_c: int, mlp: str):
    if gated(mlp):
        w1_ref, w1g_ref, w2_ref, o_ref = wo_refs
    else:
        w1_ref, w2_ref, o_ref = wo_refs
        w1g_ref = None
    g = pl.program_id(0)
    i = pl.program_id(1)
    v = valid_ref[g]

    @pl.when(i * blk_c < v)
    def _compute():
        rows = i * blk_c + jax.lax.broadcasted_iota(jnp.int32,
                                                    (blk_c, 1), 0)
        h = jnp.where(rows < v, h_ref[...], jnp.zeros((), h_ref.dtype))
        u = jax.lax.dot_general(h, w1_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if w1g_ref is not None:
            gg = jax.lax.dot_general(h, w1g_ref[...],
                                     (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            act = _act(mlp, u, gg)
        else:
            act = _act(mlp, u, None)
        out = jax.lax.dot_general(act, w2_ref[...].astype(jnp.float32),
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)

    @pl.when(i * blk_c >= v)
    def _skip():
        # fully padded capacity block: no MXU work, exact zeros out
        o_ref[...] = jnp.zeros_like(o_ref)


def grouped_expert_ffn_pallas(h: Array, w1: Array, w1_gate: Array | None,
                              w2: Array, valid: Array, mlp: str, *,
                              blk_c: int = 128,
                              interpret: bool = False) -> Array:
    """The Pallas engine (see module docstring).  ``valid`` may be any
    integer/float array of per-group counts; blocks wholly past the count
    are skipped via the scalar-prefetched predicate."""
    G, c, d = h.shape
    e = w1.shape[0]
    gpe = G // e
    assert G % e == 0, (G, e)
    blk = blk_c if (c % blk_c == 0) else c
    kernel = functools.partial(_gemm_kernel, blk_c=blk, mlp=mlp)

    def w_spec(w):
        return pl.BlockSpec((None,) + w.shape[1:],
                            lambda g_, i, v: (g_ // gpe, 0, 0))

    in_specs = [pl.BlockSpec((None, blk, d), lambda g_, i, v: (g_, i, 0)),
                w_spec(w1)]
    operands = [h, w1]
    if gated(mlp):
        in_specs.append(w_spec(w1_gate))
        operands.append(w1_gate)
    in_specs.append(w_spec(w2))
    operands.append(w2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                       # valid counts
        grid=(G, c // blk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, blk, d), lambda g_, i, v: (g_, i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(h.shape, h.dtype),
        interpret=interpret,
    )(valid.astype(jnp.int32), *operands)


# ---------------------------------------------------------------------------
# Differentiable Pallas path: bwd recomputes through the jnp engine
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _pallas_ffn(operands, valid_f, mlp, interpret):
    h, w1, w1g, w2 = operands
    return grouped_expert_ffn_pallas(h, w1, w1g if w1g is not None else None,
                                     w2, valid_f, mlp, interpret=interpret)


def _pallas_ffn_fwd(operands, valid_f, mlp, interpret):
    return _pallas_ffn(operands, valid_f, mlp, interpret), \
        (operands, valid_f)


def _pallas_ffn_bwd(mlp, interpret, res, dy):
    operands, valid_f = res
    h, w1, w1g, w2 = operands

    def ref(h_, w1_, w1g_, w2_):
        return grouped_expert_ffn_jnp(h_, w1_, w1g_, w2_, valid_f, mlp)

    if w1g is None:
        _, vjp = jax.vjp(lambda a, b, c: ref(a, b, None, c), h, w1, w2)
        dh, dw1, dw2 = vjp(dy)
        dw1g = None
    else:
        _, vjp = jax.vjp(ref, h, w1, w1g, w2)
        dh, dw1, dw1g, dw2 = vjp(dy)
    return (dh, dw1, dw1g, dw2), jnp.zeros_like(valid_f)


_pallas_ffn.defvjp(_pallas_ffn_fwd, _pallas_ffn_bwd)


# ---------------------------------------------------------------------------
# Dispatch (mirrors kernels/paged_attention.py::paged_attention)
# ---------------------------------------------------------------------------


def grouped_expert_ffn(h: Array, w1: Array, w1_gate: Array | None,
                       w2: Array, valid: Array, *, mlp: str,
                       engine: str = "auto") -> Array:
    """Batched expert FFN over capacity groups with padded rows skipped:
    Pallas kernel on TPU (or REPRO_PALLAS=interpret), jnp masked einsum
    elsewhere.  ``engine`` pins an implementation for tests."""
    from repro.kernels.ops import _pallas_mode
    # valid rides as f32 through the custom VJP (counts are tiny ints —
    # exact in f32) so the cotangent is ordinary zeros, not float0
    valid_f = valid.astype(jnp.float32)
    if engine == "pallas" or (engine == "auto"
                              and _pallas_mode() in ("on", "interpret")):
        return _pallas_ffn((h, w1, w1_gate, w2), valid_f, mlp,
                           _pallas_mode() != "on")
    return grouped_expert_ffn_jnp(h, w1, w1_gate, w2, valid_f, mlp)
