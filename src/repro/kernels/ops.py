"""Jit'd kernel entry points + dispatch policy.

``flash_attention`` picks the best implementation for the runtime:
  * Pallas TPU kernel (flash_attention.py) on TPU backends, or when
    REPRO_PALLAS=interpret forces interpret-mode execution (CPU tests);
  * blockwise pure-jnp flash (same online-softmax math, lax.scan over kv
    blocks — memory O(Sq * blk)) for long sequences elsewhere, including
    the 512-device CPU dry-run where the [Sq, Skv] logits of a 32k prefill
    would be terabytes;
  * the dense reference for small shapes.
"""

from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ref
from repro.kernels.flash_attention import (finalize_partials,  # noqa: F401
                                           flash_attention_carry_pallas,
                                           flash_attention_pallas,
                                           init_partials, merge_partials)
from repro.kernels.stencil import jacobi_step_pallas  # noqa: F401 (re-export)

Array = jax.Array

#: sequences at or above this use a blockwise implementation
DENSE_MAX_SEQ = 2048


def _pallas_mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "on", "off"):
        return env
    return "on" if jax.default_backend() == "tpu" else "off"


def flash_attention_applicable(q: Array, k: Array, v: Array) -> bool:
    """attend() fast-path predicate: True when any blockwise impl should
    replace the dense reference."""
    return (q.ndim == 4 and k.ndim == 4
            and q.shape[1] * k.shape[1] >= DENSE_MAX_SEQ * DENSE_MAX_SEQ
            or _pallas_mode() in ("on", "interpret"))


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "blk_q", "blk_kv"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, q_offset: int = 0, blk_q: int = 128,
                    blk_kv: int = 128) -> Array:
    return _flash_vjp(q, k, v, causal, window, q_offset, blk_q, blk_kv)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_vjp(q, k, v, causal, window, q_offset, blk_q, blk_kv):
    """Flash attention with a FLASH backward: fwd saves only (out, lse);
    bwd recomputes probabilities block-by-block.  Without this, scanning
    the online softmax saves a stacked f32 [nk, ..., Sq, blk] probability
    tensor per attention — measured as the top HBM/residual offender in
    every train cell."""
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, blk_q,
                             blk_kv)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_offset, blk_q, blk_kv):
    mode = _pallas_mode()
    sq, skv = q.shape[1], k.shape[1]
    if mode in ("on", "interpret") and sq % min(blk_q, sq) == 0 \
            and skv % min(blk_kv, skv) == 0:
        out = flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            blk_q=blk_q, blk_kv=blk_kv, interpret=(mode == "interpret"))
        # lse recomputed blockwise for the bwd residual (cheap: no V pass);
        # a production TPU build would emit it from the fwd kernel.
        lse = _lse_blockwise(q, k, causal, window, q_offset,
                             max(blk_kv, 512))
        return out, lse
    if sq * skv > DENSE_MAX_SEQ * DENSE_MAX_SEQ:
        return _blockwise_fwd(q, k, v, causal, window, q_offset,
                              max(blk_kv, 512))
    out = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset)
    lse = _lse_blockwise(q, k, causal, window, q_offset, k.shape[1])
    return out, lse


def _flash_fwd_rule(q, k, v, causal, window, q_offset, blk_q, blk_kv):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, blk_q,
                               blk_kv)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, q_offset, blk_q, blk_kv, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_blockwise(q, k, v, out, lse, dout, causal, window,
                                q_offset, max(blk_kv, 512))


_flash_vjp.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def _pad_kv(k, v, blk):
    skv = k.shape[1]
    if skv % blk:
        pad = blk - skv % blk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k, v, skv


def _blk_mask(sq, blk, ki, qpos, skv_valid, causal, window):
    kpos = ki * blk + jnp.arange(blk)
    mask = jnp.broadcast_to((kpos < skv_valid)[None, :], (sq, blk))
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    return mask


def _blockwise_fwd(q, k, v, causal, window, q_offset, blk_kv):
    """Online-softmax forward returning (out, lse)."""
    b, sq, h, hd = q.shape
    k, v, skv_valid = _pad_kv(k, v, min(blk_kv, k.shape[1]))
    skv = k.shape[1]
    blk = min(blk_kv, skv)
    nk = skv // blk
    kvh = k.shape[2]
    groups = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(b, sq, kvh, groups, hd).astype(jnp.float32) * scale
    qf = qf.transpose(0, 2, 3, 1, 4)                 # [b,kvh,g,sq,hd]
    qpos = q_offset + jnp.arange(sq)

    def body(carry, ki):
        m, l, acc = carry
        ks = lax.dynamic_slice_in_dim(k, ki * blk, blk, axis=1)
        vs = lax.dynamic_slice_in_dim(v, ki * blk, blk, axis=1)
        logits = jnp.einsum("bkgqd,bskd->bkgqs", qf,
                            ks.astype(jnp.float32))
        mask = _blk_mask(sq, blk, ki, qpos, skv_valid, causal, window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vs.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, groups, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, groups, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, groups, sq, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))         # [b,kvh,g,sq]
    return out, lse


def _lse_blockwise(q, k, causal, window, q_offset, blk_kv):
    """LSE only (no V pass) — residual for kernels without an lse output."""
    b, sq, h, hd = q.shape
    k2, _, skv_valid = _pad_kv(k, k, min(blk_kv, k.shape[1]))
    skv = k2.shape[1]
    blk = min(blk_kv, skv)
    nk = skv // blk
    kvh = k.shape[2]
    groups = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(b, sq, kvh, groups, hd).astype(jnp.float32) * scale
    qf = qf.transpose(0, 2, 3, 1, 4)
    qpos = q_offset + jnp.arange(sq)

    def body(carry, ki):
        m, l = carry
        ks = lax.dynamic_slice_in_dim(k2, ki * blk, blk, axis=1)
        logits = jnp.einsum("bkgqd,bskd->bkgqs", qf,
                            ks.astype(jnp.float32))
        mask = _blk_mask(sq, blk, ki, qpos, skv_valid, causal, window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        l_new = jnp.exp(m - m_new) * l + jnp.sum(
            jnp.where(mask[None, None, None],
                      jnp.exp(logits - m_new[..., None]), 0.0), axis=-1)
        return (m_new, l_new), None

    m0 = jnp.full((b, kvh, groups, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, groups, sq), jnp.float32)
    (m, l), _ = lax.scan(body, (m0, l0), jnp.arange(nk))
    return m + jnp.log(jnp.maximum(l, 1e-30))


def _flash_bwd_blockwise(q, k, v, out, lse, dout, causal, window, q_offset,
                         blk_kv):
    """Flash backward: recompute p per kv block from (q, k, lse); memory
    stays O(block), matching the fwd."""
    b, sq, h, hd = q.shape
    k, v, skv_valid = _pad_kv(k, v, min(blk_kv, k.shape[1]))
    skv = k.shape[1]
    blk = min(blk_kv, skv)
    nk = skv // blk
    kvh = k.shape[2]
    groups = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(b, sq, kvh, groups, hd).astype(jnp.float32)
    qf = qf.transpose(0, 2, 3, 1, 4)                 # [b,kvh,g,sq,hd]
    do = dout.reshape(b, sq, kvh, groups, hd).astype(jnp.float32)
    do = do.transpose(0, 2, 3, 1, 4)
    of = out.reshape(b, sq, kvh, groups, hd).astype(jnp.float32)
    of = of.transpose(0, 2, 3, 1, 4)
    dsum = jnp.sum(do * of, axis=-1)                 # [b,kvh,g,sq]
    qpos = q_offset + jnp.arange(sq)

    def body(dq_acc, ki):
        ks = lax.dynamic_slice_in_dim(k, ki * blk, blk, axis=1) \
            .astype(jnp.float32)
        vs = lax.dynamic_slice_in_dim(v, ki * blk, blk, axis=1) \
            .astype(jnp.float32)
        logits = jnp.einsum("bkgqd,bskd->bkgqs", qf * scale, ks)
        mask = _blk_mask(sq, blk, ki, qpos, skv_valid, causal, window)
        p = jnp.where(mask[None, None, None],
                      jnp.exp(logits - lse[..., None]), 0.0)
        dv_blk = jnp.einsum("bkgqs,bkgqd->bskd", p, do)
        dp = jnp.einsum("bkgqd,bskd->bkgqs", do, vs)
        ds = p * (dp - dsum[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bkgqs,bskd->bkgqd", ds, ks)
        dk_blk = jnp.einsum("bkgqs,bkgqd->bskd", ds, qf)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, kvh, groups, sq, hd), jnp.float32)
    dq, (dk_blks, dv_blks) = lax.scan(body, dq0, jnp.arange(nk))
    dq = dq.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)
    dk = dk_blks.transpose(1, 0, 2, 3, 4).reshape(b, skv, kvh, hd)
    dv = dv_blks.transpose(1, 0, 2, 3, 4).reshape(b, skv, kvh, hd)
    dk = dk[:, :skv_valid].astype(k.dtype)
    dv = dv[:, :skv_valid].astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Streamed flash steps (ring attention) — carry in/out, traced offsets
# ---------------------------------------------------------------------------


def _step_mask(sq, blk, ki, q_offset, k_offset, skv_valid, causal, window):
    """Mask for one kv sub-block when BOTH q and k sit at global offsets
    (which may be traced scalars — ring ranks derive them from
    lax.axis_index).  ``skv_valid`` masks the zero-padding of ragged kv."""
    qpos = q_offset + jnp.arange(sq)
    kloc = ki * blk + jnp.arange(blk)
    kpos = k_offset + kloc
    mask = jnp.broadcast_to((kloc < skv_valid)[None, :], (sq, blk))
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    return mask


def _to_grouped(x, kvh, groups):
    """[B, Sq, H(, hd)] -> [b, kvh, g, sq(, hd)] (internal GQA layout)."""
    b, sq = x.shape[:2]
    if x.ndim == 3:
        return x.reshape(b, sq, kvh, groups).transpose(0, 2, 3, 1)
    hd = x.shape[-1]
    return x.reshape(b, sq, kvh, groups, hd).transpose(0, 2, 3, 1, 4)


def _from_grouped(x):
    """[b, kvh, g, sq(, hd)] -> [B, Sq, H(, hd)]."""
    b, kvh, g, sq = x.shape[:4]
    if x.ndim == 4:
        return x.transpose(0, 3, 1, 2).reshape(b, sq, kvh * g)
    return x.transpose(0, 3, 1, 2, 4).reshape(b, sq, kvh * g, x.shape[-1])


def _flash_step_jnp(q, k, v, m, l, acc, causal, window, q_offset, k_offset,
                    blk_kv):
    """Pure-jnp carry step (lax.scan over kv sub-blocks) — the attend_ref-
    family engine behind flash_attention_step where Pallas can't lower."""
    b, sq, h, hd = q.shape
    k, v, skv_valid = _pad_kv(k, v, min(blk_kv, k.shape[1]))
    skv = k.shape[1]
    blk = min(blk_kv, skv)
    nk = skv // blk
    kvh = k.shape[2]
    groups = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qf = _to_grouped(q.astype(jnp.float32) * scale, kvh, groups)

    mi = _to_grouped(m, kvh, groups)
    li = _to_grouped(l, kvh, groups)
    ai = _to_grouped(acc, kvh, groups)

    def body(carry, ki):
        mc, lc, ac = carry
        ks = lax.dynamic_slice_in_dim(k, ki * blk, blk, axis=1)
        vs = lax.dynamic_slice_in_dim(v, ki * blk, blk, axis=1)
        logits = jnp.einsum("bkgqd,bskd->bkgqs", qf,
                            ks.astype(jnp.float32))
        mask = _step_mask(sq, blk, ki, q_offset, k_offset, skv_valid,
                          causal, window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(mc, m_cur)
        alpha = jnp.exp(mc - m_new)
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        l_new = alpha * lc + jnp.sum(p, axis=-1)
        a_new = ac * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vs.astype(jnp.float32))
        return (m_new, l_new, a_new), None

    (mi, li, ai), _ = lax.scan(body, (mi, li, ai), jnp.arange(nk))
    return _from_grouped(mi), _from_grouped(li), _from_grouped(ai)


def flash_attention_step(q: Array, k: Array, v: Array,
                         carry: tuple[Array, Array, Array] | None = None, *,
                         causal: bool = True, window: int = 0,
                         q_offset=0, k_offset=0, blk_q: int = 128,
                         blk_kv: int = 128) -> tuple[Array, Array, Array]:
    """Fold one KV block into an online-softmax carry (m, l, acc — the
    public [B, Sq, H(, hd)] layout of kernels/flash_attention.py).

    This is the per-arrival work item of ring attention: each ring step
    calls it on the KV block that just landed while the next block is in
    flight.  ``q_offset``/``k_offset`` may be traced int32 scalars.
    Dispatch mirrors ``flash_attention``: Pallas carry kernel on TPU (or
    REPRO_PALLAS=interpret), jnp blockwise scan elsewhere."""
    b, sq, h, hd = q.shape
    if carry is None:
        carry = init_partials(b, sq, h, hd)
    m, l, acc = carry
    mode = _pallas_mode()
    skv = k.shape[1]
    if mode in ("on", "interpret") and sq % min(blk_q, sq) == 0 \
            and skv % min(blk_kv, skv) == 0:
        return flash_attention_carry_pallas(
            q, k, v, m, l, acc, causal=causal, window=window,
            q_offset=q_offset, k_offset=k_offset, blk_q=blk_q,
            blk_kv=blk_kv, interpret=(mode == "interpret"))
    return _flash_step_jnp(q, k, v, m, l, acc, causal, window, q_offset,
                           k_offset, max(blk_kv, 512))


def flash_attention_bwd_block(q: Array, k: Array, v: Array, dout: Array,
                              lse: Array, dsum: Array, *, causal: bool,
                              window: int = 0, q_offset=0, k_offset=0,
                              blk_kv: int = 512
                              ) -> tuple[Array, Array, Array]:
    """Backward of one streamed flash step, recomputing p from (q, k, lse).

    q, dout: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd];
    lse, dsum: [B, Sq, H] (dsum = sum(dout * out, -1), computed once by the
    caller — it is block-independent).  Returns f32 (dq_contrib, dk, dv) so
    ring ranks can accumulate across steps without dtype round-trips.
    Offsets may be traced; memory stays O(Sq * blk) via the inner scan."""
    b, sq, h, hd = q.shape
    k, v, skv_valid = _pad_kv(k, v, min(blk_kv, k.shape[1]))
    skv = k.shape[1]
    blk = min(blk_kv, skv)
    nk = skv // blk
    kvh = k.shape[2]
    groups = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qf = _to_grouped(q.astype(jnp.float32), kvh, groups)
    do = _to_grouped(dout.astype(jnp.float32), kvh, groups)
    lse_g = _to_grouped(lse, kvh, groups)
    dsum_g = _to_grouped(dsum, kvh, groups)

    def body(dq_acc, ki):
        ks = lax.dynamic_slice_in_dim(k, ki * blk, blk, axis=1) \
            .astype(jnp.float32)
        vs = lax.dynamic_slice_in_dim(v, ki * blk, blk, axis=1) \
            .astype(jnp.float32)
        logits = jnp.einsum("bkgqd,bskd->bkgqs", qf * scale, ks)
        mask = _step_mask(sq, blk, ki, q_offset, k_offset, skv_valid,
                          causal, window)
        p = jnp.where(mask[None, None, None],
                      jnp.exp(logits - lse_g[..., None]), 0.0)
        dv_blk = jnp.einsum("bkgqs,bkgqd->bskd", p, do)
        dp = jnp.einsum("bkgqd,bskd->bkgqs", do, vs)
        ds = p * (dp - dsum_g[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bkgqs,bskd->bkgqd", ds, ks)
        dk_blk = jnp.einsum("bkgqs,bkgqd->bskd", ds, qf)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, kvh, groups, sq, hd), jnp.float32)
    dq, (dk_blks, dv_blks) = lax.scan(body, dq0, jnp.arange(nk))
    dq = _from_grouped(dq)
    dk = dk_blks.transpose(1, 0, 2, 3, 4).reshape(b, skv, kvh, hd)
    dv = dv_blks.transpose(1, 0, 2, 3, 4).reshape(b, skv, kvh, hd)
    return dq, dk[:, :skv_valid], dv[:, :skv_valid]


def flash_attention_blockwise(q: Array, k: Array, v: Array, *,
                              causal: bool = True, window: int = 0,
                              q_offset: int = 0, blk_kv: int = 512) -> Array:
    """Online-softmax flash in pure jnp (lax.scan over kv blocks).  Same
    math as the Pallas kernel; used where Pallas can't lower (CPU dry-run)
    and as the kernel's second oracle for long shapes."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    blk = min(blk_kv, skv)
    if skv % blk:
        # ragged kv (e.g. whisper's 1500 frames): pad and mask
        pad = blk - skv % blk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    skv_valid = skv
    skv = k.shape[1]
    nk = skv // blk
    groups = h // kvh
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(b, sq, kvh, groups, hd).astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(sq)

    def body(carry, ki):
        m, l, acc = carry
        ks = lax.dynamic_slice_in_dim(k, ki * blk, blk, axis=1) \
            .astype(jnp.float32)
        vs = lax.dynamic_slice_in_dim(v, ki * blk, blk, axis=1) \
            .astype(jnp.float32)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, ks)
        kpos = ki * blk + jnp.arange(blk)
        mask = jnp.broadcast_to((kpos < skv_valid)[None, :], (sq, blk))
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window > 0:
            mask &= qpos[:, None] - kpos[None, :] < window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bkgqs,bskd->bkgqd",
                                                      p, vs)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, groups, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, groups, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, groups, sq, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)
