"""Paged decode attention — Pallas TPU kernel + jnp oracle engine.

The serving runtime (repro/serve) stores each sequence's KV cache as a
chain of fixed-size PAGES drawn from a shared pool ([n_pages, page, KV,
hd] per layer) instead of a contiguous [B, S_max, KV, hd] slab; a per-slot
page table maps logical block i of slot b to pool page ``table[b, i]``.
Decode attention then has to gather K/V *through the page table* — the
classic vLLM paged-attention shape.

Two engines with identical math:

  * ``paged_attention_pallas`` — the table rides in scalar-prefetch SMEM
    (``pltpu.PrefetchScalarGridSpec``): the k/v BlockSpec index maps read
    ``table[b, i]`` to pick which pool page the next grid step DMAs, so
    the gather costs nothing beyond the page loads themselves.  The
    (m, l, acc) online-softmax state accumulates across the page grid in
    VMEM scratch exactly like kernels/flash_attention.py.
  * ``paged_attention_partials_jnp`` — a lax.scan over table columns that
    computes one flash partial per page and folds it with the
    ``merge_partials`` LSE combinator (the same combinator the ring
    attention and the distributed tests use).  It additionally supports a
    traced ``pool_offset`` for pools sharded over mesh axes: pages owned
    by other ranks contribute an empty partial, and the caller LSE-merges
    across the mesh (flash-decoding, distributed — see
    models/attention.py::attention_decode_paged).

Per-slot queries are single tokens (q: [B, H, hd]); ``lens[b]`` is the
number of valid cache positions of slot b (0 = nothing to attend — the
finalize guard returns zeros).  Sliding windows mask ``kpos <
lens - window`` so SWA layers can keep their full page chain.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import (NEG_INF, finalize_partials,
                                           init_partials, merge_partials,
                                           pl_scratch)

Array = jax.Array


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page: int, n_pages_max: int,
                  window: int, scale: float, groups: int):
    """Online-softmax accumulation over one slot's page chain.  Grid is
    (B, n_pages_max) with pages innermost; the k/v refs already hold pool
    page ``table[b, i]`` (the index maps did the gather)."""
    i = pl.program_id(1)
    b = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                # [H, hd]
    k = k_ref[...].astype(jnp.float32)                # [page, KV, hd]
    v = v_ref[...].astype(jnp.float32)
    # GQA: expand kv heads to the q-head axis (head h reads kv head h//g)
    ke = jnp.repeat(k, groups, axis=1)                # [page, H, hd]
    ve = jnp.repeat(v, groups, axis=1)
    logits = jax.lax.dot_general(
        q, ke.transpose(1, 0, 2), (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale   # [H, page]

    valid_len = len_ref[b]
    kpos = i * page + lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = kpos < valid_len
    if window > 0:
        mask &= kpos >= valid_len - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                               # [H, 1]
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, ve.transpose(1, 0, 2), (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)           # [H, hd]
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(i == n_pages_max - 1)
    def _finish():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_pallas(q: Array, k_pages: Array, v_pages: Array,
                           table: Array, lens: Array, *, window: int = 0,
                           interpret: bool = False) -> Array:
    """q: [B, H, hd]; k_pages, v_pages: [n_pages, page, KV, hd];
    table: [B, n_pages_max] int32 pool page ids (unused entries may hold
    any in-range id — their positions are masked by ``lens``);
    lens: [B] int32 valid lengths.  Returns [B, H, hd] in q's dtype."""
    b, h, hd = q.shape
    n_pool, page, kvh, _ = k_pages.shape
    n_pages_max = table.shape[1]
    groups = h // kvh
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _paged_kernel, page=page, n_pages_max=n_pages_max, window=window,
        scale=scale, groups=groups)

    kv_spec = pl.BlockSpec(
        (None, page, kvh, hd),
        lambda b_, i, tbl, ln: (tbl[b_, i], 0, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # table, lens
        grid=(b, n_pages_max),
        in_specs=[
            pl.BlockSpec((None, h, hd), lambda b_, i, tbl, ln: (b_, 0, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=pl.BlockSpec((None, h, hd),
                               lambda b_, i, tbl, ln: (b_, 0, 0)),
        scratch_shapes=[
            pl_scratch((h, 1), jnp.float32),
            pl_scratch((h, 1), jnp.float32),
            pl_scratch((h, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lens.astype(jnp.int32), q, k_pages, v_pages)


# ---------------------------------------------------------------------------
# jnp engine: per-page partials merged with the shared LSE combinators
# ---------------------------------------------------------------------------


def paged_attention_partials_jnp(q: Array, k_pages: Array, v_pages: Array,
                                 table: Array, lens: Array, *,
                                 window: int = 0, pool_offset=0
                                 ) -> tuple[Array, Array, Array]:
    """Flash partials of ``q`` [B, H, hd] against the page chains in a
    (possibly rank-local) pool.  ``pool_offset`` (may be a traced scalar —
    mesh ranks derive it from their cache rank) converts the table's
    GLOBAL page ids to local pool indices: entries outside the local pool
    contribute an empty partial, so partials from all ranks LSE-merge to
    the full attention.  Returns (m, l, acc) in the public
    [B, 1, H] / [B, 1, H, hd] carry layout of kernels/flash_attention.py.
    """
    b, h, hd = q.shape
    n_loc, page, kvh, _ = k_pages.shape
    groups = h // kvh
    n_pages_max = table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    # grouped GQA layout (q head h = kv*G + g, matching the kernels'
    # h // G mapping): accumulate in f32 WITHOUT materialising a
    # group-expanded copy of the pages — same trick as attention_decode
    qg = (q.astype(jnp.float32) * scale).reshape(b, kvh, groups, hd)

    def body(carry, i):
        pid = table[:, i].astype(jnp.int32) - pool_offset        # [B]
        owned = (pid >= 0) & (pid < n_loc)
        safe = jnp.clip(pid, 0, n_loc - 1)
        kb = k_pages[safe]                         # [B, page, KV, hd]
        vb = v_pages[safe]
        logits = jnp.einsum("bkgd,bskd->bkgs", qg, kb,
                            preferred_element_type=jnp.float32)
        kpos = i * page + jnp.arange(page)                       # [page]
        valid = owned[:, None] & (kpos[None, :] < lens[:, None])
        if window > 0:
            valid &= kpos[None, :] >= lens[:, None] - window
        vmask = valid[:, None, None, :]            # [B, 1, 1, page]
        logits = jnp.where(vmask, logits, NEG_INF)
        m_i = jnp.max(logits, axis=-1)                      # [B, KV, G]
        p_i = jnp.exp(logits - m_i[..., None])
        p_i = jnp.where(vmask, p_i, 0.0)
        l_i = jnp.sum(p_i, axis=-1)
        acc_i = jnp.einsum("bkgs,bskd->bkgd", p_i.astype(vb.dtype), vb,
                           preferred_element_type=jnp.float32)
        part = (m_i.reshape(b, 1, h), l_i.reshape(b, 1, h),
                acc_i.astype(jnp.float32).reshape(b, 1, h, hd))
        return merge_partials(carry, part), None

    carry = init_partials(b, 1, h, hd)
    carry, _ = lax.scan(body, carry, jnp.arange(n_pages_max))
    return carry


def paged_attention_jnp(q: Array, k_pages: Array, v_pages: Array,
                        table: Array, lens: Array, *,
                        window: int = 0) -> Array:
    """Self-contained jnp paged attention (the kernel's oracle)."""
    m, l, acc = paged_attention_partials_jnp(
        q, k_pages, v_pages, table, lens, window=window)
    out, _ = finalize_partials(m, l, acc, out_dtype=q.dtype)
    return out[:, 0]


# ---------------------------------------------------------------------------
# Dispatch (mirrors kernels/ops.py::flash_attention)
# ---------------------------------------------------------------------------


def paged_kernel_enabled() -> bool:
    from repro.kernels.ops import _pallas_mode
    return _pallas_mode() in ("on", "interpret")


def paged_attention(q: Array, k_pages: Array, v_pages: Array, table: Array,
                    lens: Array, *, window: int = 0,
                    engine: str = "auto") -> Array:
    """Decode attention through a page table: Pallas kernel on TPU (or
    REPRO_PALLAS=interpret), jnp page-scan elsewhere.  ``engine`` pins an
    implementation for tests."""
    from repro.kernels.ops import _pallas_mode
    if engine == "pallas" or (engine == "auto"
                              and _pallas_mode() in ("on", "interpret")):
        return paged_attention_pallas(
            q, k_pages, v_pages, table, lens, window=window,
            interpret=(_pallas_mode() != "on"))
    return paged_attention_jnp(q, k_pages, v_pages, table, lens,
                               window=window)
