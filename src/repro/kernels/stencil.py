"""Jacobi 5-point stencil — Pallas TPU kernels (the paper's own kernel).

The paper's running example (Fig. 2-4) is a 2-D Jacobi sweep; MDMP manages
its halo exchange.  Within a shard the sweep is a memory-bound stencil.
Two kernels live here:

  * ``jacobi_step_pallas``      — one sweep, tiled through VMEM.  Overlapping
    (haloed) reads are expressed the TPU-idiomatic way: the four shifted
    neighbour views of ``u`` are passed as separate inputs, so every
    BlockSpec stays disjoint.  Oracle: kernels/ref.py::jacobi_step_ref.

  * ``jacobi_multistep_pallas`` — the temporally-blocked kernel: a row-tile
    (plus a k-deep halo apron) is streamed HBM->VMEM ONCE and ``k`` sweeps
    are applied in VMEM before the tile is written back, cutting HBM traffic
    ~k x.  Each sweep's valid region shrinks by one row at every tile edge
    (the classic trapezoidal / redundant-ghost scheme), which is why the
    apron must be k rows deep.  Rows pinned by physical Dirichlet
    boundaries do NOT shrink: a per-call frozen-row count (SMEM scalar,
    applied in the first/last grid block only) keeps boundary and
    out-of-domain ghost rows at their initial value through all k sweeps.
    Oracle: k applications of jacobi_step_ref.

The same trapezoid powers the distributed deep-halo schedule
(core/halo.py::jacobi_solve with k>1): there the k-row apron arrives from
ring neighbours via one halo exchange per k sweeps instead of per sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _jacobi_kernel(up_ref, down_ref, left_ref, right_ref, f_ref, o_ref):
    up = up_ref[...].astype(jnp.float32)
    down = down_ref[...].astype(jnp.float32)
    left = left_ref[...].astype(jnp.float32)
    right = right_ref[...].astype(jnp.float32)
    f = f_ref[...].astype(jnp.float32)
    o_ref[...] = (0.25 * (up + down + left + right - f)).astype(o_ref.dtype)


def jacobi_step_pallas(u: Array, f: Array, *, blk_m: int = 256,
                       blk_n: int = 256, interpret: bool = False) -> Array:
    """One Jacobi sweep on the interior of ``u`` ([M, N]); boundary
    rows/cols are Dirichlet (copied through).  f: [M, N] source term."""
    m, n = u.shape
    mi, ni = m - 2, n - 2                        # interior size
    blk_m = min(blk_m, mi)
    blk_n = min(blk_n, ni)
    assert mi % blk_m == 0 and ni % blk_n == 0, (mi, ni, blk_m, blk_n)
    grid = (mi // blk_m, ni // blk_n)

    views = (u[:-2, 1:-1], u[2:, 1:-1], u[1:-1, :-2], u[1:-1, 2:],
             f[1:-1, 1:-1])
    spec = pl.BlockSpec((blk_m, blk_n), lambda i, j: (i, j))
    interior = pl.pallas_call(
        _jacobi_kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((mi, ni), u.dtype),
        interpret=interpret,
    )(*views)
    return u.at[1:-1, 1:-1].set(interior)


# ---------------------------------------------------------------------------
# Temporally-blocked multi-sweep kernel (k sweeps per HBM round-trip)
# ---------------------------------------------------------------------------


def ksweep_trapezoid(tile: Array, f_tile: Array, k: int, frozen_top,
                     frozen_bot) -> Array:
    """Apply ``k`` masked Jacobi sweeps to a halo-padded row tile.

    tile, f_tile: [T, N] float32.  Columns 0 and N-1 are Dirichlet (never
    updated); rows 0 and T-1 are likewise never updated (each sweep's
    stencil cannot reach them).  ``frozen_top``/``frozen_bot`` additionally
    pin that many leading/trailing rows to their INITIAL value through all
    k sweeps — used for physical-boundary ghost rows, which must act as a
    constant Dirichlet condition rather than participate in the redundant
    ghost trapezoid.  May be traced scalars.

    Validity contract (the trapezoid): if tile rows [0, T) hold iteration-0
    values, then after this call rows [k, T-k) hold iteration-k values
    (frozen edges do not shrink).  Shared verbatim by the Pallas kernel and
    the jnp deep-halo path so both produce bit-identical schedules.
    """
    t_rows = tile.shape[0]
    rows = lax.broadcasted_iota(jnp.int32, (t_rows, 1), 0)
    upd = (rows >= frozen_top) & (rows < t_rows - frozen_bot)
    for _ in range(k):                            # k is static: unrolled
        new = 0.25 * (tile[:-2, 1:-1] + tile[2:, 1:-1]
                      + tile[1:-1, :-2] + tile[1:-1, 2:]
                      - f_tile[1:-1, 1:-1])
        mid = jnp.concatenate([tile[1:-1, :1], new, tile[1:-1, -1:]], axis=1)
        swept = jnp.concatenate([tile[:1], mid, tile[-1:]], axis=0)
        tile = jnp.where(upd, swept, tile)
    return tile


def _jacobi_multistep_kernel(frozen_ref, utop_ref, umid_ref, ubot_ref,
                             ftop_ref, fmid_ref, fbot_ref, o_ref, *, k: int):
    """One grid step: assemble the (blk_m + 2k, N) apron tile in VMEM from
    the three disjoint row-block inputs, run k sweeps, write the blk_m
    center rows.  Frozen-edge depths apply only in the first/last block."""
    i = pl.program_id(0)
    nb = pl.num_programs(0)
    frozen_top = jnp.where(i == 0, frozen_ref[0, 0], 0)
    frozen_bot = jnp.where(i == nb - 1, frozen_ref[0, 1], 0)
    tile = jnp.concatenate(
        [utop_ref[...], umid_ref[...], ubot_ref[...]], axis=0
    ).astype(jnp.float32)
    f_tile = jnp.concatenate(
        [ftop_ref[...], fmid_ref[...], fbot_ref[...]], axis=0
    ).astype(jnp.float32)
    out = ksweep_trapezoid(tile, f_tile, k, frozen_top, frozen_bot)
    o_ref[...] = out[k:-k].astype(o_ref.dtype)


def jacobi_ksweep_pallas(u_pad: Array, f_pad: Array, k: int, frozen_top,
                         frozen_bot, *, blk_m: int = 256,
                         interpret: bool = False) -> Array:
    """k Jacobi sweeps over the center rows of a k-halo-padded block.

    u_pad, f_pad: [m + 2k, N] — the local block with its k-row apron (ghost
    slabs from ring neighbours, zeros outside the physical domain).
    Returns the [m, N] center after k sweeps; the apron is consumed by the
    trapezoidal shrink, so the result is exact (allclose to k unit sweeps).

    ``frozen_top``/``frozen_bot`` (int scalars, may be traced) pin that many
    leading/trailing PADDED rows — pass k at a non-periodic physical edge
    so the zero ghost slab behaves as a constant boundary, 0 elsewhere.

    Each grid step streams blk_m + 2k rows of u and f HBM->VMEM, runs all
    k sweeps on the VMEM-resident tile, and writes blk_m rows back: the
    HBM traffic per sweep drops ~k x vs. calling jacobi_step_pallas k
    times, which is the whole point of the temporal blocking.
    """
    assert k >= 1
    mp, n = u_pad.shape
    m = mp - 2 * k
    assert m >= 1, (mp, k)
    if m % blk_m != 0 or blk_m % k != 0 or blk_m < k:
        blk_m = m                                 # single row-tile fallback
    grid = (m // blk_m,)

    # Three disjoint row-block views assemble each (blk_m + 2k)-row apron
    # tile: top apron rows [i*blk_m, i*blk_m + k), center rows
    # [i*blk_m + k, i*blk_m + k + blk_m), bottom apron rows
    # [i*blk_m + k + blk_m, i*blk_m + 2k + blk_m) — all in u_pad coords.
    halo_stride = max(blk_m // k, 1)              # block-index stride of the
    top_spec = pl.BlockSpec((k, n), lambda i: (i * halo_stride, 0))
    mid_spec = pl.BlockSpec((blk_m, n), lambda i: (i, 0))
    frozen = jnp.stack([jnp.asarray(frozen_top, jnp.int32),
                        jnp.asarray(frozen_bot, jnp.int32)]).reshape(1, 2)
    kernel = functools.partial(_jacobi_multistep_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # frozen depths
            top_spec,                                    # u top apron
            mid_spec,                                    # u center
            top_spec,                                    # u bottom apron
            top_spec,                                    # f top apron
            mid_spec,                                    # f center
            top_spec,                                    # f bottom apron
        ],
        out_specs=mid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), u_pad.dtype),
        interpret=interpret,
    )(frozen, u_pad, u_pad[k:-k], u_pad[blk_m + k:],
      f_pad, f_pad[k:-k], f_pad[blk_m + k:])


def jacobi_multistep_pallas(u: Array, f: Array, *, k: int,
                            blk_m: int = 256,
                            interpret: bool = False) -> Array:
    """``k`` Jacobi sweeps on the interior of ``u`` ([M, N]) in ONE HBM
    round-trip — temporally-blocked equivalent of calling
    ``jacobi_step_pallas`` k times (boundary rows/cols Dirichlet, same
    oracle: k x jacobi_step_ref).

    Implementation: pad with k zero rows top and bottom, freeze the padding
    plus the true boundary row (k + 1 rows) so the Dirichlet condition
    survives all k sweeps, and run the trapezoidal slab kernel.
    """
    z = jnp.zeros((k,) + u.shape[1:], u.dtype)
    u_pad = jnp.concatenate([z, u, z], axis=0)
    f_pad = jnp.concatenate([z, f, z], axis=0)
    return jacobi_ksweep_pallas(u_pad, f_pad, k, k + 1, k + 1,
                                blk_m=blk_m, interpret=interpret)
