"""Jacobi 5-point stencil — Pallas TPU kernel (the paper's own kernel).

The paper's running example (Fig. 2-4) is a 2-D Jacobi sweep; MDMP manages
its halo exchange.  Within a shard the sweep is a memory-bound stencil —
this kernel tiles it through VMEM.  Overlapping (haloed) reads are
expressed the TPU-idiomatic way: the four shifted neighbour views of ``u``
are passed as separate inputs, so every BlockSpec stays disjoint and each
grid step streams five aligned (blk_m, blk_n) tiles HBM->VMEM and writes
one.  blk_n multiples of 128 keep the lanes full.  Oracle:
kernels/ref.py::jacobi_step_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _jacobi_kernel(up_ref, down_ref, left_ref, right_ref, f_ref, o_ref):
    up = up_ref[...].astype(jnp.float32)
    down = down_ref[...].astype(jnp.float32)
    left = left_ref[...].astype(jnp.float32)
    right = right_ref[...].astype(jnp.float32)
    f = f_ref[...].astype(jnp.float32)
    o_ref[...] = (0.25 * (up + down + left + right - f)).astype(o_ref.dtype)


def jacobi_step_pallas(u: Array, f: Array, *, blk_m: int = 256,
                       blk_n: int = 256, interpret: bool = False) -> Array:
    """One Jacobi sweep on the interior of ``u`` ([M, N]); boundary
    rows/cols are Dirichlet (copied through).  f: [M, N] source term."""
    m, n = u.shape
    mi, ni = m - 2, n - 2                        # interior size
    blk_m = min(blk_m, mi)
    blk_n = min(blk_n, ni)
    assert mi % blk_m == 0 and ni % blk_n == 0, (mi, ni, blk_m, blk_n)
    grid = (mi // blk_m, ni // blk_n)

    views = (u[:-2, 1:-1], u[2:, 1:-1], u[1:-1, :-2], u[1:-1, 2:],
             f[1:-1, 1:-1])
    spec = pl.BlockSpec((blk_m, blk_n), lambda i, j: (i, j))
    interior = pl.pallas_call(
        _jacobi_kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((mi, ni), u.dtype),
        interpret=interpret,
    )(*views)
    return u.at[1:-1, 1:-1].set(interior)
