"""Sharded AdamW + gradient clipping + LR schedule.

Optimizer states inherit the parameter sharding exactly (every update is
elementwise), so FSDP/ZeRO-3 state sharding falls out of the param layout.
``moment_dtype`` comes from the arch config (bf16 moments for the 100B+
archs — DESIGN.md §3.1 memory posture).  The global-norm clip is the only
cross-shard operation; its scalar crosses the mesh through MDMP managed
reductions.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import managed

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_schedule(step: Array, cfg: AdamWConfig) -> Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(grads: Any, axes: Sequence[str] = ()) -> Array:
    """Global L2 norm of a (sharded) grad tree; partial sums-of-squares are
    psum'd across ``axes`` so every shard agrees on the clip factor."""
    ssq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    for ax in axes:
        ssq = managed.managed_all_reduce(ssq, ax)
    return jnp.sqrt(ssq)


def adamw_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig,
                 *, psum_axes: Sequence[str] = (),
                 gnorm: Array | None = None) -> tuple[Any, dict, dict]:
    """One AdamW step.  ``gnorm`` may be precomputed (the train step builds
    a replication-aware norm).  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(step, cfg)
    if gnorm is None:
        gnorm = global_norm(grads, psum_axes)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / bc1
        nhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
