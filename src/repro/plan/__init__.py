"""Whole-program communication planner — the MDMP compiler.

The per-subsystem managed runtime (core/managed.py) resolves every
communication knob LOCALLY: each call site assumes the link and the
overlap budget are its own.  This package closes the gap to the paper's
compiler view: every ``CommRegion`` declaration and every collective the
jaxpr instrumentation extracts lowers to a ``CommOp`` node (ir.py), and a
joint pass (planner.py) prices the whole program's schedule under SHARED
constraints — per-link bandwidth serialised across ops whose readiness
windows overlap on the same mesh axis, stash capacity pooled, one overlap
account per contention set — and emits a single coordinated
``ProgramPlan`` whose knobs override local resolution via
``managed.install_plan``.
"""

from repro.plan.ir import (CommOp, crosscheck_collectives,
                           lower_collectives, lower_region, lower_specs,
                           lower_train_ops, train_geometry)
from repro.plan.planner import (Candidate, OpChoice, ProgramPlan,
                                candidates_for, plan_program)

__all__ = [
    "CommOp", "lower_specs", "lower_region", "lower_collectives",
    "lower_train_ops", "train_geometry", "crosscheck_collectives",
    "Candidate", "OpChoice", "ProgramPlan", "candidates_for",
    "plan_program",
]
