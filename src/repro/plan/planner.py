"""Joint planner: price the WHOLE program's communication schedule.

Per-subsystem resolution (core/managed.py) answers "what is the best
knob for THIS op, assuming the link and the overlap budget are mine?".
That assumption breaks the moment two subsystems' readiness windows
overlap on the same mesh axis — an interleaved pipeline handoff and an
MoE expert stream both claiming the ring each hide their wire under the
same compute ONCE, not once each.  This pass prices the joint schedule:

  * every op's candidate knobs reduce to ``(wire_s, msgs, hide_s,
    stash_bytes)`` components (cost_model.CommComponents) plus a
    knob-dependent compute base;
  * ops are grouped into CONTENTION SETS — connected components of
    (same mesh axis AND overlapping readiness windows);
  * each set draws its wires from ONE ``overlap.OverlapAccount`` seeded
    with the LARGEST single hide any member offers (the compute stream
    hides the link once), pays alpha per message, and pools its stash
    bytes against the capacity cap;
  * coordinate descent over the product knob space, seeded from each
    op's LOCAL pick, walks to a fixpoint — the joint cost of the emitted
    plan is never worse than the local seeds', and strictly better
    whenever backing one op off its local optimum frees the link.

The emitted ``ProgramPlan`` carries one knob per (op, axis); installing
it (``managed.install_plan``) makes every ``resolve_*`` entry point
prefer the planner's knob over local resolution, and the decision trail
gets one DecisionRecord per op plus an ``op="program_plan"`` summary.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import cost_model, managed
from repro.core.cost_model import CommComponents
from repro.core.overlap import OverlapAccount
from repro.obs.tracer import get_tracer
from repro.plan.ir import CommOp

_EPS = 1e-15


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One knob setting for one op, priced to shared-constraint units."""
    knob: dict                      # {"mode", "chunks", ["virtual", ...]}
    comps: CommComponents
    base_s: float = 0.0             # knob-dependent compute (never shared)

    def solo_s(self, alpha: float) -> float:
        """This op's cost if it owned the link (the local resolver's
        objective): exposed wire + message latency + its compute."""
        return self.comps.solo_s(alpha) + self.base_s


def _trivial() -> list[Candidate]:
    return [Candidate(knob={"mode": "bulk", "chunks": 1},
                      comps=CommComponents(0.0, 0, 0.0, 0))]


def _collective_candidates(op: CommOp, hw) -> list[Candidate]:
    coll = op.meta.get("collective", op.op_name)
    if coll not in ("all_gather", "reduce_scatter", "all_reduce",
                    "all_to_all"):
        coll = "all_gather"
    hide = float(op.meta.get("compute_time_s", 0.0))
    out = []
    for mode in ("bulk", "interleaved"):
        for chunks in ((1,) if mode == "bulk" else (1, 2, 4)):
            comps = cost_model.collective_components(
                coll, op.nbytes, op.axis_size, mode=mode, chunks=chunks,
                compute_time_s=hide, hw=hw)
            out.append(Candidate(knob={"mode": mode, "chunks": chunks},
                                 comps=comps))
    return out


def _halo_candidates(op: CommOp, hw) -> list[Candidate]:
    rows_local = int(op.meta.get("rows_local", 1))
    cols = int(op.meta.get("cols", max(1, op.nbytes // op.dtype_bytes)))
    out = []
    for k in (1, 2, 4, 8):
        _, mem, flops = cost_model.halo_sweep_terms(
            k, rows_local, cols, dtype_bytes=op.dtype_bytes, hw=hw,
            axis_size=op.axis_size)
        # per sweep: 2 halo slabs cross the link, alpha amortised 1/k
        wire = 2.0 * cols * op.dtype_bytes / hw.link_bw \
            if op.axis_size > 1 else 0.0
        out.append(Candidate(
            knob={"mode": "bulk" if k == 1 else "aggregated", "chunks": k},
            comps=CommComponents(wire_s=wire, msgs=2.0 / k, hide_s=0.0),
            base_s=max(mem, flops)))
    return out


def _attention_candidates(op: CommOp, hw) -> list[Candidate]:
    m = op.meta
    n = max(1, op.axis_size)
    b, s_local = int(m["batch"]), int(m["s_local"])
    h, kv, hd, d = (int(m["heads"]), int(m["kv_heads"]),
                    int(m["head_dim"]), int(m["d_model"]))
    ib = op.dtype_bytes
    cf = 0.5 if m.get("causal", True) else 1.0
    flash_step = cost_model.attention_flash_step_s(b, s_local, h, hd, hw)
    attn_full = cf * n * flash_step
    x_shard = b * s_local * d * ib
    wq_shard = d * (h * hd // n) * ib
    w_gather_wire = 2.0 * cost_model.collective_wire_s(
        "all_gather", wq_shard, n, hw)
    qo_local = b * s_local * h * hd * ib
    kv_shard = 2.0 * b * s_local * kv * hd * ib
    steps = n - 1
    # msgs = collective DISPATCH counts (cost_model.collective_msgs):
    # bulk/ulysses fire fused ops, the ring fires one permute per step
    cands = [
        Candidate(                   # bulk sequence-gather: AG + RS
            knob={"mode": "bulk", "chunks": 1},
            comps=CommComponents(
                wire_s=(cost_model.collective_wire_s("all_gather",
                                                     x_shard, n, hw)
                        + cost_model.collective_wire_s("reduce_scatter",
                                                       x_shard * n, n, hw)),
                msgs=2, hide_s=0.0),
            base_s=attn_full),
        Candidate(                   # ulysses: 2 w-AG + 2 a2a + kv-AG
            knob={"mode": "ulysses", "chunks": 1},
            comps=CommComponents(
                wire_s=(w_gather_wire
                        + 2.0 * cost_model.collective_wire_s(
                            "all_to_all", qo_local, n, hw)
                        + cost_model.collective_wire_s(
                            "all_gather", kv_shard, n, hw)),
                msgs=5, hide_s=0.0),
            base_s=attn_full),
        Candidate(                   # ring kv streaming: wire hides under
            knob={"mode": "ring", "chunks": 1},   # the per-step flash
            comps=CommComponents(
                wire_s=w_gather_wire + steps * kv_shard / hw.link_bw,
                msgs=2 + steps,
                hide_s=steps * cf * flash_step),
            base_s=attn_full),
    ]
    return cands


def _moe_candidates(op: CommOp, hw) -> list[Candidate]:
    m = op.meta
    n = max(1, op.axis_size)
    layout = m.get("layout", "ep_a2a")
    cf = float(m.get("capacity_factor", 1.25))
    cap, flops_row, comm, dense_ffn = cost_model._moe_terms(
        int(m["tokens_local"]), int(m["d_model"]), int(m["n_experts"]),
        int(m["top_k"]), int(m["d_ff_expert"]), n,
        int(m.get("mults", 3)), op.dtype_bytes, cf, layout, hw)
    occ = min(1.0, 1.0 / max(cf, 1e-6))
    ffn_s = int(m["n_experts"]) * cap * occ * flops_row / hw.peak_flops
    steps = max(1, n - 1)
    wire = max(0.0, comm - 2.0 * steps * hw.alpha_s)
    # msgs = dispatch counts: bulk fires two fused a2a ops, the stream
    # fires ~(2 + g) permutes per ring step (block + counts forward, g
    # chunk returns — managed_expert_stream's issue pattern)
    cands = [Candidate(knob={"mode": "bulk", "chunks": 1,
                             "capacity_factor": cf},
                       comps=CommComponents(wire_s=wire, msgs=2,
                                            hide_s=0.0),
                       base_s=ffn_s)]
    unit = int(m["tokens_local"]) if layout == "expert_tp" else cap
    if n > 1:
        for g in (1, 2, 4, 8):
            if unit % g:
                continue
            cands.append(Candidate(
                knob={"mode": "stream", "chunks": g,
                      "capacity_factor": cf},
                comps=CommComponents(wire_s=wire, msgs=steps * (2 + g),
                                     hide_s=ffn_s),
                base_s=ffn_s))
    dense_bytes = int(m["tokens_local"]) * int(m["d_model"]) * op.dtype_bytes
    dense_wire = (cost_model.collective_wire_s("all_gather", dense_bytes,
                                               n, hw)
                  + cost_model.collective_wire_s("reduce_scatter",
                                                 n * dense_bytes, n, hw))
    cands.append(Candidate(
        knob={"mode": "dense", "chunks": 1, "capacity_factor": cf},
        comps=CommComponents(wire_s=dense_wire, msgs=2, hide_s=0.0),
        base_s=dense_ffn))
    return cands


def _pipeline_candidates(op: CommOp, hw) -> list[Candidate]:
    m = op.meta
    s = max(1, op.axis_size)
    batch_fwd_s = float(m.get("batch_fwd_s", 0.0))
    batch_bytes = float(m.get("batch_bytes", op.nbytes))
    n_layers = m.get("n_layers")
    budget = max(0.0, min(1.0, float(m.get("overlap_budget", 1.0))))
    micros = tuple(m.get("candidate_micro", (4, 8, 16, 32)))
    virtuals = tuple(m.get("candidate_virtual", (2,)))
    cands = []
    for mm in sorted({int(c) for c in micros if c >= 1}):
        variants = [("gpipe", mm, 1), ("1f1b", mm, 1)]
        for v in sorted({int(c) for c in virtuals if c >= 2}):
            if mm % s:
                continue
            if n_layers is not None and v * s > int(n_layers):
                continue
            variants.append(("interleaved", mm, v))
        for sched, mmm, v in variants:
            link = 2.0 * (batch_bytes / mmm) / hw.link_bw
            # recover the (wire, hide, compute) decomposition from the
            # same closed form the local decision uses: with budget=0 the
            # whole link is exposed, so compute falls out of t0
            t0, ticks = cost_model.pipeline_schedule_time(
                sched, mmm, s, v, batch_fwd_s, batch_bytes, hw=hw,
                overlap_budget=0.0)
            compute = t0 - ticks * (2.0 * hw.alpha_s + link)
            exp_tick = max(0.0, link - budget * compute / ticks)
            wire = ticks * link
            hide = wire - ticks * exp_tick
            stash = int(cost_model.pipeline_stash_slots(sched, mmm, s, v)
                        * batch_bytes / mmm)
            cands.append(Candidate(
                knob={"mode": sched, "chunks": mmm, "virtual": v},
                comps=CommComponents(wire_s=wire, msgs=2 * ticks,
                                     hide_s=max(0.0, hide),
                                     stash_bytes=stash),
                base_s=max(0.0, compute)))
    return cands


def _pinned_candidate(op: CommOp, hw) -> list[Candidate]:
    """Serve / preempt / ckpt knobs don't contend for step-time links;
    the joint pass carries the LOCAL decision through unchanged so the
    ProgramPlan still binds and trails every declared knob."""
    m = op.meta
    if op.kind == "serve":
        d = cost_model.decide_serve_schedule(
            m["n_params"], m["batch_slots"], m["mean_prompt"],
            m["mean_new"], max_prompt=m.get("max_prompt"),
            dtype_bytes=op.dtype_bytes, hw=hw)
        knob = {"mode": d.mode, "chunks": d.chunk}
    elif op.kind == "preempt":
        d = cost_model.decide_preempt(
            m.get("mean_pages", 1), m["page_bytes"], m["replay_tokens"],
            m["n_params"], batch_slots=m.get("batch_slots", 1),
            dtype_bytes=op.dtype_bytes, hw=hw)
        knob = {"mode": d.policy, "chunks": 1}
    else:                           # ckpt
        d = cost_model.decide_checkpoint(
            m.get("step_s", 1.0), m["snapshot_bytes"],
            mtbf_s=m.get("mtbf_s", 1800.0),
            write_bw=m.get("write_bw"), hw=hw)
        knob = {"mode": d.mode, "chunks": d.interval}
    return [Candidate(knob=knob, comps=CommComponents(0.0, 0, 0.0, 0))]


def candidates_for(op: CommOp, hw=None) -> list[Candidate]:
    """The op's knob space, priced — each subsystem's existing candidate
    list expressed in shared-constraint components."""
    hw = hw or managed.get_config().hw
    if op.axis_size <= 1 and op.kind not in ("serve", "preempt", "ckpt",
                                             "pipeline"):
        return _trivial()
    if op.kind == "halo":
        return _halo_candidates(op, hw)
    if op.kind == "attention":
        return _attention_candidates(op, hw)
    if op.kind == "moe":
        return _moe_candidates(op, hw)
    if op.kind == "pipeline":
        return _pipeline_candidates(op, hw)
    if op.kind in ("serve", "preempt", "ckpt"):
        return _pinned_candidate(op, hw)
    return _collective_candidates(op, hw)


# ---------------------------------------------------------------------------
# Joint pricing under shared constraints
# ---------------------------------------------------------------------------


def contention_sets(ops: Sequence[CommOp]) -> list[list[int]]:
    """Connected components of (same axis AND overlapping windows) —
    the groups whose wires serialise on one link."""
    n = len(ops)
    parent = list(range(n))

    def find(i):
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    for i in range(n):
        for j in range(i + 1, n):
            if ops[i].overlaps(ops[j]):
                ri, rj = find(i), find(j)
                if ri != rj:
                    parent[ri] = rj
    groups: dict[int, list[int]] = {}
    for i in range(n):
        groups.setdefault(find(i), []).append(i)
    return [sorted(g) for g in sorted(groups.values())]


def joint_cost(ops: Sequence[CommOp], chosen: Sequence[Candidate], *,
               hw=None, stash_cap_bytes: int | None = None,
               sets: Sequence[Sequence[int]] | None = None) -> float:
    """Modeled step seconds of one joint knob assignment.

    Per contention set: ONE OverlapAccount seeded with the largest hide
    any member offers (the adjacent compute hides the link once), every
    member's wire drawn from it, alpha per message on top.  Stash bytes
    pool across the WHOLE program against the cap."""
    hw = hw or managed.get_config().hw
    if sets is None:
        sets = contention_sets(ops)
    if stash_cap_bytes is not None:
        pooled = sum(c.comps.stash_bytes for c in chosen)
        if pooled > stash_cap_bytes:
            return math.inf
    total = sum(c.base_s for c in chosen)
    for group in sets:
        acct = OverlapAccount(
            budget_s=max((chosen[i].comps.hide_s for i in group),
                         default=0.0))
        exposed = 0.0
        msgs = 0
        for i in group:
            exposed += acct.draw(chosen[i].comps.wire_s)
            msgs += chosen[i].comps.msgs
        total += exposed + hw.alpha_s * msgs
    return total


# ---------------------------------------------------------------------------
# The plan object + the search
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class OpChoice:
    """Per-op row of the coordinated plan's decision trail."""
    op: CommOp
    knob: dict
    local_knob: dict
    local_solo_s: float             # local pick, priced standalone
    chosen_solo_s: float            # planner pick, priced standalone


@dataclasses.dataclass
class ProgramPlan:
    """One coordinated knob assignment for the whole program.

    ``knob_for(op_name, axis)`` is the contract ``managed._plan_knob``
    duck-types against: a dict with at least {"mode", "chunks"} when the
    plan binds that call site, None otherwise."""
    signature: str
    topology: str
    knobs: dict[str, dict]          # "op_name|axis" -> knob dict
    choices: list[OpChoice]
    joint_cost_s: float             # coordinated assignment, shared constraints
    local_joint_cost_s: float       # local picks under shared constraints
    local_solo_sum_s: float         # concatenation of local plans (no sharing)
    notes: list[str] = dataclasses.field(default_factory=list)

    def knob_for(self, op_name: str, axis: str) -> dict | None:
        return self.knobs.get(f"{op_name}|{axis}")

    @property
    def coordinated(self) -> bool:
        return any(c.knob != c.local_knob for c in self.choices)

    def summary(self) -> str:
        lines = [
            f"program_plan[{self.topology}] {len(self.choices)} ops: "
            f"joint={self.joint_cost_s * 1e6:.1f}us "
            f"local-joint={self.local_joint_cost_s * 1e6:.1f}us "
            f"local-concat={self.local_solo_sum_s * 1e6:.1f}us "
            f"({'coordinated' if self.coordinated else 'local picks stand'})"
        ]
        for c in self.choices:
            moved = "" if c.knob == c.local_knob else "   <- coordinated"
            lines.append(
                f"  {c.op.op_name:20s} axis={c.op.axis:6s} "
                f"{c.op.label:24s} "
                f"local={c.local_knob.get('mode')}:"
                f"{c.local_knob.get('chunks')} -> "
                f"plan={c.knob.get('mode')}:{c.knob.get('chunks')}{moved}")
        for n in self.notes:
            lines.append(f"  note: {n}")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "signature": self.signature,
            "topology": self.topology,
            "knobs": self.knobs,
            "joint_cost_s": self.joint_cost_s,
            "local_joint_cost_s": self.local_joint_cost_s,
            "local_solo_sum_s": self.local_solo_sum_s,
            "notes": list(self.notes),
            "ops": [c.op.to_dict() for c in self.choices],
            "choices": [{"knob": c.knob, "local_knob": c.local_knob,
                         "local_solo_s": c.local_solo_s,
                         "chosen_solo_s": c.chosen_solo_s}
                        for c in self.choices],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ProgramPlan":
        ops = [CommOp.from_dict(o) for o in d.get("ops", [])]
        choices = [OpChoice(op=op, knob=ch["knob"],
                            local_knob=ch["local_knob"],
                            local_solo_s=ch["local_solo_s"],
                            chosen_solo_s=ch["chosen_solo_s"])
                   for op, ch in zip(ops, d.get("choices", []))]
        return cls(signature=d["signature"], topology=d["topology"],
                   knobs=dict(d["knobs"]),
                   choices=choices,
                   joint_cost_s=float(d["joint_cost_s"]),
                   local_joint_cost_s=float(d["local_joint_cost_s"]),
                   local_solo_sum_s=float(d["local_solo_sum_s"]),
                   notes=list(d.get("notes", [])))


def program_signature(ops: Sequence[CommOp]) -> str:
    return ";".join(sorted(f"{o.op_name}|{o.axis}|{o.nbytes}"
                           for o in ops))


def program_topology(ops: Sequence[CommOp]) -> str:
    axes = {}
    for o in ops:
        axes[o.axis] = max(axes.get(o.axis, 1), o.axis_size)
    return "x".join(f"{a}{n}" for a, n in sorted(axes.items())) or "scalar"


def plan_program(ops: Sequence[CommOp], *, hw=None,
                 stash_cap_bytes: int | None = None,
                 max_rounds: int = 8,
                 notes: Sequence[str] = (),
                 log: bool = True) -> ProgramPlan:
    """Search the product knob space and emit the coordinated plan.

    Coordinate descent seeded from each op's LOCAL pick: one op at a
    time, try its whole candidate list against the others' current
    knobs, keep strict improvements, iterate to a fixpoint.  The result
    can only match or beat the local assignment's joint cost."""
    cfg = managed.get_config()
    hw = hw or cfg.hw
    ops = list(ops)
    with get_tracer().span("plan.resolve", op="program_plan",
                           track="plan", n_ops=len(ops)):
        return _plan_program_body(ops, cfg, hw, stash_cap_bytes,
                                  max_rounds, notes, log)


def _plan_program_body(ops, cfg, hw, stash_cap_bytes, max_rounds, notes,
                       log) -> ProgramPlan:
    order = sorted(range(len(ops)), key=lambda i: ops[i].key)
    cand_lists = [candidates_for(op, hw) for op in ops]
    sets = contention_sets(ops)

    # seed: every op takes its locally-optimal knob (what per-subsystem
    # resolution would have done)
    local_idx = [min(range(len(cl)),
                     key=lambda j: (cl[j].solo_s(hw.alpha_s), j))
                 for cl in cand_lists]
    chosen_idx = list(local_idx)

    def cost_of(idxs):
        return joint_cost(ops, [cand_lists[i][idxs[i]]
                                for i in range(len(ops))],
                          hw=hw, stash_cap_bytes=stash_cap_bytes,
                          sets=sets)

    local_joint = cost_of(local_idx)
    best = local_joint
    for _ in range(max_rounds):
        improved = False
        for i in order:
            cur = chosen_idx[i]
            for j in range(len(cand_lists[i])):
                if j == cur:
                    continue
                chosen_idx[i] = j
                t = cost_of(chosen_idx)
                if t < best - _EPS:
                    best, cur = t, j
                    improved = True
                else:
                    chosen_idx[i] = cur
            chosen_idx[i] = cur
        if not improved:
            break

    alpha = hw.alpha_s
    choices = []
    for i, op in enumerate(ops):
        lc = cand_lists[i][local_idx[i]]
        cc = cand_lists[i][chosen_idx[i]]
        choices.append(OpChoice(op=op, knob=dict(cc.knob),
                                local_knob=dict(lc.knob),
                                local_solo_s=lc.solo_s(alpha),
                                chosen_solo_s=cc.solo_s(alpha)))
    local_solo_sum = sum(c.local_solo_s for c in choices)
    plan = ProgramPlan(
        signature=program_signature(ops),
        topology=program_topology(ops),
        knobs={f"{c.op.op_name}|{c.op.axis}": dict(c.knob)
               for c in choices},
        choices=choices, joint_cost_s=best,
        local_joint_cost_s=local_joint,
        local_solo_sum_s=local_solo_sum, notes=list(notes))

    if log and cfg.log_decisions:
        for c in choices:
            managed.log_decision(managed.DecisionRecord(
                op=c.op.op_name, axis=c.op.axis, nbytes=c.op.nbytes,
                mode=str(c.knob.get("mode")),
                chunks=int(c.knob.get("chunks") or 1),
                predicted_bulk_s=c.local_solo_s,
                predicted_interleaved_s=c.chosen_solo_s))
        managed.log_decision(managed.DecisionRecord(
            op="program_plan", axis=plan.topology,
            nbytes=sum(o.nbytes for o in ops),
            mode="coordinated" if plan.coordinated else "local",
            chunks=len(ops),
            predicted_bulk_s=local_solo_sum,
            predicted_interleaved_s=best))
    return plan
