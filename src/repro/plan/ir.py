"""Comm-IR: every declared or extracted communication as one ``CommOp``.

A ``CommOp`` is the planner's unit of work — one communication decision
site with everything the joint cost pass needs: which mesh axis it
crosses, how many bytes, WHEN during the step its operand is ready /
consumed (the readiness window, normalised to [0, 1] of the step), and
the kind-specific geometry the cost model prices from.

Two lowering sources, cross-checked against each other:

  * ``lower_specs`` / ``lower_region`` — the declarative source: every
    ``CommSpec`` a ``CommRegion`` declares (send/recv/collective, halo,
    attention, pipeline, moe, serve(+preempt), checkpoint) lowers to one
    op whose window comes from the region's instrumented readiness when
    available.
  * ``lower_collectives`` — the extracted source: the jaxpr collectives
    ``instrument._walk`` records (primitive, axis, payload bytes, depth)
    lower to generic collective ops windowed by program depth.

``crosscheck_collectives`` reconciles the two: per mesh axis, the bytes
the declarations claim should cover what the trace actually moves —
a declaration the trace never exercises, or traced traffic nothing
declared, is exactly the drift the paper's managed runtime exists to
catch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from repro.core import instrument

#: CommSpec.kind -> the DecisionRecord op name the knob resolves under
#: (core/managed.py DECISION_OPS).  send/recv declarations price as the
#: all_gather family — the managed runtime executes them that way.
_KIND_TO_OP = {
    "send": "all_gather",
    "recv": "all_gather",
    "all_gather": "all_gather",
    "reduce_scatter": "reduce_scatter",
    "all_reduce": "all_reduce",
    "all_to_all": "all_to_all",
    "halo": "halo_aggregation",
    "attention": "attention_schedule",
    "pipeline": "pipeline_schedule",
    "moe": "moe_dispatch",
    "serve": "serve_schedule",
    "preempt": "preempt_policy",
    "ckpt": "ckpt_interval",
}

#: default readiness window per kind when no instrumented record pins it:
#: fwd-path streams occupy the front of the step, gradient reductions the
#: back half, step-level schedules (pipeline handoffs, serving quanta)
#: the whole step, recovery traffic the tail.  Deterministic by design —
#: the planner's contention sets must not depend on trace luck.
_DEFAULT_WINDOW = {
    "attention": (0.0, 0.6),
    "moe": (0.1, 0.7),
    "halo": (0.0, 0.6),
    "pipeline": (0.0, 1.0),
    "serve": (0.0, 1.0),
    "preempt": (0.0, 1.0),
    "ckpt": (0.9, 1.0),
    "all_reduce": (0.4, 1.0),       # gradient sync lives in the backward
    "reduce_scatter": (0.4, 1.0),
}


@dataclasses.dataclass
class CommOp:
    """One communication decision site in the program."""
    kind: str                       # CommSpec kind family (see _KIND_TO_OP)
    label: str                      # source declaration / extraction label
    op_name: str                    # DecisionRecord op the knob logs under
    axis: str                       # mesh axis the bytes cross
    axis_size: int
    nbytes: int                     # per-rank payload of one execution
    dtype_bytes: int = 4
    phase: str = "step"             # fwd | bwd | step | io
    window: tuple[float, float] = (0.0, 1.0)   # readiness in [0, 1]
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def key(self) -> str:
        return f"{self.op_name}|{self.axis}|{self.label}"

    def overlaps(self, other: "CommOp") -> bool:
        """Same link, intersecting readiness windows — the ops CONTEND."""
        if self.axis != other.axis:
            return False
        a0, a1 = self.window
        b0, b1 = other.window
        return a0 < b1 and b0 < a1

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["window"] = list(self.window)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CommOp":
        d = dict(d)
        d["window"] = tuple(d.get("window", (0.0, 1.0)))
        return cls(**d)


def _window_from_report(spec_kind: str, label: str,
                        report: instrument.RegionReport | None
                        ) -> tuple[float, float]:
    """Readiness window of a declared operand: sends open when the last
    write lands (instrumented readiness) and run to the end of the step;
    recvs open at step start and close at the first read (consumption
    slack).  Falls back to the kind's deterministic default."""
    default = _DEFAULT_WINDOW.get(spec_kind, (0.0, 1.0))
    if report is None or label not in report.records:
        return default
    rec = report.records[label]
    total = report.total_eqns
    if rec.writes > 0:
        t0 = max(0.0, min(1.0, rec.readiness(total)))
        return (t0, 1.0) if t0 < 1.0 else (0.99, 1.0)
    t1 = max(0.0, min(1.0, rec.consumption_slack(total)))
    return (0.0, t1) if t1 > 0.0 else (0.0, 0.01)


def _phase_for(kind: str) -> str:
    if kind in ("attention", "moe", "halo", "send", "recv", "all_gather",
                "all_to_all"):
        return "fwd"
    if kind in ("all_reduce", "reduce_scatter"):
        return "bwd"
    if kind == "ckpt":
        return "io"
    return "step"


def lower_specs(specs: Sequence[Any], axis_sizes: dict[str, int],
                report: instrument.RegionReport | None = None
                ) -> list[CommOp]:
    """Lower ``CommSpec`` declarations (core/region.py) to CommOps.

    Each spec's packed ``shape`` tuple is unpacked into the meta dict the
    planner's pricing needs — the same encodings ``CommRegion.plan``
    feeds the per-kind resolvers."""
    ops: list[CommOp] = []
    for spec in specs:
        kind = spec.kind
        op_name = _KIND_TO_OP.get(kind)
        if op_name is None:        # a collective family named directly
            op_name = _KIND_TO_OP.get(spec.collective, "all_gather")
        n = int(axis_sizes.get(spec.axis, 1))
        meta: dict[str, Any] = {"collective": spec.collective}
        site = getattr(spec, "site", None)
        if site is not None:            # declaration provenance -> diagnostics
            meta["site"] = (str(site[0]), int(site[1]))
        dtype_bytes = 4
        if kind == "halo" and spec.shape is not None:
            rows_local, cols = spec.shape
            dtype_bytes = max(1, spec.nbytes // max(1, cols))
            meta.update(rows_local=int(rows_local), cols=int(cols))
        elif kind == "attention" and spec.shape is not None:
            (batch, s_local, heads, kv_heads, head_dim, d_model, causal,
             ib) = spec.shape
            dtype_bytes = int(ib)
            meta.update(batch=int(batch), s_local=int(s_local),
                        heads=int(heads), kv_heads=int(kv_heads),
                        head_dim=int(head_dim), d_model=int(d_model),
                        causal=bool(causal))
        elif kind == "pipeline" and spec.shape is not None:
            n_layers, fwd_ps = spec.shape
            meta.update(n_layers=int(n_layers),
                        batch_fwd_s=float(fwd_ps) * 1e-12,
                        batch_bytes=int(spec.nbytes))
        elif kind == "moe" and spec.shape is not None:
            (tokens_local, d_model, n_experts, top_k, d_ff_expert,
             cf_milli, mults, ib) = spec.shape
            dtype_bytes = int(ib)
            meta.update(tokens_local=int(tokens_local),
                        d_model=int(d_model), n_experts=int(n_experts),
                        top_k=int(top_k), d_ff_expert=int(d_ff_expert),
                        capacity_factor=float(cf_milli) / 1000.0,
                        mults=int(mults))
        elif kind == "serve" and spec.shape is not None:
            (batch_slots, mean_prompt, mean_new, max_prompt, n_params,
             ib) = spec.shape
            dtype_bytes = int(ib)
            meta.update(batch_slots=int(batch_slots),
                        mean_prompt=int(mean_prompt),
                        mean_new=int(mean_new), max_prompt=int(max_prompt),
                        n_params=int(n_params))
        elif kind == "preempt" and spec.shape is not None:
            (batch_slots, page_bytes, mean_pages, mean_prompt, n_params,
             ib) = spec.shape
            dtype_bytes = int(ib)
            meta.update(batch_slots=int(batch_slots),
                        page_bytes=int(page_bytes),
                        mean_pages=int(mean_pages),
                        replay_tokens=int(mean_prompt),
                        n_params=int(n_params))
        elif kind == "ckpt" and spec.shape is not None:
            snapshot_bytes, step_ns, mtbf_s, bw = spec.shape
            meta.update(snapshot_bytes=int(snapshot_bytes),
                        step_s=float(step_ns) * 1e-9,
                        mtbf_s=float(mtbf_s),
                        write_bw=float(bw) if bw else None)
        ops.append(CommOp(
            kind=kind, label=spec.label, op_name=op_name, axis=spec.axis,
            axis_size=n, nbytes=int(spec.nbytes), dtype_bytes=dtype_bytes,
            phase=_phase_for(kind),
            window=_window_from_report(kind, spec.label, report),
            meta=meta))
    return ops


def lower_region(region: Any,
                 report: instrument.RegionReport | None = None
                 ) -> list[CommOp]:
    """Lower everything a ``CommRegion`` declares.  ``report`` (from
    ``instrument.analyze_region`` / ``region.plan``) refines windows with
    the instrumented readiness of each tracked operand."""
    return lower_specs(region._specs, region.axis_sizes, report)


def lower_collectives(records: Sequence[instrument.CollectiveRecord],
                      axis_sizes: dict[str, int],
                      max_depth: int | None = None) -> list[CommOp]:
    """Lower the jaxpr collectives the instrumentation extracted.  Depth
    orders the window: a collective at depth d of D occupies the
    [d/D, 1] tail of the step (its operand is ready once the producing
    program prefix ran)."""
    total = max_depth if max_depth is not None else \
        max((r.depth for r in records), default=1)
    total = max(1, total)
    prim_to_op = {"psum": "all_reduce", "psum_scatter": "reduce_scatter",
                  "ppermute": "all_to_all"}
    ops = []
    for i, r in enumerate(records):
        op_name = prim_to_op.get(r.primitive, r.primitive)
        if op_name not in _KIND_TO_OP.values():
            op_name = "all_gather"
        t0 = max(0.0, min(0.99, r.depth / total))
        meta: dict[str, Any] = {"collective": op_name,
                                "depth": int(r.depth),
                                "primitive": r.primitive,
                                "trips": int(getattr(r, "trips", 1))}
        src = getattr(r, "source", "")
        if src:                         # jaxpr eqn provenance -> diagnostics
            meta["source"] = src
        ops.append(CommOp(
            kind="collective", label=f"{r.primitive}#{i}", op_name=op_name,
            axis=r.axis, axis_size=int(axis_sizes.get(r.axis, 1)),
            nbytes=int(r.nbytes), phase="fwd", window=(t0, 1.0),
            meta=meta))
    return ops


def crosscheck_collectives(ops: Sequence[CommOp],
                           report: instrument.RegionReport
                           ) -> list[str]:
    """Reconcile declared ops against the trace's extracted collectives.

    Returns human-readable discrepancy notes (empty = consistent): a mesh
    axis whose TRACED bytes exceed what the declarations cover means
    undeclared traffic the planner cannot coordinate; declared bytes with
    no traced collective on that axis means the declaration didn't
    execute (stale region)."""
    declared: dict[str, int] = {}
    for op in ops:
        declared[op.axis] = declared.get(op.axis, 0) + op.nbytes
    traced = report.collective_bytes_by_axis()
    notes: list[str] = []
    for axis, tb in sorted(traced.items()):
        db = declared.get(axis, 0)
        if db == 0:
            notes.append(f"axis {axis}: {tb}B traced but nothing declared")
        elif tb > 4 * db:
            notes.append(f"axis {axis}: traced {tb}B >> declared {db}B")
    for axis, db in sorted(declared.items()):
        if db > 0 and traced and axis not in traced:
            notes.append(f"axis {axis}: {db}B declared, none traced")
    return notes


def train_geometry(cfg, *, mesh_axes: dict[str, int], batch: int, seq: int,
                   hw, pipeline: str = "none") -> dict:
    """Build the per-subsystem geometry dicts a training launch lowers
    from — the single source launch/train.py's planner path AND the
    static-verifier preflight (launch/lint.py) share, so the linted
    program is exactly the planned one.

    Returns ``{"mesh_axes", "grad_bytes", "attention", "moe",
    "pipeline"}`` — feed the last four straight into ``lower_train_ops``.
    """
    import jax.numpy as jnp
    ib = int(jnp.dtype(cfg.dtype).itemsize)
    dp = int(mesh_axes.get("data", 1))
    tp = int(mesh_axes.get("model", 1))
    pods = int(mesh_axes.get("pod", 1))
    b_loc = max(1, int(batch) // max(1, dp))
    attention = None
    if getattr(cfg, "n_heads", 0) and tp > 1:
        attention = {"batch": b_loc, "s_local": max(1, seq // tp),
                     "heads": cfg.n_heads, "kv_heads": cfg.n_kv_heads,
                     "head_dim": cfg.head_dim, "d_model": cfg.d_model,
                     "causal": True, "dtype_bytes": ib}
    moe_geom = None
    if getattr(cfg, "moe", None) is not None and tp > 1:
        moe_geom = {"tokens_local": b_loc * seq,
                    "d_model": cfg.d_model,
                    "n_experts": cfg.moe.n_experts,
                    "top_k": cfg.moe.top_k,
                    "d_ff_expert": cfg.moe.d_ff_expert,
                    "capacity_factor": cfg.moe.capacity_factor,
                    "mults": 3, "dtype_bytes": ib}
    pipe_geom = None
    if pipeline != "none":
        # mirror build_train_step's cost-model inputs exactly
        n_stage = pods
        pipe_geom = {
            "axis": "pod", "n_layers": cfg.n_layers,
            "batch_fwd_s": (2.0 * cfg.param_count() / n_stage
                            * (b_loc * seq) / hw.peak_flops),
            "batch_bytes": (b_loc * (seq // max(1, tp))
                            * cfg.d_model * ib),
            "local_batch": b_loc,
            "candidate_micro": tuple(
                m for m in (1, 2, 4, 8, 16, 32, 64)
                if b_loc % m == 0)}
    return {"mesh_axes": dict(mesh_axes),
            "grad_bytes": int(cfg.param_count()) * 4,
            "attention": attention, "moe": moe_geom,
            "pipeline": pipe_geom}


def lower_train_ops(*, mesh_axes: dict[str, int], model_axis: str = "model",
                    data_axes: Sequence[str] = ("pod", "data"),
                    grad_bytes: int = 0, dtype_bytes: int = 4,
                    pipeline: dict | None = None,
                    attention: dict | None = None,
                    moe: dict | None = None) -> list[CommOp]:
    """Lower a training step's communication set without a trace — the
    launch-path source (launch/train.py --plan).  Emits:

      * one gradient all_reduce per replicated data axis (``grad_bytes``
        per rank, backward window),
      * the pipeline handoff op on its axis when ``pipeline`` geometry is
        given ({axis, n_layers, batch_fwd_s, batch_bytes}),
      * the attention schedule op on the model axis when ``attention``
        geometry is given (resolve_attention_schedule kwargs),
      * the MoE dispatch op on the model axis when ``moe`` geometry is
        given (resolve_moe_dispatch kwargs).
    """
    ops: list[CommOp] = []
    if attention and mesh_axes.get(model_axis, 1) > 1:
        a = dict(attention)
        ib = int(a.get("dtype_bytes", 2))
        nbytes = (2 * a["batch"] * a["s_local"] * a["kv_heads"]
                  * a["head_dim"] * ib)
        ops.append(CommOp(
            kind="attention", label="train.attention",
            op_name="attention_schedule", axis=model_axis,
            axis_size=mesh_axes[model_axis], nbytes=nbytes,
            dtype_bytes=ib, phase="fwd",
            window=_DEFAULT_WINDOW["attention"], meta=a))
    if moe and mesh_axes.get(model_axis, 1) > 1:
        m = dict(moe)
        ib = int(m.get("dtype_bytes", 2))
        from repro.core import cost_model
        cap = cost_model.moe_capacity(m["tokens_local"], m["top_k"],
                                      m["n_experts"],
                                      m.get("capacity_factor", 1.25))
        nbytes = m["n_experts"] * cap * m["d_model"] * ib
        ops.append(CommOp(
            kind="moe", label="train.moe", op_name="moe_dispatch",
            axis=model_axis, axis_size=mesh_axes[model_axis],
            nbytes=nbytes, dtype_bytes=ib, phase="fwd",
            window=_DEFAULT_WINDOW["moe"], meta=m))
    if pipeline:
        p = dict(pipeline)
        axis = p.pop("axis", "pod")
        ops.append(CommOp(
            kind="pipeline", label="train.pipeline",
            op_name="pipeline_schedule", axis=axis,
            axis_size=mesh_axes.get(axis, 1),
            nbytes=int(p.get("batch_bytes", 0)), phase="step",
            window=_DEFAULT_WINDOW["pipeline"], meta=p))
    for axis in data_axes:
        if mesh_axes.get(axis, 1) > 1 and grad_bytes > 0:
            # pipeline training syncs grads over the pipeline axis via the
            # stage executor, not a step-level all_reduce — skip it there
            if pipeline and axis == (pipeline.get("axis") or "pod"):
                continue
            ops.append(CommOp(
                kind="all_reduce", label=f"train.grads.{axis}",
                op_name="all_reduce", axis=axis,
                axis_size=mesh_axes[axis], nbytes=int(grad_bytes),
                dtype_bytes=dtype_bytes, phase="bwd",
                window=_DEFAULT_WINDOW["all_reduce"],
                meta={"collective": "all_reduce"}))
    return ops
