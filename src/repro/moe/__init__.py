"""Managed expert-parallel dispatch — the fifth managed subsystem.

MoE token routing is the most data-dependent communication in the
codebase: how many bytes cross the EP axis per layer is decided by a
router at runtime.  This package owns the dispatch bookkeeping (capacity
math, index-based gather/combine, per-expert valid counts) shared by the
model blocks (models/moe.py), the streamed executor
(core/managed.py::managed_expert_stream), the grouped-expert GEMM
(kernels/grouped_matmul.py) and the decision machinery
(core/cost_model.py::decide_moe_dispatch).
"""

from repro.moe.dispatch import (capacity_for, combine_from_buffers,
                                dispatch_indices, expert_counts,
                                gather_to_buffers)

__all__ = ["capacity_for", "combine_from_buffers", "dispatch_indices",
           "expert_counts", "gather_to_buffers"]
