"""Capacity-limited token dispatch bookkeeping (index-based, GShard
semantics) — shared by every MoE dispatch schedule.

The one-hot [T, E, C] dispatch tensor would be terabytes at 32k-token
microbatches, so dispatch is a stable expert-major argsort: entry (t, k)
lands at slot ``pos`` within expert e's capacity block iff fewer than C
earlier entries routed to e (``keep``); overflow entries park in a
sentinel row that contributes exactly zero on combine.

``capacity_for`` is the ONE place capacity is computed (PR 5 satellite):
the seed code floored ``int(t * top_k / e * capacity_factor)`` in two
blocks, so ``capacity_factor=1.0`` with perfectly balanced routing could
still drop tokens — this rounds UP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cost_model import moe_capacity

Array = jax.Array


def capacity_for(tokens: int, e_cfg, capacity_factor: float | None = None
                 ) -> int:
    """Per-expert capacity C for ``tokens`` routed top-k among
    ``e_cfg.n_experts`` experts.  Rounds UP so a capacity factor of 1.0
    never drops under perfectly balanced routing (the seed's ``int(...)``
    floored).  ``capacity_factor`` overrides the config's static guess —
    the managed decision layer re-picks it from instrumented routing.
    Delegates to ``cost_model.moe_capacity`` so the planner/tuner price
    exactly the C the blocks execute."""
    cf = e_cfg.capacity_factor if capacity_factor is None else capacity_factor
    return moe_capacity(tokens, e_cfg.top_k, e_cfg.n_experts, cf)


def dispatch_indices(top_idx: Array, n_experts: int, capacity: int
                     ) -> tuple[Array, Array, Array, Array]:
    """Capacity-limited dispatch bookkeeping (index-based).

    top_idx: [T, K] expert ids.  Returns
      dest  [T*K] slot in the [E*C] buffer (or E*C for dropped entries),
      tok   [T*K] source token of each (t, k) entry in expert-sorted order,
      keep  [T*K] 1.0 where the entry fit under capacity,
      order [T*K] the expert-major argsort permuting flat (t, k) entries
            into the order of the three arrays above (combine_from_buffers
            uses it to align the gate weights).
    """
    t, k = top_idx.shape
    flat_e = top_idx.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)            # expert-major order
    sorted_e = flat_e[order]
    tok = order // k
    # position of each entry within its expert's buffer
    pos = jnp.arange(t * k) - jnp.searchsorted(sorted_e,
                                               sorted_e, side="left")
    keep = (pos < capacity).astype(jnp.float32)
    dest = jnp.where(pos < capacity, sorted_e * capacity + pos,
                     n_experts * capacity)               # overflow bucket
    return dest, tok, keep, order


def expert_counts(top_idx: Array, n_experts: int, capacity: int) -> Array:
    """Per-expert KEPT row counts [E] int32 (``min(load_e, C)``) — the
    scalar-prefetched valid counts the grouped-expert GEMM uses to skip
    padded capacity rows.  Consistent with ``dispatch_indices``: rows
    [0, count_e) of expert e's capacity block hold real tokens, the rest
    are zero padding."""
    flat = jnp.sort(top_idx.reshape(-1))
    eids = jnp.arange(n_experts)
    load = (jnp.searchsorted(flat, eids, side="right")
            - jnp.searchsorted(flat, eids, side="left"))
    return jnp.minimum(load, capacity).astype(jnp.int32)


def gather_to_buffers(x2: Array, dest: Array, tok: Array, keep: Array,
                      n_experts: int, capacity: int) -> Array:
    """x2: [T, D] -> expert buffers [E, C, D] (dropped tokens zeroed)."""
    d = x2.shape[-1]
    rows = x2[tok] * keep[:, None].astype(x2.dtype)
    buf = jnp.zeros((n_experts * capacity + 1, d), x2.dtype)
    buf = buf.at[dest].set(rows, mode="drop")
    return buf[:-1].reshape(n_experts, capacity, d)


def combine_from_buffers(out: Array, dest: Array, tok: Array, keep: Array,
                         gates: Array, order: Array, t: int) -> Array:
    """out: [E, C, D] -> y [T, D], weighting by the (t, k) gate.
    dest/tok/keep are in expert-sorted order; ``order`` permutes the flat
    [T*K] gate entries into that order."""
    e, c, d = out.shape
    flat = jnp.concatenate([out.reshape(e * c, d),
                            jnp.zeros((1, d), out.dtype)])
    k = gates.shape[1]
    g = gates.reshape(t * k)[order]
    rows = flat[dest] * (g * keep)[:, None].astype(out.dtype)
    y = jnp.zeros((t, d), out.dtype)
    return y.at[tok].add(rows)
