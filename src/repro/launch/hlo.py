"""Compiled-HLO static analyzer: loop-weighted FLOPs, HBM bytes, and
collective link-bytes for the roofline.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which makes
it useless for scanned-layer programs (a 96-layer scan under-counts 96x).
Instead we parse the optimised module text:

  * split into computations; follow ``while(body=%comp)`` edges weighted by
    the ``known_trip_count`` backend config (nested loops multiply);
  * FLOPs: every ``dot`` costs 2 * prod(result dims) * prod(contracting
    dims) (operand shapes resolved through a per-computation symbol table);
  * HBM bytes: every materialising op (fusion/dot/copy/scatter/...) reads
    its operands and writes its result once — the post-fusion module makes
    this a good HBM-traffic model;
  * collectives: converted to per-chip ICI link bytes with ring algebra:
        all-gather          (n-1)/n * result
        reduce-scatter      (n-1)   * result
        all-reduce          2(n-1)/n * result
        all-to-all          (n-1)/n * result
        collective-permute  result
    (n = replica group size parsed per op).

Conditional branches are counted at multiplier 1 each (upper bound).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

#: ops whose operands/results we count as HBM traffic.  Bare layout /
#: elementwise ops (transpose, reshape, broadcast, convert, tanh, ...) are
#: EXCLUDED: they appear standalone in CPU HLO but fuse into neighbours on
#: the TPU target; fusions already account for their traffic.
_MATERIALIZING = _COLLECTIVES + (
    "fusion", "dot", "convolution", "copy",
    "concatenate", "slice", "dynamic-slice", "dynamic-update-slice",
    "scatter", "gather", "reduce", "reduce-window", "sort", "pad")

_SHAPE_RE = re.compile(
    r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.-]+|[\w.-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z][\w-]*)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%[\w.-]+|[\w.-]+)\s+\(.*\)\s*->")
_OPERAND_RE = re.compile(r"%[\w.-]+|\b[a-z][\w.-]*\b")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_BODY_RE = re.compile(r"body=(%[\w.-]+|[\w.-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CALLS_RE = re.compile(r"calls=(%[\w.-]+|[\w.-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_elems_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _result_dims(result: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.search(result)
    if not m:
        return "", []
    dtype, dims = m.group(1), m.group(2)
    return dtype, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class OpInfo:
    name: str
    result: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[OpInfo]
    shapes: dict[str, str]


def _parse(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and line.rstrip().endswith("{"):
            name = mc.group(2).lstrip("%")
            cur = Computation(name=name, ops=[], shapes={})
            comps[name] = cur
            if mc.group(1):
                entry = name
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        md = _DEF_RE.match(line)
        if md:
            name = md.group(1).lstrip("%")
            result, op, rest = md.group(2), md.group(3), md.group(4)
            cur.ops.append(OpInfo(name=name, result=result, op=op,
                                  rest=rest))
            cur.shapes[name] = result
    return comps, entry


def _multipliers(comps: dict[str, Computation], entry: str
                 ) -> dict[str, float]:
    mult: dict[str, float] = {entry: 1.0}
    queue = [entry]
    while queue:
        cname = queue.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops:
            if op.op == "while":
                body = _BODY_RE.search(op.rest)
                trip = _TRIP_RE.search(op.rest)
                n = int(trip.group(1)) if trip else 1
                if body:
                    b = body.group(1).lstrip("%")
                    mult[b] = mult.get(b, 0.0) + m * n
                    queue.append(b)
            elif op.op == "conditional":
                br = _BRANCHES_RE.search(op.rest)
                if br:
                    for b in br.group(1).split(","):
                        b = b.strip().lstrip("%")
                        mult[b] = mult.get(b, 0.0) + m
                        queue.append(b)
            elif op.op in ("call", "async-start"):
                c = _CALLS_RE.search(op.rest)
                if c:
                    b = c.group(1).lstrip("%")
                    mult[b] = mult.get(b, 0.0) + m
                    queue.append(b)
    return mult


def _operands(op: OpInfo) -> list[str]:
    # operand list = leading %refs before any attribute (key=value)
    depth = 0
    out = []
    token = ""
    for ch in op.rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        token += ch
    for t in token.split(","):
        t = t.strip()
        if t.startswith("%"):
            out.append(t.lstrip("%"))
        elif re.fullmatch(r"[\w.-]+", t or "#"):
            out.append(t)
    return out


def _dot_flops(op: OpInfo, shapes: dict[str, str]) -> float:
    dtype, rdims = _result_dims(op.result)
    operands = _operands(op)
    if not operands:
        return 0.0
    lhs = shapes.get(operands[0], "")
    _, ldims = _result_dims(lhs)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contracted = 1
    if mc and mc.group(1):
        for d in mc.group(1).split(","):
            if int(d) < len(ldims):
                contracted *= ldims[int(d)]
    return 2.0 * float(max(contracted, 1)) * float(math.prod(rdims or [0]))


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return default


def _link_bytes(op: str, result_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return (n - 1) / n * result_bytes
    if op == "reduce-scatter":
        return (n - 1) * result_bytes
    if op == "all-reduce":
        return 2 * (n - 1) / n * result_bytes
    if op == "all-to-all":
        return (n - 1) / n * result_bytes
    if op == "collective-permute":
        return float(result_bytes)
    return 0.0


def analyze_hlo_text(text: str, default_group: int = 2) -> dict[str, Any]:
    comps, entry = _parse(text)
    mult = _multipliers(comps, entry)

    flops = 0.0
    hbm = 0.0
    coll_bytes: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_counts: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    # only walk computations reachable via control flow (fusion bodies are
    # costed at their call sites)
    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            base = op.op.replace("-start", "")
            if base in ("while", "conditional", "parameter", "constant",
                        "tuple", "get-tuple-element", "bitcast",
                        "after-all", "partition-id"):
                continue
            if op.op.endswith("-done"):
                continue
            rbytes = _shape_elems_bytes(op.result)
            if base in _COLLECTIVES:
                n = _group_size(op.rest, default_group)
                payload = rbytes
                if op.result.startswith("("):
                    # async start tuples: take the largest element
                    payload = max(
                        (_shape_elems_bytes(f"{d}[{s}]")
                         for d, s in _SHAPE_RE.findall(op.result)),
                        default=0)
                coll_bytes[base] += m * _link_bytes(base, payload, n)
                coll_counts[base] += m
                hbm += m * payload
                continue
            if base == "dot":
                flops += m * _dot_flops(op, comp.shapes)
            if base in _MATERIALIZING:
                if base == "dynamic-update-slice":
                    # in-place update: traffic = the updated slice (read +
                    # write), NOT the whole buffer
                    ops_ = _operands(op)
                    upd = (_shape_elems_bytes(comp.shapes.get(ops_[1], ""))
                           if len(ops_) > 1 else 0)
                    hbm += m * 2 * upd if upd >= 1 << 20 else 0
                    continue
                if base == "dynamic-slice":
                    hbm += m * 2 * rbytes if rbytes >= 1 << 20 else 0
                    continue
                # HBM-traffic model: count only >=1 MiB tensors (smaller
                # intermediates live in VMEM/registers on the TPU target)
                opbytes = sum(
                    b for b in (_shape_elems_bytes(comp.shapes.get(o, ""))
                                for o in _operands(op))
                    if b >= 1 << 20)
                if rbytes < 1 << 20:
                    rbytes = 0
                hbm += m * (rbytes + opbytes)

    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": sum(coll_bytes.values()),
        "collective_detail": {"bytes_per_kind": coll_bytes,
                              "counts": coll_counts},
    }


def analyze_compiled(compiled, n_chips: int) -> dict[str, Any]:
    """Roofline inputs for one compiled cell.  All numbers are PER CHIP
    (the SPMD module is the per-device program)."""
    raw_cost = compiled.cost_analysis()
    if isinstance(raw_cost, (list, tuple)):
        raw_cost = raw_cost[0]
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0)),
        }
    except Exception:                                    # backend-dependent
        mem_stats = {}
    stats = analyze_hlo_text(compiled.as_text())
    return {
        "n_chips": n_chips,
        "flops_per_chip": stats["flops"],
        "hbm_bytes_per_chip": stats["hbm_bytes"],
        "collective_bytes_per_chip": stats["collective_bytes"],
        "collective_detail": stats["collective_detail"],
        "raw_cost_analysis": {
            "flops_body_once": float(raw_cost.get("flops", 0.0)),
            "bytes_body_once": float(raw_cost.get("bytes accessed", 0.0)),
        },
        "memory": mem_stats,
    }
