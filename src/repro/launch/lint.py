"""mdmplint CLI — run the static communication verifier standalone.

    # lint a training launch (no devices needed — pure geometry):
    PYTHONPATH=src python -m repro.launch.lint --target train \
        --arch granite-34b --reduced --mesh 2x2x2 --pipeline 1f1b \
        --batch 8 --seq 128

    # lint a serving launch:
    PYTHONPATH=src python -m repro.launch.lint --target serve \
        --arch mamba2-130m --reduced --slots 4

    # lint a corpus case (tests/lint_corpus/*.json):
    PYTHONPATH=src python -m repro.launch.lint \
        --case tests/lint_corpus/nonbijective_permute.json -v

Exit status 1 iff any error-severity diagnostic — the CI gate greps the
``MDMPxxx`` line prefixes and trusts the status.  ``--plan FILE`` loads
a stored ProgramPlan JSON (core/tuner.store_program_plan) instead of
re-planning, so the lint runs against the knobs a previous launch
actually installed.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro import analysis


def _mesh_axes(spec: str | None, pipeline: str) -> dict[str, int]:
    if spec:
        dims = tuple(int(x) for x in spec.split("x"))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
        return dict(zip(axes, dims))
    if pipeline != "none":
        return {"pod": 2, "data": 1, "model": 1}
    return {"data": 2, "model": 1}


def _train_graph(args, hw, plan) -> analysis.CommGraph:
    from repro import configs
    from repro.plan import lower_train_ops, plan_program, train_geometry
    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    mesh_axes = _mesh_axes(args.mesh, args.pipeline)
    geo = train_geometry(cfg, mesh_axes=mesh_axes, batch=args.batch,
                         seq=args.seq, hw=hw, pipeline=args.pipeline)
    ops = lower_train_ops(
        mesh_axes=geo["mesh_axes"], grad_bytes=geo["grad_bytes"],
        pipeline=geo["pipeline"], attention=geo["attention"],
        moe=geo["moe"])
    if plan is None:
        plan = plan_program(ops, hw=hw,
                            notes=[f"launch.lint {args.arch}"])
    return analysis.from_ops(
        f"train:{args.arch}", axis_sizes=mesh_axes, declared=ops,
        plan=plan, hw=hw)


def _serve_graph(args, hw, plan) -> analysis.CommGraph:
    from repro import configs
    from repro.plan import CommOp, plan_program
    import numpy as np
    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    ib = int(np.dtype("float32").itemsize)
    n_params = float(cfg.param_count())
    # per-page KV bytes across layers — the same order the engine
    # allocates; lint only needs the magnitude, not the exact pool
    page_bytes = 2 * cfg.n_layers * args.page_size * cfg.d_model * ib
    mean_prompt = (args.prompt_len + 4) / 2.0
    mean_pages = max(1, (args.prompt_len + args.new_tokens
                         + args.page_size - 1) // args.page_size)
    ops = [
        CommOp(kind="serve", label="serve.schedule",
               op_name="serve_schedule", axis="serve",
               axis_size=args.slots, nbytes=int(n_params) * ib,
               dtype_bytes=ib, phase="serve",
               meta={"batch_slots": args.slots,
                     "mean_prompt": mean_prompt,
                     "mean_new": float(args.new_tokens),
                     "max_prompt": float(args.prompt_len),
                     "n_params": n_params}),
        CommOp(kind="preempt", label="serve.preempt",
               op_name="preempt_policy", axis="serve",
               axis_size=args.slots, nbytes=int(page_bytes),
               dtype_bytes=ib, phase="serve",
               meta={"batch_slots": args.slots,
                     "page_bytes": int(page_bytes),
                     "mean_pages": mean_pages,
                     "replay_tokens": args.prompt_len,
                     "n_params": n_params}),
    ]
    if plan is None:
        plan = plan_program(ops, hw=hw,
                            notes=[f"launch.lint serve {args.arch}"])
    return analysis.from_ops(
        f"serve:{args.arch}", axis_sizes={"serve": args.slots},
        declared=ops, plan=plan, hw=hw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.lint")
    ap.add_argument("--case", default=None,
                    help="lint-corpus JSON case instead of a launch "
                         "config")
    ap.add_argument("--target", default="train",
                    choices=("train", "serve"))
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 2x2x2")
    ap.add_argument("--pipeline", default="none",
                    choices=("none", "gpipe", "1f1b", "interleaved",
                             "auto"))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--plan", default=None,
                    help="stored ProgramPlan JSON to lint against "
                         "(default: re-plan from the geometry)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print declared/traced side-by-side + fix "
                         "hints")
    args = ap.parse_args(argv)

    from repro.core import managed
    hw = managed.get_config().hw
    plan = None
    if args.plan:
        from repro.plan import ProgramPlan
        with open(args.plan) as f:
            plan = ProgramPlan.from_dict(json.load(f))

    if args.case:
        with open(args.case) as f:
            case = json.load(f)
        graph = analysis.from_corpus(case, hw=hw)
        if plan is not None:
            graph.plan = plan
    else:
        if not args.arch:
            ap.error("--arch is required without --case")
        graph = (_train_graph(args, hw, plan) if args.target == "train"
                 else _serve_graph(args, hw, plan))

    diags = analysis.run_all(graph)
    out = analysis.render(diags, verbose=args.verbose)
    if out:
        print(out)
    print(analysis.summary(diags, graph.name))
    return analysis.exit_code(diags)


if __name__ == "__main__":
    sys.exit(main())
