import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init) — this module is the ONLY place the 512
# placeholder devices exist; tests and benches see the real device count.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the REAL step function (train_step for train_4k,
prefill for prefill_32k, decode for decode_32k / long_500k) with
ShapeDtypeStruct stand-ins on the production mesh, compiles it, and records

  * memory_analysis()      — proves the cell fits per-device HBM,
  * cost_analysis()        — FLOPs / bytes for §Roofline,
  * collective bytes       — parsed from the compiled module (launch/hlo.py),

into a JSON artifact consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-7b \
      --shape train_4k [--multipod] [--out results/dryrun.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod]

Incremental: cells already present in --out are skipped unless --force.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, \
    shape_applicable
from repro.launch import hlo
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.sharding import (MeshCtx, global_shape_dtypes,
                                     spec_pspecs)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, kind: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out = {}
    if kind == "train":
        out = {"tokens": tok, "labels": tok}
    elif kind == "prefill":
        out = {"tokens": tok}
    if kind in ("train", "prefill"):
        if cfg.encoder is not None:
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder.n_frames, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.vision is not None:
            out["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.vision.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               mdmp_mode: str = "bulk", mesh_shape: str | None = None,
               accum_override: int | None = None,
               remat_override: bool | None = None,
               attn_impl: str | None = None):
    """Build + lower + compile one cell; returns the record dict.

    ``mesh_shape`` (e.g. "256x1", "64x4") re-roles the SAME 256 chips into
    a different (data, model) split — the §Perf sharding-scheme knob.
    ``mdmp_mode`` lowers with interleaved rings instead of bulk
    collectives."""
    import dataclasses as _dc
    cfg = configs.get_config(arch)
    if accum_override is not None:
        cfg = _dc.replace(cfg, accum_steps=accum_override)
    if remat_override is not None:
        cfg = _dc.replace(cfg, remat=remat_override)
    if attn_impl:
        cfg = _dc.replace(cfg, attn_impl=attn_impl)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": reason}

    if mesh_shape:
        dims = tuple(int(x) for x in mesh_shape.split("x"))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
        mesh = jax.make_mesh(dims, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode=mdmp_mode)
    model = Model(cfg, ctx)
    specs = model.param_specs()
    params_sds = global_shape_dtypes(specs, jnp.dtype(cfg.dtype))

    t0 = time.monotonic()
    if shape.kind == "train":
        from repro.train.train_loop import build_train_step
        step, _, _ = build_train_step(model, AdamWConfig(
            moment_dtype=cfg.moment_dtype), mesh, donate=False)
        opt_sds = {
            "mu": global_shape_dtypes(specs, jnp.dtype(cfg.moment_dtype)),
            "nu": global_shape_dtypes(specs, jnp.dtype(cfg.moment_dtype)),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        batch = input_specs(cfg, shape, "train")
        lowered = step.lower(params_sds, opt_sds, batch)
    elif shape.kind == "prefill":
        from repro.train.serve_loop import build_prefill_step
        step = build_prefill_step(model, mesh)
        batch = input_specs(cfg, shape, "prefill")
        lowered = step.lower(params_sds, batch)
    else:  # decode
        from repro.train.serve_loop import build_decode_step
        step, cache_sds, _ = build_decode_step(model, mesh, shape)
        tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = step.lower(params_sds, cache_sds, tok, pos)
    t_lower = time.monotonic() - t0

    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    rec = hlo.analyze_compiled(compiled, n_chips)
    rec.update({
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_shape or ("2x16x16" if multi_pod else "16x16"),
        "mdmp_mode": mdmp_mode,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    })
    print(f"[dryrun] {arch} {shape_name} {'2x16x16' if multi_pod else '16x16'}"
          f" OK  flops/chip={rec['flops_per_chip']:.3e}"
          f" hbm/chip={rec['hbm_bytes_per_chip']:.3e}"
          f" coll/chip={rec['collective_bytes_per_chip']:.3e}"
          f" peak_mem={rec['memory'].get('peak_bytes', 0)/2**30:.2f}GiB"
          f" (lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    print("  memory_analysis:", rec["memory"])
    print("  cost_analysis: flops=%.4e bytes=%.4e" % (
        rec["flops_per_chip"], rec["hbm_bytes_per_chip"]))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--mdmp-mode", default="bulk")
    ap.add_argument("--mesh-shape", default=None,
                    help="re-role the chips, e.g. 256x1 or 64x4 (§Perf)")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--attn-impl", default=None,
                    help="megatron | ulysses (a2a attention)")
    ap.add_argument("--fsdp-dtype", default=None,
                    help="quantised FSDP gather payload, e.g. float8_e4m3fn")
    ap.add_argument("--tag", default="",
                    help="suffix for the result key (perf experiments)")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    archs = configs.list_archs() if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]

    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = args.mesh_shape or \
                    ("2x16x16" if mp else "16x16")
                key = f"{arch}|{shape_name}|{mesh_name}{args.tag}"
                if key in results and results[key].get("status") == "ok" \
                        and not args.force:
                    print(f"[dryrun] {key} cached, skipping")
                    continue
                try:
                    from repro.core import managed as _m
                    _m.get_config().fsdp_gather_dtype = args.fsdp_dtype
                    results[key] = lower_cell(
                        arch, shape_name, mp, mdmp_mode=args.mdmp_mode,
                        mesh_shape=args.mesh_shape,
                        accum_override=args.accum,
                        remat_override=(False if args.no_remat else None),
                        attn_impl=args.attn_impl)
                    if args.tag:
                        results[key]["mesh"] = mesh_name + args.tag
                except Exception as e:     # record failures for triage
                    results[key] = {"status": "error",
                                    "error": f"{type(e).__name__}: {e}"}
                    print(f"[dryrun] {key} ERROR: {e}")
                    traceback.print_exc()
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=2)

    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()
