"""Trace inspection CLI — summarize or diff mdmptrace Chrome traces.

    PYTHONPATH=src python -m repro.launch.trace /tmp/run.json
    PYTHONPATH=src python -m repro.launch.trace --diff A.json B.json \
        [--threshold 0.5]

Summary mode re-prints what the run knew: per-track span totals, per-op
measured seconds, the decision instants, and the embedded calibration
ledger — everything reconstructed from the file alone, so a trace is a
self-contained artifact you can hand to someone without the repo state
that produced it.

Diff mode compares per-span-name mean durations between two traces and
exits non-zero when any shared hot path regressed by more than
``--threshold`` (relative, so 0.5 = +50%) — the CI hook that stops a
perf regression from landing silently.
"""

from __future__ import annotations

import argparse
import sys
from collections import defaultdict

from repro.obs.export import load_trace, trace_tracks


def _spans(doc: dict) -> list[dict]:
    return [e for e in doc["traceEvents"] if e.get("ph") == "X"]


def _decisions(doc: dict) -> list[dict]:
    return [e for e in doc["traceEvents"]
            if e.get("ph") == "i" and e.get("s") == "p"]


def _by_name(doc: dict) -> dict[str, tuple[int, float]]:
    """span name -> (count, total seconds)."""
    acc: dict[str, tuple[int, float]] = defaultdict(lambda: (0, 0.0))
    for e in _spans(doc):
        n, tot = acc[e["name"]]
        acc[e["name"]] = (n + 1, tot + float(e.get("dur", 0.0)) / 1e6)
    return dict(acc)


def summarize(path: str) -> None:
    doc = load_trace(path)
    other = doc.get("otherData", {})
    tracks = trace_tracks(doc)
    spans = _spans(doc)
    print(f"{path}: run={other.get('run', '?')} "
          f"{len(spans)} spans (dropped={other.get('dropped', 0)}), "
          f"{other.get('n_decisions', 0)} decisions")

    per_track: dict[str, tuple[int, float]] = defaultdict(lambda: (0, 0.0))
    for e in spans:
        name = tracks.get(e["tid"], f"tid{e['tid']}")
        n, tot = per_track[name]
        per_track[name] = (n + 1, tot + float(e.get("dur", 0.0)) / 1e6)
    print("tracks:")
    for name, (n, tot) in sorted(per_track.items(),
                                 key=lambda kv: -kv[1][1]):
        print(f"  {name:<16} {n:4d} spans  {tot * 1e3:10.2f} ms")

    print("hot paths:")
    for name, (n, tot) in sorted(_by_name(doc).items(),
                                 key=lambda kv: -kv[1][1]):
        print(f"  {name:<22} {n:4d} x {tot / n * 1e6:10.1f} us "
              f"= {tot * 1e3:8.2f} ms")

    decs = _decisions(doc)
    if decs:
        print("decisions:")
        for e in decs:
            a = e.get("args", {})
            print(f"  {a.get('op', '?')}[{a.get('axis', '?')}] "
                  f"mode={a.get('mode', '?')} chunks={a.get('chunks')} "
                  f"nbytes={a.get('nbytes')} "
                  f"bulk={a.get('predicted_bulk_s', 0):.3e}s "
                  f"chosen={a.get('predicted_interleaved_s', 0):.3e}s")

    cal = other.get("calibration")
    if cal:
        print(f"calibration: coverage {cal.get('coverage', 0) * 100:.0f}%")
        for key, r in sorted(cal.get("ratios", {}).items()):
            flag = (" MISCALIBRATED"
                    if key in cal.get("miscalibrated", {}) else "")
            print(f"  {key} ratio={r:.2f}{flag}")


def diff(path_a: str, path_b: str, threshold: float) -> int:
    a, b = load_trace(path_a), load_trace(path_b)
    na, nb = _by_name(a), _by_name(b)
    shared = sorted(set(na) & set(nb))
    only_a, only_b = sorted(set(na) - set(nb)), sorted(set(nb) - set(na))
    print(f"diff {path_a} -> {path_b}: {len(shared)} shared hot paths, "
          f"threshold +{threshold * 100:.0f}%")
    worst = 0.0
    failed = []
    for name in shared:
        ca, ta = na[name]
        cb, tb = nb[name]
        mean_a, mean_b = ta / ca, tb / cb
        rel = (mean_b - mean_a) / mean_a if mean_a > 0 else 0.0
        worst = max(worst, rel)
        mark = ""
        if rel > threshold:
            failed.append(name)
            mark = "  REGRESSED"
        print(f"  {name:<22} {mean_a * 1e6:10.1f}us -> "
              f"{mean_b * 1e6:10.1f}us ({rel * 100:+7.1f}%){mark}")
    for name in only_a:
        print(f"  {name:<22} only in {path_a}")
    for name in only_b:
        print(f"  {name:<22} only in {path_b}")
    if failed:
        print(f"FAIL: {len(failed)} hot path(s) regressed past "
              f"+{threshold * 100:.0f}%: {', '.join(failed)}")
        return 1
    print(f"OK: worst shared-path change {worst * 100:+.1f}%")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize one mdmptrace Chrome trace, or --diff two")
    ap.add_argument("paths", nargs="+", metavar="TRACE.json")
    ap.add_argument("--diff", action="store_true",
                    help="compare two traces (per-span-name mean "
                         "durations); exit 1 on a regression past "
                         "--threshold")
    ap.add_argument("--threshold", type=float, default=0.5,
                    help="relative regression bound for --diff "
                         "(0.5 = +50%%)")
    args = ap.parse_args(argv)
    if args.diff:
        if len(args.paths) != 2:
            ap.error("--diff needs exactly two trace files")
        return diff(args.paths[0], args.paths[1], args.threshold)
    for p in args.paths:
        summarize(p)
    return 0


if __name__ == "__main__":
    sys.exit(main())
