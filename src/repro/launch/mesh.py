"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  Shapes:

  * single pod:  (16, 16)      axes ("data", "model")   = 256 chips
  * multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

The dry-run (launch/dryrun.py) materialises these over 512 forced host
devices; real deployments get them from the TPU slice topology.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False) -> Mesh:
    """8-device miniature with the same axis structure (CI / CPU tests)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
