"""Serving driver CLI — the managed serving runtime (repro/serve).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
        --reduced --schedule auto --requests 8 --new-tokens 16

``--schedule static`` reproduces the unmanaged baseline (padded waves =
the seed Generator); ``continuous`` pins continuous batching;  ``auto``
lets the managed runtime pick mode + scheduling quantum from the serve
cost model and correct it online from the measured step latencies.  The
decision trail (DecisionRecord op="serve_schedule") is printed at the
end.  Prompt lengths are MIXED by default (--prompt-len down to
--min-prompt-len) — the workload where continuous batching pays.

Overload robustness knobs: ``--pages`` under-provisions the KV page pool
so optimistic admission needs its preemption backstop (``--preempt``
swap / recompute / auto — every pool-exhaustion event prints as a
``preempt_policy`` decision); ``--slo-ttft`` / ``--max-queue`` turn on
SLO shedding and queue backpressure; ``--fault-plan 'burst@3:16'``
injects a deterministic arrival flood (see core/faults.py).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core import managed
from repro.core.faults import FaultPlan
from repro.models.model import Model
from repro.parallel.sharding import MeshCtx, infer_shardings
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import RequestRejected


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--min-prompt-len", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size (default slots*max_seq worth; "
                    "smaller values exercise the preemption backstop)")
    ap.add_argument("--schedule", default="auto",
                    choices=("static", "continuous", "auto"))
    ap.add_argument("--chunk", type=int, default=None,
                    help="pin the scheduling quantum C")
    ap.add_argument("--preempt", default="auto",
                    choices=("swap", "recompute", "auto"),
                    help="pool-exhaustion policy (auto = cost model)")
    ap.add_argument("--slo-ttft", type=float, default=None,
                    help="TTFT SLO in seconds (estimates beyond it shed)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="pending-queue bound (backpressure shedding)")
    ap.add_argument("--fault-plan", default=None,
                    help="e.g. 'burst@3:16;pool_squeeze@5:0.5'")
    ap.add_argument("--plan", default="local",
                    choices=("local", "program", "auto"),
                    help="communication planning scope: 'program'/'auto' "
                         "run the whole-program planner over the serving "
                         "comm set (schedule + preempt knobs) and install "
                         "the coordinated ProgramPlan before the run")
    ap.add_argument("--mdmp-mode", default="auto")
    ap.add_argument("--verify", default="warn",
                    choices=("off", "warn", "strict"),
                    help="static-verifier preflight (repro.analysis): "
                         "'warn' prints findings and logs a "
                         "DecisionRecord(op=\"lint\"); 'strict' exits "
                         "non-zero on any error")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record every quantum/swap/preemption to a "
                         "Chrome-trace JSON (open in ui.perfetto.dev), "
                         "print the predicted-vs-measured calibration "
                         "report, and embed the ledger in the file")
    args = ap.parse_args()

    if args.trace:
        # install before the engine resolves anything so admission,
        # preflight and every quantum land on one ring
        from repro import obs
        obs.install_tracer(obs.Tracer())

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode=args.mdmp_mode)
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))

    plan = (FaultPlan.parse(args.fault_plan) if args.fault_plan
            else None)
    engine = ServeEngine(model, mesh, params, slots=args.slots,
                         max_seq=args.max_seq, page_size=args.page_size,
                         n_pages=args.pages, schedule=args.schedule,
                         chunk=args.chunk, fault_plan=plan,
                         preempt=args.preempt,
                         slo_ttft_s=args.slo_ttft,
                         max_queue=args.max_queue)
    prog = None
    if args.plan != "local" or args.verify != "off":
        # Lower the serving comm set once — the whole-program planner
        # (--plan) and the static-verifier preflight (--verify) both
        # consume it.
        import jax.numpy as jnp
        from repro.plan import CommOp, plan_program
        n_params = float(cfg.param_count())
        ib = int(jnp.dtype(cfg.dtype).itemsize)
        lo0 = min(args.min_prompt_len, args.prompt_len)
        mean_prompt = (lo0 + args.prompt_len) / 2.0
        mean_pages = max(1, (args.prompt_len + args.new_tokens
                             + args.page_size - 1) // args.page_size)
        ops = [
            CommOp(kind="serve", label="serve.schedule",
                   op_name="serve_schedule", axis="serve",
                   axis_size=args.slots,
                   nbytes=int(n_params) * ib, dtype_bytes=ib,
                   phase="serve",
                   meta={"batch_slots": args.slots,
                         "mean_prompt": mean_prompt,
                         "mean_new": float(args.new_tokens),
                         "max_prompt": float(args.prompt_len),
                         "n_params": n_params}),
            CommOp(kind="preempt", label="serve.preempt",
                   op_name="preempt_policy", axis="serve",
                   axis_size=args.slots,
                   nbytes=int(engine._page_bytes), dtype_bytes=ib,
                   phase="serve",
                   meta={"batch_slots": args.slots,
                         "page_bytes": int(engine._page_bytes),
                         "mean_pages": mean_pages,
                         "replay_tokens": args.prompt_len,
                         "n_params": n_params}),
        ]
        prog = plan_program(ops, notes=[f"launch.serve {args.arch}"])
        if args.plan != "local":
            kind = "coordinated" if prog.coordinated else "local"
            print(f"decision program_plan({kind} ops={len(prog.choices)} "
                  f"topo={prog.topology} "
                  f"local-concat={prog.local_solo_sum_s * 1e6:.1f}us "
                  f"joint={prog.joint_cost_s * 1e6:.1f}us)")
            for line in prog.summary().splitlines()[1:]:
                print(f"  trail{line}")
            managed.install_plan(prog)
        if args.verify != "off":
            # Static-verifier preflight over the serving comm set under
            # the knobs this launch will run.
            from repro import analysis
            graph = analysis.from_ops(
                f"serve:{args.arch}", axis_sizes={"serve": args.slots},
                declared=ops, plan=prog)
            analysis.preflight(graph, args.verify)
    rng = np.random.default_rng(0)
    lo = min(args.min_prompt_len, args.prompt_len)
    plens = rng.integers(lo, args.prompt_len + 1, size=args.requests)
    rids = []
    for p in plens:
        prompt = rng.integers(0, cfg.vocab_size - 1,
                              size=int(p)).astype(np.int32)
        try:
            rids.append(engine.submit(prompt, args.new_tokens))
        except RequestRejected as e:          # shed at the door
            print(f"shed: {e}")
            rids.append(None)

    t0 = time.perf_counter()
    out = engine.run()
    dt = time.perf_counter() - t0
    served = sum(len(v) for v in out.values())
    total = int(sum(int(plens[i]) for i, r in enumerate(rids)
                    if r is not None)) + served
    s = engine.metrics.summary()
    print(f"{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s end-to-end; "
          f"{s['useful_tok_s']:.1f} useful tok/s, occupancy "
          f"{s['occupancy']:.2f}, batch {args.slots} slots)")
    print(f"TTFT {s['mean_ttft_s'] * 1e3:.1f}ms  TPOT "
          f"{s['mean_tpot_s'] * 1e3:.2f}ms  quanta {s['quanta']}  "
          f"pages high-water {engine.pt.high_water}/"
          f"{engine.cache_cfg.n_pages}")
    print(f"overload: sheds {s['sheds']}  preempts {s['preempts']}  "
          f"swap {s['swap_bytes']} B  p99 TTFT "
          f"{s['p99_ttft_s'] * 1e3:.1f}ms")
    if args.slo_ttft is not None:
        met = engine.metrics.slo_met_tokens(args.slo_ttft)
        print(f"SLO-goodput: {met} tokens within "
              f"{args.slo_ttft * 1e3:.0f}ms TTFT "
              f"({met / dt:.1f} tok/s)")
    for rec in managed.decision_log():
        if rec.op == "serve_schedule":
            print(f"decision serve_schedule({rec.mode}, C={rec.chunks}) "
                  f"pred static={rec.predicted_bulk_s * 1e6:.1f}us/tok "
                  f"chosen={rec.predicted_interleaved_s * 1e6:.1f}us/tok")
        elif rec.op == "preempt_policy":
            print(f"decision preempt_policy({rec.mode}, "
                  f"pages={rec.chunks}, {rec.nbytes} B) "
                  f"pred recompute={rec.predicted_bulk_s * 1e3:.2f}ms "
                  f"chosen={rec.predicted_interleaved_s * 1e3:.2f}ms")
    for i, r in enumerate(rids[:4]):
        if r is not None and r in out:
            print(f"  req{i} (P={int(plens[i])}): {out[r].tolist()}")
    if args.trace:
        from repro import obs
        tr = obs.get_tracer()
        decisions = managed.decision_log()
        # decode-graph decisions (attention/halo modes) fire at trace
        # time inside the jitted decode step the quantum span runs
        obs.cover_with(tr.spans(), "serve.quantum",
                       (r.op for r in decisions))
        led = obs.CalibrationLedger()
        led.correlate(tr.spans(), decisions)
        print(led.report())
        obs.write_chrome_trace(
            args.trace, tr, decisions,
            other_data={"run": f"serve:{args.arch}",
                        "calibration": led.snapshot()})
        print(f"trace: {args.trace} ({tr.n_spans} spans, "
              f"{len(decisions)} decisions, "
              f"coverage {led.coverage() * 100:.0f}%)")


if __name__ == "__main__":
    main()
