"""Serving driver CLI (batched greedy decoding).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
        --reduced --requests 4 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import ShapeConfig
from repro.models.model import Model
from repro.parallel.sharding import MeshCtx, infer_shardings
from repro.train.serve_loop import Generator


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--mdmp-mode", default="auto")
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode=args.mdmp_mode)
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))

    shape = ShapeConfig("serve", seq_len=args.max_seq,
                        global_batch=args.requests, kind="decode")
    gen = Generator(model, mesh, shape, params)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size - 1,
                           size=(args.requests, args.prompt_len)
                           ).astype(np.int32)
    t0 = time.perf_counter()
    out = gen.generate(prompts, n_new=args.new_tokens)
    dt = time.perf_counter() - t0
    total = args.requests * (args.prompt_len + args.new_tokens)
    print(f"{total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, batch {args.requests})")
    for i in range(min(args.requests, 4)):
        print(f"  req{i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
