"""Training driver CLI.

    PYTHONPATH=src python -m repro.launch.train --arch granite-34b \
        --reduced --steps 20 [--mdmp-mode auto|bulk|interleaved] [--resume]

Full (non-reduced) configs need a real TPU slice; on this host use
--reduced (the same code path at toy scale).
"""

from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import MeshCtx
from repro.train.train_loop import TrainLoop, TrainLoopConfig, \
    build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mdmp-mode", default="auto",
                    choices=["auto", "bulk", "interleaved"])
    ap.add_argument("--pipeline", default="none",
                    choices=["none", "gpipe", "1f1b", "interleaved",
                             "auto"],
                    help="run the pod axis as pipeline stages (auto = "
                         "managed schedule decision)")
    ap.add_argument("--microbatches", type=int, default=None,
                    help="pipeline microbatch count M (default: the "
                         "cost model's pick)")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=["bulk", "stream", "dense", "auto"],
                    help="MoE expert-dispatch schedule (auto = managed "
                         "cost-model decision, logged per layer)")
    ap.add_argument("--plan", default="local",
                    choices=["local", "program", "auto"],
                    help="communication planning scope: 'local' keeps "
                         "per-subsystem resolution; 'program'/'auto' run "
                         "the whole-program planner (repro.plan) over the "
                         "step's comm set and install the coordinated "
                         "ProgramPlan before tracing")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 (data x model) or 2x2x2 "
                         "(pod x data x model); default = all devices "
                         "on data")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-pod", action="store_true")
    ap.add_argument("--ckpt-every", default=None,
                    help="checkpoint interval in steps, or 'auto' for "
                         "the managed Young/Daly cadence (re-resolved "
                         "online from measured step time + write bw)")
    ap.add_argument("--mtbf", type=float, default=1800.0,
                    help="assumed mean time between failures, seconds "
                         "(feeds the Young/Daly cadence)")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault injection spec, e.g. "
                         "'transient@6;slow@9:0.5;corrupt@14' "
                         "(core/faults.py grammar)")
    args = ap.parse_args()

    import dataclasses
    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    if args.moe_dispatch is not None:
        if cfg.moe is None:
            ap.error(f"--moe-dispatch set but {args.arch} has no MoE "
                     "layers")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch=args.moe_dispatch))
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
    elif args.pipeline != "none":
        dims = (jax.device_count(), 1, 1)
        axes = ("pod", "data", "model")
    else:
        dims = (jax.device_count(), 1)
        axes = ("data", "model")
    if args.pipeline != "none" and "pod" not in axes:
        ap.error("--pipeline needs a pod axis: pass a 3-axis --mesh "
                 "like 2x2x2 (pod x data x model)")
    mesh = jax.make_mesh(dims, axes)
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode=args.mdmp_mode)
    model = Model(cfg, ctx)
    print(f"arch={args.arch} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dims} mdmp={args.mdmp_mode} pipeline={args.pipeline}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                          total_steps=args.steps,
                          moment_dtype=cfg.moment_dtype)
    from repro.core import managed as managed_lib
    from repro.core.tuner import ScheduleTuner
    managed_lib.clear_decision_log()
    tuner = ScheduleTuner()
    if args.plan != "local":
        # Whole-program pass: lower this step's communication set to
        # comm-IR ops, price the JOINT schedule, and install the plan so
        # every resolve_* call below prefers the coordinated knob.
        import jax.numpy as jnp
        from repro.plan import lower_train_ops, plan_program
        hw = managed_lib.get_config().hw
        ib = jnp.dtype(cfg.dtype).itemsize
        gb, sl = args.batch, args.seq
        b_loc = max(1, gb // max(1, ctx.dp))
        attention = None
        if getattr(cfg, "n_heads", 0) and ctx.tp > 1:
            attention = {"batch": b_loc, "s_local": max(1, sl // ctx.tp),
                         "heads": cfg.n_heads, "kv_heads": cfg.n_kv_heads,
                         "head_dim": cfg.head_dim, "d_model": cfg.d_model,
                         "causal": True, "dtype_bytes": ib}
        moe_geom = None
        if cfg.moe is not None and ctx.tp > 1:
            moe_geom = {"tokens_local": b_loc * sl,
                        "d_model": cfg.d_model,
                        "n_experts": cfg.moe.n_experts,
                        "top_k": cfg.moe.top_k,
                        "d_ff_expert": cfg.moe.d_ff_expert,
                        "capacity_factor": cfg.moe.capacity_factor,
                        "mults": 3, "dtype_bytes": ib}
        pipe_geom = None
        if args.pipeline != "none":
            # mirror build_train_step's cost-model inputs exactly
            n_stage = ctx.pods
            pipe_geom = {
                "axis": "pod", "n_layers": cfg.n_layers,
                "batch_fwd_s": (2.0 * cfg.param_count() / n_stage
                                * (b_loc * sl) / hw.peak_flops),
                "batch_bytes": (b_loc * (sl // max(1, ctx.tp))
                                * cfg.d_model * ib),
                "candidate_micro": tuple(
                    m for m in (1, 2, 4, 8, 16, 32, 64)
                    if b_loc % m == 0)}
        ops = lower_train_ops(
            mesh_axes=dict(ctx.axis_sizes),
            grad_bytes=int(cfg.param_count()) * 4,
            pipeline=pipe_geom, attention=attention, moe=moe_geom)
        prog = plan_program(ops, hw=hw,
                            notes=[f"launch.train {args.arch}"])
        kind = "coordinated" if prog.coordinated else "local"
        print(f"decision program_plan({kind} ops={len(prog.choices)} "
              f"topo={prog.topology} "
              f"local-concat={prog.local_solo_sum_s * 1e6:.1f}us "
              f"joint={prog.joint_cost_s * 1e6:.1f}us)")
        for line in prog.summary().splitlines()[1:]:
            print(f"  trail{line}")
        tuner.store_program_plan(prog)
        managed_lib.install_plan(prog)
    step_fn, pshard, bshard = build_train_step(
        model, opt_cfg, mesh, compress_pod=args.compress_pod,
        pipeline=args.pipeline, pipe_microbatches=args.microbatches,
        global_batch=args.batch, seq_len=args.seq)
    for rec in managed_lib.decision_log():
        if rec.op == "pipeline_schedule":
            print(f"decision pipeline_schedule({rec.mode} M={rec.chunks} "
                  f"axis={rec.axis} handoff={rec.nbytes/1e3:.1f}kB "
                  f"bulk={rec.predicted_bulk_s*1e3:.2f}ms "
                  f"chosen={rec.predicted_interleaved_s*1e3:.2f}ms)")
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch))
    managed_cadence = args.ckpt_every == "auto"
    ckpt_every = (max(5, args.steps // 4)
                  if args.ckpt_every in (None, "auto")
                  else int(args.ckpt_every))
    from repro.core.faults import FaultPlan
    fault_plan = (FaultPlan.parse(args.fault_plan)
                  if args.fault_plan else None)
    loop = TrainLoop(step_fn, model, opt_cfg, data,
                     TrainLoopConfig(total_steps=args.steps,
                                     ckpt_every=ckpt_every,
                                     ckpt_dir=args.ckpt,
                                     managed_cadence=managed_cadence,
                                     mtbf_s=args.mtbf),
                     pshard, bshard, tuner=tuner,
                     fault_plan=fault_plan)
    params, opt, s0 = (loop.resume_or_init() if args.resume
                       else loop.init_state())
    out = loop.run(params, opt, s0)
    for rec in managed_lib.decision_log():
        if rec.op == "ckpt_interval":
            print(f"decision ckpt_interval({rec.mode} N={rec.chunks} "
                  f"axis={rec.axis} snap={rec.nbytes/1e6:.1f}MB "
                  f"fixed_ovh={rec.predicted_bulk_s:.4f} "
                  f"chosen_ovh={rec.predicted_interleaved_s:.4f})")
    for r in out["replayed"]:
        print(f"replan {r['op']}: {r['mode']}:{r['chunks']} "
              f"{r['axis']}{r['old_n']} -> {r['axis']}{r['new_n']}")
    if fault_plan is not None:
        left = fault_plan.unfired()
        print(f"faults injected={len(fault_plan.events) - len(left)} "
              f"unfired={len(left)} restarts={out['restarts']} "
              f"steps_executed={out['steps_executed']}")
    if args.moe_dispatch is not None:
        # the dispatch decision fires at trace time (first step); print
        # the unique trail entries the managed runtime logged
        seen = set()
        for rec in managed_lib.decision_log():
            key = (rec.op, rec.mode, rec.chunks, rec.nbytes)
            if rec.op == "moe_dispatch" and key not in seen:
                seen.add(key)
                print(f"decision moe_dispatch({rec.mode} g={rec.chunks} "
                      f"axis={rec.axis} a2a={rec.nbytes/1e3:.1f}kB "
                      f"bulk={rec.predicted_bulk_s*1e3:.3f}ms "
                      f"chosen={rec.predicted_interleaved_s*1e3:.3f}ms)")
    for h in out["history"][:: max(1, len(out["history"]) // 10)]:
        print(f"  step {h['step']:4d} loss {h['loss']:.4f} "
              f"{h['time_s']:.2f}s")
    print(f"done at step {out['step']}, final loss "
          f"{out['history'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
