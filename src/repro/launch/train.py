"""Training driver CLI.

    PYTHONPATH=src python -m repro.launch.train --arch granite-34b \
        --reduced --steps 20 [--mdmp-mode auto|bulk|interleaved] [--resume]

Full (non-reduced) configs need a real TPU slice; on this host use
--reduced (the same code path at toy scale).
"""

from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import MeshCtx
from repro.train.train_loop import TrainLoop, TrainLoopConfig, \
    build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mdmp-mode", default="auto",
                    choices=["auto", "bulk", "interleaved"])
    ap.add_argument("--pipeline", default="none",
                    choices=["none", "gpipe", "1f1b", "interleaved",
                             "auto"],
                    help="run the pod axis as pipeline stages (auto = "
                         "managed schedule decision)")
    ap.add_argument("--microbatches", type=int, default=None,
                    help="pipeline microbatch count M (default: the "
                         "cost model's pick)")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=["bulk", "stream", "dense", "auto"],
                    help="MoE expert-dispatch schedule (auto = managed "
                         "cost-model decision, logged per layer)")
    ap.add_argument("--plan", default="local",
                    choices=["local", "program", "auto"],
                    help="communication planning scope: 'local' keeps "
                         "per-subsystem resolution; 'program'/'auto' run "
                         "the whole-program planner (repro.plan) over the "
                         "step's comm set and install the coordinated "
                         "ProgramPlan before tracing")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 (data x model) or 2x2x2 "
                         "(pod x data x model); default = all devices "
                         "on data")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-pod", action="store_true")
    ap.add_argument("--ckpt-every", default=None,
                    help="checkpoint interval in steps, or 'auto' for "
                         "the managed Young/Daly cadence (re-resolved "
                         "online from measured step time + write bw)")
    ap.add_argument("--mtbf", type=float, default=1800.0,
                    help="assumed mean time between failures, seconds "
                         "(feeds the Young/Daly cadence)")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault injection spec, e.g. "
                         "'transient@6;slow@9:0.5;corrupt@14' "
                         "(core/faults.py grammar)")
    ap.add_argument("--verify", default="warn",
                    choices=["off", "warn", "strict"],
                    help="static-verifier preflight (repro.analysis): "
                         "'warn' prints findings and logs a "
                         "DecisionRecord(op=\"lint\"); 'strict' exits "
                         "non-zero on any error with the declared/"
                         "traced side-by-side")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record every hot path to a Chrome-trace JSON "
                         "(open in ui.perfetto.dev), print the "
                         "predicted-vs-measured calibration report, and "
                         "embed the calibration ledger in the file")
    args = ap.parse_args()

    if args.trace:
        # install before anything resolves so planner/lint/step spans
        # and decision timestamps all land on one ring
        from repro import obs
        obs.install_tracer(obs.Tracer())

    import dataclasses
    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    if args.moe_dispatch is not None:
        if cfg.moe is None:
            ap.error(f"--moe-dispatch set but {args.arch} has no MoE "
                     "layers")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch=args.moe_dispatch))
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
    elif args.pipeline != "none":
        dims = (jax.device_count(), 1, 1)
        axes = ("pod", "data", "model")
    else:
        dims = (jax.device_count(), 1)
        axes = ("data", "model")
    if args.pipeline != "none" and "pod" not in axes:
        ap.error("--pipeline needs a pod axis: pass a 3-axis --mesh "
                 "like 2x2x2 (pod x data x model)")
    mesh = jax.make_mesh(dims, axes)
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode=args.mdmp_mode)
    model = Model(cfg, ctx)
    print(f"arch={args.arch} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dims} mdmp={args.mdmp_mode} pipeline={args.pipeline}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                          total_steps=args.steps,
                          moment_dtype=cfg.moment_dtype)
    from repro.core import managed as managed_lib
    from repro.core.tuner import ScheduleTuner
    managed_lib.clear_decision_log()
    tuner = ScheduleTuner()
    prog = None
    if args.plan != "local" or args.verify != "off":
        # Lower this step's communication set to comm-IR ops once —
        # the whole-program planner (--plan) and the static-verifier
        # preflight (--verify) both consume it, so the linted program
        # is exactly the planned one.
        from repro.plan import (lower_train_ops, plan_program,
                                train_geometry)
        hw = managed_lib.get_config().hw
        geo = train_geometry(cfg, mesh_axes=dict(ctx.axis_sizes),
                             batch=args.batch, seq=args.seq, hw=hw,
                             pipeline=args.pipeline)
        ops = lower_train_ops(
            mesh_axes=geo["mesh_axes"], grad_bytes=geo["grad_bytes"],
            pipeline=geo["pipeline"], attention=geo["attention"],
            moe=geo["moe"])
        prog = plan_program(ops, hw=hw,
                            notes=[f"launch.train {args.arch}"])
    if args.plan != "local":
        # Whole-program pass: price the JOINT schedule and install the
        # plan so every resolve_* call below prefers the coordinated
        # knob.
        kind = "coordinated" if prog.coordinated else "local"
        print(f"decision program_plan({kind} ops={len(prog.choices)} "
              f"topo={prog.topology} "
              f"local-concat={prog.local_solo_sum_s * 1e6:.1f}us "
              f"joint={prog.joint_cost_s * 1e6:.1f}us)")
        for line in prog.summary().splitlines()[1:]:
            print(f"  trail{line}")
        tuner.store_program_plan(prog)
        managed_lib.install_plan(prog)
    if args.verify != "off":
        # Static-verifier preflight: drift/permute/deadlock/race/
        # feasibility passes over the lowered comm set under the knobs
        # this launch will actually run (forced flags override the
        # plan's picks, so strict mode catches the clamp BEFORE the
        # executor silently degrades it).
        from repro import analysis
        key = "pipeline_schedule|pod"
        if args.microbatches is not None:
            knob = dict(prog.knobs.get(key)
                        or {"mode": args.pipeline, "virtual": 2})
            knob["chunks"] = args.microbatches
            if args.pipeline not in ("none", "auto"):
                knob["mode"] = args.pipeline
            prog.knobs[key] = knob
        elif args.pipeline not in ("none", "auto") and key in prog.knobs:
            prog.knobs[key] = dict(prog.knobs[key],
                                   mode=args.pipeline)
        graph = analysis.from_ops(
            f"train:{args.arch}", axis_sizes=dict(ctx.axis_sizes),
            declared=ops, plan=prog, hw=hw)
        analysis.preflight(graph, args.verify)
    step_fn, pshard, bshard = build_train_step(
        model, opt_cfg, mesh, compress_pod=args.compress_pod,
        pipeline=args.pipeline, pipe_microbatches=args.microbatches,
        global_batch=args.batch, seq_len=args.seq)
    for rec in managed_lib.decision_log():
        if rec.op == "pipeline_schedule":
            print(f"decision pipeline_schedule({rec.mode} M={rec.chunks} "
                  f"axis={rec.axis} handoff={rec.nbytes/1e3:.1f}kB "
                  f"bulk={rec.predicted_bulk_s*1e3:.2f}ms "
                  f"chosen={rec.predicted_interleaved_s*1e3:.2f}ms)")
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch))
    managed_cadence = args.ckpt_every == "auto"
    ckpt_every = (max(5, args.steps // 4)
                  if args.ckpt_every in (None, "auto")
                  else int(args.ckpt_every))
    from repro.core.faults import FaultPlan
    fault_plan = (FaultPlan.parse(args.fault_plan)
                  if args.fault_plan else None)
    loop = TrainLoop(step_fn, model, opt_cfg, data,
                     TrainLoopConfig(total_steps=args.steps,
                                     ckpt_every=ckpt_every,
                                     ckpt_dir=args.ckpt,
                                     managed_cadence=managed_cadence,
                                     mtbf_s=args.mtbf),
                     pshard, bshard, tuner=tuner,
                     fault_plan=fault_plan)
    params, opt, s0 = (loop.resume_or_init() if args.resume
                       else loop.init_state())
    out = loop.run(params, opt, s0)
    for rec in managed_lib.decision_log():
        if rec.op == "ckpt_interval":
            print(f"decision ckpt_interval({rec.mode} N={rec.chunks} "
                  f"axis={rec.axis} snap={rec.nbytes/1e6:.1f}MB "
                  f"fixed_ovh={rec.predicted_bulk_s:.4f} "
                  f"chosen_ovh={rec.predicted_interleaved_s:.4f})")
    for r in out["replayed"]:
        print(f"replan {r['op']}: {r['mode']}:{r['chunks']} "
              f"{r['axis']}{r['old_n']} -> {r['axis']}{r['new_n']}")
    if fault_plan is not None:
        left = fault_plan.unfired()
        print(f"faults injected={len(fault_plan.events) - len(left)} "
              f"unfired={len(left)} restarts={out['restarts']} "
              f"steps_executed={out['steps_executed']}")
    if args.moe_dispatch is not None:
        # the dispatch decision fires at trace time (first step); print
        # the unique trail entries the managed runtime logged
        seen = set()
        for rec in managed_lib.decision_log():
            key = (rec.op, rec.mode, rec.chunks, rec.nbytes)
            if rec.op == "moe_dispatch" and key not in seen:
                seen.add(key)
                print(f"decision moe_dispatch({rec.mode} g={rec.chunks} "
                      f"axis={rec.axis} a2a={rec.nbytes/1e3:.1f}kB "
                      f"bulk={rec.predicted_bulk_s*1e3:.3f}ms "
                      f"chosen={rec.predicted_interleaved_s*1e3:.3f}ms)")
    for h in out["history"][:: max(1, len(out["history"]) // 10)]:
        print(f"  step {h['step']:4d} loss {h['loss']:.4f} "
              f"{h['time_s']:.2f}s")
    print(f"done at step {out['step']}, final loss "
          f"{out['history'][-1]['loss']:.4f}")
    if args.trace:
        from repro import obs
        tr = obs.get_tracer()
        decisions = managed_lib.decision_log()
        # jit-interior decisions (attention/halo/MoE/pipeline modes) have
        # no host-side span of their own — the train.step span covers the
        # XLA program they were compiled into
        obs.cover_with(tr.spans(), "train.step",
                       (r.op for r in decisions))
        led = obs.CalibrationLedger()
        led.correlate(tr.spans(), decisions)
        print(led.report())
        obs.write_chrome_trace(
            args.trace, tr, decisions,
            other_data={"run": f"train:{args.arch}",
                        "calibration": led.snapshot()})
        print(f"trace: {args.trace} ({tr.n_spans} spans, "
              f"{len(decisions)} decisions, "
              f"coverage {led.coverage() * 100:.0f}%)")


if __name__ == "__main__":
    main()
