"""Checkpoint instrumentation — the runtime counters the cadence is
planned from.

Same contract as ``serve/metrics.py``: iteration k's measured behaviour
schedules iteration k+1.  For checkpointing the "iteration" is one async
save: every save records how long the on-device snapshot blocked the
loop, how long the chunked D2H drain took, how long the writer thread
spent on disk, and the snapshot bytes.  ``write_bw_estimate`` /
``ckpt_cost_s_estimate`` invert those records into the δ (per-checkpoint
cost) and bandwidth terms of the Young/Daly model; ``TrainLoop`` feeds
them back into ``managed.resolve_checkpoint`` to re-resolve the cadence
as the EWMA step time drifts.
"""

from __future__ import annotations

import dataclasses

from repro.obs.registry import MetricsRegistry


@dataclasses.dataclass(frozen=True)
class SaveRecord:
    step: int
    nbytes: int
    snapshot_s: float        # on-device donated-copy dispatch (loop-blocking)
    drain_s: float           # chunked device->host transfer (writer thread)
    write_s: float           # serialisation + atomic commit (writer thread)


@dataclasses.dataclass(frozen=True)
class RestoreRecord:
    step: int
    restore_s: float


class CheckpointMetrics:
    """Estimators ride the unified ``obs.MetricsRegistry``; the record
    lists stay for tests and the summary's byte count."""

    def __init__(self, registry: MetricsRegistry | None = None):
        self.reg = registry if registry is not None else MetricsRegistry()
        self.saves: list[SaveRecord] = []
        self.restores: list[RestoreRecord] = []
        # max-rate / min-cost: "a slow save means contention, not a
        # slower disk" — the noise-robust estimators as registry extrema
        self._write_bw = self.reg.extremum("ckpt.write_bw", kind="max")
        self._cost = self.reg.extremum("ckpt.cost_s", kind="min")
        self._restore = self.reg.extremum("ckpt.restore_s", kind="min")

    # -- recording -----------------------------------------------------------

    def note_save(self, step: int, nbytes: int, snapshot_s: float,
                  drain_s: float, write_s: float) -> None:
        self.saves.append(SaveRecord(step, nbytes, snapshot_s, drain_s,
                                     write_s))
        if drain_s + write_s > 0:
            self._write_bw.observe(nbytes / (drain_s + write_s))
        self._cost.observe(snapshot_s + drain_s)

    def note_restore(self, step: int, restore_s: float) -> None:
        self.restores.append(RestoreRecord(step, restore_s))
        self._restore.observe(restore_s)

    # -- estimates fed back into the cost model ------------------------------

    def write_bw_estimate(self) -> float | None:
        """Measured end-to-end checkpoint bandwidth, bytes/s: running max
        over saves of nbytes / (drain + write)."""
        return self._write_bw.value

    def ckpt_cost_s_estimate(self) -> float | None:
        """δ of the Young/Daly model: the per-checkpoint seconds the run
        actually pays (snapshot block + the metered drain; the disk write
        rides the writer thread off the critical path) — running min."""
        return self._cost.value

    def restore_s_estimate(self) -> float | None:
        return self._restore.value

    # -- aggregates ----------------------------------------------------------

    def summary(self) -> dict:
        return {
            "saves": len(self.saves),
            "restores": len(self.restores),
            "bytes": self.saves[-1].nbytes if self.saves else 0,
            "write_bw": self.write_bw_estimate() or 0.0,
            "ckpt_cost_s": self.ckpt_cost_s_estimate() or 0.0,
            "restore_s": self.restore_s_estimate() or 0.0,
        }
