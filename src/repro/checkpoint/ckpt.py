"""Checkpointing: manifest-versioned npz shards, atomic commit, async save,
elastic restore.

Layout:   <dir>/step_<k>/arrays.npz + manifest.json  (+ .tmp staging)

Fault-tolerance contract (DESIGN.md §4):
  * atomic: the step directory is staged as ``.tmp`` and os.replace'd into
    place — a crash mid-save never corrupts the latest checkpoint, and
    ``latest_step`` only trusts directories whose manifest + arrays both
    landed;
  * elastic: arrays are saved UNSHARDED (gathered logical arrays), so a
    restart may resume on any mesh shape — re-sharding happens at load via
    device_put with the new mesh's shardings (and the persisted tuner
    winners are replayed onto the new topology, tuner.replan_for_mesh);
  * async: ``save_async`` blocks the train loop only for the ON-DEVICE
    snapshot (an HBM-bandwidth copy, so the next step may donate the live
    buffers); the device->host drain then runs on the writer thread in
    chunks metered under the overlap budget (core/overlap.py::
    drain_chunk_bytes — each chunk's D2H pull stalls the step stream at
    most ``budget`` of one step), followed by serialisation + the atomic
    commit.  Every save's (snapshot, drain, write) seconds and bytes land
    in checkpoint/metrics.py — the counters the Young/Daly cadence
    decision (cost_model.decide_checkpoint) re-resolves from.

On real multi-host pods each host writes only its address-local shards and
the manifest records the union; this single-process implementation writes
the whole tree (the code path is the same, the collective set is empty).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.metrics import CheckpointMetrics
from repro.obs.tracer import get_tracer

_STEP_RE = re.compile(r"^step_(\d+)$")

#: default drain chunk (64 MiB) when no metered size is configured
DEFAULT_DRAIN_CHUNK = 64 * 1024 * 1024


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None
         ) -> str:
    """Synchronous checkpoint write with atomic commit."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = {}
    manifest = {"step": step, "extra": extra or {}, "keys": []}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype in ("bfloat16", "float8_e4m3fn",
                                              "float8_e5m2"):
            # npz can't round-trip ml_dtypes: store widened, record dtype
            arr = arr.astype(np.float32)
        arrays[key] = arr
        manifest["keys"].append(
            {"key": key, "shape": list(arr.shape), "dtype": dtype})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def valid_steps(ckpt_dir: str) -> list[int]:
    """Committed checkpoint steps, ascending.  A directory only counts
    when both the manifest and the arrays landed — a crashed save's
    leftovers (``.tmp`` staging, a partial dir) are never trusted."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        if not m:
            continue
        path = os.path.join(ckpt_dir, d)
        if (os.path.exists(os.path.join(path, "manifest.json"))
                and os.path.exists(os.path.join(path, "arrays.npz"))):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = valid_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (elastic re-shard onto the current mesh)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(final, "arrays.npz")) as npz:
        arrays = {k: npz[k] for k in npz.files}

    dtypes = {k["key"]: k["dtype"] for k in manifest["keys"]}
    flat_like = _flatten_with_paths(like)
    leaves = []
    for key, leaf in flat_like:
        assert key in arrays, f"checkpoint missing {key}"
        arr = arrays[key]
        want = tuple(leaf.shape) if hasattr(leaf, "shape") else None
        assert want is None or tuple(arr.shape) == want, \
            f"{key}: ckpt {arr.shape} vs model {want}"
        saved_dt = dtypes.get(key, str(arr.dtype))
        if str(arr.dtype) != saved_dt:
            arr = np.asarray(jnp.asarray(arr).astype(saved_dt))
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["extra"]


def restore_latest(ckpt_dir: str, like: Any, shardings: Any | None = None
                   ) -> tuple[Any, dict, int] | None:
    """Restore the newest readable checkpoint, falling back step by step
    past corrupt ones (a truncated shard passes the directory check but
    fails the load — e.g. the ``corrupt@k`` fault).  A corrupt directory
    is quarantined (renamed ``*.corrupt``) so it is never retried and the
    next GC removes it.  Returns (tree, extra, step) or None."""
    for step in reversed(valid_steps(ckpt_dir)):
        try:
            tree, extra = restore(ckpt_dir, step, like, shardings)
            return tree, extra, step
        except Exception:               # noqa: BLE001 — fallback path
            bad = os.path.join(ckpt_dir, f"step_{step:08d}")
            try:
                os.replace(bad, bad + ".corrupt")
            except OSError:
                shutil.rmtree(bad, ignore_errors=True)
    return None


# ---------------------------------------------------------------------------
# Async manager: on-device snapshot -> metered drain -> atomic write
# ---------------------------------------------------------------------------


@jax.jit
def _device_copy(tree: Any) -> Any:
    return jax.tree.map(jnp.copy, tree)


def _drain_leaf(x: Any, chunk_bytes: int) -> np.ndarray:
    """Pull one leaf to host in <= ``chunk_bytes`` pieces so no single
    D2H transfer stalls the step stream longer than the metered budget."""
    if not isinstance(x, jax.Array):
        return np.asarray(x)
    nbytes = x.size * x.dtype.itemsize
    if x.ndim == 0 or nbytes <= chunk_bytes:
        return np.asarray(jax.device_get(x))
    rows_per = max(1, int(chunk_bytes // max(1, nbytes // x.shape[0])))
    parts = [np.asarray(jax.device_get(x[i:i + rows_per]))
             for i in range(0, x.shape[0], rows_per)]
    return np.concatenate(parts, axis=0)


class CheckpointManager:
    """Async saves + retention.  ``wait()`` before reading a checkpoint
    back or exiting.

    ``save_async`` blocks only for the on-device snapshot copy (the live
    buffers may be donated by the very next train step); the drain +
    write ride the writer thread.  ``drain_chunk_bytes`` meters the D2H
    chunking (core/overlap.py::drain_chunk_bytes); ``metrics`` collects
    the per-save counters the cadence decision feeds on.
    """

    def __init__(self, ckpt_dir: str, keep: int = 3, *,
                 metrics: CheckpointMetrics | None = None,
                 drain_chunk_bytes: int | None = None):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.metrics = metrics or CheckpointMetrics()
        self.drain_chunk_bytes = drain_chunk_bytes or DEFAULT_DRAIN_CHUNK
        self._thread: threading.Thread | None = None
        self._error: list[BaseException] = []

    def save_async(self, step: int, tree: Any,
                   extra: dict | None = None) -> None:
        self.wait()
        # capture the ambient tracer HERE: the writer thread emits its
        # drain/commit spans on the same ring, concurrent with the loop
        tr = get_tracer()
        t0 = time.perf_counter()
        with tr.span("ckpt.snapshot", track="ckpt", step=step,
                     buffer="ckpt_snapshot"):
            snapshot = _device_copy(tree)
            # the snapshot must materialise before returning: the
            # caller's next step donates the source buffers, and the
            # copy is what the drain reads.  This block is the δ the
            # loop pays up front — an HBM copy, not a PCIe round trip.
            jax.block_until_ready(snapshot)
        snapshot_s = time.perf_counter() - t0
        nbytes = sum(leaf.size * leaf.dtype.itemsize
                     for leaf in jax.tree.leaves(snapshot)
                     if hasattr(leaf, "size"))
        chunk = self.drain_chunk_bytes

        def work():
            try:
                t1 = time.perf_counter()
                with tr.span("ckpt.drain", track="ckpt", step=step,
                             nbytes=nbytes, buffer="ckpt_snapshot"):
                    host_tree = jax.tree.map(
                        lambda x: _drain_leaf(x, chunk), snapshot)
                drain_s = time.perf_counter() - t1
                t2 = time.perf_counter()
                with tr.span("ckpt.commit", track="ckpt", step=step,
                             nbytes=nbytes):
                    save(self.ckpt_dir, step, host_tree, extra)
                    self._gc()
                self.metrics.note_save(step, nbytes, snapshot_s, drain_s,
                                       time.perf_counter() - t2)
            except BaseException as e:   # surfaced on next wait()
                self._error.append(e)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def _gc(self) -> None:
        """Retention + hygiene: keep the last ``keep`` committed steps,
        drop everything stale — crashed saves' ``.tmp`` staging dirs and
        quarantined ``.corrupt`` dirs included (they used to live
        forever)."""
        for d in os.listdir(self.ckpt_dir):
            if d.startswith("step_") and (d.endswith(".tmp")
                                          or d.endswith(".corrupt")):
                shutil.rmtree(os.path.join(self.ckpt_dir, d),
                              ignore_errors=True)
        for s in valid_steps(self.ckpt_dir)[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
