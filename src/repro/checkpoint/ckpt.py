"""Checkpointing: manifest-versioned npz shards, atomic commit, async save,
elastic restore.

Layout:   <dir>/step_<k>/arrays.npz + manifest.json  (+ .tmp staging)

Fault-tolerance contract (DESIGN.md §4):
  * atomic: the step directory is staged as ``.tmp`` and os.rename'd into
    place — a crash mid-save never corrupts the latest checkpoint;
  * elastic: arrays are saved UNSHARDED (gathered logical arrays), so a
    restart may resume on any mesh shape — re-sharding happens at load via
    device_put with the new mesh's shardings;
  * async: ``save_async`` hands the host copy to a writer thread so the
    train loop only blocks for the device->host transfer.

On real multi-host pods each host writes only its address-local shards and
the manifest records the union; this single-process implementation writes
the whole tree (the code path is the same, the collective set is empty).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None
         ) -> str:
    """Synchronous checkpoint write with atomic commit."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = {}
    manifest = {"step": step, "extra": extra or {}, "keys": []}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype in ("bfloat16", "float8_e4m3fn",
                                              "float8_e5m2"):
            # npz can't round-trip ml_dtypes: store widened, record dtype
            arr = arr.astype(np.float32)
        arrays[key] = arr
        manifest["keys"].append(
            {"key": key, "shape": list(arr.shape), "dtype": dtype})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (elastic re-shard onto the current mesh)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(final, "arrays.npz")) as npz:
        arrays = {k: npz[k] for k in npz.files}

    dtypes = {k["key"]: k["dtype"] for k in manifest["keys"]}
    flat_like = _flatten_with_paths(like)
    leaves = []
    for key, leaf in flat_like:
        assert key in arrays, f"checkpoint missing {key}"
        arr = arrays[key]
        want = tuple(leaf.shape) if hasattr(leaf, "shape") else None
        assert want is None or tuple(arr.shape) == want, \
            f"{key}: ckpt {arr.shape} vs model {want}"
        saved_dt = dtypes.get(key, str(arr.dtype))
        if str(arr.dtype) != saved_dt:
            import jax.numpy as jnp
            arr = np.asarray(jnp.asarray(arr).astype(saved_dt))
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["extra"]


class CheckpointManager:
    """Async saves + retention.  ``wait()`` before reading a checkpoint
    back or exiting."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: list[BaseException] = []

    def save_async(self, step: int, tree: Any,
                   extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error.append(e)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
