from repro.checkpoint.ckpt import (CheckpointManager, latest_step, restore,
                                   restore_latest, save, valid_steps)
from repro.checkpoint.metrics import CheckpointMetrics

__all__ = ["CheckpointManager", "CheckpointMetrics", "latest_step",
           "restore", "restore_latest", "save", "valid_steps"]
