"""Sharding rules and mesh context for the explicit-collectives runtime.

The whole framework runs model code inside ONE ``shard_map`` over the full
mesh — no collective is ever inserted by GSPMD, every byte that crosses a
link goes through an MDMP managed op (core/managed.py).  That is the
paper's contract ("the user declares communication, the runtime manages
it") enforced architecturally.

Parameter layout (identical for train and serve — no resharding between
them):

  * every TP-partitioned dimension (heads, d_ff, vocab, experts) is sharded
    over the ``model`` axis;
  * one remaining large dimension (usually d_model) is sharded over the
    ``data`` axis — this is the FSDP/ZeRO-3 shard, gathered-on-use in
    training, contracted-in-place in decode;
  * the ``pod`` axis (multi-pod mesh) replicates parameters: pure DP with
    hierarchical gradient reduction, or pipeline stages when enabled.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map_compat(fn: Callable, mesh: Mesh, in_specs, out_specs,
                     check_vma: bool = False) -> Callable:
    """Version-portable ``shard_map``: the single place the repo touches the
    API.  jax >= 0.5 exposes ``jax.shard_map`` (with ``check_vma``); on
    0.4.x the alias does not exist, so fall back to
    ``jax.experimental.shard_map.shard_map`` (whose equivalent knob is
    ``check_rep``).  Every call site routes through here (via ``smap``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def smap(fn: Callable, mesh: Mesh, in_specs, out_specs) -> Callable:
    """shard_map with VMA checking off (ring collectives produce values the
    replication checker cannot infer; correctness is covered by tests)."""
    return shard_map_compat(fn, mesh, in_specs, out_specs, check_vma=False)


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def padded(n: int, m: int) -> tuple[int, int]:
    """(padded_size, pad_amount)."""
    p = pad_to_multiple(n, m)
    return p, p - n


# ---------------------------------------------------------------------------
# Mesh context
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    """Static view of the mesh as seen by model code inside shard_map.

    Axis conventions: ``data`` = FSDP + batch, ``model`` = TP/EP/SP,
    ``pod`` = cross-pod DP (or pipeline stages).  Sizes are static.
    """
    axis_sizes: dict[str, int]          # e.g. {"pod": 2, "data": 16, "model": 16}
    mdmp_mode: str = "auto"             # threaded into managed collectives

    @property
    def tp(self) -> int:
        return self.axis_sizes.get("model", 1)

    @property
    def dp(self) -> int:
        return self.axis_sizes.get("data", 1)

    @property
    def pods(self) -> int:
        return self.axis_sizes.get("pod", 1)

    @property
    def has_pod(self) -> bool:
        return "pod" in self.axis_sizes

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return (("pod", "data") if self.has_pod else ("data",))

    @property
    def batch_shards(self) -> int:
        return self.dp * self.pods

    @property
    def all_axes(self) -> tuple[str, ...]:
        return tuple(self.axis_sizes.keys())

    def local_batch(self, global_batch: int) -> int:
        assert global_batch % self.batch_shards == 0, (
            f"global batch {global_batch} not divisible by "
            f"{self.batch_shards} batch shards")
        return global_batch // self.batch_shards

    @staticmethod
    def from_mesh(mesh: Mesh, mdmp_mode: str = "auto") -> "MeshCtx":
        return MeshCtx(axis_sizes=dict(zip(mesh.axis_names,
                                           mesh.devices.shape)),
                       mdmp_mode=mdmp_mode)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

#: logical dimension names -> mesh axis they shard over (None = replicated)
LOGICAL_RULES: dict[str, str | None] = {
    "layers": None,        # scan dimension, never sharded
    "embed": "data",       # d_model rows: the FSDP shard
    "embed_nofsdp": None,  # d_model when the tensor is tiny (norms)
    "heads": "model",
    "kv_heads": None,      # replicated (GQA kv < tp; see DESIGN.md)
    "ff": "model",
    "vocab": "model",
    "experts": "model",    # EP: experts sharded by expert id
    "expert_ff": None,
    "ssm_heads": "model",
    "inner": "model",      # SSM d_inner (= heads * headdim), head-sharded
    "conv": None,
    "state": None,
    "frames": None,
    "null": None,
}


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Global shape + logical axes of one parameter."""
    shape: tuple[int, ...]
    logical: tuple[str, ...]
    dtype: Any = None

    def pspec(self) -> P:
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)
        return P(*[LOGICAL_RULES[l] for l in self.logical])

    def local_shape(self, ctx: MeshCtx) -> tuple[int, ...]:
        out = []
        for s, l in zip(self.shape, self.logical):
            ax = LOGICAL_RULES[l]
            n = ctx.axis_sizes.get(ax, 1) if ax else 1
            assert s % n == 0, f"dim {l}={s} not divisible by {ax}={n}"
            out.append(s // n)
        return tuple(out)


def infer_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    """ParamSpec tree -> NamedSharding tree (for jit in_shardings)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s.pspec()), spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_pspecs(spec_tree: Any) -> Any:
    """ParamSpec tree -> PartitionSpec tree (for shard_map in_specs)."""
    return jax.tree.map(lambda s: s.pspec(), spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def global_shape_dtypes(spec_tree: Any, default_dtype) -> Any:
    """ParamSpec tree -> ShapeDtypeStruct tree (dry-run stand-ins)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
