from repro.parallel.sharding import (LOGICAL_RULES, MeshCtx, ParamSpec,
                                     global_shape_dtypes, infer_shardings,
                                     pad_to_multiple, padded,
                                     shard_map_compat, smap, spec_pspecs)

__all__ = ["LOGICAL_RULES", "MeshCtx", "ParamSpec", "global_shape_dtypes",
           "infer_shardings", "pad_to_multiple", "padded",
           "shard_map_compat", "smap",
           "spec_pspecs"]
