"""Pipeline parallelism over the ``pod`` axis.

The multi-pod mesh's default posture is hierarchical DP across pods; this
module provides the alternative: the pod axis as pipeline STAGES.  Layers
split into ``n_pods`` contiguous stages; microbatches stream through a
GPipe schedule whose stage handoff is a single managed collective-permute
(the MDMP "message") per tick — compute on microbatch i overlaps the
permute of microbatch i-1 exactly like the paper's intermingled sends.

Used by launch/dryrun.py's --pipeline demo cell and the dist test; the
schedule works for any stage_fn (the dense block stack here).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def pipeline_apply(stage_fn: Callable[[Array, Any], Array],
                   stage_params: Any, x_microbatches: Array,
                   axis_name: str = "pod") -> Array:
    """GPipe over the ``axis_name`` stages.

    stage_fn(x, params) -> x    this rank's layer sub-stack
    stage_params                this rank's stage parameters (local)
    x_microbatches: [M, B, ...] microbatches (equal on every stage; only
                                stage 0's input content matters)
    Returns [M, B, ...] outputs (valid on the LAST stage; other stages
    return in-flight garbage — callers psum-select, see pipeline_lm_loss).

    Schedule: T = M + S - 1 ticks; at tick t stage s processes microbatch
    t - s.  The inter-stage handoff is one collective_permute per tick.
    """
    n_stage = lax.psum(1, axis_name)
    sid = lax.axis_index(axis_name)
    m = x_microbatches.shape[0]
    ticks = m + n_stage - 1
    perm = [(i, i + 1) for i in range(n_stage - 1)]

    def tick(carry, t):
        inflight, outputs = carry
        # stage 0 injects microbatch t; others take the handoff
        mb_idx = jnp.clip(t - sid, 0, m - 1)
        inject = x_microbatches[jnp.clip(t, 0, m - 1)]
        x_in = jnp.where(sid == 0, inject, inflight)
        active = (t - sid >= 0) & (t - sid < m)
        y = stage_fn(x_in, stage_params)
        y = jnp.where(active, y, inflight)
        # last stage records its finished microbatch
        outputs = lax.cond(
            active & (sid == n_stage - 1),
            lambda o: lax.dynamic_update_slice_in_dim(
                o, y[None], mb_idx, axis=0),
            lambda o: o, outputs)
        # hand off to the next stage (MDMP message)
        handoff = lax.ppermute(y, axis_name, perm)
        return (handoff, outputs), None

    inflight0 = jnp.zeros_like(x_microbatches[0])
    outputs0 = jnp.zeros_like(x_microbatches)
    (_, outputs), _ = lax.scan(tick, (inflight0, outputs0),
                               jnp.arange(ticks))
    return outputs


def select_last_stage(x: Array, axis_name: str = "pod") -> Array:
    """Broadcast the last stage's value to every stage (masked psum)."""
    n_stage = lax.psum(1, axis_name)
    sid = lax.axis_index(axis_name)
    mask = (sid == n_stage - 1).astype(x.dtype)
    return lax.psum(x * mask, axis_name)


def stage_layer_slice(n_layers: int, axis_name: str = "pod"
                      ) -> tuple[Array, int]:
    """(first layer index of this stage, layers per stage)."""
    n_stage = lax.psum(1, axis_name)
    per = n_layers // n_stage
    return lax.axis_index(axis_name) * per, per
