"""Managed pipeline parallelism over the ``pod`` axis.

The multi-pod mesh's default posture is hierarchical DP across pods; this
module provides the alternative: the pod axis as pipeline STAGES.  Layers
split into contiguous chunks (one per *virtual* stage; ``virtual=1`` is the
classic one-chunk-per-rank layout) and microbatches stream through a
lock-step schedule whose per-tick stage handoff is a single managed
collective-permute (the MDMP "message") — compute on one microbatch
overlaps the permute of the neighbouring one exactly like the paper's
intermingled sends.

Three schedules share one executor, driven by host-built timetables:

  * ``gpipe``        — all forwards, then all backwards.  Simple, but every
                       stage stashes O(M) microbatch activations.
  * ``1f1b``         — the backward of microbatch i starts as soon as the
                       last stage finishes its forward; forwards and
                       backwards share ticks, so at most O(S) activations
                       are ever live per stage.
  * ``interleaved``  — ``virtual`` layer chunks per rank (Megatron-style
                       circular placement: chunk j of rank r is virtual
                       stage j*S + r).  The ramp shrinks by the chunk
                       factor at the cost of ~virtual x more (smaller)
                       handoffs.

Which schedule (and microbatch count / virtual factor) to run is a managed
decision: ``core/cost_model.decide_pipeline_schedule`` models each
timetable's ticks x (alpha + bytes/bw) + bubble, and
``core/managed.resolve_pipeline_schedule`` logs the choice.

The timetables are built (and their invariants checked) on the host at
trace time; every handoff is *tight* by construction — the consuming rank
runs the dependent unit exactly one tick after the producer — so the
executor needs no receive queues, just the activation stash.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.obs.tracer import dispatch_span

Array = jax.Array

SCHEDULES = ("gpipe", "1f1b", "interleaved")


# ---------------------------------------------------------------------------
# Layer -> stage/chunk partitioning
# ---------------------------------------------------------------------------


def chunk_bounds(n_layers: int, n_chunks: int, chunk_idx):
    """(first layer, layer count) of chunk ``chunk_idx`` when ``n_layers``
    split into ``n_chunks`` contiguous chunks.  The remainder
    ``n_layers % n_chunks`` is distributed to the FIRST chunks (one extra
    layer each) so no layer is ever dropped.  ``chunk_idx`` may be a python
    int (host partitioning) or a traced value (inside shard_map)."""
    base, rem = divmod(int(n_layers), int(n_chunks))
    if isinstance(chunk_idx, (int, np.integer)):
        lo = chunk_idx * base + min(int(chunk_idx), rem)
        return lo, base + (1 if chunk_idx < rem else 0)
    lo = chunk_idx * base + jnp.minimum(chunk_idx, rem)
    return lo, base + (chunk_idx < rem).astype(jnp.int32)


def stage_layer_slice(n_layers: int, axis_name: str = "pod"):
    """(first layer index of this stage, layers of this stage).

    Remainder layers go to the first ``n_layers % n_stage`` stages; the
    returned count is therefore per-stage (a traced value), not uniform.
    Callers that need a static slice extent should slice
    ``max_chunk_layers`` rows (see ``slice_chunk_params``) and mask."""
    n_stage = lax.psum(1, axis_name)
    return chunk_bounds(n_layers, n_stage, lax.axis_index(axis_name))


def max_chunk_layers(n_layers: int, n_chunks: int) -> int:
    """Static upper bound on any chunk's layer count."""
    return -(-int(n_layers) // int(n_chunks))


def slice_chunk_params(stacked: Any, n_layers: int, n_chunks: int,
                      chunk_idx) -> tuple[Any, Any]:
    """Slice chunk ``chunk_idx``'s layers out of a leaf-stacked layer tree.

    Returns (chunk tree with static leading dim ``max_chunk_layers``,
    per — the number of VALID leading rows).  Rows past ``per`` are other
    chunks' layers; apply them under a mask (``masked_chunk_apply``).

    When the partition is uneven the last chunks' ``lo + mx`` would run
    past the stack, so the slice start is clamped in-bounds and the rows
    rotated so this chunk's layers lead — an O(mx)-row shuffle per call,
    never a copy of the whole stack."""
    mx = max_chunk_layers(n_layers, n_chunks)
    lo, per = chunk_bounds(n_layers, n_chunks, chunk_idx)
    even = n_chunks * mx == int(n_layers)
    lo_c = lo if even else jnp.minimum(lo, int(n_layers) - mx)
    shift = lo - lo_c

    def one(a):
        rows = lax.dynamic_slice_in_dim(a, lo_c, mx, axis=0)
        return rows if even else jnp.roll(rows, -shift, axis=0)

    return jax.tree.map(one, stacked), per


def masked_chunk_apply(layer_fn: Callable[[Array, Any], Array],
                       chunk_params: Any, per, x: Array) -> Array:
    """Apply the (padded) layer chunk: row i runs only while ``i < per``
    (identity otherwise), so uneven stage partitions stay correct under a
    static scan extent."""
    mx = jax.tree.leaves(chunk_params)[0].shape[0]

    def body(carry, xs):
        i, p = xs
        y = layer_fn(carry, p)
        return jnp.where(i < per, y, carry), None

    out, _ = lax.scan(body, x, (jnp.arange(mx), chunk_params))
    return out


# ---------------------------------------------------------------------------
# Host-built lock-step timetables
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineSchedule:
    """One schedule's timetable: per tick and rank, the forward / backward
    lane's (microbatch, virtual chunk, stash slot), -1 = idle.  ``n_stash``
    is the peak live activation count per rank — the memory contrast
    between schedules (gpipe: M; 1f1b: <= 2S-1)."""
    name: str
    n_stage: int
    n_micro: int
    virtual: int
    ticks: int
    n_stash: int
    f_mb: np.ndarray          # [T, S] int32
    f_chunk: np.ndarray
    f_slot: np.ndarray
    b_mb: np.ndarray
    b_chunk: np.ndarray
    b_slot: np.ndarray


def _timetable(name: str, m: int, s: int, v: int):
    """(mb, virtual stage) -> tick for the F and B lanes.  Every schedule
    here is *tight*: F(mb, q) runs exactly one tick after F(mb, q-1) and
    B(mb, q) exactly one tick after B(mb, q+1), so handoffs never queue."""
    n_virtual = s * v
    fwd: dict[tuple[int, int], int] = {}
    bwd: dict[tuple[int, int], int] = {}
    if name in ("gpipe", "1f1b"):
        if v != 1:
            raise ValueError(f"{name} runs one chunk per rank (virtual=1)")
        for mb in range(m):
            for q in range(s):
                fwd[(mb, q)] = mb + q
                bwd[(mb, q)] = ((m + s - 1) + (m - 1 - mb) + (s - 1 - q)
                                if name == "gpipe"
                                else 2 * s - 1 - q + mb)
    elif name == "interleaved":
        if v < 2:
            raise ValueError("interleaved needs virtual >= 2")
        if m % s:
            raise ValueError(
                f"interleaved needs n_micro % n_stage == 0 (got {m} % {s})")
        for mb in range(m):
            g, i = divmod(mb, s)
            last_f = g * v * s + (v - 1) * s + i + (s - 1)
            for q in range(n_virtual):
                j, r = divmod(q, s)
                fwd[(mb, q)] = g * v * s + j * s + i + r
                bwd[(mb, q)] = last_f + 1 + (n_virtual - 1 - q)
    else:
        raise ValueError(f"unknown pipeline schedule {name!r}")
    return fwd, bwd


def build_schedule(name: str, n_micro: int, n_stage: int,
                   virtual: int = 1) -> PipelineSchedule:
    """Build (and verify) the lock-step timetable for one schedule."""
    m, s = int(n_micro), int(n_stage)
    v = int(virtual) if name == "interleaved" else 1
    n_virtual = s * v
    fwd, bwd = _timetable(name, m, s, v)
    ticks = 1 + max(max(fwd.values()), max(bwd.values()))

    f_mb = np.full((ticks, s), -1, np.int32)
    f_chunk = np.full((ticks, s), -1, np.int32)
    b_mb = np.full((ticks, s), -1, np.int32)
    b_chunk = np.full((ticks, s), -1, np.int32)
    for (mb, q), t in fwd.items():
        r = q % s
        assert f_mb[t, r] < 0, ("F lane collision", name, t, r)
        f_mb[t, r], f_chunk[t, r] = mb, q
        if q > 0:                       # tight forward handoff
            assert fwd[(mb, q - 1)] == t - 1, (name, mb, q)
        assert bwd[(mb, q)] > t, (name, mb, q)
    for (mb, q), t in bwd.items():
        r = q % s
        assert b_mb[t, r] < 0, ("B lane collision", name, t, r)
        b_mb[t, r], b_chunk[t, r] = mb, q
        if q < n_virtual - 1:           # tight backward handoff
            assert bwd[(mb, q + 1)] == t - 1, (name, mb, q)

    # Stash slots: allocated at F, freed after B.  A slot freed by this
    # tick's B only re-enters the pool NEXT tick (the executor runs F's
    # stash write before B's read).
    f_slot = np.full((ticks, s), -1, np.int32)
    b_slot = np.full((ticks, s), -1, np.int32)
    n_stash = 1
    for r in range(s):
        free: list[int] = []
        live: dict[tuple[int, int], int] = {}
        hwm = 0
        for t in range(ticks):
            if f_mb[t, r] >= 0:
                slot = free.pop() if free else hwm
                if slot == hwm:
                    hwm += 1
                f_slot[t, r] = slot
                live[(int(f_mb[t, r]), int(f_chunk[t, r]))] = slot
            if b_mb[t, r] >= 0:
                slot = live.pop((int(b_mb[t, r]), int(b_chunk[t, r])))
                b_slot[t, r] = slot
                free.append(slot)
        assert not live, (name, r, live)
        n_stash = max(n_stash, hwm)

    return PipelineSchedule(
        name=name, n_stage=s, n_micro=m, virtual=v, ticks=ticks,
        n_stash=n_stash, f_mb=f_mb, f_chunk=f_chunk, f_slot=f_slot,
        b_mb=b_mb, b_chunk=b_chunk, b_slot=b_slot)


# ---------------------------------------------------------------------------
# The lock-step executor (forward + backward through the pipeline)
# ---------------------------------------------------------------------------


def pipeline_value_and_grad(chunk_fn: Callable, loss_fn: Callable,
                            params: Any, x_proto, sched: PipelineSchedule,
                            axis_name: str = "pod", *, mean: bool = True,
                            grad_seed_scale: float = 1.0,
                            reduce_grads: bool = True
                            ) -> tuple[Array, Any]:
    """Run the pipelined training step: loss AND grads flow through the
    pipeline via explicit fwd/bwd ticks.

    chunk_fn(params, chunk_idx, mb_idx, x) -> y
        one virtual stage's layer chunk; y has ``x_proto``'s shape/dtype.
        The FIRST virtual stage (chunk_idx == 0, only ever run on rank 0)
        must ignore ``x`` and build its input from the microbatch index
        (embedding / injection).
    loss_fn(params, y, mb_idx) -> scalar
        per-microbatch loss from the LAST virtual stage's output.
    x_proto: array or ShapeDtypeStruct of the inter-stage activation block.

    Per tick every rank runs at most one F unit (stashing the chunk INPUT;
    the chunk itself is recomputed in the backward — rematerialisation)
    and one B unit (vjp of the chunk, seeding from the loss at the last
    virtual stage), then hands activations forward / gradients backward
    with one collective-permute each — the two MDMP messages of this
    subsystem.  Backward compute of microbatch i overlaps the handoff of
    microbatch i+1 exactly like the paper's intermingled sends.

    Returns (loss, grads): loss is psum'd over ``axis_name`` (valid on all
    ranks); grads cover this rank's chunks (zeros elsewhere) unless
    ``reduce_grads`` also psums them.  ``mean=True`` returns per-microbatch
    means; ``mean=False`` the sums.  ``grad_seed_scale`` multiplies the
    backward seed only (shard_map replication corrections) — the reported
    loss is never scaled by it.
    """
    s = sched.n_stage
    n_virtual = s * sched.virtual
    m = sched.n_micro
    sid = lax.axis_index(axis_name) if s > 1 else jnp.int32(0)
    fwd_perm = [(i, (i + 1) % s) for i in range(s)]
    bwd_perm = [(i, (i - 1) % s) for i in range(s)]
    act_shape = tuple(x_proto.shape)
    act_dtype = x_proto.dtype
    zero_act = jnp.zeros(act_shape, act_dtype)
    seed_scale = (1.0 / m if mean else 1.0) * grad_seed_scale

    tables = {k: jnp.asarray(getattr(sched, k))
              for k in ("f_mb", "f_chunk", "f_slot",
                        "b_mb", "b_chunk", "b_slot")}

    def tick(carry, row):
        fwd_msg, bwd_msg, stash, grads, loss_acc = carry
        if s > 1:
            # issue both permutes FIRST: the handoffs of the neighbouring
            # microbatches overlap this tick's chunk compute.
            x_recv = lax.ppermute(fwd_msg, axis_name, fwd_perm)
            dy_recv = lax.ppermute(bwd_msg, axis_name, bwd_perm)
        else:
            x_recv, dy_recv = fwd_msg, bwd_msg
        f_mb = jnp.take(row["f_mb"], sid)
        f_chunk = jnp.take(row["f_chunk"], sid)
        f_slot = jnp.take(row["f_slot"], sid)
        b_mb = jnp.take(row["b_mb"], sid)
        b_chunk = jnp.take(row["b_chunk"], sid)
        b_slot = jnp.take(row["b_slot"], sid)

        def run_f(ops):
            stash_c, x_in = ops
            y = chunk_fn(params, f_chunk, jnp.maximum(f_mb, 0), x_in)
            stash_c = lax.dynamic_update_slice_in_dim(
                stash_c, x_in[None].astype(stash_c.dtype),
                jnp.maximum(f_slot, 0), axis=0)
            return y.astype(act_dtype), stash_c

        y_out, stash = lax.cond(f_mb >= 0, run_f,
                                lambda ops: (zero_act, ops[0]),
                                (stash, x_recv))

        def run_b(ops):
            grads_c, loss_c, dy = ops
            mb = jnp.maximum(b_mb, 0)
            x_in = lax.dynamic_index_in_dim(
                stash, jnp.maximum(b_slot, 0), axis=0, keepdims=False)

            def do_last(_):
                def fn(p, xi):
                    return loss_fn(p, chunk_fn(p, b_chunk, mb, xi), mb)
                lval, vjp = jax.vjp(fn, params, x_in)
                dp, dx = vjp(jnp.asarray(seed_scale, lval.dtype))
                return dp, dx, lval.astype(jnp.float32)

            def do_mid(_):
                def fn(p, xi):
                    return chunk_fn(p, b_chunk, mb, xi)
                y, vjp = jax.vjp(fn, params, x_in)
                dp, dx = vjp(dy.astype(y.dtype))
                return dp, dx, jnp.float32(0.0)

            dp, dx, lval = lax.cond(b_chunk == n_virtual - 1,
                                    do_last, do_mid, None)
            grads_c = jax.tree.map(jnp.add, grads_c, dp)
            return grads_c, loss_c + lval, dx.astype(act_dtype)

        grads, loss_acc, dx_out = lax.cond(
            b_mb >= 0, run_b,
            lambda ops: (ops[0], ops[1], zero_act),
            (grads, loss_acc, dy_recv))

        return (y_out, dx_out, stash, grads, loss_acc), None

    stash0 = jnp.zeros((sched.n_stash,) + act_shape, act_dtype)
    grads0 = jax.tree.map(jnp.zeros_like, params)
    carry0 = (zero_act, zero_act, stash0, grads0, jnp.float32(0.0))
    # one span per pipeline dispatch; scale = tick count so dur/scale is
    # measured per-tick seconds when this runs eagerly
    with dispatch_span("pipeline.ticks", carry0[0],
                       op="pipeline_schedule", axis=axis_name,
                       nbytes=int(np.prod(act_shape))
                       * jnp.dtype(act_dtype).itemsize,
                       scale=max(1, int(sched.ticks)),
                       schedule=sched.name, buffer="stage_handoff"):
        (_, _, _, grads, loss_acc), _ = lax.scan(tick, carry0, tables)

    loss = loss_acc / m if mean else loss_acc
    if s > 1:
        loss = lax.psum(loss, axis_name)       # only the last stage adds
        if reduce_grads:
            grads = jax.tree.map(lambda g: lax.psum(g, axis_name), grads)
    return loss, grads


# ---------------------------------------------------------------------------
# Forward-only GPipe (the bulk baseline; kept for inference / demos)
# ---------------------------------------------------------------------------


def pipeline_apply(stage_fn: Callable[[Array, Any], Array],
                   stage_params: Any, x_microbatches: Array,
                   axis_name: str = "pod") -> Array:
    """Forward-only GPipe over the ``axis_name`` stages.

    stage_fn(x, params) -> x    this rank's layer sub-stack
    stage_params                this rank's stage parameters (local)
    x_microbatches: [M, B, ...] microbatches (equal on every stage; only
                                stage 0's input content matters)
    Returns [M, B, ...] outputs (valid on the LAST stage; other stages
    return in-flight garbage — callers psum-select, see select_last_stage).

    Schedule: T = M + S - 1 ticks; at tick t stage s processes microbatch
    t - s.  The inter-stage handoff is one collective_permute per tick.
    """
    n_stage = lax.psum(1, axis_name)
    sid = lax.axis_index(axis_name)
    m = x_microbatches.shape[0]
    ticks = m + n_stage - 1
    perm = [(i, i + 1) for i in range(n_stage - 1)]

    def tick(carry, t):
        inflight, outputs = carry
        # stage 0 injects microbatch t; others take the handoff
        mb_idx = jnp.clip(t - sid, 0, m - 1)
        inject = x_microbatches[jnp.clip(t, 0, m - 1)]
        x_in = jnp.where(sid == 0, inject, inflight)
        active = (t - sid >= 0) & (t - sid < m)
        y = stage_fn(x_in, stage_params)
        y = jnp.where(active, y, inflight)
        # last stage records its finished microbatch
        outputs = lax.cond(
            active & (sid == n_stage - 1),
            lambda o: lax.dynamic_update_slice_in_dim(
                o, y[None], mb_idx, axis=0),
            lambda o: o, outputs)
        # hand off to the next stage (MDMP message)
        handoff = lax.ppermute(y, axis_name, perm)
        return (handoff, outputs), None

    inflight0 = jnp.zeros_like(x_microbatches[0])
    outputs0 = jnp.zeros_like(x_microbatches)
    with dispatch_span("pipeline.apply", x_microbatches,
                       op="pipeline_schedule", axis=axis_name,
                       nbytes=int(inflight0.size)
                       * inflight0.dtype.itemsize,
                       scale=max(1, int(ticks)), schedule="gpipe_fwd",
                       buffer="stage_handoff"):
        (_, outputs), _ = lax.scan(tick, (inflight0, outputs0),
                                   jnp.arange(ticks))
    return outputs


def select_last_stage(x: Array, axis_name: str = "pod") -> Array:
    """Broadcast the last stage's value to every stage (masked psum)."""
    n_stage = lax.psum(1, axis_name)
    sid = lax.axis_index(axis_name)
    mask = (sid == n_stage - 1).astype(x.dtype)
    return lax.psum(x * mask, axis_name)
