"""Gradient compression for the thin cross-pod links: int8 quantisation
with error feedback.

``compressed_psum(g, axis, err)``: quantise (g + err) to int8 with a
per-tensor scale, exchange the int8 payload + scales with an all-gather
(summing happens after dequantisation, so no int8 overflow), and keep the
local quantisation residual as the next step's error feedback.  Bytes on
the wire: n * (size/4 + 4) vs n * size for an fp32 ring — ~4x less.  Error
feedback makes the bias vanish over steps (tested: compressed training
tracks uncompressed loss).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import managed

Array = jax.Array


def quantize_int8(x: Array) -> tuple[Array, Array]:
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: Array, axis_name: str, err: Array | None
                    ) -> tuple[Array, Array]:
    """Error-feedback int8 psum across ``axis_name``.
    Returns (summed grad (f32-accurate up to quantisation), new error)."""
    g32 = g.astype(jnp.float32)
    if err is not None and err.shape == g.shape:
        g32 = g32 + err.astype(jnp.float32)
    q, scale = quantize_int8(g32)
    new_err = (g32 - dequantize_int8(q, scale)).astype(g.dtype)

    n = lax.psum(1, axis_name)
    # exchange int8 payloads; dequantise with each sender's scale, then sum
    q_all = managed.managed_all_gather(q[None], axis_name)      # [n, ...]
    s_all = managed.managed_all_gather(scale[None], axis_name)  # [n]
    deq = q_all.astype(jnp.float32) * s_all.reshape(
        (n,) + (1,) * (q.ndim))
    total = jnp.sum(deq, axis=0)
    return total.astype(g.dtype), new_err
