"""mdmptrace — the zero-dependency span/event tracer (the SEVENTH managed
subsystem's sensor).

MDMP's contract is "implement communications optimally using information
provided by the user and data collected from instrumenting the code" —
this module is the *collecting* half at runtime granularity: every hot
path (serve quanta/swaps, pipeline ticks, halo exchange dispatches,
attention ring steps, MoE dispatch chunks, checkpoint snapshot/drain/
commit, planner resolution, lint preflight) opens a :class:`Span` under
the ambient tracer, and the exporters (``obs/export.py``) and the
calibration ledger (``obs/calibrate.py``) consume the resulting event
stream.

Design constraints, in order:

1.  **Disabled is free.**  The default ambient tracer is a shared
    :data:`NULL` singleton whose ``span()`` returns one reusable no-op
    context manager — no allocation beyond the kwargs dict at the call
    site, no clock reads, no list growth.  ``bench_trace_overhead``
    asserts the enabled path costs <2% of a step and the disabled path
    is bit-identical to untraced code.
2.  **Bounded.**  Events land in a ``deque(maxlen=capacity)`` ring so a
    week-long serve run cannot OOM the host; the drop count is kept.
3.  **Thread-correct.**  Span nesting is tracked per thread (the
    checkpoint writer thread emits drain/commit spans concurrently with
    the train loop), and the ambient tracer itself is installed on a
    thread-local exactly like ``managed.use_config`` — but with a
    process-wide default so worker threads spawned *after*
    ``install_tracer`` inherit it.

Spans carry free-form ``attrs``; the conventional keys the rest of the
repo reads are ``op`` (a ``managed.DECISION_OPS`` name — the calibration
join key), ``axis`` (mesh axis -> a per-axis comm track in the Chrome
export), ``nbytes``, ``scale`` (how many predicted units the span
covers: tokens for serve quanta, sweeps for halo, train-seconds for the
checkpoint cadence), ``buffer``/``reads``/``writes`` (measured
in-flight windows and accesses for mdmplint pass 4), and ``track`` (an
explicit export track override).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Iterator


@dataclasses.dataclass(frozen=True)
class Span:
    """One closed interval on the monotonic clock.  ``t0`` is seconds on
    ``time.perf_counter`` (shared origin across threads), ``dur`` its
    length, ``depth`` the nesting depth *within its thread* at open time
    (0 = top level)."""

    name: str
    t0: float
    dur: float
    depth: int
    tid: int
    attrs: dict[str, Any]

    @property
    def t1(self) -> float:
        return self.t0 + self.dur


@dataclasses.dataclass(frozen=True)
class Instant:
    """A zero-duration event (DecisionRecords export as these)."""

    name: str
    t: float
    tid: int
    attrs: dict[str, Any]


class _NullSpan:
    """The reusable no-op context manager the disabled path hands out."""

    __slots__ = ()

    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc: Any) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every operation is a no-op, every query is
    empty.  ONE shared instance (:data:`NULL`) serves the whole process."""

    __slots__ = ()
    enabled = False

    def span(self, name: str, **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **attrs: Any) -> None:
        return None

    def spans(self) -> list[Span]:
        return []

    def instants(self) -> list[Instant]:
        return []


NULL = NullTracer()


class _SpanCtx:
    """The live context manager: clocks on enter/exit, ring append on
    exit.  A plain class (not ``contextlib.contextmanager``) keeps the
    per-span overhead to two attribute writes and two clock reads."""

    __slots__ = ("_tracer", "_name", "_attrs", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> "_SpanCtx":
        stack = self._tracer._stack()
        self._depth = len(stack)
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        t1 = time.perf_counter()
        tr = self._tracer
        tr._stack().pop()
        tr._events.append(Span(
            name=self._name, t0=self._t0, dur=t1 - self._t0,
            depth=self._depth, tid=threading.get_ident(),
            attrs=self._attrs))
        tr.n_spans += 1
        return False

    def note(self, **attrs: Any) -> None:
        """Attach attrs discovered mid-span (e.g. bytes counted while
        draining) — must be called before ``__exit__``."""
        self._attrs.update(attrs)


class Tracer:
    """The live tracer: a bounded ring of :class:`Span`/:class:`Instant`
    events with per-thread nesting stacks."""

    enabled = True

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._events: deque[Span] = deque(maxlen=self.capacity)
        self._instants: deque[Instant] = deque(maxlen=self.capacity)
        self._local = threading.local()
        self.t_origin = time.perf_counter()
        self.n_spans = 0              # total ever opened (ring may drop)

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = []
            self._local.stack = st
        return st

    # -- recording -----------------------------------------------------------

    def span(self, name: str, **attrs: Any) -> _SpanCtx:
        """``with tracer.span("serve.quantum", op="serve_schedule",
        axis="serve", nbytes=..., scale=tokens): ...``"""
        return _SpanCtx(self, name, attrs)

    def instant(self, name: str, **attrs: Any) -> None:
        self._instants.append(Instant(
            name=name, t=time.perf_counter(),
            tid=threading.get_ident(), attrs=attrs))

    # -- queries -------------------------------------------------------------

    def spans(self) -> list[Span]:
        return list(self._events)

    def instants(self) -> list[Instant]:
        return list(self._instants)

    @property
    def dropped(self) -> int:
        """Spans the ring evicted (0 unless the run outgrew capacity)."""
        return max(0, self.n_spans - len(self._events))

    def clear(self) -> None:
        self._events.clear()
        self._instants.clear()
        self.n_spans = 0


# ---------------------------------------------------------------------------
# Ambient tracer — thread-local override over a process-wide default, the
# same shape as managed.use_config / managed.install_plan.
# ---------------------------------------------------------------------------

_STATE = threading.local()
_DEFAULT: NullTracer | Tracer = NULL


def get_tracer() -> NullTracer | Tracer:
    """The ambient tracer for this thread (:data:`NULL` unless one was
    installed) — the ONE call every instrumentation site makes."""
    tr = getattr(_STATE, "tracer", None)
    return tr if tr is not None else _DEFAULT


def install_tracer(tracer: NullTracer | Tracer | None) -> None:
    """Install (or clear, with None) the process-wide default tracer —
    the launcher entry point.  Worker threads (the checkpoint writer)
    see it without any per-thread setup."""
    global _DEFAULT
    _DEFAULT = tracer if tracer is not None else NULL


class use_tracer:
    """``with obs.use_tracer(Tracer()) as tr: ...`` — scoped, this
    thread only (tests; the launchers use :func:`install_tracer`)."""

    def __init__(self, tracer: NullTracer | Tracer | None):
        self._new = tracer if tracer is not None else NULL

    def __enter__(self) -> NullTracer | Tracer:
        self._old = getattr(_STATE, "tracer", None)
        _STATE.tracer = self._new
        return self._new

    def __exit__(self, *exc: Any) -> None:
        _STATE.tracer = self._old


def iter_spans(tracer: Tracer, name_prefix: str = "") -> Iterator[Span]:
    for s in tracer.spans():
        if s.name.startswith(name_prefix):
            yield s


def dispatch_span(name: str, operand: Any = None, **attrs: Any):
    """A span at a possibly-jit-traced dispatch boundary (halo solves,
    ring attention, expert streams, pipeline scans).

    When ``operand`` is an abstract jax tracer the body is being TRACED,
    not run: the span still lands (it marks the dispatch in the timeline
    and measures trace/lower time) but is tagged ``jit=True`` so the
    calibration ledger excludes it from measured ratios.  When the
    operand is concrete (eager execution) the span is a real runtime
    measurement.  The jax import is lazy and optional — the tracer core
    stays dependency-free."""
    tr = get_tracer()
    if not tr.enabled:
        return _NULL_SPAN
    if operand is not None:
        global _JAX_TRACER_CLS
        if _JAX_TRACER_CLS is None:
            try:
                from jax.core import Tracer as _JaxTracer
                _JAX_TRACER_CLS = _JaxTracer
            except Exception:   # noqa: BLE001 — no jax, no tagging
                _JAX_TRACER_CLS = ()
        if isinstance(operand, _JAX_TRACER_CLS):
            attrs["jit"] = True
    return tr.span(name, **attrs)


#: lazily-resolved jax.core.Tracer (() when jax is unavailable) — one
#: import, not one per span
_JAX_TRACER_CLS: Any = None
