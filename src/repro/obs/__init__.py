"""repro.obs — mdmptrace: the observability subsystem (the SEVENTH
managed subsystem, cross-cutting the other six).

Four pieces, one loop:

* ``tracer``   — zero-dependency span/event tracer (bounded ring,
  thread-correct nesting, free when disabled);
* ``registry`` — ONE metrics registry (counters/gauges/histograms/EWMA/
  extrema) that serve/, checkpoint/ and the train loop build on;
* ``export``   — Chrome-trace-event/Perfetto JSON with per-mesh-axis
  comm tracks + DecisionRecord instants, and measured in-flight windows
  for mdmplint pass 4;
* ``calibrate``— the predicted-vs-measured ledger joining
  DecisionRecords to spans, plus the Recalibrator that triggers tuner
  re-resolution on sustained miscalibration.

Instrument -> cost-model -> decide -> **measure -> calibrate ->
re-resolve**: this package is the feedback edge the paper's managed
contract promises.
"""

from repro.obs.calibrate import (CalibrationLedger, CalibrationSample,
                                 Recalibrator, chosen_predicted_s,
                                 cover_with)
from repro.obs.export import (load_trace, measured_windows,
                              to_chrome_trace, trace_tracks,
                              write_chrome_trace)
from repro.obs.registry import (Counter, Ewma, Extremum, Gauge,
                                Histogram, MetricsRegistry)
from repro.obs.tracer import (NULL, Instant, NullTracer, Span, Tracer,
                              dispatch_span, get_tracer, install_tracer,
                              use_tracer)

__all__ = [
    "CalibrationLedger", "CalibrationSample", "Recalibrator",
    "chosen_predicted_s", "cover_with",
    "load_trace", "measured_windows", "to_chrome_trace", "trace_tracks",
    "write_chrome_trace",
    "Counter", "Ewma", "Extremum", "Gauge", "Histogram",
    "MetricsRegistry",
    "NULL", "Instant", "NullTracer", "Span", "Tracer", "dispatch_span",
    "get_tracer", "install_tracer", "use_tracer",
]
