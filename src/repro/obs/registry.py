"""One metrics registry — counters/gauges/histograms/EWMAs/extrema.

Before this module, three subsystems hand-rolled the same estimators:
``serve/metrics.py`` kept a min-over-quanta step estimator, ``checkpoint/
metrics.py`` a max-rate bandwidth estimator and a min-cost δ estimator,
and ``TrainLoop.run`` an inline EWMA with a 25%-drift trigger.  They now
all build on the primitives here; the public APIs of ``ServeMetrics``
and ``CheckpointMetrics`` are unchanged (the migration is internal).

The noise-robustness conventions those modules documented are encoded as
first-class metric kinds:

* :class:`Extremum` ``kind="min"`` — "the min is the noise-robust
  estimator on a shared host" (a slow sample means contention, not a
  slower machine): per-step seconds, per-checkpoint cost.
* :class:`Extremum` ``kind="max"`` — same argument for *rates*:
  measured bandwidth.
* :class:`Ewma` — drifting quantities (step time under changing load),
  with :meth:`Ewma.drift_frac` exposing the relative deviation the
  TrainLoop cadence trigger compares against its threshold.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def add(self, n: int | float = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float | None = None

    def set(self, v: float) -> None:
        self.value = float(v)


class Extremum:
    """Running min or max; ``value`` is None until the first observation."""

    __slots__ = ("kind", "value", "count")

    def __init__(self, kind: str = "min") -> None:
        assert kind in ("min", "max"), kind
        self.kind = kind
        self.value: float | None = None
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        if self.value is None:
            self.value = v
        elif self.kind == "min":
            self.value = min(self.value, v)
        else:
            self.value = max(self.value, v)

    def reset(self) -> None:
        self.value = None
        self.count = 0


class Ewma:
    """Exponentially-weighted moving average, seeded by the first sample
    (``v = alpha*v + (1-alpha)*x`` thereafter) — the exact recurrence the
    TrainLoop hand-rolled, factored out so serve/ckpt/calibration share
    it."""

    __slots__ = ("alpha", "value", "count")

    def __init__(self, alpha: float = 0.9) -> None:
        self.alpha = float(alpha)
        self.value: float | None = None
        self.count = 0

    def update(self, x: float) -> float:
        x = float(x)
        self.count += 1
        if self.value is None:
            self.value = x
        else:
            self.value = self.alpha * self.value + (1 - self.alpha) * x
        return self.value

    def drift_frac(self, baseline: float | None) -> float:
        """|ewma - baseline| / baseline — the relative drift the managed
        re-resolution triggers threshold on.  inf when there is no
        baseline yet (so 'no baseline' always trips a trigger)."""
        if self.value is None:
            return 0.0
        if baseline is None or baseline <= 0:
            return math.inf
        return abs(self.value - baseline) / baseline

    def reset(self) -> None:
        self.value = None
        self.count = 0


class Histogram:
    """Reservoir of the most recent ``window`` observations with running
    count/sum (the running aggregates never forget; percentiles are over
    the window)."""

    __slots__ = ("window", "samples", "count", "sum")

    def __init__(self, window: int = 4096) -> None:
        self.window = int(window)
        self.samples: deque[float] = deque(maxlen=self.window)
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.samples.append(v)
        self.count += 1
        self.sum += v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the window (p in [0, 1])."""
        xs = sorted(self.samples)
        if not xs:
            return 0.0
        idx = min(len(xs) - 1, max(0, math.ceil(p * len(xs)) - 1))
        return xs[idx]

    @property
    def median(self) -> float:
        return self.percentile(0.5)


@dataclasses.dataclass
class MetricsRegistry:
    """Get-or-create registry keyed by metric name.  Re-requesting a name
    returns the same object (and asserts the kind matches — a name that
    is a counter in one module and a gauge in another is a bug)."""

    metrics: dict[str, Any] = dataclasses.field(default_factory=dict)

    def _get(self, name: str, factory, kind) -> Any:
        m = self.metrics.get(name)
        if m is None:
            m = factory()
            self.metrics[name] = m
        assert isinstance(m, kind), (
            f"metric {name!r} already registered as "
            f"{type(m).__name__}, requested {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, Gauge)

    def histogram(self, name: str, window: int = 4096) -> Histogram:
        return self._get(name, lambda: Histogram(window), Histogram)

    def ewma(self, name: str, alpha: float = 0.9) -> Ewma:
        return self._get(name, lambda: Ewma(alpha), Ewma)

    def extremum(self, name: str, kind: str = "min") -> Extremum:
        return self._get(name, lambda: Extremum(kind), Extremum)

    def snapshot(self) -> dict[str, Any]:
        """Plain-data view for export (`otherData.metrics` in the Chrome
        trace)."""
        out: dict[str, Any] = {}
        for name, m in sorted(self.metrics.items()):
            if isinstance(m, Counter):
                out[name] = m.value
            elif isinstance(m, Gauge):
                out[name] = m.value
            elif isinstance(m, Extremum):
                out[name] = {"kind": m.kind, "value": m.value,
                             "count": m.count}
            elif isinstance(m, Ewma):
                out[name] = {"ewma": m.value, "count": m.count,
                             "alpha": m.alpha}
            elif isinstance(m, Histogram):
                out[name] = {"count": m.count, "mean": m.mean,
                             "p50": m.median, "p99": m.percentile(0.99)}
        return out
