"""The predicted-vs-measured calibration ledger.

Every managed decision logs a DecisionRecord with *predicted* seconds;
every instrumented hot path emits spans with *measured* seconds.  This
module joins the two on ``(op, axis)`` and maintains per-op residual
ratios ``measured / predicted`` — the number that says whether the cost
model's terms are right, per term:

* ratio ~ 1.0: the model is calibrated, trust its mode choices;
* ratio >> 1: the model is optimistic (a bandwidth/latency term too
  high, an overhead term missing) — the chosen mode may be wrong;
* ratio << 1: the model is pessimistic — it may be leaving faster
  interleavings on the table.

``CalibrationLedger.report()`` names the term behind each op (via
:data:`TERM_HINTS`) and flags ops outside tolerance.  ``Recalibrator``
is the *actuator*: it generalizes the two one-off drift hacks the repo
grew — ServeEngine's "re-resolve once after 3 quanta" warmup retune and
TrainLoop's "re-resolve when the step EWMA drifts >25% off the resolved
baseline" — into one policy object both now use.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Iterable, Sequence

from repro.obs.registry import Ewma
from repro.obs.tracer import Span

#: ops whose resolve_* entry point stores the CHOSEN prediction in
#: ``predicted_interleaved_s`` (the generic ``_resolve`` call sites store
#: bulk-vs-interleaved candidate times instead, so the chosen one depends
#: on the recorded mode)
RESOLVER_OPS = frozenset({
    "halo_aggregation", "attention_schedule", "pipeline_schedule",
    "serve_schedule", "preempt_policy", "ckpt_interval", "moe_dispatch",
})

#: which cost-model term each op's residual ratio indicts — the names a
#: human greps for in core/cost_model.py when the report flags an op
TERM_HINTS = {
    "halo_aggregation": "halo wire/sweep terms (decide_halo_aggregation)",
    "attention_schedule": "attention roofline (decide_attention_schedule)",
    "pipeline_schedule": "stage handoff/bubble terms "
                         "(decide_pipeline_schedule)",
    "serve_schedule": "serve step roofline (decide_serve_schedule)",
    "preempt_policy": "PCIe swap bw / replay terms (decide_preempt)",
    "ckpt_interval": "Young/Daly overhead terms (decide_checkpoint)",
    "moe_dispatch": "a2a dispatch terms (decide_moe_dispatch)",
    "program_plan": "joint contention model (plan_program)",
    "lint": "static preflight (no runtime term)",
    "ring_attention": "ring permute/flash overlap terms",
    "expert_stream": "expert ring stream terms",
}


def chosen_predicted_s(rec: Any) -> float:
    """The prediction for the mode the decision actually chose."""
    if rec.op in RESOLVER_OPS or rec.mode != "bulk":
        return float(rec.predicted_interleaved_s)
    return float(rec.predicted_bulk_s)


@dataclasses.dataclass
class CalibrationSample:
    op: str
    axis: str
    predicted_s: float        # chosen prediction, per unit
    measured_s: float         # sum(dur)/sum(scale) over matching spans
    n_spans: int
    #: True when the spans measure THIS op directly; False when the op
    #: is merely covered by an enclosing span (a jitted train step
    #: declaring the collectives compiled into it via an ``ops=`` attr).
    #: Covering samples count for correlation coverage but make no
    #: per-op ratio claim — runtime inside one XLA program cannot be
    #: attributed per collective from the host.
    attributed: bool = True

    @property
    def ratio(self) -> float:
        if self.predicted_s <= 0:
            return float("inf") if self.measured_s > 0 else 1.0
        return self.measured_s / self.predicted_s


def cover_with(spans: Iterable[Span], span_name: str,
               ops: Iterable[str]) -> int:
    """Declare that every ``span_name`` span *covers* ``ops`` — decisions
    for collectives compiled INTO that span's XLA program (their own
    dispatch_span fired at trace time, tagged jit).  Correlation then
    counts those decisions as covered (coverage) without claiming a
    per-op ratio.  Returns the number of spans annotated."""
    ops = sorted(set(ops))
    n = 0
    for s in spans:
        if s.name == span_name and "ops" not in s.attrs:
            s.attrs["ops"] = ops
            n += 1
    return n


@dataclasses.dataclass
class CalibrationLedger:
    """Join DecisionRecords to measured spans and keep per-(op, axis)
    residual ratios."""

    tolerance: float = 0.25
    samples: list[CalibrationSample] = dataclasses.field(
        default_factory=list)
    uncorrelated: list[Any] = dataclasses.field(default_factory=list)
    n_decisions: int = 0

    def correlate(self, spans: Iterable[Span],
                  decisions: Sequence[Any]) -> None:
        """One pass: pool measured spans by their ``op`` attr (and
        ``axis`` when present), then attach each decision to its pool.
        Pooling (rather than 1:1 matching) is deliberate: a re-resolved
        op contributes ALL its spans to the calibration of every
        decision about it — the ledger measures the model, not one
        quantum."""
        by_key: dict[tuple[str, str | None], list[Span]] = defaultdict(list)
        covered: dict[str, list[Span]] = defaultdict(list)
        for s in spans:
            if s.attrs.get("jit"):
                # fired at jax trace time, dur measures tracing not the
                # collective — structural only, never a calibration input
                continue
            for cov in s.attrs.get("ops", ()):
                covered[str(cov)].append(s)
            op = s.attrs.get("op")
            if not op:
                continue
            by_key[(str(op), None)].append(s)
            ax = s.attrs.get("axis")
            if ax:
                by_key[(str(op), str(ax))].append(s)
        for rec in decisions:
            self.n_decisions += 1
            pool = by_key.get((rec.op, rec.axis)) \
                or by_key.get((rec.op, None))
            if pool:
                dur = sum(s.dur for s in pool)
                scale = sum(float(s.attrs.get("scale", 1.0)) for s in pool)
                self.samples.append(CalibrationSample(
                    op=rec.op, axis=rec.axis,
                    predicted_s=chosen_predicted_s(rec),
                    measured_s=dur / max(scale, 1e-30), n_spans=len(pool)))
                continue
            cover = covered.get(rec.op)
            if cover:
                self.samples.append(CalibrationSample(
                    op=rec.op, axis=rec.axis,
                    predicted_s=chosen_predicted_s(rec),
                    measured_s=0.0, n_spans=len(cover),
                    attributed=False))
                continue
            self.uncorrelated.append(rec)

    # -- aggregates ----------------------------------------------------------

    def coverage(self) -> float:
        """Fraction of decisions correlated to at least one measured
        span (the >=90% acceptance bar)."""
        if self.n_decisions == 0:
            return 1.0
        return len(self.samples) / self.n_decisions

    def ratios(self) -> dict[tuple[str, str], float]:
        """(op, axis) -> mean residual ratio over finite samples."""
        acc: dict[tuple[str, str], list[float]] = defaultdict(list)
        for s in self.samples:
            if not s.attributed:
                continue
            r = s.ratio
            if r != float("inf"):
                acc[(s.op, s.axis)].append(r)
        return {k: sum(v) / len(v) for k, v in acc.items() if v}

    def miscalibrated(self) -> dict[tuple[str, str], float]:
        return {k: r for k, r in self.ratios().items()
                if abs(r - 1.0) > self.tolerance}

    def report(self) -> str:
        """Human trail, one line per (op, axis): predicted vs measured
        per-unit seconds, the residual ratio, and — when flagged — which
        cost-model term is off and by how much."""
        lines = [f"calibration: {len(self.samples)}/{self.n_decisions} "
                 f"decisions correlated "
                 f"(coverage {self.coverage() * 100:.0f}%)"]
        per_key: dict[tuple[str, str], list[CalibrationSample]] = \
            defaultdict(list)
        for s in self.samples:
            per_key[(s.op, s.axis)].append(s)
        for (op, axis), ss in sorted(per_key.items()):
            direct = [x for x in ss if x.attributed]
            if not direct:
                lines.append(f"  {op}[{axis}] n={len(ss)} COVERED by an "
                             f"enclosing span (no per-op ratio)")
                continue
            ss = direct
            pred = sum(x.predicted_s for x in ss) / len(ss)
            meas = sum(x.measured_s for x in ss) / len(ss)
            finite = [x.ratio for x in ss if x.ratio != float("inf")]
            if not finite:
                lines.append(f"  {op}[{axis}] n={len(ss)} predicted=0 "
                             f"measured={meas:.3e}s UNPRICED")
                continue
            ratio = sum(finite) / len(finite)
            line = (f"  {op}[{axis}] n={len(ss)} "
                    f"predicted={pred:.3e}s measured={meas:.3e}s "
                    f"ratio={ratio:.2f}")
            if abs(ratio - 1.0) > self.tolerance:
                pct = (ratio - 1.0) * 100
                term = TERM_HINTS.get(op, "unmapped term")
                line += (f" MISCALIBRATED({pct:+.0f}%) -> {term}")
            lines.append(line)
        if self.uncorrelated:
            ops = sorted({r.op for r in self.uncorrelated})
            lines.append(f"  uncorrelated: {len(self.uncorrelated)} "
                         f"decisions ({', '.join(ops)})")
        return "\n".join(lines)

    def snapshot(self) -> dict:
        """Plain-data view, embedded in the trace's otherData so the CLI
        can re-print the ledger from the file alone."""
        return {
            "coverage": self.coverage(),
            "ratios": {f"{op}[{axis}]": r
                       for (op, axis), r in sorted(self.ratios().items())},
            "miscalibrated": {f"{op}[{axis}]": r for (op, axis), r
                              in sorted(self.miscalibrated().items())},
        }


class Recalibrator:
    """When should a managed knob be re-resolved?  ONE policy for what
    used to be two hand-rolled hacks:

    * **warmup**: fire once as soon as ``warmup`` measurements exist and
      nothing was ever resolved from measurements (ServeEngine's
      "re-resolve after 3 quanta");
    * **sustained drift**: fire whenever the measurement EWMA deviates
      from the value the knob was last resolved against by more than
      ``threshold`` (TrainLoop's ">25% off the resolved step time").

    The caller feeds measurements via :meth:`note` and asks
    :meth:`should_retune`; after actually re-resolving it calls
    :meth:`rebase` with the value it resolved against.
    """

    def __init__(self, threshold: float = 0.25, warmup: int = 3,
                 alpha: float = 0.9):
        self.threshold = float(threshold)
        self.warmup = int(warmup)
        self.ewma = Ewma(alpha)
        self.baseline: float | None = None
        self.retunes = 0

    def note(self, measured: float) -> None:
        self.ewma.update(measured)

    @property
    def value(self) -> float | None:
        return self.ewma.value

    def should_retune(self) -> bool:
        if self.ewma.count == 0:
            return False
        if self.baseline is None:
            # never resolved from measurements: fire at warmup
            return self.ewma.count >= self.warmup
        return self.ewma.drift_frac(self.baseline) > self.threshold

    def rebase(self, resolved_against: float | None = None) -> None:
        """Record that a re-resolution happened (against the EWMA unless
        an explicit value is given)."""
        self.baseline = (self.ewma.value if resolved_against is None
                         else float(resolved_against))
        self.retunes += 1
