"""Chrome-trace-event / Perfetto export of a traced run.

``to_chrome_trace`` turns a :class:`~repro.obs.tracer.Tracer` plus the
run's DecisionRecords into the Trace Event Format dict that
chrome://tracing and https://ui.perfetto.dev open directly:

* one **track** (a named ``tid`` with a ``thread_name`` metadata event)
  per communication axis (``comm:data``, ``comm:stage``, ``comm:serve``,
  ...), plus ``compute``, ``serve``, ``ckpt`` and a ``decisions`` track;
* every span is a ``ph="X"`` complete event (``ts``/``dur`` in
  microseconds from the trace origin) with its attrs as ``args``;
* every DecisionRecord is a ``ph="i"`` instant on the ``decisions``
  track carrying the predicted seconds — side by side with the measured
  spans it will be calibrated against.

``measured_windows`` is the bridge to mdmplint pass 4: spans that carry
a ``buffer`` attr are measured in-flight windows, spans carrying
``reads``/``writes`` are measured buffer accesses — see
``analysis.graph.attach_trace``.
"""

from __future__ import annotations

import json
from typing import Any, Iterable, Sequence

from repro.obs.tracer import Instant, Span, Tracer

#: export track for spans that declare neither ``track`` nor ``axis``
DEFAULT_TRACK = "compute"
DECISION_TRACK = "decisions"


def track_of(name: str, attrs: dict[str, Any]) -> str:
    """The export track for one span: explicit ``track`` attr wins, else
    an ``axis`` attr makes it a per-axis comm track, else compute."""
    t = attrs.get("track")
    if t:
        return str(t)
    ax = attrs.get("axis")
    if ax:
        return f"comm:{ax}"
    return DEFAULT_TRACK


def _decision_args(rec: Any) -> dict[str, Any]:
    return {
        "op": rec.op, "axis": rec.axis, "nbytes": rec.nbytes,
        "mode": rec.mode, "chunks": rec.chunks,
        "predicted_bulk_s": rec.predicted_bulk_s,
        "predicted_interleaved_s": rec.predicted_interleaved_s,
    }


def to_chrome_trace(tracer: Tracer, decisions: Sequence[Any] = (),
                    other_data: dict[str, Any] | None = None) -> dict:
    """Assemble the Trace Event Format dict.  Timestamps are rebased to
    the earliest event so the trace starts at ts=0."""
    spans = tracer.spans()
    instants = tracer.instants()
    stamped = [r for r in decisions if getattr(r, "t", None) is not None]

    origins = ([s.t0 for s in spans] + [i.t for i in instants]
               + [r.t for r in stamped])
    t_origin = min(origins, default=tracer.t_origin)

    # stable track -> tid mapping: decisions first, then sorted names
    tracks: dict[str, int] = {DECISION_TRACK: 0}
    names = sorted({track_of(s.name, s.attrs) for s in spans}
                   | {track_of(i.name, i.attrs) for i in instants})
    for n in names:
        tracks.setdefault(n, len(tracks))

    events: list[dict] = []
    for name, tid in tracks.items():
        events.append({"ph": "M", "pid": 0, "tid": tid,
                       "name": "thread_name", "args": {"name": name}})
    for s in spans:
        events.append({
            "ph": "X", "pid": 0, "tid": tracks[track_of(s.name, s.attrs)],
            "name": s.name, "ts": (s.t0 - t_origin) * 1e6,
            "dur": s.dur * 1e6, "args": dict(s.attrs, depth=s.depth)})
    for i in instants:
        events.append({
            "ph": "i", "s": "t", "pid": 0,
            "tid": tracks[track_of(i.name, i.attrs)],
            "name": i.name, "ts": (i.t - t_origin) * 1e6,
            "args": dict(i.attrs)})
    for rec in decisions:
        t = getattr(rec, "t", None)
        ts = (t - t_origin) * 1e6 if t is not None else 0.0
        events.append({
            "ph": "i", "s": "p", "pid": 0, "tid": tracks[DECISION_TRACK],
            "name": f"decision:{rec.op}", "ts": ts,
            "args": _decision_args(rec)})

    other = {"n_spans": tracer.n_spans, "dropped": tracer.dropped,
             "n_decisions": len(decisions)}
    if other_data:
        other.update(other_data)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}


def write_chrome_trace(path: str, tracer: Tracer,
                       decisions: Sequence[Any] = (),
                       other_data: dict[str, Any] | None = None) -> dict:
    doc = to_chrome_trace(tracer, decisions, other_data)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def load_trace(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc.get("traceEvents"), list), (
        f"{path}: not a Chrome trace (no traceEvents list)")
    return doc


def trace_tracks(doc: dict) -> dict[int, str]:
    """tid -> track name from the thread_name metadata events."""
    return {e["tid"]: e["args"]["name"] for e in doc["traceEvents"]
            if e.get("ph") == "M" and e.get("name") == "thread_name"}


# ---------------------------------------------------------------------------
# Measured in-flight windows for mdmplint pass 4
# ---------------------------------------------------------------------------


def measured_windows(spans: Iterable[Span]) -> tuple[
        list[tuple[str, float, float, str]],
        list[tuple[str, float, str, str]]]:
    """Extract (inflight, accesses) from a span stream, rebased so the
    earliest participating span starts at t=0.

    * A span with a ``buffer`` attr is a measured in-flight window on
      that buffer: ``(buffer, t0, t1, label)``.
    * A span with ``reads``/``writes`` attrs (str or sequence of str)
      yields one measured access per named buffer at the span midpoint:
      ``(buffer, t, "read"|"write", label)``.

    ``analysis.graph.attach_trace`` turns these into the typed
    ``InFlight``/``BufferAccess`` rows pass 4 checks — real windows
    instead of corpus-declared ones.
    """
    spans = list(spans)
    picked = [s for s in spans
              if s.attrs.get("buffer") or s.attrs.get("reads")
              or s.attrs.get("writes")]
    t_origin = min((s.t0 for s in picked), default=0.0)
    inflight: list[tuple[str, float, float, str]] = []
    accesses: list[tuple[str, float, str, str]] = []
    for s in picked:
        t0, t1 = s.t0 - t_origin, s.t1 - t_origin
        buf = s.attrs.get("buffer")
        if buf:
            inflight.append((str(buf), t0, t1, s.name))
        mid = 0.5 * (t0 + t1)
        for key, access in (("reads", "read"), ("writes", "write")):
            v = s.attrs.get(key)
            if not v:
                continue
            names = [v] if isinstance(v, str) else list(v)
            for b in names:
                accesses.append((str(b), mid, access, s.name))
    return inflight, accesses
