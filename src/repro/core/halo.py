"""Managed halo exchange — the paper's running Jacobi example, TPU-native.

The paper's Figure 2 (bulk: exchange full halos, then compute) vs Figure 3
(intermingled: compute boundary rows first, send each as soon as written,
compute the interior while messages fly).  Here:

  * ``halo_exchange``       — bulk: two ppermutes of the full halo slabs.
  * ``halo_exchange_overlapped`` — the Figure-3 schedule: boundary slabs are
    produced and sent first; the interior compute is issued *between* the
    permute-starts and the halo consumption, so XLA's async collective
    engine overlaps the DMA with interior compute.  Semantically identical.
  * ``jacobi_solve(mode="aggregated", k=...)`` — the paper's third knob,
    message AGGREGATION: exchange a k-row slab once per k sweeps instead of
    a 1-row slab every sweep, and redundantly compute the ghost trapezoid
    (kernels/stencil.py::ksweep_trapezoid).  Per sweep this pays

        comm:  2*alpha/k + 2*cols*B/link_bw      (k x fewer messages,
                                                  same halo bytes)
        mem:   ~3*rows*cols*B/(k*hbm_bw)         (k sweeps per HBM
                                                  round-trip of the tile)
        flops: (rows + 2*(k-1))*cols*c/peak      (redundant ghost rows)

    so aggregation wins whenever per-message latency (alpha) or HBM
    streaming dominates the small redundant-compute tax — exactly the
    managed decision core/cost_model.py::decide_halo_aggregation makes.

Both operate on a 1-D process-grid decomposition (rows sharded over one
mesh axis) of an n-D local block, matching the paper's benchmark.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.obs.tracer import dispatch_span

Array = jax.Array


def _edge_perms(n: int) -> tuple[list, list]:
    fwd = [(i, i + 1) for i in range(n - 1)]   # non-periodic, like the paper
    bwd = [(i + 1, i) for i in range(n - 1)]
    return fwd, bwd


def halo_exchange(x: Array, axis_name: str, *, halo: int = 1,
                  periodic: bool = False) -> tuple[Array, Array]:
    """Exchange ``halo`` rows with ring neighbours along ``axis_name``.

    Returns ``(lo_halo, hi_halo)`` — the rows received from the previous /
    next rank (zeros at the boundary when non-periodic, matching
    MPI_PROC_NULL semantics in the paper's code).
    """
    n = lax.psum(1, axis_name)
    if n == 1:
        if periodic:
            return x[-halo:], x[:halo]
        z = jnp.zeros((halo,) + x.shape[1:], x.dtype)
        return z, z
    if periodic:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [((i + 1) % n, i) for i in range(n)]
    else:
        fwd, bwd = _edge_perms(n)
    # send my last rows forward -> neighbour's lo halo
    lo = lax.ppermute(x[-halo:], axis_name, fwd)
    # send my first rows backward -> neighbour's hi halo
    hi = lax.ppermute(x[:halo], axis_name, bwd)
    return lo, hi


def jacobi_step_bulk(u: Array, f: Array, axis_name: str,
                     periodic: bool = False) -> Array:
    """Paper Figure 2: exchange halos, then the 5-point update — comm and
    compute fully separated."""
    lo, hi = halo_exchange(u, axis_name, periodic=periodic)
    up = jnp.concatenate([lo, u, hi], axis=0)
    return _five_point(up, f)


def jacobi_step_overlapped(u: Array, f: Array, axis_name: str,
                           periodic: bool = False) -> Array:
    """Paper Figure 3: start the halo messages, compute the interior while
    they are in flight, then compute the two boundary rows that need the
    halos.  Identical result, intermingled schedule."""
    lo, hi = halo_exchange(u, axis_name,          # permute-starts issue here
                           periodic=periodic)
    # Interior rows (2..m-3 of the update) depend only on local data: XLA
    # schedules this compute between permute-start and permute-done.
    m = u.shape[0]
    up_int = u                                     # rows 0..m-1 available
    interior = 0.25 * (up_int[:-2, 1:-1] + up_int[2:, 1:-1]
                       + up_int[1:-1, :-2] + up_int[1:-1, 2:]
                       - f[1:-1, 1:-1])            # rows 1..m-2
    # Boundary rows 0 and m-1 need lo/hi halos (consume the messages last).
    row0 = 0.25 * (lo[:, 1:-1] + u[1:2, 1:-1]
                   + u[0:1, :-2] + u[0:1, 2:] - f[0:1, 1:-1])
    rowm = 0.25 * (u[m - 2:m - 1, 1:-1] + hi[:, 1:-1]
                   + u[m - 1:m, :-2] + u[m - 1:m, 2:] - f[m - 1:m, 1:-1])
    core = jnp.concatenate([row0, interior, rowm], axis=0)
    # Columns 0 and -1 are fixed boundary (Dirichlet), copied through.
    out = u.at[:, 1:-1].set(core)
    return out


def _five_point(up: Array, f: Array) -> Array:
    """5-point Jacobi update on a halo-padded block ``up`` ([m+2, n]),
    Dirichlet columns."""
    new = 0.25 * (up[:-2, 1:-1] + up[2:, 1:-1]
                  + up[1:-1, :-2] + up[1:-1, 2:] - f[:, 1:-1])
    out = up[1:-1].at[:, 1:-1].set(new)
    return out


# ---------------------------------------------------------------------------
# Aggregated (deep-halo, temporally-blocked) schedule — k sweeps/exchange
# ---------------------------------------------------------------------------


def _frozen_depths(axis_name: str, k: int, periodic: bool):
    """Ghost-slab rows outside the physical domain must stay constant
    (zeros) through all k sweeps; rows from a real neighbour participate in
    the redundant trapezoid instead.  Returns (frozen_top, frozen_bot) row
    counts as traced scalars."""
    if periodic:
        return jnp.int32(0), jnp.int32(0)
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    frozen_top = jnp.where(idx == 0, k, 0)
    frozen_bot = jnp.where(idx == n - 1, k, 0)
    return frozen_top, frozen_bot


def jacobi_step_aggregated(u: Array, f: Array, flo: Array, fhi: Array,
                           axis_name: str, k: int, *,
                           periodic: bool = False, engine: str = "jnp",
                           blk_m: int = 256,
                           interpret: bool = True) -> Array:
    """k Jacobi sweeps for ONE k-row halo exchange (the aggregation knob).

    ``flo``/``fhi`` are the source term's k-row ghost slabs — f is
    iteration-invariant, so the caller exchanges it once per solve, not per
    step (see ``jacobi_solve``).

    engine="jnp" runs the trapezoid as plain XLA ops (portable; what the
    CPU-hosted benchmarks measure); engine="pallas" runs the VMEM-resident
    multi-sweep kernel (kernels/stencil.py) so the k x HBM-traffic saving
    is realised on TPU.  Both share ksweep_trapezoid, so they agree
    bit-for-bit.
    """
    from repro.kernels.stencil import jacobi_ksweep_pallas, ksweep_trapezoid

    lo, hi = halo_exchange(u, axis_name, halo=k, periodic=periodic)
    u_pad = jnp.concatenate([lo, u, hi], axis=0)
    f_pad = jnp.concatenate([flo, f, fhi], axis=0)
    frozen_top, frozen_bot = _frozen_depths(axis_name, k, periodic)
    if engine == "pallas":
        return jacobi_ksweep_pallas(u_pad, f_pad, k, frozen_top, frozen_bot,
                                    blk_m=blk_m, interpret=interpret)
    out = ksweep_trapezoid(u_pad.astype(jnp.float32),
                           f_pad.astype(jnp.float32), k,
                           frozen_top, frozen_bot)
    return out[k:-k].astype(u.dtype)


def jacobi_solve(u0: Array, f: Array, axis_name: str, iters: int,
                 mode: str = "bulk", *, k: int = 1,
                 periodic: bool = False, engine: str = "jnp",
                 blk_m: int = 256, interpret: bool = True) -> Array:
    """Run ``iters`` Jacobi sweeps with the selected halo schedule.

    mode="bulk"        — paper Fig 2: 1-row exchange, then compute.
    mode="interleaved" — paper Fig 3: 1-row exchange overlapped with the
                         interior compute.
    mode="aggregated"  — deep halos: one k-row exchange per k sweeps plus a
                         redundant ghost trapezoid; pick ``k`` with
                         cost_model.decide_halo_aggregation (k=1 degrades
                         exactly to bulk).  Message count drops from
                         2*iters to 2*ceil(iters/k) + 2 (the +2 is the
                         one-time f-ghost exchange).
    """
    # one span per solve dispatch; scale=iters so dur/scale is measured
    # per-sweep seconds, the unit decide_halo_aggregation predicts
    row_bytes = int(u0.size // max(1, u0.shape[0])) * u0.dtype.itemsize
    with dispatch_span("halo.solve", u0, op="halo_aggregation",
                       axis=axis_name, nbytes=k * row_bytes, mode=mode,
                       k=k, scale=iters, buffer="halo_rows"):
        return _jacobi_solve(u0, f, axis_name, iters, mode, k=k,
                             periodic=periodic, engine=engine,
                             blk_m=blk_m, interpret=interpret)


def _jacobi_solve(u0: Array, f: Array, axis_name: str, iters: int,
                  mode: str = "bulk", *, k: int = 1,
                  periodic: bool = False, engine: str = "jnp",
                  blk_m: int = 256, interpret: bool = True) -> Array:
    if mode == "aggregated":
        k = max(1, int(k))
        u = u0
        blocks, rem = divmod(iters, k)
        if blocks > 0 and k > u0.shape[0]:
            raise ValueError(
                f"aggregation factor k={k} exceeds the local block height "
                f"{u0.shape[0]}: the ghost trapezoid would swallow the "
                f"whole shard (cost_model.decide_halo_aggregation caps k)")
        if blocks > 0:
            # f is iteration-invariant: ship its ghost slabs once.
            flo, fhi = halo_exchange(f, axis_name, halo=k, periodic=periodic)

            def body(_, u):
                return jacobi_step_aggregated(
                    u, f, flo, fhi, axis_name, k, periodic=periodic,
                    engine=engine, blk_m=blk_m, interpret=interpret)

            u = lax.fori_loop(0, blocks, body, u)

        def tail(_, u):
            return jacobi_step_bulk(u, f, axis_name, periodic)

        return lax.fori_loop(0, rem, tail, u)

    step = {"bulk": jacobi_step_bulk,
            "interleaved": jacobi_step_overlapped}[mode]

    def body(_, u):
        return step(u, f, axis_name, periodic)

    return lax.fori_loop(0, iters, body, u0)
