"""Managed halo exchange — the paper's running Jacobi example, TPU-native.

The paper's Figure 2 (bulk: exchange full halos, then compute) vs Figure 3
(intermingled: compute boundary rows first, send each as soon as written,
compute the interior while messages fly).  Here:

  * ``halo_exchange``       — bulk: two ppermutes of the full halo slabs.
  * ``halo_exchange_overlapped`` — the Figure-3 schedule: boundary slabs are
    produced and sent first; the interior compute is issued *between* the
    permute-starts and the halo consumption, so XLA's async collective
    engine overlaps the DMA with interior compute.  Semantically identical.

Both operate on a 1-D process-grid decomposition (rows sharded over one
mesh axis) of an n-D local block, matching the paper's benchmark.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def _edge_perms(n: int) -> tuple[list, list]:
    fwd = [(i, i + 1) for i in range(n - 1)]   # non-periodic, like the paper
    bwd = [(i + 1, i) for i in range(n - 1)]
    return fwd, bwd


def halo_exchange(x: Array, axis_name: str, *, halo: int = 1,
                  periodic: bool = False) -> tuple[Array, Array]:
    """Exchange ``halo`` rows with ring neighbours along ``axis_name``.

    Returns ``(lo_halo, hi_halo)`` — the rows received from the previous /
    next rank (zeros at the boundary when non-periodic, matching
    MPI_PROC_NULL semantics in the paper's code).
    """
    n = lax.psum(1, axis_name)
    if n == 1:
        z = jnp.zeros((halo,) + x.shape[1:], x.dtype)
        return z, z
    if periodic:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [((i + 1) % n, i) for i in range(n)]
    else:
        fwd, bwd = _edge_perms(n)
    # send my last rows forward -> neighbour's lo halo
    lo = lax.ppermute(x[-halo:], axis_name, fwd)
    # send my first rows backward -> neighbour's hi halo
    hi = lax.ppermute(x[:halo], axis_name, bwd)
    return lo, hi


def jacobi_step_bulk(u: Array, f: Array, axis_name: str) -> Array:
    """Paper Figure 2: exchange halos, then the 5-point update — comm and
    compute fully separated."""
    lo, hi = halo_exchange(u, axis_name)
    up = jnp.concatenate([lo, u, hi], axis=0)
    return _five_point(up, f)


def jacobi_step_overlapped(u: Array, f: Array, axis_name: str) -> Array:
    """Paper Figure 3: start the halo messages, compute the interior while
    they are in flight, then compute the two boundary rows that need the
    halos.  Identical result, intermingled schedule."""
    lo, hi = halo_exchange(u, axis_name)          # permute-starts issue here
    # Interior rows (2..m-3 of the update) depend only on local data: XLA
    # schedules this compute between permute-start and permute-done.
    m = u.shape[0]
    up_int = u                                     # rows 0..m-1 available
    interior = 0.25 * (up_int[:-2, 1:-1] + up_int[2:, 1:-1]
                       + up_int[1:-1, :-2] + up_int[1:-1, 2:]
                       - f[1:-1, 1:-1])            # rows 1..m-2
    # Boundary rows 0 and m-1 need lo/hi halos (consume the messages last).
    row0 = 0.25 * (lo[:, 1:-1] + u[1:2, 1:-1]
                   + u[0:1, :-2] + u[0:1, 2:] - f[0:1, 1:-1])
    rowm = 0.25 * (u[m - 2:m - 1, 1:-1] + hi[:, 1:-1]
                   + u[m - 1:m, :-2] + u[m - 1:m, 2:] - f[m - 1:m, 1:-1])
    core = jnp.concatenate([row0, interior, rowm], axis=0)
    # Columns 0 and -1 are fixed boundary (Dirichlet), copied through.
    out = u.at[:, 1:-1].set(core)
    return out


def _five_point(up: Array, f: Array) -> Array:
    """5-point Jacobi update on a halo-padded block ``up`` ([m+2, n]),
    Dirichlet columns."""
    new = 0.25 * (up[:-2, 1:-1] + up[2:, 1:-1]
                  + up[1:-1, :-2] + up[1:-1, 2:] - f[:, 1:-1])
    out = up[1:-1].at[:, 1:-1].set(new)
    return out


def jacobi_solve(u0: Array, f: Array, axis_name: str, iters: int,
                 mode: str = "bulk") -> Array:
    """Run ``iters`` Jacobi sweeps with the selected halo schedule."""
    step = {"bulk": jacobi_step_bulk,
            "interleaved": jacobi_step_overlapped}[mode]

    def body(_, u):
        return step(u, f, axis_name)

    return lax.fori_loop(0, iters, body, u0)
