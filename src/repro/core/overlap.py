"""As-ready gradient reduction — MDMP's "last write triggers send" for
data-parallel training.

In a bulk-synchronous data-parallel step the gradient all-reduce happens
after the whole backward pass (the paper's Figure 2 phase separation).  The
MDMP schedule fires each parameter's reduction the moment its gradient is
fully written — i.e. per-layer, *inside* the backward scan, overlapping
layer i's reduction with layer i-1's backward compute.

In JAX this falls out of autodiff once parameters are gathered-on-use:

    w_full = managed_all_gather(w_shard, 'data')     # FSDP forward
    ... use w_full ...

The transpose of (ring) all-gather is a (ring) reduce-scatter, and scan
transposition places it in the per-layer backward step — exactly the
as-ready schedule.  This module packages that pattern plus the explicit
psum fallback for replicated (non-FSDP) parameters, and a bucketing helper
(the paper's message-aggregation counter-knob) for benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from functools import partial

from repro.core.managed import (get_config, managed_all_gather,
                                managed_all_reduce, managed_reduce_scatter)

Array = jax.Array


@dataclasses.dataclass
class OverlapAccount:
    """A SINGLE pooled overlap budget, in seconds of hideable compute.

    Per-subsystem resolution lets every op assume it can hide its wire
    under the adjacent compute — but on one device the compute stream
    hides the link ONCE, not once per op.  The whole-program planner
    (plan/planner.py) opens one account per contention set (ops whose
    readiness windows overlap on the same mesh axis), seeds it with the
    LARGEST single hide the set's interleaved knobs offer, and draws every
    op's wire from it; whatever doesn't fit is exposed serial link time."""
    budget_s: float
    drawn_s: float = 0.0

    @property
    def remaining_s(self) -> float:
        return max(0.0, self.budget_s - self.drawn_s)

    def draw(self, wire_s: float) -> float:
        """Hide as much of ``wire_s`` as the account still covers; returns
        the EXPOSED remainder (serial link seconds the step must pay)."""
        hidden = min(max(0.0, wire_s), self.remaining_s)
        self.drawn_s += hidden
        return max(0.0, wire_s) - hidden


def fsdp_gather(w_shard: Array, axis_name: str, *, axis: int = 0,
                mode: str | None = None) -> Array:
    """Gather an FSDP-sharded parameter (sharded on ``axis``) for use.

    Differentiating through this op yields the as-ready reduce-scatter of
    the gradient in the backward pass (bulk or ring to match ``mode``).

    When ``MDMPConfig.fsdp_gather_dtype`` is set (e.g. 'float8_e4m3fn'),
    the gather payload is quantised per-shard (absmax scale) — half the
    FSDP link bytes vs bf16 — while master weights stay bf16 and the
    gradient reduce-scatter stays exact (weight-only quantisation).
    """
    qdt = get_config().fsdp_gather_dtype
    if qdt and w_shard.ndim >= 2 and w_shard.size >= 1 << 16:
        return _fsdp_gather_q(w_shard, axis_name, axis, mode, qdt)
    if axis == 0:
        return managed_all_gather(w_shard, axis_name, mode=mode)
    moved = jnp.moveaxis(w_shard, axis, 0)
    out = managed_all_gather(moved, axis_name, mode=mode)
    return jnp.moveaxis(out, 0, axis)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _fsdp_gather_q(w_shard, axis_name, axis, mode, qdt):
    return _fsdp_gather_q_impl(w_shard, axis_name, axis, mode, qdt)


def _fsdp_gather_q_impl(w_shard, axis_name, axis, mode, qdt):
    moved = jnp.moveaxis(w_shard, axis, 0) if axis else w_shard
    qdtype = jnp.dtype(qdt)
    fmax = float(jnp.finfo(qdtype).max)
    absmax = jnp.max(jnp.abs(moved.astype(jnp.float32)))
    scale = jnp.maximum(absmax, 1e-12) / fmax
    q = (moved.astype(jnp.float32) / scale).astype(qdtype)
    qg = managed_all_gather(q, axis_name, mode)              # fp8 payload
    s_all = managed_all_gather(scale.reshape(1), axis_name, mode)
    n = s_all.shape[0]
    m = moved.shape[0]
    blocks = qg.reshape((n, m) + qg.shape[1:]).astype(jnp.float32)
    deq = blocks * s_all.reshape((n,) + (1,) * (blocks.ndim - 1))
    out = deq.reshape(qg.shape).astype(w_shard.dtype)
    return jnp.moveaxis(out, 0, axis) if axis else out


def _fsdp_gather_q_fwd(w_shard, axis_name, axis, mode, qdt):
    return _fsdp_gather_q_impl(w_shard, axis_name, axis, mode, qdt), None


def _fsdp_gather_q_bwd(axis_name, axis, mode, qdt, _, dy):
    # gradient path stays EXACT (bf16/f32 reduce-scatter)
    moved = jnp.moveaxis(dy, axis, 0) if axis else dy
    g = managed_reduce_scatter(moved, axis_name, mode)
    return (jnp.moveaxis(g, 0, axis) if axis else g,)


_fsdp_gather_q.defvjp(_fsdp_gather_q_fwd, _fsdp_gather_q_bwd)


def fsdp_gather_tree(params: Any, axis_name: str, *, min_size: int = 1024,
                     mode: str | None = None) -> Any:
    """Gather every FSDP-sharded leaf of a param tree.  Leaves smaller than
    ``min_size`` elements are assumed replicated and passed through."""
    def gather(w):
        if w.ndim >= 1 and w.size >= min_size:
            return fsdp_gather(w, axis_name, mode=mode)
        return w
    return jax.tree.map(gather, params)


def reduce_replicated_grads(grads: Any, axis_names: Sequence[str], *,
                            mean: bool = True) -> Any:
    """Bulk psum/pmean for gradients of replicated parameters (the
    leftovers that don't flow through an fsdp_gather transpose)."""
    def red(g):
        out = g
        for ax in axis_names:
            out = managed_all_reduce(out, ax)
        if mean:
            denom = 1
            for ax in axis_names:
                denom = denom * lax.psum(1, ax)
            out = out / denom
        return out
    return jax.tree.map(red, grads)


# ---------------------------------------------------------------------------
# Bucketed reduction — the message-aggregation baseline/knob
# ---------------------------------------------------------------------------


def bucketed_all_reduce(grads: Any, axis_name: str, *,
                        bucket_bytes: int = 32 * 1024 * 1024,
                        mode: str | None = None) -> Any:
    """Flatten the grad tree into buckets of ~``bucket_bytes`` and reduce
    each bucket with one collective.  bucket_bytes=inf reproduces the
    single-bulk-message baseline; small buckets approach the paper's
    fine-grained per-datum messaging.  Used by the benchmark harness to
    sweep the aggregation/overlap trade-off.

    Buckets are formed PER DTYPE: concatenating a mixed tree in the first
    leaf's dtype would silently downcast (e.g. f32 grads squeezed through
    bf16 when a bf16 leaf happens to come first) — each dtype group keeps
    its exact dtype end to end."""
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads

    groups: dict[Any, list[int]] = {}
    for i, leaf in enumerate(leaves):
        groups.setdefault(jnp.dtype(leaf.dtype), []).append(i)

    out_leaves: list[Any] = [None] * len(leaves)
    for dtype, idxs in groups.items():
        flat = [jnp.ravel(leaves[i]) for i in idxs]
        sizes = [f.size for f in flat]
        concat = jnp.concatenate(flat) if len(flat) > 1 else flat[0]

        per_bucket = max(1, int(bucket_bytes // dtype.itemsize))
        total = concat.size
        reduced_parts = []
        start = 0
        while start < total:
            stop = min(start + per_bucket, total)
            part = lax.slice_in_dim(concat, start, stop, axis=0)
            reduced_parts.append(managed_all_reduce(part, axis_name,
                                                    mode=mode))
            start = stop
        red = (jnp.concatenate(reduced_parts)
               if len(reduced_parts) > 1 else reduced_parts[0])

        off = 0
        for i, size in zip(idxs, sizes):
            out_leaves[i] = red[off:off + size].reshape(leaves[i].shape)
            off += size
    return jax.tree.unflatten(treedef, out_leaves)


def drain_chunk_bytes(step_s: float, write_bw: float, *,
                      budget: float = 0.1,
                      min_bytes: int = 1 << 16,
                      max_bytes: int = 1 << 27) -> int:
    """Chunk size for a checkpoint's D2H drain, metered under the overlap
    budget: each chunk's device->host pull may stall the step stream for
    at most ``budget`` of one step's compute, so

        chunk_bytes = budget * step_s * write_bw

    — the same alpha-beta reasoning as the collective chunking, applied
    to recovery traffic.  A whole-tree blocking device_get is the
    ``budget=inf`` bulk baseline (what save_async did before the drain
    was managed); tiny chunks pay per-transfer latency, the dual knob.

    The serving preemption path reuses this meter for KV page swaps:
    a preempted request's page chain drains to host (and restores back)
    in chunks of this size, so eviction traffic never stalls the decode
    stream for more than ``budget`` of a step either (serve/engine.py,
    cost_model.decide_preempt prices the same chunking's alpha cost)."""
    want = int(max(0.0, budget) * max(step_s, 1e-6) * max(write_bw, 1.0))
    return max(min_bytes, min(max_bytes, want))


def grad_accumulate(step_grads_fn, microbatches: int, *, mean: bool = True):
    """Gradient accumulation driver: ``step_grads_fn(mb) -> (loss, grads)``
    over ``microbatches`` stacked microbatches (leading axis).  Returns a
    function of the stacked batch producing ``(mean_loss, mean_grads)``
    with the default ``mean=True`` — loss AND grads are averaged over the
    microbatches — or ``(mean_loss, summed_grads)`` with ``mean=False``
    (the raw accumulator, for optimizers that fold the 1/M into the
    learning rate).  Runs via lax.scan so HLO size stays independent of
    the accumulation factor."""
    def accumulate(stacked_batch):
        def body(carry, mb):
            loss_acc, grads_acc = carry
            loss, grads = step_grads_fn(mb)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), None

        mb0 = jax.tree.map(lambda x: x[0], stacked_batch)
        loss0, grads0 = step_grads_fn(mb0)
        rest = jax.tree.map(lambda x: x[1:], stacked_batch)
        (loss, grads), _ = lax.scan(body, (loss0, grads0), rest)
        scale = 1.0 / microbatches
        if mean:
            grads = jax.tree.map(lambda g: g * scale, grads)
        return loss * scale, grads

    return accumulate
