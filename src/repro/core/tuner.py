"""Runtime schedule tuner — the paper's iteration-(k)→(k+1) adaptation.

MDMP records data-access behaviour during early iterations and uses it to
schedule later iterations.  The TPU analogue cannot re-schedule inside a
compiled step, but it CAN re-pick schedules *between* steps: each managed
call site is keyed by (op, shape, dtype, axis), seeded with the cost-model
decision, and updated from measurements (wall-clock on real hardware, or
HLO-derived estimates in this container).  Changing a decision re-lowers
only the affected step function — the paper's "evaluate different
communication optimisations at runtime to auto-tune" (Sec. 4).

The cache is JSON-serialisable so tuned schedules persist across restarts
(they ride along with checkpoints).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import re
from typing import Any

from repro.core import cost_model
from repro.core.cost_model import HardwareModel, TPU_V5E


def call_site_key(op: str, shape: tuple, dtype: str, axis: str,
                  axis_size: int) -> str:
    return f"{op}|{'x'.join(map(str, shape))}|{dtype}|{axis}{axis_size}"


@dataclasses.dataclass
class TunerEntry:
    key: str
    mode: str
    chunks: int
    predicted_s: float
    measured_s: dict[str, float] = dataclasses.field(default_factory=dict)
    trials: int = 0

    def best_measured(self) -> tuple[str, float] | None:
        if not self.measured_s:
            return None
        k = min(self.measured_s, key=self.measured_s.get)
        return k, self.measured_s[k]


class ScheduleTuner:
    """Measure-and-adapt schedule cache for managed call sites."""

    #: candidate (mode, chunks) variants trialled per call site
    CANDIDATES = (("bulk", 1), ("interleaved", 1), ("interleaved", 2),
                  ("interleaved", 4))

    #: candidate (mode, k) variants for halo call sites — ``chunks`` carries
    #: the aggregation factor k (sweeps per exchange); bulk is k=1
    HALO_CANDIDATES = (("bulk", 1), ("aggregated", 2), ("aggregated", 4),
                       ("aggregated", 8))

    #: candidate schedules for attention call sites — ``mode`` carries the
    #: schedule name (bulk sequence-gather / ulysses a2a / ring streaming)
    ATTENTION_CANDIDATES = (("bulk", 1), ("ulysses", 1), ("ring", 1))

    #: candidate (mode, C) variants for serving call sites — ``mode``
    #: carries the batching mode, ``chunks`` the scheduling quantum C
    SERVE_CANDIDATES = (("static", 8), ("continuous", 2),
                        ("continuous", 8), ("continuous", 32))

    #: candidate (schedule, M) variants for pipeline call sites — ``mode``
    #: carries the schedule name, ``chunks`` the microbatch count M
    #: (interleaved variants run virtual=2 chunks per rank)
    PIPELINE_CANDIDATES = (("gpipe", 8), ("1f1b", 8), ("1f1b", 16),
                           ("interleaved", 8))

    #: candidate (schedule, g) variants for MoE dispatch call sites —
    #: ``mode`` carries the schedule (bulk a2a / chunked-stream /
    #: dense-fallback), ``chunks`` the stream chunk count g
    MOE_CANDIDATES = (("bulk", 1), ("stream", 2), ("stream", 4),
                      ("dense", 1))

    #: candidate policies for preemption call sites — ``mode`` carries
    #: the policy (swap KV to host / drop-and-recompute / head-of-line
    #: wait), ``chunks`` is unused (always 1)
    PREEMPT_CANDIDATES = (("recompute", 1), ("swap", 1), ("wait", 1))

    #: candidate (mode, N) variants for checkpoint-cadence call sites —
    #: ``mode`` carries fixed/daly, ``chunks`` the interval in steps
    #: (fixed:25 is the unmanaged baseline every prior PR shipped)
    CKPT_CANDIDATES = (("fixed", 25), ("daly", 4), ("daly", 10),
                       ("daly", 50))

    #: reserved JSON key the program plans persist under — never a call
    #: site (call_site_key always contains "|")
    PROGRAM_PLANS_KEY = "__program_plans__"

    def __init__(self, hw: HardwareModel = TPU_V5E,
                 path: str | None = None):
        self.hw = hw
        self.path = path
        self._entries: dict[str, TunerEntry] = {}
        self._program_plans: dict[str, dict] = {}
        if path and os.path.exists(path):
            self.load(path)

    # -- decisions ----------------------------------------------------------

    def decide(self, op: str, shape: tuple, dtype_str: str, axis: str,
               axis_size: int, *, nbytes: int,
               compute_time_s: float = 0.0,
               collective: str = "all_gather") -> TunerEntry:
        key = call_site_key(op, shape, dtype_str, axis, axis_size)
        entry = self._entries.get(key)
        if entry is None:
            d = cost_model.decide(nbytes, axis_size,
                                  compute_time_s=compute_time_s,
                                  hw=self.hw, collective=collective)
            entry = TunerEntry(key=key, mode=d.mode, chunks=d.chunks,
                               predicted_s=d.interleaved_time_s)
            self._entries[key] = entry
        return entry

    def decide_halo(self, axis: str, axis_size: int, rows_local: int,
                    cols: int, *, dtype_str: str = "float32",
                    dtype_bytes: int = 4) -> TunerEntry:
        """Aggregation decision for a halo call site: seeded from the cost
        model's k (``chunks`` carries k), then overridden by measurements
        fed back through ``record(key, "aggregated", k, seconds)`` — the
        paper's iteration-(k)->(k+1) adaptation applied to the aggregation
        knob.  Persisted like every other entry."""
        key = call_site_key("halo_jacobi", (rows_local, cols), dtype_str,
                            axis, axis_size)
        entry = self._entries.get(key)
        if entry is None:
            d = cost_model.decide_halo_aggregation(
                rows_local, cols, axis_size, dtype_bytes=dtype_bytes,
                hw=self.hw)
            entry = TunerEntry(key=key, mode=d.mode, chunks=d.k,
                               predicted_s=d.aggregated_sweep_s)
            self._entries[key] = entry
        return entry

    def decide_attention(self, axis: str, axis_size: int, batch: int,
                         s_local: int, heads: int, kv_heads: int,
                         head_dim: int, d_model: int, *,
                         dtype_str: str = "bfloat16", dtype_bytes: int = 2,
                         causal: bool = True) -> TunerEntry:
        """Schedule decision for an SP attention call site: seeded from the
        three-way cost model (``mode`` carries the schedule name, chunks is
        unused), then overridden by measurements fed back through
        ``record(key, "ring", 1, seconds)`` etc.  Persisted like every
        other entry so a measured winner survives restarts."""
        key = call_site_key(
            "attention_sp", (batch, s_local, heads, kv_heads, head_dim,
                             d_model, int(causal)), dtype_str, axis,
            axis_size)
        entry = self._entries.get(key)
        if entry is None:
            d = cost_model.decide_attention_schedule(
                batch, s_local, heads, kv_heads, head_dim, d_model,
                axis_size, dtype_bytes=dtype_bytes, causal=causal,
                hw=self.hw)
            entry = TunerEntry(key=key, mode=d.schedule, chunks=1,
                               predicted_s=d.chosen_s)
            self._entries[key] = entry
        return entry

    def decide_pipeline(self, axis: str, axis_size: int, n_layers: int,
                        batch_shape: tuple, batch_fwd_s: float,
                        batch_bytes: int, *,
                        dtype_str: str = "float32") -> TunerEntry:
        """Schedule decision for a pipeline-parallel call site: seeded from
        the pipeline cost model (``mode`` carries the schedule name,
        ``chunks`` the microbatch count M), then overridden by measured
        step seconds fed back through ``record(key, "1f1b", M, seconds)``
        — the paper's iteration-(k)->(k+1) adaptation applied to the
        pipeline knob.  Persisted like every other entry."""
        key = call_site_key("pipeline", (n_layers, *batch_shape), dtype_str,
                            axis, axis_size)
        entry = self._entries.get(key)
        if entry is None:
            d = cost_model.decide_pipeline_schedule(
                axis_size, batch_fwd_s, batch_bytes, n_layers=n_layers,
                hw=self.hw)
            entry = TunerEntry(key=key, mode=d.schedule, chunks=d.n_micro,
                               predicted_s=d.chosen_s)
            self._entries[key] = entry
        return entry

    def decide_moe(self, axis: str, axis_size: int, tokens_local: int,
                   d_model: int, n_experts: int, top_k: int,
                   d_ff_expert: int, *, dtype_str: str = "bfloat16",
                   dtype_bytes: int = 2, mults: int = 3,
                   capacity_factor: float = 1.25) -> TunerEntry:
        """Schedule decision for an MoE dispatch call site: seeded from
        the three-way dispatch cost model (``mode`` carries the schedule
        name, ``chunks`` the stream chunk count g), then overridden by
        measured step seconds fed back through
        ``record(key, "stream", g, seconds)`` — and re-resolved online
        from instrumented routing (imbalance/drop rate) through
        ``managed.resolve_moe_dispatch``'s measured_* inputs, the way
        the serving engine re-resolves after measured quanta.  Persisted
        like every other entry."""
        # the capacity factor is part of the call-site signature: it sizes
        # the [E, C, D] buffers every schedule moves, so different cf =
        # different operand shapes = a separate tuned entry
        cap = cost_model.moe_capacity(tokens_local, top_k, n_experts,
                                      capacity_factor)
        key = call_site_key(
            "moe_dispatch",
            (tokens_local, d_model, n_experts, top_k, d_ff_expert, cap),
            dtype_str, axis, axis_size)
        entry = self._entries.get(key)
        if entry is None:
            d = cost_model.decide_moe_dispatch(
                tokens_local, d_model, n_experts, top_k, d_ff_expert,
                axis_size, mults=mults, dtype_bytes=dtype_bytes,
                capacity_factor=capacity_factor, hw=self.hw)
            entry = TunerEntry(key=key, mode=d.schedule, chunks=d.g,
                               predicted_s=d.chosen_s)
            self._entries[key] = entry
        return entry

    def decide_serve(self, batch_slots: int, mean_prompt: int,
                     mean_new: int, n_params: int, *,
                     dtype_str: str = "bfloat16", dtype_bytes: int = 2,
                     max_prompt: int | None = None) -> TunerEntry:
        """Schedule decision for a serving call site: seeded from the
        serve cost model (``mode`` carries static/continuous, ``chunks``
        the scheduling quantum C), then overridden by measured tokens/s
        fed back through ``record(key, "continuous", C, seconds_per_tok)``
        — the paper's iteration-(k)->(k+1) adaptation applied to the
        batching knob.  Persisted like every other entry."""
        key = call_site_key(
            "serve_schedule",
            (batch_slots, int(mean_prompt), int(mean_new), int(n_params)),
            dtype_str, "serve", batch_slots)
        entry = self._entries.get(key)
        if entry is None:
            d = cost_model.decide_serve_schedule(
                n_params, batch_slots, mean_prompt, mean_new,
                max_prompt=max_prompt, dtype_bytes=dtype_bytes, hw=self.hw)
            entry = TunerEntry(key=key, mode=d.mode, chunks=d.chunk,
                               predicted_s=1.0 / max(d.chosen_tok_s,
                                                     1e-30))
            self._entries[key] = entry
        return entry

    def decide_preempt(self, axis: str, batch_slots: int, page_bytes: int,
                       n_params: int, *, victim_pages: int = 1,
                       replay_tokens: int = 0,
                       dtype_str: str = "bfloat16", dtype_bytes: int = 2,
                       step_s: float | None = None) -> TunerEntry:
        """Policy decision for a serving preemption call site: seeded
        from the swap-vs-recompute-vs-wait cost model (``mode`` carries
        the policy), then overridden by measured eviction costs fed back
        through ``record(key, "swap", 1, seconds)`` — and re-resolved
        online per event from serve/metrics.py's measured step seconds
        and swap bandwidth through ``managed.resolve_preempt``.  The key
        is per serving SITE (slots, page bytes, params), not per event —
        victim geometry varies every exhaustion, so it parameterises the
        resolve, not the cache."""
        key = call_site_key(
            "preempt", (batch_slots, int(page_bytes), int(n_params)),
            dtype_str, axis, batch_slots)
        entry = self._entries.get(key)
        if entry is None:
            d = cost_model.decide_preempt(
                victim_pages, page_bytes, replay_tokens, n_params,
                step_s=step_s, batch_slots=batch_slots,
                dtype_bytes=dtype_bytes, hw=self.hw)
            entry = TunerEntry(key=key, mode=d.policy, chunks=1,
                               predicted_s=d.chosen_s)
            self._entries[key] = entry
        return entry

    def decide_ckpt(self, axis: str, axis_size: int, snapshot_bytes: int,
                    step_s: float, *, mtbf_s: float = 1800.0,
                    write_bw: float | None = None,
                    ckpt_cost_s: float | None = None,
                    restore_s: float | None = None) -> TunerEntry:
        """Cadence decision for a checkpoint call site: seeded from the
        Young/Daly cost model (``mode`` carries fixed/daly, ``chunks``
        the interval in steps), then overridden by measured overhead fed
        back through ``record(key, "daly", N, overhead)`` — and
        re-resolved online by the train loop as the EWMA step time and
        measured write bandwidth (checkpoint/metrics.py) drift.
        Persisted like every other entry so the cadence survives
        restarts (it rides along with the checkpoint itself)."""
        key = call_site_key("ckpt_interval", (int(snapshot_bytes),),
                            "bytes", axis, axis_size)
        entry = self._entries.get(key)
        if entry is None:
            d = cost_model.decide_checkpoint(
                step_s, snapshot_bytes, mtbf_s=mtbf_s, write_bw=write_bw,
                ckpt_cost_s=ckpt_cost_s, restore_s=restore_s, hw=self.hw)
            entry = TunerEntry(key=key, mode=d.mode, chunks=d.interval,
                               predicted_s=d.chosen_overhead)
            self._entries[key] = entry
        return entry

    # -- measurement feedback (iteration k informs iteration k+1) -----------

    def record(self, key: str, mode: str, chunks: int,
               measured_s: float) -> None:
        entry = self._entries.get(key)
        if entry is None:
            entry = TunerEntry(key=key, mode=mode, chunks=chunks,
                               predicted_s=math.inf)
            self._entries[key] = entry
        variant = f"{mode}:{chunks}"
        prev = entry.measured_s.get(variant)
        # EWMA so stragglers/noise don't flip schedules on one sample.
        entry.measured_s[variant] = (measured_s if prev is None
                                     else 0.7 * prev + 0.3 * measured_s)
        entry.trials += 1
        best = entry.best_measured()
        if best is not None:
            mode_s, chunks_s = best[0].split(":")
            entry.mode, entry.chunks = mode_s, int(chunks_s)

    def next_trial(self, key: str) -> tuple[str, int] | None:
        """Suggest an untried candidate variant for this call site (the
        paper's 'evaluate different communication optimisations at
        runtime'), or None when the sweep is complete.  Halo call sites
        sweep the aggregation factors instead of the chunk counts."""
        candidates = (self.HALO_CANDIDATES if key.startswith("halo")
                      else self.ATTENTION_CANDIDATES
                      if key.startswith("attention")
                      else self.PREEMPT_CANDIDATES
                      if key.startswith("preempt")
                      else self.SERVE_CANDIDATES
                      if key.startswith("serve")
                      else self.PIPELINE_CANDIDATES
                      if key.startswith("pipeline")
                      else self.MOE_CANDIDATES
                      if key.startswith("moe")
                      else self.CKPT_CANDIDATES
                      if key.startswith("ckpt")
                      else self.CANDIDATES)
        entry = self._entries.get(key)
        if entry is None:
            return candidates[0]
        tried = set(entry.measured_s)
        for mode, chunks in candidates:
            if f"{mode}:{chunks}" not in tried:
                return mode, chunks
        return None

    # -- program plans (plan/planner.py output, keyed by program+topology) ---

    @staticmethod
    def program_plan_key(signature: str, topology: str) -> str:
        return f"{signature}@{topology}"

    def store_program_plan(self, plan) -> str:
        """Persist a ``plan.planner.ProgramPlan`` keyed by (program
        signature, topology) — the whole-program analogue of a call-site
        entry.  Rides along in the same JSON cache / checkpoint."""
        key = self.program_plan_key(plan.signature, plan.topology)
        self._program_plans[key] = plan.to_dict()
        return key

    def get_program_plan(self, signature: str, topology: str):
        """Return the stored ``ProgramPlan`` for this (program, topology),
        or None.  Lazy import keeps core free of a plan dependency."""
        d = self._program_plans.get(self.program_plan_key(signature,
                                                          topology))
        if d is None:
            return None
        from repro.plan.planner import ProgramPlan
        return ProgramPlan.from_dict(d)

    @property
    def program_plans(self) -> dict[str, dict]:
        return dict(self._program_plans)

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> str:
        blob = {k: dataclasses.asdict(v)
                for k, v in self._entries.items()}
        if self._program_plans:
            blob[self.PROGRAM_PLANS_KEY] = dict(self._program_plans)
        return json.dumps(blob, indent=2)

    def save(self, path: str | None = None) -> None:
        path = path or self.path
        assert path, "no tuner cache path configured"
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.to_json())
        os.replace(tmp, path)

    def load(self, path: str) -> None:
        with open(path) as f:
            self.load_entries(json.load(f))

    def load_entries(self, raw: dict) -> None:
        """Install entries from a ``to_json``-shaped dict (e.g. the tuner
        state a checkpoint carried along).  The reserved
        ``__program_plans__`` key holds the persisted whole-program plans,
        not a call-site entry."""
        for k, v in raw.items():
            if k == self.PROGRAM_PLANS_KEY:
                self._program_plans.update(v)
                continue
            self._entries[k] = TunerEntry(**v)

    @property
    def entries(self) -> dict[str, TunerEntry]:
        return dict(self._entries)


# ---------------------------------------------------------------------------
# Elastic re-planning — persisted winners replayed onto a new topology
# ---------------------------------------------------------------------------


_DTYPE_BYTES = {"float64": 8, "float32": 4, "int32": 4, "bfloat16": 2,
                "float16": 2, "float8_e4m3fn": 1, "float8_e5m2": 1,
                "int8": 1, "bytes": 1}


def parse_call_site_key(key: str) -> tuple[str, tuple[int, ...], str,
                                           str, int]:
    """Invert ``call_site_key`` -> (op, shape, dtype, axis, axis_size)."""
    op, shape_s, dtype, axis_tag = key.split("|")
    shape = tuple(int(x) for x in shape_s.split("x")) if shape_s else ()
    m = re.match(r"^(.*?)(\d+)$", axis_tag)
    assert m, f"unparseable axis tag in tuner key {key!r}"
    return op, shape, dtype, m.group(1), int(m.group(2))


def replan_for_mesh(tuner: ScheduleTuner, new_axis_sizes: dict[str, int],
                    *, step_s: float = 0.1, mtbf_s: float = 1800.0
                    ) -> list[dict]:
    """Replay every persisted tuner winner onto a NEW topology.

    An N-way-mesh checkpoint restoring onto M ranks invalidates every
    tuned call-site key (keys embed ``axis{axis_size}``, and the per-rank
    operand geometry changes with the shard count).  This pass walks the
    persisted entries, rescales each call site's per-rank shape to the
    new axis extent (total work is conserved: ``local' = local * n_old /
    n_new``), re-resolves the subsystem's managed decision with the OLD
    winner pinned — so the decision trail shows the replay, old->new —
    and installs a fresh entry under the new-topology key carrying the
    winner forward.  Measurements do NOT transfer (a different topology
    is a different machine as far as wall clocks go): the new entries
    start unmeasured, and the normal iteration-(k)->(k+1) loop re-earns
    or overturns each winner.

    Returns one record per replayed entry:
    ``{op, axis, old_key, new_key, mode, chunks, old_n, new_n}``.
    """
    from repro.core import managed

    replayed: list[dict] = []
    for old_key, old in sorted(tuner.entries.items()):
        try:
            op, shape, dtype, axis, n_old = parse_call_site_key(old_key)
        except (ValueError, AssertionError):
            continue
        n_new = int(new_axis_sizes.get(axis, n_old))
        ib = _DTYPE_BYTES.get(dtype, 4)

        def rescale(local: int) -> int:
            return max(1, local * n_old // max(1, n_new))

        if op == "halo_jacobi" and len(shape) == 2:
            rows_local, cols = rescale(shape[0]), shape[1]
            managed.resolve_halo_aggregation(
                axis, n_new, rows_local, cols, dtype_bytes=ib,
                k=old.chunks)
            entry = tuner.decide_halo(axis, n_new, rows_local, cols,
                                      dtype_str=dtype, dtype_bytes=ib)
        elif op == "attention_sp" and len(shape) == 7:
            b, s_local, h, kv, hd, d_model, causal = shape
            s_local = rescale(s_local)
            managed.resolve_attention_schedule(
                axis, n_new, b, s_local, h, kv, hd, d_model,
                dtype_bytes=ib, causal=bool(causal), schedule=old.mode)
            entry = tuner.decide_attention(
                axis, n_new, b, s_local, h, kv, hd, d_model,
                dtype_str=dtype, dtype_bytes=ib, causal=bool(causal))
        elif op == "pipeline" and len(shape) >= 2:
            n_layers, batch_shape = shape[0], shape[1:]
            rows, width = batch_shape[0], batch_shape[-1]
            batch_bytes = rows * width * ib
            # per-stage forward estimate: ~2 GEMM flops per element over
            # this stage's layer share (the bench's formula)
            batch_fwd_s = (2.0 * 2.0 * rows * width * width
                           * (n_layers / max(1, n_new))
                           / tuner.hw.peak_flops)
            managed.resolve_pipeline_schedule(
                axis, n_new, batch_fwd_s, batch_bytes, n_layers=n_layers,
                schedule=old.mode, n_micro=old.chunks,
                virtual=2 if old.mode == "interleaved" else 1)
            entry = tuner.decide_pipeline(axis, n_new, n_layers,
                                          batch_shape, batch_fwd_s,
                                          batch_bytes, dtype_str=dtype)
        elif op == "moe_dispatch" and len(shape) == 6:
            t_loc, d_model, e, k, f, cap = shape
            t_loc = rescale(t_loc)
            cf = cap * e / max(1, shape[0] * k)      # invert moe_capacity
            managed.resolve_moe_dispatch(
                axis, n_new, t_loc, d_model, e, k, f, dtype_bytes=ib,
                capacity_factor=cf, schedule=old.mode, g=old.chunks)
            entry = tuner.decide_moe(axis, n_new, t_loc, d_model, e, k, f,
                                     dtype_str=dtype, dtype_bytes=ib,
                                     capacity_factor=cf)
        elif op == "serve_schedule" and len(shape) == 4:
            slots, mp, mn, n_params = shape
            slots = int(new_axis_sizes.get(axis, slots))
            managed.resolve_serve_schedule(
                axis, slots, float(mp), float(mn), float(n_params),
                dtype_bytes=ib, schedule=old.mode, chunk=old.chunks)
            entry = tuner.decide_serve(slots, mp, mn, n_params,
                                       dtype_str=dtype, dtype_bytes=ib)
        elif op == "preempt" and len(shape) == 3:
            slots, page_bytes, n_params = shape
            slots = int(new_axis_sizes.get(axis, slots))
            managed.resolve_preempt(
                axis, 1, page_bytes, 0, float(n_params),
                batch_slots=slots, dtype_bytes=ib, policy=old.mode)
            entry = tuner.decide_preempt(axis, slots, page_bytes,
                                         n_params, dtype_str=dtype,
                                         dtype_bytes=ib)
        elif op == "ckpt_interval" and len(shape) == 1:
            managed.resolve_checkpoint(
                axis, step_s, shape[0], mtbf_s=mtbf_s,
                interval=old.chunks)
            entry = tuner.decide_ckpt(axis, n_new, shape[0], step_s,
                                      mtbf_s=mtbf_s)
        else:
            continue
        # the replayed winner carries forward; measurements start fresh
        entry.mode, entry.chunks = old.mode, old.chunks
        replayed.append({"op": op, "axis": axis, "old_key": old_key,
                         "new_key": entry.key, "mode": old.mode,
                         "chunks": old.chunks, "old_n": n_old,
                         "new_n": n_new})

    replayed.extend(replan_program_plans(tuner, new_axis_sizes))
    return replayed


def replan_program_plans(tuner: ScheduleTuner,
                         new_axis_sizes: dict[str, int]) -> list[dict]:
    """Re-run the whole-program planner over every persisted ProgramPlan
    on the NEW topology.  Each stored plan's CommOps are rebuilt with the
    new axis extents and their per-rank payloads rescaled (total bytes
    conserved, like the call-site replay above); the joint pass then
    re-searches the knob space from scratch — a knob the old topology
    forced off its local optimum may be free again on the new one.  The
    fresh plan is stored under the new-topology key and one
    ``program_plan`` record per re-plan is returned (and logged to the
    decision trail by ``plan_program`` itself)."""
    from repro.plan.ir import CommOp
    from repro.plan.planner import plan_program

    #: per-rank meta fields that shrink/grow with the shard count
    local_fields = ("tokens_local", "s_local", "rows_local")

    out: list[dict] = []
    for old_key, d in sorted(tuner.program_plans.items()):
        ops = [CommOp.from_dict(o) for o in d.get("ops", [])]
        if not ops:
            continue
        changed = False
        for op in ops:
            n_old = max(1, op.axis_size)
            n_new = int(new_axis_sizes.get(op.axis, n_old))
            if n_new == n_old:
                continue
            changed = True
            op.axis_size = n_new
            op.nbytes = max(1, op.nbytes * n_old // n_new)
            for f in local_fields:
                if f in op.meta:
                    op.meta[f] = max(1, int(op.meta[f]) * n_old // n_new)
        plan = plan_program(ops, hw=tuner.hw,
                            notes=[f"replanned from {old_key}"]
                            if changed else [])
        tuner.store_program_plan(plan)
        out.append({"op": "program_plan", "axis": plan.topology,
                    "old_key": old_key,
                    "new_key": tuner.program_plan_key(plan.signature,
                                                      plan.topology),
                    "mode": "coordinated" if plan.coordinated else "local",
                    "chunks": len(plan.choices),
                    "old_n": 0, "new_n": 0})
    return out
