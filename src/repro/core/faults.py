"""Deterministic fault injection — the failure taxonomy as a declared plan.

Fault tolerance is only testable (and benchmarkable) if failures are
reproducible: a ``FaultPlan`` is a list of (kind, step) events parsed from
a compact spec string, each firing exactly once at its step.  The train
loop threads the plan through ``TrainLoop`` (via ``FaultPlan.train_hook``)
and the serving engine checks ``serve_quantum`` at every quantum boundary,
so the recovery paths — restore-and-retry, checkpoint-fallback, replica
drain/re-admit — run under test and under ``benchmarks/measured.py::
bench_faults`` instead of staying theoretical.

Kinds (the taxonomy, EXPERIMENTS.md §Fault-tolerance):

  transient@k        one step-k exception (a flaky collective / preempted
                     host); the loop restores the latest checkpoint
  rank_death@k       a rank dies at step k (``RankDeath``); in this
                     single-process simulation the restart path is the
                     same restore, on a real pod it triggers the elastic
                     re-plan (``tuner.replan_for_mesh``)
  slow@k:sec        a straggler: step k stalls ``sec`` seconds (feeds the
                     EWMA straggler detector, raises nothing)
  corrupt@k[:bytes]  step k truncates the LATEST checkpoint's arrays.npz
                     to ``bytes`` (default 16) and then dies — recovery
                     must fall back to the previous step
  replica_death@q    serving: the replica dies before quantum q
                     (``ReplicaDeath``); in-flight requests are drained
                     and re-admitted to survivors
  burst@q:n          serving OVERLOAD: n synthetic requests arrive at
                     quantum q (deterministic prompts seeded from q), so
                     admission-control/shedding runs under test
  pool_squeeze@q:f   serving OVERLOAD: the usable KV page pool shrinks
                     to fraction f at quantum q (a co-tenant claiming
                     HBM), so the preemption backstop runs under test

Spec grammar:  ``kind@step[:arg]`` joined by ``;`` or ``,`` — e.g.
``"transient@6;slow@9:0.5;corrupt@14"``.  The overload kinds are
deterministic by construction: same plan + seed => identical shed/
preempt/decision sequences (asserted in tests/test_overload.py).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable


class FaultError(RuntimeError):
    """Base class of every injected failure."""


class RankDeath(FaultError):
    """A training rank died (node loss); restart from checkpoint."""


class ReplicaDeath(FaultError):
    """A serving replica died; drain + re-admit its in-flight requests."""


KINDS = ("transient", "rank_death", "slow", "corrupt", "replica_death",
         "burst", "pool_squeeze")


@dataclasses.dataclass
class FaultEvent:
    kind: str
    step: int
    arg: float = 0.0
    fired: bool = False


@dataclasses.dataclass
class FaultPlan:
    events: list[FaultEvent] = dataclasses.field(default_factory=list)

    @staticmethod
    def parse(spec: str) -> "FaultPlan":
        events = []
        for tok in spec.replace(",", ";").split(";"):
            tok = tok.strip()
            if not tok:
                continue
            kind, _, rest = tok.partition("@")
            assert kind in KINDS, f"unknown fault kind {kind!r} (in {spec!r})"
            step_s, _, arg_s = rest.partition(":")
            events.append(FaultEvent(kind=kind, step=int(step_s),
                                     arg=float(arg_s) if arg_s else 0.0))
        return FaultPlan(events=sorted(events, key=lambda e: e.step))

    # -- firing (each event exactly once) ------------------------------------

    def fire(self, kind: str, step: int) -> FaultEvent | None:
        for ev in self.events:
            if ev.kind == kind and ev.step == step and not ev.fired:
                ev.fired = True
                return ev
        return None

    def unfired(self) -> list[FaultEvent]:
        return [ev for ev in self.events if not ev.fired]

    # -- training ------------------------------------------------------------

    def train_hook(self, ckpt_dir: str | None = None
                   ) -> Callable[[int], None]:
        """A ``TrainLoop.fault_hook``: raises / stalls / corrupts per the
        plan.  ``ckpt_dir`` is needed for ``corrupt`` events (they attack
        the latest on-disk checkpoint before dying)."""

        def hook(step: int) -> None:
            ev = self.fire("slow", step)
            if ev is not None:
                time.sleep(ev.arg)
            ev = self.fire("corrupt", step)
            if ev is not None:
                assert ckpt_dir is not None, \
                    "corrupt@k fault needs the checkpoint dir"
                corrupt_latest(ckpt_dir,
                               keep_bytes=int(ev.arg) if ev.arg else 16)
                raise RankDeath(f"injected rank death at step {step} "
                                "(latest checkpoint shard corrupted)")
            ev = self.fire("transient", step)
            if ev is not None:
                raise FaultError(f"injected transient fault at step {step}")
            ev = self.fire("rank_death", step)
            if ev is not None:
                raise RankDeath(f"injected rank death at step {step}")

        return hook

    # -- serving -------------------------------------------------------------

    def serve_quantum(self, quantum_idx: int) -> None:
        """Called by the engine before dispatching quantum ``quantum_idx``;
        raises ``ReplicaDeath`` when the plan kills this replica here."""
        ev = self.fire("replica_death", quantum_idx)
        if ev is not None:
            raise ReplicaDeath(
                f"injected replica death before quantum {quantum_idx}")

    def serve_overload(self, quantum_idx: int) -> list[FaultEvent]:
        """Overload events due at this quantum boundary (each fired
        exactly once, in plan order): ``burst`` events the engine turns
        into synthetic submissions, ``pool_squeeze`` into a
        ``PageTable.squeeze``.  Raises nothing — overload degrades
        service, it doesn't kill the replica."""
        out = []
        for kind in ("burst", "pool_squeeze"):
            ev = self.fire(kind, quantum_idx)
            while ev is not None:
                out.append(ev)
                ev = self.fire(kind, quantum_idx)
        return out


def corrupt_latest(ckpt_dir: str, *, keep_bytes: int = 16) -> str | None:
    """Truncate the latest checkpoint's ``arrays.npz`` to ``keep_bytes``
    (a torn write / lost object shard).  The manifest survives, so only a
    restore attempt discovers the damage — exercising the fallback-to-
    previous-step path, not just ``latest_step`` validation."""
    from repro.checkpoint import ckpt as ckpt_lib
    step = ckpt_lib.latest_step(ckpt_dir)
    if step is None:
        return None
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    with open(path, "rb+") as f:
        f.truncate(keep_bytes)
    return path
