"""Trace-time data-access instrumentation — the paper's read/write tracking.

MDMP instruments every read and write of communicated data inside a
communication region, and uses the counts from iteration k to schedule
iteration k+1 ("launch the communication of that data once it is ready").

On TPU the schedule is static, so the *same information* is extracted at
trace time by walking the jaxpr of the region: for each tracked operand we
count consuming equations (reads), producing equations along its def-use
chain (writes), and the program depth at which the last write / first read
occurs.  ``readiness`` — how early a send operand is fully produced, or how
late a receive operand is first consumed — is exactly what the managed
scheduler needs to know how much compute is available to hide the message.

This costs nothing at runtime (the paper's Table 1 shows its runtime
counters cost ~10-20x on STREAM; the trace-time equivalent is free), which
we report as a TPU-model advantage in EXPERIMENTS.md §Paper-repro.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core as jcore


@dataclasses.dataclass
class AccessRecord:
    """Read/write profile of one tracked operand inside a region."""
    label: str
    reads: int = 0
    writes: int = 0
    first_read_depth: int | None = None
    last_write_depth: int | None = None

    def readiness(self, total_depth: int) -> float:
        """For send operands: fraction of the region's program that runs
        *before* the operand is fully produced (0 = ready immediately,
        1 = ready only at the end — no overlap opportunity)."""
        if total_depth <= 0 or self.last_write_depth is None:
            return 0.0
        return self.last_write_depth / total_depth

    def consumption_slack(self, total_depth: int) -> float:
        """For recv operands: fraction of the region that runs before the
        first read (1 = consumed only at the end — maximal overlap)."""
        if total_depth <= 0 or self.first_read_depth is None:
            return 1.0
        return self.first_read_depth / total_depth


#: jaxpr primitives that move bytes across a mesh axis.  ``psum`` carries
#: its axes under ``axes`` (names, unlike reduce_sum's int dims); the rest
#: under ``axis_name`` (a bare name or a tuple of names).
COLLECTIVE_PRIMS = {"psum", "all_gather", "all_to_all", "ppermute",
                    "reduce_scatter", "psum_scatter"}


@dataclasses.dataclass(frozen=True)
class CollectiveRecord:
    """One collective equation extracted from the region's jaxpr — the
    planner needs the mesh axis and the payload bytes to serialise link
    contention across ops sharing that axis.  ``trips`` is the static
    trip count of the enclosing ``scan`` nest (a ppermute inside a ring
    body executes ``length`` times per region run but is ONE logical
    site); ``source`` is the user-code ``file:line`` the equation traces
    to, for the static verifier's diagnostics."""
    primitive: str                 # jaxpr primitive name ("psum", ...)
    axis: str                      # mesh axis the bytes cross
    nbytes: int                    # payload bytes (sum of array operands)
    depth: int                     # program depth of the equation
    trips: int = 1                 # executions per region run (scan nest)
    source: str = ""               # user-frame "file:line" provenance


@dataclasses.dataclass
class RegionReport:
    records: dict[str, AccessRecord]
    total_eqns: int
    collectives: list[CollectiveRecord] = dataclasses.field(
        default_factory=list)

    def overlap_budget(self, label: str) -> float:
        """Fraction of the region's equations available to overlap the
        communication of ``label`` (sends: after last write; recvs: before
        first read)."""
        rec = self.records[label]
        if rec.writes > 0:
            return 1.0 - rec.readiness(self.total_eqns)
        return rec.consumption_slack(self.total_eqns)

    def collective_bytes_by_axis(self) -> dict[str, int]:
        """Total extracted payload bytes per mesh axis (one logical site
        inside a scanned ring body contributes ``nbytes * trips`` — the
        bytes a full region run actually moves)."""
        out: dict[str, int] = {}
        for c in self.collectives:
            out[c.axis] = out.get(c.axis, 0) + c.nbytes * max(1, c.trips)
        return out


def _collective_axes(eqn) -> tuple[str, ...]:
    """Mesh axis names of one collective eqn (normalised to a tuple)."""
    ax = eqn.params.get("axis_name", eqn.params.get("axes", ()))
    if isinstance(ax, str):
        return (ax,)
    return tuple(a for a in ax if isinstance(a, str))


def _source_of(eqn) -> str:
    """Repo-relative ``file:line`` of the user frame this eqn traces to
    (empty when source info is unavailable — e.g. synthetic jaxprs)."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is None:
            return ""
        fn = frame.file_name
        for marker in ("src/repro/", "tests/", "benchmarks/", "examples/"):
            i = fn.find(marker)
            if i >= 0:
                fn = fn[i:]
                break
        return f"{fn}:{frame.start_line}"
    except Exception:
        return ""


def _walk(jaxpr: jcore.Jaxpr, tracked: dict[Any, str],
          records: dict[str, AccessRecord], depth0: int,
          collectives: list[CollectiveRecord] | None = None,
          trips: int = 1) -> int:
    """Walk eqns, propagating tracked vars through aliasing ops; returns the
    depth after this jaxpr.  When ``collectives`` is given, every collective
    eqn (psum / all_gather / all_to_all / ppermute / reduce_scatter) is
    recorded ONCE per logical site with its mesh axis name, payload bytes,
    and ``trips`` — the product of enclosing static scan lengths (a ring
    body's ppermute runs ``length`` times per region execution)."""
    depth = depth0
    alias_prims = {"convert_element_type", "reshape", "transpose",
                   "squeeze", "broadcast_in_dim", "copy", "pjit",
                   "custom_jvp_call", "custom_vjp_call", "remat",
                   "checkpoint",
                   # jax >= 0.4 names the staged-out custom-derivative
                   # call sites *_jaxpr; the fwd body rides in fun_jaxpr
                   "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr"}
    def _raw(p):
        return p.jaxpr if isinstance(p, jcore.ClosedJaxpr) else (
            p if isinstance(p, jcore.Jaxpr) else None)

    for eqn in jaxpr.eqns:
        depth += 1
        if collectives is not None and \
                eqn.primitive.name in COLLECTIVE_PRIMS:
            nbytes = sum(
                int(v.aval.size) * v.aval.dtype.itemsize
                for v in eqn.invars
                if not isinstance(v, jcore.Literal)
                and getattr(v.aval, "shape", None) is not None)
            for ax in _collective_axes(eqn):
                collectives.append(CollectiveRecord(
                    primitive=eqn.primitive.name, axis=ax,
                    nbytes=nbytes, depth=depth, trips=trips,
                    source=_source_of(eqn)))
        # (sub-jaxpr, outer operands aligned to its invars, trip multiplier).
        # while's two jaxprs bind DIFFERENT operand subsets (cond_consts +
        # carry vs body_consts + carry); cond's first invar is the branch
        # index, bound by no branch; everything else binds eqn.invars
        # positionally.  A scan body executes ``length`` times — its
        # collectives are one logical site each with that trip count
        # (while trip counts are dynamic: the multiplier stays 1).
        sub_trips = trips
        if eqn.primitive.name == "scan":
            sub_trips = trips * max(1, int(eqn.params.get("length", 1)))
        sub_jaxprs = []
        if eqn.primitive.name == "while":
            cn = eqn.params["cond_nconsts"]
            bn = eqn.params["body_nconsts"]
            ops = list(eqn.invars)
            carry = ops[cn + bn:]
            sub_jaxprs.append((_raw(eqn.params["cond_jaxpr"]),
                               ops[:cn] + carry))
            sub_jaxprs.append((_raw(eqn.params["body_jaxpr"]),
                               ops[cn:cn + bn] + carry))
        else:
            default_ops = list(eqn.invars)
            if eqn.primitive.name == "cond":
                default_ops = default_ops[1:]
            for param in eqn.params.values():
                if _raw(param) is not None:
                    sub_jaxprs.append((_raw(param), default_ops))
                elif isinstance(param, (tuple, list)):
                    # cond carries its branches as a tuple of ClosedJaxprs
                    for p in param:
                        if _raw(p) is not None:
                            sub_jaxprs.append((_raw(p), default_ops))
        for v in eqn.invars:
            if isinstance(v, jcore.Literal):
                continue
            label = tracked.get(v)
            if label is not None:
                rec = records[label]
                rec.reads += 1
                if rec.first_read_depth is None:
                    rec.first_read_depth = depth
        # Writes: an eqn that *produces* a tracked value.  We propagate
        # tracking through pure aliasing ops and in-place-style updates
        # (dynamic_update_slice, add into accumulators is NOT aliasing).
        if eqn.primitive.name in alias_prims or \
                eqn.primitive.name == "dynamic_update_slice":
            for vin in eqn.invars:
                if not isinstance(vin, jcore.Literal) and vin in tracked:
                    label = tracked[vin]
                    for vout in eqn.outvars:
                        tracked[vout] = label
                    rec = records[label]
                    rec.writes += 1
                    rec.last_write_depth = depth
                    break
        # Recurse into sub-jaxprs (scan/while/cond/pjit bodies): map tracked
        # outer vars to inner binders positionally.  Binders pair with the
        # UNFILTERED operand list — a Literal operand still consumes its
        # binder position (that binder is literal-bound and simply never
        # tracked); filtering literals out first would slide every later
        # binder onto the wrong outer operand.  Only ``sub.invars`` bind
        # eqn operands: constvars are closure constants (ClosedJaxpr
        # consts), and zipping them in front would slide every scan
        # carry/xs binder onto the wrong outer operand.
        for sub, operands in sub_jaxprs:
            inner_tracked = dict()
            for inner_v, outer_v in zip(list(sub.invars), operands):
                if isinstance(outer_v, jcore.Literal):
                    continue
                if outer_v in tracked:
                    inner_tracked[inner_v] = tracked[outer_v]
            # collective extraction must see EVERY sub-jaxpr (a shard_map
            # body's collectives exist whether or not a tracked operand
            # threads into it); access tracking still needs inner binders.
            if inner_tracked or collectives is not None:
                depth = _walk(sub, {**tracked, **inner_tracked}, records,
                              depth, collectives, sub_trips)
    return depth


# ---------------------------------------------------------------------------
# MoE routing statistics — the data-dependent communication counters
# ---------------------------------------------------------------------------
#
# Unlike halo/attention/pipeline traffic, MoE dispatch bytes are decided by
# a ROUTER at runtime: the trace-time jaxpr walk above cannot see them.
# This is exactly the case where the paper's runtime read/write counters
# earn their keep, so the routing path gets true runtime instrumentation:
# ``moe_routing_stats`` is traceable (cheap — one histogram per layer) and
# ``capture_routing`` records host-side summaries that feed the
# iteration-(k)->(k+1) capacity/schedule re-resolution
# (cost_model.decide_moe_dispatch's measured_* inputs).


@dataclasses.dataclass
class RoutingRecord:
    """Host-side routing profile of one MoE dispatch call site."""
    label: str
    n_experts: int
    capacity: int
    tokens: int
    top_k: int
    histogram: np.ndarray          # [E] routed (t, k) assignments
    drop_rate: float               # fraction of assignments over capacity
    occupancy: float               # kept rows / (E * C) buffer slots
    imbalance: float               # max expert load / mean expert load


def moe_routing_stats(top_idx, n_experts: int, capacity: int) -> dict:
    """Routing statistics from a router's top-k expert ids [T, K]
    (traceable — returns jnp values usable inside jit):

      histogram [E]   assignments per expert,
      drop_rate []    fraction of (t, k) assignments past capacity,
      occupancy []    realised buffer occupancy (kept / E*C),
      imbalance []    max load / mean load (feeds the capacity-factor
                      re-resolution: cf >= imbalance drops nothing).
    """
    flat = top_idx.reshape(-1)
    # scatter-add histogram: O(T*K), not the O(T*K*E) one-hot blow-up
    hist = jnp.zeros(n_experts, jnp.float32).at[flat].add(1.0)
    kept = jnp.minimum(hist, float(capacity))
    total = jnp.maximum(jnp.float32(flat.shape[0]), 1.0)
    mean_load = jnp.maximum(jnp.mean(hist), 1e-9)
    return {
        "histogram": hist,
        "drop_rate": 1.0 - jnp.sum(kept) / total,
        "occupancy": jnp.sum(kept) / float(n_experts * capacity),
        "imbalance": jnp.max(hist) / mean_load,
    }


_ROUTING_LOG: list[RoutingRecord] = []


def capture_routing(label: str, top_idx, n_experts: int,
                    capacity: int) -> RoutingRecord:
    """Summarise CONCRETE routed ids and append to the routing log (the
    runtime counter readout: benchmarks/tuners call this on a sampled
    batch between steps, then hand ``imbalance``/``drop_rate`` back to
    ``managed.resolve_moe_dispatch``)."""
    t, k = np.asarray(top_idx).shape
    stats = jax.tree.map(np.asarray,
                         moe_routing_stats(jnp.asarray(top_idx), n_experts,
                                           capacity))
    rec = RoutingRecord(
        label=label, n_experts=n_experts, capacity=capacity, tokens=t,
        top_k=k, histogram=stats["histogram"],
        drop_rate=float(stats["drop_rate"]),
        occupancy=float(stats["occupancy"]),
        imbalance=float(stats["imbalance"]))
    _ROUTING_LOG.append(rec)
    return rec


def routing_log() -> list[RoutingRecord]:
    return list(_ROUTING_LOG)


def clear_routing_log() -> None:
    _ROUTING_LOG.clear()


def analyze_region(fn: Callable, *example_args: Any,
                   tracked_args: Sequence[int | str] | None = None,
                   labels: Sequence[str] | None = None) -> RegionReport:
    """Trace ``fn`` and produce read/write records for the tracked inputs.

    ``tracked_args``: indices into the flattened argument list (default:
    all array arguments).  ``labels``: names for the report.
    """
    closed = jax.make_jaxpr(fn)(*example_args)
    jaxpr = closed.jaxpr
    flat_invars = list(jaxpr.invars)
    if tracked_args is None:
        tracked_args = list(range(len(flat_invars)))
    if labels is None:
        labels = [f"arg{i}" for i in tracked_args]

    tracked: dict[Any, str] = {}
    records: dict[str, AccessRecord] = {}
    for i, label in zip(tracked_args, labels):
        tracked[flat_invars[i]] = label
        records[label] = AccessRecord(label=label)

    collectives: list[CollectiveRecord] = []
    total = _walk(jaxpr, tracked, records, 0, collectives)
    return RegionReport(records=records, total_eqns=total,
                        collectives=collectives)
