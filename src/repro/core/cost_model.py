"""Alpha-beta communication cost model for MDMP scheduling decisions.

The paper's central trade-off (Sec. 4, Fig. 5b/6b): decomposing one bulk
message into many fine-grained messages pays one latency (alpha) per
message but allows communication to overlap the computation that produces
or consumes the data.  MDMP "manages" that decision for the user.  On TPU
the same decision exists at tile granularity: a chunked ppermute-ring
schedule pays (chunks * steps) collective-permute latencies but overlaps
each chunk's DMA with the adjacent chunk's compute.

This module is the decision engine: given operand bytes, mesh-axis size,
and an estimate of the compute available to hide the transfer, it predicts
bulk vs interleaved cost and picks a chunk count.  It also owns the dual
knob — message AGGREGATION (``decide_halo_aggregation``): when latency
dominates, coarsen the schedule to one k-row halo slab per k stencil
sweeps, trading alpha*(messages saved) + the k x HBM-streaming saving of
the temporally-blocked kernel against beta*(f-ghost bytes) + the redundant
ghost-trapezoid FLOPs.  Constants default to
TPU v5e (the production target); the paper's machines (HECToR / HELIOS /
JUQUEEN) are included so the paper's crossover figures can be reproduced
by the benchmark harness.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# ---------------------------------------------------------------------------
# Hardware models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Alpha-beta(-gamma) machine description.

    alpha_s:        per-message (per collective-permute hop) latency, seconds
    link_bw:        per-link bandwidth, bytes/second
    peak_flops:     per-chip peak (bf16 for TPUs), flop/s
    hbm_bw:         per-chip HBM bandwidth, bytes/second
    vmem_bytes:     per-core fast-memory capacity
    hbm_bytes:      per-chip main memory capacity
    """

    name: str
    alpha_s: float
    link_bw: float
    peak_flops: float
    hbm_bw: float
    vmem_bytes: int = 0
    hbm_bytes: int = 0
    # Fine-grained-messaging behaviour (for the paper-reproduction model):
    # per-message CPU issue overhead, async-progression efficiency (1.0 =
    # transfers fully progress in the background; 0.0 = no overlap, which is
    # what the paper observed on HELIOS), and the scalar flop rate of the
    # delay loop (single core, not the vector peak).
    issue_overhead_s: float = 1.0e-7
    overlap_eff: float = 1.0
    scalar_flops: float = 0.0


# TPU v5e — the production target for every roofline number in EXPERIMENTS.md.
TPU_V5E = HardwareModel(
    name="tpu_v5e",
    alpha_s=1.0e-6,          # ~1us collective-permute hop latency on ICI
    link_bw=50.0e9,          # ~50 GB/s per ICI link
    peak_flops=197.0e12,     # bf16
    hbm_bw=819.0e9,
    vmem_bytes=128 * 1024 * 1024,
    hbm_bytes=16 * 1024 ** 3,
)

# The paper's evaluation machines, with representative 2013-era constants
# (interconnect latency / bandwidth from published specs).  Used only by the
# paper-reproduction benchmarks to show the crossover ordering matches the
# paper (HECToR/JUQUEEN cross over, HELIOS's fatter network does not).
HECTOR_XE6 = HardwareModel(
    name="hector_cray_xe6", alpha_s=1.5e-6, link_bw=5.0e9,
    peak_flops=147.2e9 * 32, hbm_bw=85.0e9,
    issue_overhead_s=2.0e-7, overlap_eff=1.0, scalar_flops=2.3e9)
HELIOS_BULLX = HardwareModel(
    name="helios_bullx_b510", alpha_s=1.2e-6, link_bw=4.0e9,
    peak_flops=2.7e9 * 8 * 16, hbm_bw=102.0e9,
    # The paper found MPI always beat MDMP on HELIOS: its MPI did not
    # progress non-blocking messages asynchronously -> no overlap benefit.
    issue_overhead_s=2.0e-7, overlap_eff=0.0, scalar_flops=2.7e9)
JUQUEEN_BGQ = HardwareModel(
    name="juqueen_bgq", alpha_s=2.5e-6, link_bw=2.0e9,
    peak_flops=204.8e9, hbm_bw=42.6e9,
    issue_overhead_s=4.0e-7, overlap_eff=1.0, scalar_flops=1.6e9)

DEFAULT_HW = TPU_V5E


# ---------------------------------------------------------------------------
# Collective cost primitives (ring algorithms, which is what managed.py emits)
# ---------------------------------------------------------------------------


def ring_all_gather_time(nbytes_shard: float, n: int, hw: HardwareModel,
                         chunks: int = 1) -> float:
    """Ring all-gather of an ``nbytes_shard`` shard across ``n`` ranks."""
    if n <= 1:
        return 0.0
    steps = (n - 1) * max(1, chunks)
    return steps * hw.alpha_s + (n - 1) * nbytes_shard / hw.link_bw


def ring_reduce_scatter_time(nbytes_full: float, n: int, hw: HardwareModel,
                             chunks: int = 1) -> float:
    """Ring reduce-scatter of an ``nbytes_full`` operand across ``n`` ranks."""
    if n <= 1:
        return 0.0
    shard = nbytes_full / n
    steps = (n - 1) * max(1, chunks)
    return steps * hw.alpha_s + (n - 1) * shard / hw.link_bw


def ring_all_reduce_time(nbytes: float, n: int, hw: HardwareModel,
                         chunks: int = 1) -> float:
    """RS + AG ring all-reduce."""
    return (ring_reduce_scatter_time(nbytes, n, hw, chunks)
            + ring_all_gather_time(nbytes / max(n, 1), n, hw, chunks))


def all_to_all_time(nbytes_local: float, n: int, hw: HardwareModel,
                    chunks: int = 1) -> float:
    """Ring-style all-to-all: each rank exchanges 1/n of its local operand
    with every peer ((n-1) permute steps of nbytes_local/n each)."""
    if n <= 1:
        return 0.0
    steps = (n - 1) * max(1, chunks)
    return steps * hw.alpha_s + (n - 1) * (nbytes_local / n) / hw.link_bw


def point_to_point_time(nbytes: float, hw: HardwareModel,
                        messages: int = 1) -> float:
    """The paper's PingPong primitive: ``messages`` sends carrying
    ``nbytes`` total."""
    return messages * hw.alpha_s + nbytes / hw.link_bw


# ---------------------------------------------------------------------------
# Bulk vs interleaved decision (the "managed" in MDMP)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScheduleDecision:
    mode: str                 # "bulk" | "interleaved"
    chunks: int               # ring sub-chunks per step (1 = plain ring)
    bulk_time_s: float        # predicted comm+compute, bulk schedule
    interleaved_time_s: float  # predicted comm+compute, chosen interleave
    comm_time_s: float        # raw transfer time of the collective
    compute_time_s: float     # compute available for overlap

    @property
    def predicted_speedup(self) -> float:
        if self.interleaved_time_s <= 0:
            return 1.0
        return self.bulk_time_s / self.interleaved_time_s


def _pipeline_time(comm_total: float, compute_total: float, stages: int,
                   alpha: float, per_stage_msgs: int = 1) -> float:
    """Pipelined schedule over ``stages`` equal stages: comm of stage i
    overlaps compute of stage i-1.  Classic software-pipeline bound:

        T = c0 + k0 + (stages-1) * max(c, k) + alpha-per-extra-message

    where c/k are per-stage comm/compute times.
    """
    if stages <= 1:
        return comm_total + compute_total + alpha * per_stage_msgs
    c = comm_total / stages
    k = compute_total / stages
    # Every stage still pays its message latency on the critical path of the
    # comm lane; with zero fusable compute this reduces exactly to the bulk
    # ring time (no free lunch from chunking alone).
    latency = alpha * per_stage_msgs * stages
    return c + k + (stages - 1) * max(c, k) + latency


def decide(nbytes: float, axis_size: int, *, compute_time_s: float = 0.0,
           hw: HardwareModel = DEFAULT_HW,
           collective: str = "all_gather",
           candidate_chunks: Sequence[int] = (1, 2, 4),
           force_mode: str | None = None) -> ScheduleDecision:
    """Pick bulk vs interleaved (and a chunk count) for one managed call site.

    ``nbytes``          bytes of the *sharded* operand that each step moves
                        (AG: shard bytes; RS/AR: full bytes; A2A: local bytes).
    ``compute_time_s``  the compute adjacent to this collective that an
                        interleaved schedule can hide (from instrument.py's
                        readiness analysis, or a flops estimate).
    """
    n = max(1, axis_size)
    timer = {
        "all_gather": ring_all_gather_time,
        "reduce_scatter": ring_reduce_scatter_time,
        "all_reduce": ring_all_reduce_time,
        "all_to_all": all_to_all_time,
    }[collective]

    comm_bulk = timer(nbytes, n, hw, 1)
    bulk_total = comm_bulk + compute_time_s

    best_mode, best_chunks, best_time = "bulk", 1, bulk_total
    if n > 1:
        ring_steps = n - 1
        for c in candidate_chunks:
            comm_c = timer(nbytes, n, hw, c)
            stages = ring_steps * c
            t = _pipeline_time(comm_c - stages * hw.alpha_s, compute_time_s,
                               stages, hw.alpha_s)
            if t < best_time * (1.0 - 1e-9):
                best_mode, best_chunks, best_time = "interleaved", c, t

    if force_mode == "bulk":
        best_mode, best_chunks, best_time = "bulk", 1, bulk_total
    elif force_mode == "interleaved" and best_mode == "bulk":
        best_mode = "interleaved"
        best_chunks = 1
        comm_c = timer(nbytes, n, hw, 1)
        stages = max(1, (n - 1))
        best_time = _pipeline_time(comm_c - stages * hw.alpha_s,
                                   compute_time_s, stages, hw.alpha_s)

    return ScheduleDecision(
        mode=best_mode, chunks=best_chunks,
        bulk_time_s=bulk_total, interleaved_time_s=best_time,
        comm_time_s=comm_bulk, compute_time_s=compute_time_s)


def pingpong_times(n_elements: int, delay_elements: float,
                   hw: HardwareModel = DEFAULT_HW,
                   nbytes_per_element: float = 4.0,
                   flops_per_delay_element: float = 1.0,
                   sent_elements: int | None = None
                   ) -> tuple[float, float]:
    """LogP-flavoured model of the paper's (Selective)DelayPingPong family.

    One half-iteration copies ``n_elements`` between buffers with
    ``delay_elements`` adds of artificial compute per element, and sends
    ``sent_elements`` of them (default: all).

    bulk (MPI baseline): compute fully, then one message —
        T = compute + alpha + bytes/bw
    fine (MDMP): one message per sent element, issued as its last write
    retires; transfers progress asynchronously with efficiency
    ``hw.overlap_eff`` while the remaining compute runs —
        T = compute_exposed + per-message issue overhead
            + un-overlappable message time.
    Returns (bulk_s, fine_s).
    """
    scalar = hw.scalar_flops or hw.peak_flops
    t_el = delay_elements * flops_per_delay_element / scalar
    s = n_elements if sent_elements is None else sent_elements
    compute = n_elements * t_el
    msg_bytes = s * nbytes_per_element

    bulk = compute + hw.alpha_s + msg_bytes / hw.link_bw

    per_msg = hw.alpha_s + nbytes_per_element / hw.link_bw
    transfer = s * per_msg
    overhead = s * hw.issue_overhead_s
    hidden = hw.overlap_eff * min(transfer, compute)
    fine = compute + overhead + (transfer - hidden)
    return bulk, fine


def crossover_compute_per_element(n_elements: int,
                                  hw: HardwareModel = DEFAULT_HW,
                                  nbytes_per_element: float = 4.0,
                                  sent_elements: int | None = None) -> float:
    """Reproduces the paper's DelayPingPong crossover (Fig 5b/6b): the
    number of delay elements per communicated element above which MDMP's
    fine-grained intermingled messaging beats the bulk message.  Returns
    ``inf`` when fine-grained never wins (the paper's HELIOS result)."""
    def diff(d: float) -> float:
        bulk, fine = pingpong_times(n_elements, d, hw,
                                    nbytes_per_element,
                                    sent_elements=sent_elements)
        return fine - bulk

    lo, hi = 0.0, 1e9
    if diff(hi) > 0:
        return math.inf
    if diff(lo) <= 0:
        return 0.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if diff(mid) <= 0:
            hi = mid
        else:
            lo = mid
    return hi


def crossover_compute_chunked(n_elements: int, chunks: int,
                              hw: HardwareModel = DEFAULT_HW,
                              nbytes_per_element: float = 4.0) -> float:
    """The TPU-adapted crossover: intermingle at *tile* granularity
    (``chunks`` messages of n/chunks elements) instead of the paper's
    per-element messages.  Per-message overheads amortise over the tile, so
    the crossover exists at realistic constants — this is why MDMP's idea
    works on TPU at the granularity the hardware rewards (DESIGN.md §2).
    Returns delay-elements-per-element at which chunked-interleaved beats
    bulk."""
    scalar = hw.scalar_flops or hw.peak_flops
    msg_bytes = n_elements * nbytes_per_element

    def diff(d: float) -> float:
        compute = n_elements * d / scalar
        bulk = compute + hw.alpha_s + msg_bytes / hw.link_bw
        per_chunk = hw.alpha_s + (msg_bytes / chunks) / hw.link_bw
        transfer = chunks * per_chunk
        hidden = hw.overlap_eff * min(transfer * (chunks - 1) / chunks,
                                      compute)
        fine = compute + chunks * hw.issue_overhead_s + transfer - hidden
        return fine - bulk

    lo, hi = 0.0, 1e9
    if diff(hi) > 0:
        return math.inf
    if diff(lo) <= 0:
        return 0.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if diff(mid) <= 0:
            hi = mid
        else:
            lo = mid
    return hi


# ---------------------------------------------------------------------------
# Halo aggregation decision (the paper's message-AGGREGATION knob)
# ---------------------------------------------------------------------------
#
# MDMP's manager may also COARSEN communication: when per-message latency
# (alpha) dominates, ship one k-row halo slab per k iterations instead of a
# 1-row slab per iteration, and redundantly compute the ghost trapezoid
# (MatlabMPI, astro-ph/0305090, measures the same latency dominance at
# small payloads).  Per sweep, for a (rows x cols) local block:
#
#   comm(k)  = 2*alpha/k + 2*cols*B/link_bw        alpha amortised k x;
#                                                  halo bytes/sweep constant
#   mem(k)   = (3*rows + 4*k)*cols*B/(k*hbm_bw)    the temporally-blocked
#                                                  kernel streams the tile
#                                                  once per k sweeps
#   flops(k) = (rows + 2*(k-1))*cols*c/peak        redundant ghost rows
#
#   t(k)     = max(mem, flops) + comm              (stencil overlaps DMA
#                                                  with VPU work)
#
# k=1 is exactly the bulk schedule.  Aggregation wins while the k x saving
# on alpha and HBM streaming outruns the 2*(k-1) redundant ghost rows; the
# VMEM capacity of the tile (3 resident arrays of (blk+2k) x cols) caps k.


#: flops per grid point of the 5-point Jacobi update (4 adds + 1 mul + ...)
JACOBI_FLOPS_PER_POINT = 6.0


@dataclasses.dataclass(frozen=True)
class HaloAggregationDecision:
    """Outcome of the aggregation decision for one halo call site."""
    k: int                        # chosen sweeps per exchange (1 = bulk)
    per_sweep_s: dict[int, float]  # candidate k -> predicted seconds/sweep
    bulk_sweep_s: float           # t(1)
    aggregated_sweep_s: float     # t(k chosen)
    comm_sweep_s: float           # comm term at chosen k
    mem_sweep_s: float            # memory term at chosen k
    flop_sweep_s: float           # redundant-compute term at chosen k

    @property
    def mode(self) -> str:
        return "aggregated" if self.k > 1 else "bulk"

    @property
    def predicted_speedup(self) -> float:
        if self.aggregated_sweep_s <= 0:
            return 1.0
        return self.bulk_sweep_s / self.aggregated_sweep_s


def halo_sweep_terms(k: int, rows_local: int, cols: int, *,
                     dtype_bytes: int = 4, hw: HardwareModel = DEFAULT_HW,
                     flops_per_point: float = JACOBI_FLOPS_PER_POINT,
                     axis_size: int = 2) -> tuple[float, float, float]:
    """(comm_s, mem_s, flops_s) per sweep of the k-aggregated schedule.
    With ``axis_size <= 1`` no bytes cross a link, so the comm term drops
    and only the temporal-blocking (HBM) saving remains."""
    k = max(1, k)
    halo_bytes = cols * dtype_bytes
    comm = (0.0 if axis_size <= 1
            else 2.0 * hw.alpha_s / k + 2.0 * halo_bytes / hw.link_bw)
    mem = ((3.0 * rows_local + 4.0 * k) * cols * dtype_bytes
           / (k * hw.hbm_bw))
    flops = ((rows_local + 2.0 * (k - 1)) * cols * flops_per_point
             / hw.peak_flops)
    return comm, mem, flops


def halo_sweep_time(k: int, rows_local: int, cols: int, *,
                    dtype_bytes: int = 4, hw: HardwareModel = DEFAULT_HW,
                    flops_per_point: float = JACOBI_FLOPS_PER_POINT,
                    axis_size: int = 2) -> float:
    comm, mem, flops = halo_sweep_terms(
        k, rows_local, cols, dtype_bytes=dtype_bytes, hw=hw,
        flops_per_point=flops_per_point, axis_size=axis_size)
    return max(mem, flops) + comm


def decide_halo_aggregation(rows_local: int, cols: int, axis_size: int, *,
                            dtype_bytes: int = 4,
                            hw: HardwareModel = DEFAULT_HW,
                            candidate_k: Sequence[int] = (1, 2, 4, 8),
                            flops_per_point: float = JACOBI_FLOPS_PER_POINT,
                            force_k: int | None = None
                            ) -> HaloAggregationDecision:
    """Pick how many sweeps each halo exchange should carry.

    Candidates are dropped when the k-deep apron tile no longer fits VMEM
    (3 resident (rows+2k) x cols arrays) or when k exceeds the local block
    (the ghost trapezoid would swallow the whole shard); k=1 is the plain
    bulk schedule (no VMEM-resident multi-sweep tile) and always survives.
    ``axis_size=1`` still aggregates — the HBM-round-trip saving is local,
    not collective — but its comm term is zero (no link crossed).
    ``force_k`` is clamped to the same validity caps, so the returned k is
    always safe to feed to ``halo.jacobi_solve``.
    """
    def sweep_time(k: int) -> float:
        return halo_sweep_time(
            k, rows_local, cols, dtype_bytes=dtype_bytes, hw=hw,
            flops_per_point=flops_per_point, axis_size=axis_size)

    def valid(k: int) -> bool:
        if k > max(1, rows_local):
            return False
        if k > 1 and hw.vmem_bytes:
            tile_rows = min(rows_local, 256) + 2 * k
            if 3 * tile_rows * cols * dtype_bytes > hw.vmem_bytes:
                return False
        return True

    times = {k: sweep_time(k) for k in sorted({1, *candidate_k})
             if k >= 1 and valid(k)}
    if force_k is not None:
        best_k = max(1, int(force_k))
        while best_k > 1 and not valid(best_k):
            best_k -= 1
        times.setdefault(best_k, sweep_time(best_k))
    else:
        best_k = min(times, key=lambda k: (times[k], k))
    comm, mem, flops = halo_sweep_terms(
        best_k, rows_local, cols, dtype_bytes=dtype_bytes, hw=hw,
        flops_per_point=flops_per_point, axis_size=axis_size)
    return HaloAggregationDecision(
        k=best_k, per_sweep_s=times,
        bulk_sweep_s=times.get(1, sweep_time(1)),
        aggregated_sweep_s=times[best_k],
        comm_sweep_s=comm, mem_sweep_s=mem, flop_sweep_s=flops)


# ---------------------------------------------------------------------------
# Attention schedule decision (bulk gather vs ulysses a2a vs ring streaming)
# ---------------------------------------------------------------------------
#
# The SP-flow attention has three managed schedules (models/attention.py);
# per the MDMP contract the manager picks one per call site from the same
# alpha-beta machinery:
#
#   bulk (megatron)  — all-gather the SEQUENCE activations for the qkv
#                      matmuls (bytes ∝ S·B·D) + matmul-reduce-scatter of
#                      the output, then one full-sequence flash on local
#                      heads.
#   ulysses          — gather the q/o WEIGHTS over 'model' (bytes ∝ D·H·hd)
#                      and switch seq<->head sharding with two all_to_alls
#                      (bytes ∝ S·B·H·hd/tp) + a small KV seq-gather, then
#                      the same full-sequence flash.
#   ring             — q stays sequence-sharded; KV blocks stream around
#                      the ring under the flash compute (the paper's
#                      Figure-3 "send each datum as soon as it is produced"
#                      mapped onto context parallelism).  Per step the cost
#                      is max(flash_flops, link_time) + alpha: O(S_loc)
#                      activation memory and the KV transfer fully hidden
#                      once the per-block flash dominates the link.
#
# qkv/o projection FLOPs are identical across schedules and excluded; the
# attention FLOPs are identical in total but scheduled differently.  For
# causal masks the ring skips fully-masked future blocks, making the
# average rank busy ~(n+1)/2 of n steps; we charge the ring the same 0.5x
# causal factor as the bulk schedules per step (the lock-step pessimistic
# bound would be 1.0x — an async ring with slack amortises the straggler;
# see EXPERIMENTS.md §Attention-schedules).


@dataclasses.dataclass(frozen=True)
class AttentionScheduleDecision:
    """Outcome of the three-way attention-schedule decision."""
    schedule: str                  # "bulk" | "ulysses" | "ring"
    times_s: dict[str, float]      # schedule -> predicted seconds/layer
    bulk_s: float
    chosen_s: float
    comm_s: float                  # comm on the chosen schedule's crit path
    flash_s: float                 # attention compute (chosen schedule)

    @property
    def predicted_speedup(self) -> float:
        if self.chosen_s <= 0:
            return 1.0
        return self.bulk_s / self.chosen_s


def attention_flash_step_s(batch: int, s_local: int, heads: int,
                           head_dim: int,
                           hw: HardwareModel = DEFAULT_HW) -> float:
    """Seconds for ONE q-block x kv-block flash step (all heads, local
    sequence) — the unit every schedule's compute term is built from."""
    return (4.0 * batch * float(s_local) ** 2 * heads * head_dim
            / hw.peak_flops)


def attention_schedule_times(batch: int, s_local: int, heads: int,
                             kv_heads: int, head_dim: int, d_model: int,
                             axis_size: int, *, dtype_bytes: int = 2,
                             causal: bool = True,
                             hw: HardwareModel = DEFAULT_HW
                             ) -> dict[str, float]:
    """Predicted seconds per attention call for each schedule (comm on the
    critical path + attention flops; shared projection flops excluded)."""
    n = max(1, axis_size)
    cf = 0.5 if causal else 1.0
    flash_step = attention_flash_step_s(batch, s_local, heads, head_dim, hw)
    attn_full = cf * n * flash_step          # full-seq flash == n ring steps

    x_shard = batch * s_local * d_model * dtype_bytes
    t_bulk = (ring_all_gather_time(x_shard, n, hw)
              + ring_reduce_scatter_time(x_shard * n, n, hw)
              + attn_full)

    wq_shard = d_model * (heads * head_dim // n) * dtype_bytes
    w_gather = 2.0 * ring_all_gather_time(wq_shard, n, hw)   # wq and wo
    qo_local = batch * s_local * heads * head_dim * dtype_bytes
    kv_shard = 2.0 * batch * s_local * kv_heads * head_dim * dtype_bytes
    t_ulysses = (w_gather + 2.0 * all_to_all_time(qo_local, n, hw)
                 + ring_all_gather_time(kv_shard, n, hw) + attn_full)

    link_step = hw.alpha_s + kv_shard / hw.link_bw
    t_ring = (w_gather + cf * flash_step
              + (n - 1) * max(cf * flash_step, link_step))
    return {"bulk": t_bulk, "ulysses": t_ulysses, "ring": t_ring}


def decide_attention_schedule(batch: int, s_local: int, heads: int,
                              kv_heads: int, head_dim: int, d_model: int,
                              axis_size: int, *, dtype_bytes: int = 2,
                              causal: bool = True,
                              hw: HardwareModel = DEFAULT_HW,
                              force_schedule: str | None = None
                              ) -> AttentionScheduleDecision:
    """Pick the attention schedule for one call site.  ``force_schedule``
    pins the choice (an MDMPConfig bulk override, or the tuner's measured
    winner) while still reporting the modeled times."""
    times = attention_schedule_times(
        batch, s_local, heads, kv_heads, head_dim, d_model, axis_size,
        dtype_bytes=dtype_bytes, causal=causal, hw=hw)
    if force_schedule is not None:
        assert force_schedule in times, force_schedule
        best = force_schedule
    else:
        best = min(times, key=lambda s: (times[s], s))
    n = max(1, axis_size)
    cf = 0.5 if causal else 1.0
    flash_s = cf * n * attention_flash_step_s(batch, s_local, heads,
                                              head_dim, hw)
    comm_s = max(0.0, times[best] - flash_s)
    return AttentionScheduleDecision(
        schedule=best, times_s=times, bulk_s=times["bulk"],
        chosen_s=times[best], comm_s=comm_s, flash_s=flash_s)


# ---------------------------------------------------------------------------
# Pipeline schedule decision (gpipe vs 1f1b vs interleaved + microbatching)
# ---------------------------------------------------------------------------
#
# The pipeline executor (parallel/pipeline.py) runs lock-step ticks: per
# tick every stage does at most one forward and one backward unit and
# hands activations forward / gradients backward with one collective
# permute each.  The knob is (schedule, microbatch count M, virtual chunk
# factor v), and the trade is exactly the paper's control-vs-data-flow
# decision (El-Nashar, arXiv:1311.0731) at schedule granularity:
#
#   gpipe        ticks = 2(M+S-1),  critical compute = (M+S-1)(cf+cb),
#                stash = M microbatch activations per stage.
#                The bubble fraction is the classic (S-1)/(M+S-1).
#   1f1b         ticks = M+2S-1,    compute ~= M(cf+cb) + (2S-1) cb,
#                stash <= 2S (O(n_stage), independent of M).
#   interleaved  ticks = Mv+vS+S-1, compute ~= M(cf+cb) + (vS+S-1) cb / v,
#                stash <= 2vS chunk activations (each 1/1 of a microbatch
#                block).  The ramp's compute shrinks ~v x but every tick
#                still pays the per-message alpha — v x more messages.
#
# Per tick the two handoffs (activation fwd + gradient bwd) cost
# 2 alpha + 2 bytes / bw, with the bytes hidden under the tick's compute
# to the extent the stage boundary is ready early (the instrument.py
# readiness budget of the boundary operand).


#: backward flops per forward flop of a transformer chunk (dgrad + wgrad)
PIPELINE_BWD_FLOP_RATIO = 2.0


@dataclasses.dataclass(frozen=True)
class PipelineScheduleDecision:
    """Outcome of the pipeline-schedule decision for one training loop."""
    schedule: str                  # "gpipe" | "1f1b" | "interleaved"
    n_micro: int                   # microbatch count M
    virtual: int                   # virtual chunks per rank (1 unless interleaved)
    times_s: dict[str, float]      # "sched:M:v" -> predicted step seconds
    bulk_s: float                  # best gpipe variant (unmanaged baseline)
    chosen_s: float
    bubble_frac: float             # idle fraction of the chosen schedule
    stash_bytes: int               # peak activation stash per stage

    @property
    def predicted_speedup(self) -> float:
        if self.chosen_s <= 0:
            return 1.0
        return self.bulk_s / self.chosen_s


def pipeline_stash_slots(schedule: str, n_micro: int, n_stage: int,
                         virtual: int = 1) -> int:
    """Closed-form peak live activation count per stage (upper bound,
    matches the executor's host-allocated stash within +1).  Each slot
    holds ONE microbatch activation block — GPipe's slot count grows with
    M (whole batch stashed), 1f1b's is capped at 2S."""
    m, s = max(1, n_micro), max(1, n_stage)
    if schedule == "gpipe":
        return m
    if schedule == "1f1b":
        return min(m, 2 * s)
    return min(m * max(1, virtual), 2 * max(1, virtual) * s + s)


def pipeline_schedule_time(schedule: str, n_micro: int, n_stage: int,
                           virtual: int, batch_fwd_s: float,
                           batch_bytes: float, *,
                           hw: HardwareModel = DEFAULT_HW,
                           overlap_budget: float = 1.0
                           ) -> tuple[float, int]:
    """(predicted step seconds, tick count) of one schedule variant.

    ``batch_fwd_s``     one rank's forward compute for the WHOLE batch
                        (its full layer chunk set, all M microbatches) —
                        per-microbatch compute is batch_fwd_s / M.
    ``batch_bytes``     the whole batch's activation block at the stage
                        boundary — each handoff carries batch_bytes / M
                        (the gradient handoff is charged the same).
    ``overlap_budget``  fraction of a tick's compute under which the
                        transfer can hide (instrument readiness of the
                        stage boundary; 1.0 = fully hideable).
    """
    m, s, v = max(1, n_micro), max(1, n_stage), max(1, virtual)
    cf = batch_fwd_s / m
    cb = PIPELINE_BWD_FLOP_RATIO * cf
    if schedule == "gpipe":
        ticks = 2 * (m + s - 1)
        compute = (m + s - 1) * (cf + cb)
    elif schedule == "1f1b":
        ticks = m + 2 * s - 1
        compute = m * (cf + cb) + (2 * s - 1) * cb
    elif schedule == "interleaved":
        ticks = m * v + v * s + s - 1
        compute = m * (cf + cb) + (v * s + s - 1) * cb / v
    else:
        raise ValueError(f"unknown pipeline schedule {schedule!r}")
    link = 2.0 * (batch_bytes / m) / hw.link_bw
    exposed = max(0.0, link - max(0.0, min(1.0, overlap_budget))
                  * compute / ticks)
    return ticks * (2.0 * hw.alpha_s + exposed) + compute, ticks


def decide_pipeline_schedule(n_stage: int, batch_fwd_s: float,
                             batch_bytes: float, *,
                             n_layers: int | None = None,
                             stash_cap_bytes: float | None = None,
                             candidate_micro: Sequence[int] = (4, 8, 16, 32),
                             candidate_virtual: Sequence[int] = (2,),
                             hw: HardwareModel = DEFAULT_HW,
                             overlap_budget: float = 1.0,
                             force_schedule: str | None = None,
                             force_micro: int | None = None,
                             force_virtual: int | None = None
                             ) -> PipelineScheduleDecision:
    """Pick (schedule, M, v) for one pipeline-parallel training loop.

    Candidates are dropped when their activation stash (slot count x
    batch_bytes/M per slot) overruns ``stash_cap_bytes`` — this is what
    retires GPipe, whose stash is the whole batch regardless of M — or,
    for interleaved, when M %% S != 0 or v*S exceeds ``n_layers``.  1f1b
    variants are exempt from the cap (smallest stash, the always-safe
    fallback).  ``force_*`` pin the choice (an MDMPConfig override, or
    the tuner's measured winner) while still reporting the modeled
    table."""
    s = max(1, n_stage)
    micros = sorted({int(c) for c in candidate_micro if c >= 1})
    if force_micro is not None:
        # an explicit M pins the microbatch count for EVERY schedule (the
        # CLI contract), not just when the schedule is forced too
        micros = [max(1, int(force_micro))]
    virtuals = sorted({int(c) for c in candidate_virtual if c >= 2})
    if force_virtual is not None and int(force_virtual) >= 2:
        virtuals = sorted({*virtuals, int(force_virtual)})

    def variants():
        for m in micros:
            yield "gpipe", m, 1
            yield "1f1b", m, 1
            for v in virtuals:
                if m % s:
                    continue
                if n_layers is not None and v * s > n_layers:
                    continue
                yield "interleaved", m, v

    times: dict[str, float] = {}
    for sched, m, v in variants():
        if stash_cap_bytes is not None and sched != "1f1b":
            stash = pipeline_stash_slots(sched, m, s, v) * batch_bytes / m
            if stash > stash_cap_bytes:
                continue
        t, _ = pipeline_schedule_time(
            sched, m, s, v, batch_fwd_s, batch_bytes, hw=hw,
            overlap_budget=overlap_budget)
        times[f"{sched}:{m}:{v}"] = t

    def pick(pred):
        cands = [(t, k) for k, t in times.items() if pred(k)]
        return min(cands) if cands else None

    bulk = pick(lambda k: k.startswith("gpipe:"))
    if bulk is None:        # every gpipe stash overran the cap
        bulk = pick(lambda k: True)
    if force_schedule is not None:
        assert force_schedule in ("gpipe", "1f1b", "interleaved"), \
            force_schedule
        sched = force_schedule
        m = int(force_micro) if force_micro is not None else None
        v = int(force_virtual) if force_virtual is not None else None
        key = pick(lambda k, sched=sched, m=m, v=v:
                   k.startswith(sched + ":")
                   and (m is None or k.split(":")[1] == str(m))
                   and (v is None or k.split(":")[2] == str(v)))
        if key is None:     # forced variant not in the surviving table
            mm = m if m is not None else min(micros)
            vv = v if v is not None else \
                (min(virtuals) if sched == "interleaved" and virtuals else 1)
            if sched == "interleaved":
                # fail at the decision layer, not deep inside
                # build_schedule, when the forced variant is invalid
                if mm % s:
                    raise ValueError(
                        f"interleaved needs n_micro % n_stage == 0 "
                        f"(got {mm} % {s})")
                if n_layers is not None and vv * s > n_layers:
                    raise ValueError(
                        f"interleaved needs virtual*n_stage <= n_layers "
                        f"(got {vv}*{s} > {n_layers})")
            t, _ = pipeline_schedule_time(
                sched, mm, s, vv, batch_fwd_s, batch_bytes, hw=hw,
                overlap_budget=overlap_budget)
            times[f"{sched}:{mm}:{vv}"] = t
            key = (t, f"{sched}:{mm}:{vv}")
        chosen = key
    else:
        chosen = pick(lambda k: True)
    assert chosen is not None
    sched, m_str, v_str = chosen[1].split(":")
    m, v = int(m_str), int(v_str)

    cf = batch_fwd_s / m
    cb = PIPELINE_BWD_FLOP_RATIO * cf
    busy = m * (cf + cb)
    if sched == "gpipe":
        crit = (m + s - 1) * (cf + cb)
    elif sched == "1f1b":
        crit = busy + (2 * s - 1) * cb
    else:
        crit = busy + (v * s + s - 1) * cb / v
    bubble = 0.0 if crit <= 0 else max(0.0, 1.0 - busy / crit)
    return PipelineScheduleDecision(
        schedule=sched, n_micro=m, virtual=v, times_s=times,
        bulk_s=bulk[0] if bulk else chosen[0], chosen_s=chosen[0],
        bubble_frac=bubble,
        stash_bytes=int(pipeline_stash_slots(sched, m, s, v)
                        * batch_bytes / m))


# ---------------------------------------------------------------------------
# Serve schedule decision (continuous batching + scheduling quantum)
# ---------------------------------------------------------------------------
#
# The serving runtime (repro/serve) advances every active slot by one token
# per engine step; the scheduler groups C steps into one dispatched quantum
# and only admits/retires requests at quantum boundaries.  The quantum is
# the serving analogue of the halo aggregation factor k: a bigger C
# amortises the per-dispatch overhead (the alpha of this decision) over
# more tokens, but coarsens scheduling — a slot whose request finishes
# mid-quantum idles until the boundary, and a queued request waits ~C/2
# steps for admission (TTFT).  Two batching modes share the quantum knob:
#
#   static      — admit a wave of B requests, run it to completion, admit
#                 the next wave (the unmanaged baseline, = the seed
#                 Generator).  Every request pads to the wave's longest
#                 (prompt + new) length: occupancy = mean_total/max_total.
#   continuous  — refill freed slots from the queue at every quantum
#                 boundary: occupancy ~= 1 - C/(2 * mean_total) (a
#                 completing request wastes C/2 slot-steps on average).
#
# Per-engine-step time is the decode roofline: every step streams the
# weights once from HBM and does 2*N flops per slot-token —
# max(P_bytes/hbm_bw, 2*N*B/peak).  The scheduler seeds C and the mode
# from this model and corrects both online from the measured step-latency
# counters (serve/metrics.py) — the paper's iteration-(k)->(k+1) loop.


#: default per-dispatch overhead (host scheduling + launch) used when no
#: measurement is available yet; on-model for a jitted multi-device launch
DISPATCH_OVERHEAD_S = 1.0e-4


@dataclasses.dataclass(frozen=True)
class ServeScheduleDecision:
    """Outcome of the serve-schedule decision for one serving call site."""
    mode: str                      # "static" | "continuous"
    chunk: int                     # scheduling quantum C (tokens/slot/call)
    tok_s: dict[str, float]        # "mode:C" -> modeled useful tokens/s
    static_tok_s: float            # best static variant
    chosen_tok_s: float
    step_s: float                  # per-engine-step seconds (whole batch)
    dispatch_s: float              # per-quantum dispatch overhead
    ttft_s: float                  # modeled mean TTFT at the chosen schedule

    @property
    def predicted_speedup(self) -> float:
        if self.chosen_tok_s <= 0:
            return 1.0
        return self.chosen_tok_s / max(self.static_tok_s, 1e-30)


def serve_step_time(n_params: float, batch_slots: int, *,
                    dtype_bytes: int = 2,
                    hw: HardwareModel = DEFAULT_HW) -> float:
    """Decode-step roofline: one token for each of ``batch_slots`` slots
    streams the weights once from HBM (memory-bound at small batch) against
    2*N flops per slot-token (compute-bound once the batch is large)."""
    mem = n_params * dtype_bytes / hw.hbm_bw
    flops = 2.0 * n_params * max(1, batch_slots) / hw.peak_flops
    return max(mem, flops)


def serve_schedule_times(n_params: float, batch_slots: int,
                         mean_prompt: float, mean_new: float, *,
                         max_prompt: float | None = None,
                         dtype_bytes: int = 2,
                         hw: HardwareModel = DEFAULT_HW,
                         dispatch_s: float = DISPATCH_OVERHEAD_S,
                         measured_step_s: float | None = None,
                         measured_dispatch_s: float | None = None,
                         candidate_chunks: Sequence[int] = (1, 2, 4, 8, 16,
                                                            32)
                         ) -> tuple[dict[str, float], float, float]:
    """(variant -> useful tokens/s, step_s, dispatch_s) for every
    "mode:C" candidate.  Measured overrides replace the modeled roofline
    terms (metrics.py feeds them back between quanta)."""
    b = max(1, batch_slots)
    step = measured_step_s if measured_step_s is not None else \
        serve_step_time(n_params, b, dtype_bytes=dtype_bytes, hw=hw)
    disp = measured_dispatch_s if measured_dispatch_s is not None \
        else dispatch_s
    mean_total = max(1.0, float(mean_prompt) + float(mean_new))
    max_total = max(mean_total,
                    float(max_prompt if max_prompt is not None
                          else mean_prompt) + float(mean_new))
    times: dict[str, float] = {}
    for c in sorted({int(c) for c in candidate_chunks if c >= 1}):
        quantum = disp + c * step
        # static: padding to the wave's longest request is the only waste
        occ_static = mean_total / max_total
        times[f"static:{c}"] = b * c * occ_static / quantum
        # continuous: a request completing mid-quantum idles its slot for
        # C/2 steps on average before the boundary refill
        occ_cont = max(0.0, 1.0 - 0.5 * c / mean_total)
        times[f"continuous:{c}"] = b * c * occ_cont / quantum
    return times, step, disp


def serve_ttft_s(chunk: int, mean_prompt: float, step_s: float,
                 dispatch_s: float) -> float:
    """Modeled TTFT for a request admitted from the queue: half a quantum
    of boundary wait plus the prompt steps (each quantum pays one
    dispatch)."""
    c = max(1, int(chunk))
    quanta = math.ceil(max(1.0, float(mean_prompt)) / c)
    return 0.5 * (dispatch_s + c * step_s) + quanta * dispatch_s \
        + float(mean_prompt) * step_s


def decide_serve_schedule(n_params: float, batch_slots: int,
                          mean_prompt: float, mean_new: float, *,
                          max_prompt: float | None = None,
                          dtype_bytes: int = 2,
                          hw: HardwareModel = DEFAULT_HW,
                          dispatch_s: float = DISPATCH_OVERHEAD_S,
                          measured_step_s: float | None = None,
                          measured_dispatch_s: float | None = None,
                          candidate_chunks: Sequence[int] = (1, 2, 4, 8, 16,
                                                             32),
                          ttft_budget_s: float | None = None,
                          force_mode: str | None = None,
                          force_chunk: int | None = None
                          ) -> ServeScheduleDecision:
    """Pick the batching mode and scheduling quantum for one serving call
    site.  ``force_mode``/``force_chunk`` pin the choice (an MDMPConfig
    bulk override, or the tuner's measured winner) while still reporting
    the modeled table; a ``ttft_budget_s`` drops continuous candidates
    whose modeled TTFT overruns it (the smallest candidate always
    survives)."""
    times, step, disp = serve_schedule_times(
        n_params, batch_slots, mean_prompt, mean_new,
        max_prompt=max_prompt, dtype_bytes=dtype_bytes, hw=hw,
        dispatch_s=dispatch_s, measured_step_s=measured_step_s,
        measured_dispatch_s=measured_dispatch_s,
        candidate_chunks=candidate_chunks)

    def ttft(c: int) -> float:
        return serve_ttft_s(c, mean_prompt, step, disp)

    chunks = sorted({int(v.split(":")[1]) for v in times})
    static_best = max((times[f"static:{c}"], c) for c in chunks)
    cont_ok = [c for c in chunks
               if ttft_budget_s is None or ttft(c) <= ttft_budget_s]
    if not cont_ok:
        cont_ok = [min(chunks)]
    cont_best = max((times[f"continuous:{c}"], c) for c in cont_ok)

    mode, chunk = (("continuous", cont_best[1])
                   if cont_best[0] > static_best[0]
                   else ("static", static_best[1]))
    if force_mode is not None:
        assert force_mode in ("static", "continuous"), force_mode
        mode = force_mode
        chunk = (cont_best if mode == "continuous" else static_best)[1]
    if force_chunk is not None:
        chunk = max(1, int(force_chunk))
        if f"{mode}:{chunk}" not in times:
            times[f"{mode}:{chunk}"] = serve_schedule_times(
                n_params, batch_slots, mean_prompt, mean_new,
                max_prompt=max_prompt, dtype_bytes=dtype_bytes, hw=hw,
                dispatch_s=dispatch_s, measured_step_s=measured_step_s,
                measured_dispatch_s=measured_dispatch_s,
                candidate_chunks=(chunk,))[0][f"{mode}:{chunk}"]
    return ServeScheduleDecision(
        mode=mode, chunk=chunk, tok_s=times,
        static_tok_s=static_best[0], chosen_tok_s=times[f"{mode}:{chunk}"],
        step_s=step, dispatch_s=disp, ttft_s=ttft(chunk))


# ---------------------------------------------------------------------------
# Preemption decision (swap vs drop-and-recompute vs head-of-line wait)
# ---------------------------------------------------------------------------
#
# Optimistic admission's backstop: when the page pool exhausts mid-decode
# the engine must free pages, and the central trade is pure data
# movement — exactly the kind of choice MDMP manages:
#
#   swap       — D2H the victim's page chain (row-sliced chunks metered
#                by overlap.drain_chunk_bytes so the transfer never
#                stalls the step stream past its budget), H2D it back on
#                re-admission.  Cost: 2 * KV bytes over the PCIe
#                bandwidth (measured from prior swaps when available)
#                plus per-chunk alpha.
#   recompute  — release the pages and rebuild the victim as a
#                prompt+generated continuation (the drain() idiom): the
#                KV is re-earned by prefill-replay FLOPs, 2*N per
#                replayed token.  No host memory, no transfer; wins for
#                small models / short progress, loses once the resident
#                KV is cheaper to move than to recompute.
#   wait       — evict nobody: stall the growing slot for a quantum and
#                let retirements free pages naturally.  Priced from the
#                instrumented queue statistics (the soonest-finishing
#                other slot's remaining steps at the measured step
#                time); infinite when every slot is stalled.
#
# The chosen policy lands in the decision trail as
# DecisionRecord(op="preempt_policy") via managed.resolve_preempt, is
# persisted by tuner.decide_preempt, and is re-resolved online from
# serve/metrics.py's measured step seconds and swap bandwidth.


#: default D2H/H2D bandwidth for KV swap traffic before any transfer has
#: been measured; on-model for a PCIe gen4 x16 host link
PCIE_BW = 1.6e10


@dataclasses.dataclass(frozen=True)
class PreemptDecision:
    """Outcome of the preemption-policy decision for one overload event."""
    policy: str                    # "swap" | "recompute" | "wait"
    victim_pages: int
    swap_bytes: int                # KV bytes resident in the victim chain
    chunk_bytes: int               # metered D2H slice size
    pcie_bw: float                 # bytes/s (measured or default)
    replay_tokens: int
    times: dict[str, float]        # policy -> predicted seconds
    recompute_s: float             # the unmanaged drop-everything baseline
    chosen_s: float

    @property
    def predicted_speedup(self) -> float:
        """Modeled gain over always-drop-and-recompute (the naive
        baseline a scheduler without a cost model would ship)."""
        return max(self.recompute_s, 1e-12) / max(self.chosen_s, 1e-12)


def decide_preempt(victim_pages: int, page_bytes: int,
                   replay_tokens: int, n_params: float, *,
                   step_s: float | None = None,
                   batch_slots: int = 1, dtype_bytes: int = 2,
                   pcie_bw: float | None = None,
                   chunk_bytes: int | None = None,
                   wait_s: float | None = None,
                   allow_swap: bool = True,
                   hw: HardwareModel = DEFAULT_HW,
                   force_policy: str | None = None) -> PreemptDecision:
    """Pick the preemption policy for one pool-exhaustion event.

    ``victim_pages``/``page_bytes`` size the swap transfer (both
    directions), ``replay_tokens`` the prefill-replay FLOPs, ``wait_s``
    the instrumented head-of-line estimate (None = nothing will free —
    waiting can't help).  ``chunk_bytes`` is the metered D2H slice
    (overlap.drain_chunk_bytes); when absent the same budget formula is
    applied to the step time.  ``allow_swap=False`` removes swap from
    the candidate set (slot-indexed SSM state isn't pageable).
    ``force_policy`` pins the choice (an MDMPConfig override or the
    tuner's measured winner) while still reporting the modeled table."""
    bw = float(pcie_bw) if pcie_bw else PCIE_BW
    step = (float(step_s) if step_s is not None else
            serve_step_time(n_params, batch_slots,
                            dtype_bytes=dtype_bytes, hw=hw))
    swap_bytes = int(victim_pages) * int(page_bytes)
    if chunk_bytes is None:
        # overlap.drain_chunk_bytes' budget formula, inlined to keep the
        # cost model import-cycle-free (budget=0.1 of one step)
        chunk_bytes = max(1 << 16, min(1 << 27, int(0.1 * step * bw)))
    chunk_bytes = max(1, int(chunk_bytes))
    n_chunks = max(1, math.ceil(max(1, swap_bytes) / chunk_bytes))
    times = {
        "swap": (2.0 * swap_bytes / bw + 2.0 * n_chunks * hw.alpha_s
                 if allow_swap else math.inf),
        "recompute": 2.0 * max(0, replay_tokens) * max(n_params, 1.0)
        / hw.peak_flops,
        "wait": float(wait_s) if wait_s is not None else math.inf,
    }
    recompute_s = times["recompute"]
    if force_policy is not None:
        assert force_policy in times, force_policy
        policy = force_policy
    else:
        policy = min(times, key=lambda p: (times[p], p))
    chosen = times[policy]
    if not math.isfinite(chosen):
        # a pinned-but-impossible policy (swap on SSM state, wait with
        # nothing retiring) degrades to the always-possible rebuild
        policy, chosen = "recompute", recompute_s
    return PreemptDecision(
        policy=policy, victim_pages=int(victim_pages),
        swap_bytes=swap_bytes, chunk_bytes=chunk_bytes, pcie_bw=bw,
        replay_tokens=int(replay_tokens), times=times,
        recompute_s=recompute_s, chosen_s=chosen)


# ---------------------------------------------------------------------------
# MoE dispatch decision (bulk a2a vs chunked-stream vs dense-fallback,
# plus the capacity factor itself)
# ---------------------------------------------------------------------------
#
# MoE token routing is the most data-dependent communication in the
# codebase: per-rank dispatch bytes are E*C*D*B with C = ceil(t*K*cf/E)
# decided by a static capacity-factor guess, while the REAL traffic is the
# router's runtime histogram.  Three schedules share the knob:
#
#   bulk    — one all_to_all of the [E, C, D] capacity buffers each way
#             around the expert FFN (the unmanaged baseline).  Comm
#             2 x a2a(E*C*D*B); compute the kept rows (the grouped GEMM
#             skips padding; occupancy = kept/(E*C) ~= (1-drop)/cf).
#   stream  — the capacity buffers split into g chunks per ring block and
#             ppermute'd around the EP axis, each chunk's transfer issued
#             before the previous chunk's expert FFN (the paper's
#             intermingling at dispatch granularity).  Same bytes, wire
#             hidden under compute: classic software-pipeline bound over
#             (n-1)*g stages, 2 messages (fwd block + result return) per
#             stage.
#   dense   — no dispatch at all: all-gather the t*D tokens, every rank
#             runs its LOCAL experts on the full token set gate-masked,
#             reduce-scatter the outputs.  Comm ~ t*D bytes; compute
#             E_loc * (n*t) = E*t rows.  Wins when the a2a bytes
#             (~K*cf*t*D each way, padding included) dwarf the token
#             bytes and the engine cannot skip padding — and it never
#             drops a token (capacity-free).
#
# The capacity factor is managed the same way: with no measurement the
# declared cf stands; once instrument.capture_routing reports the realised
# imbalance (max/mean expert load) the decision re-picks the smallest
# candidate cf covering it — drop-free capacity for skewed routing,
# shrunk buffers for uniform routing (the paper's iteration-(k)->(k+1)
# adaptation applied to buffer sizing).


@dataclasses.dataclass(frozen=True)
class MoEDispatchDecision:
    """Outcome of the three-way MoE dispatch decision for one call site."""
    schedule: str                  # "bulk" | "stream" | "dense"
    g: int                         # stream chunks per ring block (1 else)
    capacity_factor: float         # chosen cf (declared or re-resolved)
    capacity: int                  # C = ceil(t * K * cf / E)
    times_s: dict[str, float]      # "schedule:g" -> predicted seconds/layer
    bulk_s: float
    chosen_s: float
    comm_s: float                  # comm term of the chosen schedule
    compute_s: float               # expert-FFN term of the chosen schedule
    drop_frac: float               # modeled residual drop rate at chosen cf
    a2a_bytes: int                 # per-direction capacity-buffer bytes
    dense_bytes: int               # per-rank token bytes of the fallback

    @property
    def predicted_speedup(self) -> float:
        if self.chosen_s <= 0:
            return 1.0
        return self.bulk_s / self.chosen_s


def moe_capacity(tokens_local: int, top_k: int, n_experts: int,
                 capacity_factor: float) -> int:
    """ceil-rounded per-expert capacity (matches moe.dispatch.capacity_for)."""
    return max(1, math.ceil(tokens_local * top_k * capacity_factor
                            / n_experts))


def _moe_terms(tokens_local: int, d_model: int, n_experts: int,
               top_k: int, d_ff_expert: int, n: int, mults: int,
               dtype_bytes: int, capacity_factor: float, layout: str,
               hw: HardwareModel) -> tuple[int, float, float, float]:
    """(capacity C, per-row FFN flops, capacity-path comm seconds, dense
    FFN seconds) of one layout.

    ep_a2a     experts sharded by id: dispatch = 2 x a2a of the [E, C, D]
               capacity buffers (C from LOCAL tokens); each kept row
               costs the full-F expert FFN; dense = AG(t*D) + every rank
               runs its E/n experts on all n*t tokens + RS(t*D).
    expert_tp  every expert ff-sharded: the wire is the sequence AG/RS
               (identical for every schedule — dispatch is local on the
               gathered tokens, C from the FULL token set); each row
               costs F/n; dense runs all E experts at F/n on all rows.
    """
    if layout == "expert_tp":
        cap = moe_capacity(tokens_local * n, top_k, n_experts,
                           capacity_factor)
        flops_row = 2.0 * mults * d_model * d_ff_expert / n
        x_bytes = tokens_local * d_model * dtype_bytes
        comm = (ring_all_gather_time(x_bytes, n, hw)
                + ring_reduce_scatter_time(n * x_bytes, n, hw))
        dense_ffn = (n_experts * tokens_local * n * flops_row
                     / hw.peak_flops)
    else:  # ep_a2a
        cap = moe_capacity(tokens_local, top_k, n_experts,
                           capacity_factor)
        flops_row = 2.0 * mults * d_model * d_ff_expert
        a2a_bytes = n_experts * cap * d_model * dtype_bytes
        comm = 2.0 * all_to_all_time(a2a_bytes, n, hw)
        dense_ffn = n_experts * tokens_local * flops_row / hw.peak_flops
    return cap, flops_row, comm, dense_ffn


def moe_dispatch_times(tokens_local: int, d_model: int, n_experts: int,
                       top_k: int, d_ff_expert: int, axis_size: int, *,
                       mults: int = 3, dtype_bytes: int = 2,
                       capacity_factor: float = 1.25,
                       occupancy: float | None = None,
                       hw: HardwareModel = DEFAULT_HW,
                       candidate_g: Sequence[int] = (2, 4, 8),
                       layout: str = "ep_a2a") -> dict[str, float]:
    """Predicted seconds per MoE layer for every "schedule:g" candidate
    (dispatch comm on the critical path + expert-FFN flops; router and
    combine flops are shared and excluded).  Stream candidates are
    restricted to g dividing the layout's chunk unit — the capacity C
    for ep_a2a, the per-rank sequence rows for expert_tp (whose "stream"
    chunks the AG/RS rings) — because the executors degrade a
    non-dividing g to 1, and pricing it would corrupt the tuner loop
    (same contract as the pipeline M-divisor filter)."""
    n = max(1, axis_size)
    cap, flops_row, comm, dense_ffn = _moe_terms(
        tokens_local, d_model, n_experts, top_k, d_ff_expert, n, mults,
        dtype_bytes, capacity_factor, layout, hw)
    unit = tokens_local if layout == "expert_tp" else cap
    occ = (min(1.0, 1.0 / max(capacity_factor, 1e-6))
           if occupancy is None else max(0.0, min(1.0, occupancy)))
    ffn_s = n_experts * cap * occ * flops_row / hw.peak_flops

    times: dict[str, float] = {}
    times["bulk:1"] = comm + ffn_s
    if n > 1:
        # the wire the stream can hide: everything but the per-hop alphas
        wire = max(0.0, comm - 2.0 * (n - 1) * hw.alpha_s)
        for g in sorted({int(g) for g in candidate_g
                         if g >= 1 and unit % g == 0}):
            stages = (n - 1) * g
            times[f"stream:{g}"] = _pipeline_time(
                wire, ffn_s, stages, hw.alpha_s, per_stage_msgs=2)
    if layout == "expert_tp":
        times["dense:1"] = comm + dense_ffn
    else:
        dense_bytes = tokens_local * d_model * dtype_bytes
        dense_comm = (ring_all_gather_time(dense_bytes, n, hw)
                      + ring_reduce_scatter_time(n * dense_bytes, n, hw))
        times["dense:1"] = dense_comm + dense_ffn
    return times


def decide_moe_dispatch(tokens_local: int, d_model: int, n_experts: int,
                        top_k: int, d_ff_expert: int, axis_size: int, *,
                        mults: int = 3, dtype_bytes: int = 2,
                        capacity_factor: float = 1.25,
                        candidate_cf: Sequence[float] = (1.0, 1.25, 1.5,
                                                         2.0, 4.0, 8.0),
                        candidate_g: Sequence[int] = (2, 4, 8),
                        measured_imbalance: float | None = None,
                        measured_drop_rate: float | None = None,
                        measured_occupancy: float | None = None,
                        hw: HardwareModel = DEFAULT_HW,
                        layout: str = "ep_a2a",
                        force_schedule: str | None = None,
                        force_g: int | None = None,
                        force_capacity_factor: float | None = None
                        ) -> MoEDispatchDecision:
    """Pick (schedule, g, capacity_factor) for one MoE dispatch call site.

    With no routing measurement the DECLARED capacity factor stands (the
    paper-faithful static guess).  A ``measured_imbalance`` from
    ``instrument.capture_routing`` re-picks the smallest candidate cf
    covering the hottest expert (cf >= imbalance is drop-free); a bare
    ``measured_drop_rate`` > 0 escalates to the next candidate above the
    declared cf.  The dense schedule is capacity-free and ignores cf.
    ``force_*`` pin choices (an MDMPConfig override, or the tuner's
    measured winner) while still reporting the modeled table."""
    cands = sorted({float(c) for c in candidate_cf if c > 0}
                   | {float(capacity_factor)})
    if force_capacity_factor is not None:
        cf = float(force_capacity_factor)
    elif measured_imbalance is not None:
        need = max(1.0, float(measured_imbalance))
        covering = [c for c in cands if c >= need]
        cf = covering[0] if covering else cands[-1]
    elif measured_drop_rate is not None and measured_drop_rate > 0:
        above = [c for c in cands if c > float(capacity_factor)]
        cf = above[0] if above else cands[-1]
    else:
        cf = float(capacity_factor)
    if measured_imbalance is not None:
        # hottest expert holds imbalance x the mean load; capacity covers
        # cf x the mean — the overhang is the modeled residual drop
        drop = max(0.0, 1.0 - cf / max(1.0, float(measured_imbalance)))
    elif measured_drop_rate and cf == float(capacity_factor):
        drop = float(measured_drop_rate)
    else:
        drop = 0.0
    occ = measured_occupancy
    if occ is None:
        occ = min(1.0, (1.0 - drop) / max(cf, 1e-6))

    times = moe_dispatch_times(
        tokens_local, d_model, n_experts, top_k, d_ff_expert, axis_size,
        mults=mults, dtype_bytes=dtype_bytes, capacity_factor=cf,
        occupancy=occ, hw=hw, candidate_g=candidate_g, layout=layout)
    n = max(1, axis_size)
    cap, flops_row, _, dense_ffn = _moe_terms(
        tokens_local, d_model, n_experts, top_k, d_ff_expert, n, mults,
        dtype_bytes, cf, layout, hw)

    unit = tokens_local if layout == "expert_tp" else cap

    def clamp_g(gg: int) -> int:
        # the executors degrade a non-dividing g to 1; clamp to the
        # nearest divisor of the layout's chunk unit so the logged g is
        # the EXECUTED g
        gg = max(1, int(gg))
        while gg > 1 and unit % gg:
            gg -= 1
        return gg

    def best_stream_g() -> int:
        # no g requested: the cost model's pick among surviving stream
        # candidates (MoEConfig's 'dispatch_g: 0 = cost-model pick')
        cands = [(t, int(k.split(":")[1])) for k, t in times.items()
                 if k.startswith("stream:")]
        return min(cands)[1] if cands else clamp_g(2)

    if force_schedule is not None:
        assert force_schedule in ("bulk", "stream", "dense"), force_schedule
        if force_schedule == "stream":
            gg = clamp_g(force_g) if force_g else best_stream_g()
        else:
            gg = 1
        key = f"{force_schedule}:{gg}"
        if key not in times:
            times[key] = moe_dispatch_times(
                tokens_local, d_model, n_experts, top_k, d_ff_expert,
                axis_size, mults=mults, dtype_bytes=dtype_bytes,
                capacity_factor=cf, occupancy=occ, hw=hw,
                candidate_g=(gg,), layout=layout).get(key,
                                                      times["bulk:1"])
        chosen = key
    elif force_g is not None and f"stream:{clamp_g(force_g)}" in times:
        chosen = f"stream:{clamp_g(force_g)}"
    else:
        chosen = min(times, key=lambda k: (times[k], k))
    sched, g_str = chosen.split(":")
    g = int(g_str)

    if sched == "dense":
        compute_s = dense_ffn
        drop = 0.0                      # capacity-free: nothing to drop
    else:
        compute_s = n_experts * cap * occ * flops_row / hw.peak_flops
    return MoEDispatchDecision(
        schedule=sched, g=g, capacity_factor=cf, capacity=cap,
        times_s=times, bulk_s=times["bulk:1"], chosen_s=times[chosen],
        comm_s=max(0.0, times[chosen] - compute_s), compute_s=compute_s,
        drop_frac=drop,
        a2a_bytes=n_experts * cap * d_model * dtype_bytes,
        dense_bytes=tokens_local * d_model * dtype_bytes)


# ---------------------------------------------------------------------------
# Checkpoint cadence decision (the Young/Daly optimum as a managed knob)
# ---------------------------------------------------------------------------
#
# Recovery traffic deserves the same alpha-beta treatment as the forward
# collectives: a checkpoint costs δ seconds (on-device snapshot block +
# the metered D2H drain; the disk write rides the writer thread), and a
# failure with MTBF M loses on average half an interval of work plus the
# restore.  First-order expected overhead per useful second at interval
# τ seconds:
#
#     overhead(τ) = δ/τ + (τ/2 + R)/M            (Daly 2006, first order)
#
# minimised at the Young/Daly optimum τ* = sqrt(2 δ M).  Goodput — useful
# steps per wall second including recovery — is step_s/(1+overhead).  The
# decision quantises τ* to a candidate step interval N (checkpoints only
# land on step boundaries), prices the whole candidate table, and reports
# the fixed-cadence baseline (ckpt_every=25) for the speedup column.
# Measured δ and write bandwidth come from checkpoint/metrics.py; the
# step time is the train loop's EWMA — iteration k prices iteration k+1.


#: default end-to-end checkpoint write bandwidth (D2H + serialisation)
#: used before the first measured save; on-model for a host NVMe path
CKPT_WRITE_BW = 2.0e9

#: the unmanaged fixed cadence every prior PR shipped (TrainLoopConfig)
CKPT_FIXED_INTERVAL = 25


@dataclasses.dataclass(frozen=True)
class CheckpointDecision:
    """Outcome of the checkpoint-cadence decision for one train loop."""
    mode: str                      # "daly" | "fixed"
    interval: int                  # chosen steps between checkpoints
    step_s: float                  # instrumented step seconds (EWMA)
    ckpt_cost_s: float             # δ — per-checkpoint critical-path cost
    snapshot_bytes: int
    write_bw: float                # bytes/s (measured or default)
    mtbf_s: float
    restore_s: float
    daly_interval_s: float         # continuous τ* = sqrt(2 δ M)
    overhead: dict[int, float]     # candidate N -> expected overhead frac
    fixed_overhead: float          # overhead at CKPT_FIXED_INTERVAL
    chosen_overhead: float

    @property
    def predicted_speedup(self) -> float:
        """Modeled goodput gain over the fixed cadence."""
        return (1.0 + self.fixed_overhead) / (1.0 + self.chosen_overhead)


def checkpoint_overhead(interval_steps: int, step_s: float,
                        ckpt_cost_s: float, mtbf_s: float,
                        restore_s: float) -> float:
    """Expected overhead fraction (non-useful seconds per useful second)
    of checkpointing every ``interval_steps`` steps under MTBF failures."""
    tau = max(1, int(interval_steps)) * max(step_s, 1e-12)
    return (ckpt_cost_s / tau
            + (0.5 * tau + restore_s) / max(mtbf_s, 1e-12))


def decide_checkpoint(step_s: float, snapshot_bytes: int, *,
                      mtbf_s: float = 1800.0,
                      write_bw: float | None = None,
                      ckpt_cost_s: float | None = None,
                      restore_s: float | None = None,
                      candidate_intervals: Sequence[int] = (2, 4, 5, 8, 10,
                                                            20, 25, 50, 100,
                                                            200),
                      hw: HardwareModel = DEFAULT_HW,
                      force_interval: int | None = None
                      ) -> CheckpointDecision:
    """Pick the checkpoint interval (steps) for one train loop.

    δ defaults to ``snapshot_bytes / write_bw`` (the drain at the write
    bandwidth; the snapshot block is a same-order HBM copy folded into
    the bandwidth term) and is overridden by a measured ``ckpt_cost_s``
    from checkpoint/metrics.py.  ``force_interval`` pins the choice (an
    MDMPConfig bulk override = the fixed baseline, or an explicit
    ``--ckpt-every``) while still reporting the modeled table."""
    bw = float(write_bw) if write_bw else CKPT_WRITE_BW
    delta = (float(ckpt_cost_s) if ckpt_cost_s is not None
             else snapshot_bytes / bw)
    delta = max(delta, 1e-9)
    rest = (float(restore_s) if restore_s is not None
            else snapshot_bytes / bw)
    step = max(float(step_s), 1e-9)
    tau_star = math.sqrt(2.0 * delta * max(mtbf_s, 1e-9))

    cands = sorted({int(n) for n in candidate_intervals if n >= 1}
                   | {CKPT_FIXED_INTERVAL})
    overhead = {n: checkpoint_overhead(n, step, delta, mtbf_s, rest)
                for n in cands}
    fixed_ov = overhead[CKPT_FIXED_INTERVAL]
    if force_interval is not None:
        interval = max(1, int(force_interval))
        mode = "fixed"
        if interval not in overhead:
            overhead[interval] = checkpoint_overhead(interval, step, delta,
                                                     mtbf_s, rest)
    else:
        interval = min(cands, key=lambda n: (overhead[n], n))
        mode = "daly"
    return CheckpointDecision(
        mode=mode, interval=interval, step_s=step, ckpt_cost_s=delta,
        snapshot_bytes=int(snapshot_bytes), write_bw=bw, mtbf_s=mtbf_s,
        restore_s=rest, daly_interval_s=tau_star, overhead=overhead,
        fixed_overhead=fixed_ov, chosen_overhead=overhead[interval])


# ---------------------------------------------------------------------------
# Roofline terms (used by benchmarks/roofline.py on dry-run artifacts)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             n_chips: int, hw: HardwareModel = DEFAULT_HW) -> RooflineTerms:
    """The three-term roofline from the spec.  ``hlo_flops``/``hlo_bytes``
    are whole-program totals from cost_analysis (already per-device in XLA's
    accounting when lowered SPMD); ``collective_bytes`` is the summed operand
    bytes of collective ops in the compiled module (per device)."""
    return RooflineTerms(
        compute_s=hlo_flops / (n_chips * hw.peak_flops),
        memory_s=hlo_bytes / (n_chips * hw.hbm_bw),
        collective_s=collective_bytes / (n_chips * hw.link_bw),
    )


# ---------------------------------------------------------------------------
# Joint-plan components (used by plan/planner.py — the MDMP compiler)
# ---------------------------------------------------------------------------
#
# The per-subsystem decide_* functions above price each knob ALONE on the
# link with a private overlap budget.  The whole-program planner instead
# needs each knob candidate decomposed into the terms it must pool across
# ops sharing a mesh axis: the bytes-on-link time (serialised within a
# contention set), the message count (alpha each, never hidden), the
# adjacent compute an interleaved schedule can hide the wire under (one
# account per contention set — compute hides the link once, not once per
# op), and the buffer footprint drawn from the pooled stash cap.


@dataclasses.dataclass(frozen=True)
class CommComponents:
    """Wire/message/hide decomposition of one knob candidate."""
    wire_s: float          # bytes-on-link seconds (no alphas)
    msgs: int              # message count (alpha_s each)
    hide_s: float          # compute available to hide wire_s (0 for bulk)
    stash_bytes: int = 0   # buffer footprint against the pooled cap

    def solo_s(self, alpha: float) -> float:
        """The LOCAL model of this knob: alone on the link, private hide
        budget — what per-subsystem resolution implicitly assumes."""
        return max(0.0, self.wire_s - self.hide_s) + alpha * self.msgs


def collective_wire_s(collective: str, nbytes: float, n: int,
                      hw: HardwareModel = DEFAULT_HW) -> float:
    """Bytes-on-link seconds of one ring collective — the alpha-free term
    of the ring_*_time primitives above (AG: shard bytes in; RS/A2A: full/
    local bytes in; AR = RS + AG of the shard)."""
    if n <= 1:
        return 0.0
    if collective == "all_gather":
        return (n - 1) * nbytes / hw.link_bw
    if collective in ("reduce_scatter", "all_to_all"):
        return (n - 1) * (nbytes / n) / hw.link_bw
    if collective == "all_reduce":
        return 2.0 * (n - 1) * (nbytes / n) / hw.link_bw
    raise ValueError(f"unknown collective {collective!r}")


def collective_msgs(collective: str, n: int, *, mode: str = "bulk",
                    chunks: int = 1) -> int:
    """Message (dispatch) count of one collective knob.  A BULK collective
    is ONE fused op (the XLA all_gather / psum / all_to_all the managed
    runtime falls through to — one dispatch regardless of n); the
    interleaved ring issues one ppermute per step, ``(n-1) * chunks`` of
    them (doubled for all_reduce's RS+AG rings).  This asymmetry is the
    planner's lever: streaming buys overlap at per-message cost, bulk
    minimises messages — the paper's aggregation counter-knob."""
    if n <= 1:
        return 0
    if mode != "interleaved":
        return 1
    steps = (n - 1) * max(1, chunks)
    return 2 * steps if collective == "all_reduce" else steps


def collective_components(collective: str, nbytes: float, n: int, *,
                          mode: str = "bulk", chunks: int = 1,
                          compute_time_s: float = 0.0,
                          hw: HardwareModel = DEFAULT_HW) -> CommComponents:
    """CommComponents of one generic managed-collective knob candidate."""
    return CommComponents(
        wire_s=collective_wire_s(collective, nbytes, n, hw),
        msgs=collective_msgs(collective, n, mode=mode, chunks=chunks),
        hide_s=compute_time_s if mode == "interleaved" else 0.0)
