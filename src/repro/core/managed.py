"""Managed collectives — MDMP's send/recv directives, TPU-native.

The paper lets the user *declare* communication (``#pragma send/recv``) and
has the runtime decide when/how to execute it, intermingling messages with
the computation that produces or consumes the data.  The JAX/TPU analogue
implemented here: every collective a model needs is expressed through a
``managed_*`` entry point that can execute in two modes:

  * ``bulk``         — exactly the unmanaged ``jax.lax`` collective.  This is
                       the paper-faithful "MDMP disabled at compile time"
                       path and the numerical oracle for every test.
  * ``interleaved``  — a chunked ``lax.ppermute`` ring schedule in which each
                       ring step's DMA overlaps the adjacent step's compute
                       (for the fused *_matmul variants the compute is fused
                       into the ring, which is the paper's "send each piece
                       as soon as its last write occurs" at tile granularity).
  * ``auto``         — the manager decides per call site using the alpha-beta
                       cost model (and shape-derived compute estimates), and
                       logs the decision (the paper's managed-runtime role).

All functions must be called inside ``shard_map`` (they use collective axis
names).  Interleaved outputs are numerically identical to bulk outputs up to
floating-point reduction order; tests assert allclose against bulk.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cost_model
from repro.core.cost_model import HardwareModel, TPU_V5E
from repro.obs.tracer import dispatch_span

Array = jax.Array


# ---------------------------------------------------------------------------
# Global MDMP configuration + decision log (the managed-runtime audit trail)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MDMPConfig:
    """Process-wide MDMP behaviour.  ``mode='auto'`` lets the cost model pick
    per call site; forcing ``bulk`` reproduces the unmanaged baseline,
    forcing ``interleaved`` reproduces the paper's always-intermingle mode.
    """
    mode: str = "auto"                # auto | bulk | interleaved
    chunks: int | None = None         # override ring sub-chunking
    hw: HardwareModel = TPU_V5E
    log_decisions: bool = True
    # quantized FSDP weight gathering (fp8 payload, bf16 master weights,
    # exact-dtype gradient reduce-scatter) — §Perf round 3
    fsdp_gather_dtype: str | None = None


_STATE = threading.local()


def get_config() -> MDMPConfig:
    cfg = getattr(_STATE, "config", None)
    if cfg is None:
        cfg = MDMPConfig()
        _STATE.config = cfg
    return cfg


class use_config:
    """``with mdmp.use_config(MDMPConfig(mode='bulk')): ...``"""

    def __init__(self, config: MDMPConfig):
        self._new = config

    def __enter__(self) -> MDMPConfig:
        self._old = getattr(_STATE, "config", None)
        _STATE.config = self._new
        return self._new

    def __exit__(self, *exc: Any) -> None:
        _STATE.config = self._old


@dataclasses.dataclass(frozen=True)
class DecisionRecord:
    op: str
    axis: str
    nbytes: int
    mode: str
    chunks: int
    predicted_bulk_s: float
    predicted_interleaved_s: float
    #: monotonic log time (``time.perf_counter``), stamped by
    #: ``log_decision`` — the join key that places the decision instant
    #: on the trace timeline next to its measured spans.  Excluded from
    #: equality so decision-trail comparisons stay timestamp-free.
    t: float | None = dataclasses.field(default=None, compare=False)


#: Every DecisionRecord ``op`` the managed runtime may emit — ONE registry
#: so the program planner, the trail printers, and the CI greps can
#: enumerate them instead of guessing free strings.  Subsystem resolvers
#: first, then the generic managed-collective call sites, then the joint
#: planner's summary record.
DECISION_OPS = frozenset({
    # subsystem resolvers (resolve_*)
    "halo_aggregation", "attention_schedule", "pipeline_schedule",
    "serve_schedule", "preempt_policy", "ckpt_interval", "moe_dispatch",
    # generic managed collectives (_resolve call sites)
    "all_gather", "reduce_scatter", "all_reduce", "all_to_all",
    "all_gather_matmul", "all_gather_matmul_multi", "gram_ag_ring",
    "matmul_reduce_scatter", "ring_attention", "expert_stream",
    # the whole-program planner (plan/planner.py) summary record
    "program_plan",
    # the static verifier's preflight audit record (repro.analysis):
    # axis carries the linted graph name, chunks the diagnostic count,
    # nbytes the error count — suppressed warnings land in the trail
    "lint",
})

_DECISION_LOG: list[DecisionRecord] = []


def log_decision(rec: DecisionRecord) -> None:
    """Append to the audit trail, enforcing the op-name registry (a typo'd
    op would silently escape every trail grep and the planner's lowering)."""
    assert rec.op in DECISION_OPS, (
        f"unregistered DecisionRecord op {rec.op!r}; add it to "
        f"managed.DECISION_OPS")
    if rec.t is None:
        # stamp log time here (not in the resolver) so every emission
        # site gets trace-timeline placement for free
        object.__setattr__(rec, "t", time.perf_counter())
    _DECISION_LOG.append(rec)


def decision_log() -> list[DecisionRecord]:
    return list(_DECISION_LOG)


def clear_decision_log() -> None:
    _DECISION_LOG.clear()


class capture_decisions:
    """``with managed.capture_decisions() as cap: ...`` — scoped view of
    the decisions logged inside the block, WITHOUT clearing or copying
    the global trail (``_DECISION_LOG`` only ever grows; tests and the
    trace exporter need "the records of THIS run", not "all records
    since import").  ``cap.records`` is live: it re-slices the trail on
    every access, so it is valid both inside the block and after exit
    (where it is pinned to the block's extent)."""

    def __init__(self) -> None:
        self._start = 0
        self._end: int | None = None

    def __enter__(self) -> "capture_decisions":
        self._start = len(_DECISION_LOG)
        self._end = None
        return self

    def __exit__(self, *exc: Any) -> None:
        self._end = len(_DECISION_LOG)

    @property
    def records(self) -> list[DecisionRecord]:
        return list(_DECISION_LOG[self._start:self._end])


# ---------------------------------------------------------------------------
# Program-plan override (the MDMP compiler's hook into every resolver)
# ---------------------------------------------------------------------------
#
# plan/planner.py emits a ProgramPlan whose knobs must win over each
# subsystem's LOCAL resolution.  The plan is installed on the same
# thread-local as MDMPConfig and consulted by every resolve_* entry point
# and by _resolve for the generic collectives.  Precedence, most-binding
# first: explicit caller knob (schedule=/k=/n_micro=/chunks=...) >
# program-plan knob > ambient mode (cfg.mode / ctx.mdmp_mode "auto") >
# cost-model auto.  The plan object is duck-typed (anything with
# ``knob_for(op, axis) -> dict | None``) so this module never imports
# plan/ (no import cycle).


def install_plan(plan: Any | None) -> None:
    """Install (or clear, with None) the active ProgramPlan for this
    thread.  Planner-chosen knobs win over local resolution wherever the
    caller did not pin an explicit knob."""
    _STATE.plan = plan


def active_plan() -> Any | None:
    return getattr(_STATE, "plan", None)


class use_plan:
    """``with managed.use_plan(program_plan): ...`` — scoped install."""

    def __init__(self, plan: Any | None):
        self._new = plan

    def __enter__(self) -> Any | None:
        self._old = getattr(_STATE, "plan", None)
        _STATE.plan = self._new
        return self._new

    def __exit__(self, *exc: Any) -> None:
        _STATE.plan = self._old


def _plan_knob(op: str, axis_name: str) -> dict | None:
    """The active plan's knob for (op, axis), or None when no plan is
    installed / the plan has no opinion on this call site."""
    plan = active_plan()
    if plan is None:
        return None
    return plan.knob_for(op, axis_name)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _axis_size(axis_name: str) -> int:
    return lax.psum(1, axis_name)


def _nbytes(x: Array) -> int:
    return int(x.size * x.dtype.itemsize)


def _resolve(op: str, axis_name: str, x: Array, mode: str | None,
             chunks: int | None, collective: str,
             compute_time_s: float = 0.0) -> tuple[str, int]:
    """Resolve mode/chunks for a call site and log the decision."""
    cfg = get_config()
    pk = _plan_knob(op, axis_name)
    if pk is not None and mode in (None, "auto") and chunks is None:
        # the program plan binds this call site; an explicit caller
        # mode/chunks would have pinned the knob above it
        mode = pk.get("mode") or mode
        chunks = pk.get("chunks")
    mode = mode or cfg.mode
    n = _axis_size(axis_name)
    decision = cost_model.decide(
        _nbytes(x), n, compute_time_s=compute_time_s, hw=cfg.hw,
        collective=collective,
        force_mode=None if mode == "auto" else mode)
    eff_chunks = chunks if chunks is not None else (
        cfg.chunks if cfg.chunks is not None else decision.chunks)
    eff_mode = decision.mode if mode == "auto" else mode
    if cfg.log_decisions:
        log_decision(DecisionRecord(
            op=op, axis=axis_name, nbytes=_nbytes(x), mode=eff_mode,
            chunks=eff_chunks,
            predicted_bulk_s=decision.bulk_time_s,
            predicted_interleaved_s=decision.interleaved_time_s))
    return eff_mode, max(1, int(eff_chunks))


def resolve_halo_aggregation(axis_name: str, axis_size: int,
                             rows_local: int, cols: int, *,
                             dtype_bytes: int = 4,
                             candidate_k: Sequence[int] = (1, 2, 4, 8),
                             mode: str | None = None,
                             k: int | None = None
                             ) -> cost_model.HaloAggregationDecision:
    """The managed-runtime entry for the aggregation knob: pick how many
    stencil sweeps each halo exchange should carry (k=1 = bulk) and log the
    decision.  Called OUTSIDE shard_map at planning time — ``axis_size`` is
    the static mesh extent, and the chosen k feeds
    ``halo.jacobi_solve(mode="aggregated", k=...)``.

    ``mode="bulk"`` (or a global MDMPConfig forcing bulk) pins k=1 — the
    paper-faithful unmanaged baseline; ``k`` pins an explicit sweep count
    (the tuner's measured override).  The DecisionRecord reuses ``chunks``
    to carry k and the predicted fields to carry seconds-per-sweep.
    """
    cfg = get_config()
    pk_plan = _plan_knob("halo_aggregation", axis_name)
    if pk_plan is not None and mode in (None, "auto") and k is None:
        k = pk_plan.get("chunks")
    eff_mode = mode or cfg.mode
    force_k = 1 if eff_mode == "bulk" else k
    decision = cost_model.decide_halo_aggregation(
        rows_local, cols, axis_size, dtype_bytes=dtype_bytes, hw=cfg.hw,
        candidate_k=candidate_k, force_k=force_k)
    if cfg.log_decisions:
        log_decision(DecisionRecord(
            op="halo_aggregation", axis=axis_name,
            nbytes=2 * decision.k * cols * dtype_bytes,
            mode=decision.mode, chunks=decision.k,
            predicted_bulk_s=decision.bulk_sweep_s,
            predicted_interleaved_s=decision.aggregated_sweep_s))
    return decision


def _ring_perm(n: int, shift: int = 1) -> list[tuple[int, int]]:
    return [(i, (i + shift) % n) for i in range(n)]


def _split(x: Array, chunks: int, axis: int = 0) -> list[Array]:
    if chunks <= 1 or x.shape[axis] % chunks != 0:
        return [x]
    return list(jnp.split(x, chunks, axis=axis))


def _ppermute_chunked(x: Array, axis_name: str, perm, chunks: int) -> Array:
    """One ring step as ``chunks`` independent collective-permutes (the
    finer-grained messages of the paper; XLA may overlap them)."""
    pieces = _split(x, chunks)
    moved = [lax.ppermute(p, axis_name, perm) for p in pieces]
    return moved[0] if len(moved) == 1 else jnp.concatenate(moved, axis=0)


# ---------------------------------------------------------------------------
# managed_all_gather
#
# Every managed collective carries a custom VJP implementing its exact
# mathematical dual as another managed collective (AG <-> RS, AR <-> AR,
# A2A <-> reverse A2A, AG-matmul <-> matmul-RS...).  This matters twice:
#  (1) memory — differentiating through the ring fori_loops would save the
#      per-step carries (O(ring_steps x operand) residuals per call);
#  (2) schedule — the backward pass stays an MDMP-interleaved ring instead
#      of whatever the loop transpose produces.
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def managed_all_gather(x: Array, axis_name: str, mode: str | None = None,
                       chunks: int | None = None) -> Array:
    """All-gather ``x`` (tiled along axis 0) across ``axis_name``."""
    return _managed_all_gather_impl(x, axis_name, mode, chunks)


def _managed_all_gather_impl(x, axis_name, mode, chunks):
    n = _axis_size(axis_name)
    if n == 1:
        return x
    eff_mode, c = _resolve("all_gather", axis_name, x, mode, chunks,
                           "all_gather")
    if eff_mode == "bulk":
        return lax.all_gather(x, axis_name, tiled=True)
    return _ring_all_gather(x, axis_name, n, c)


def _ag_fwd(x, axis_name, mode, chunks):
    return _managed_all_gather_impl(x, axis_name, mode, chunks), None


def _ag_bwd(axis_name, mode, chunks, _, dy):
    return (_managed_reduce_scatter_impl(dy, axis_name, mode, chunks),)


managed_all_gather.defvjp(_ag_fwd, _ag_bwd)


def _ring_all_gather(x: Array, axis_name: str, n: int, chunks: int) -> Array:
    idx = lax.axis_index(axis_name)
    m = x.shape[0]
    perm = _ring_perm(n)
    out = jnp.zeros((n * m,) + x.shape[1:], x.dtype)
    out = lax.dynamic_update_slice_in_dim(out, x, idx * m, axis=0)

    def body(s, carry):
        out, buf = carry
        buf = _ppermute_chunked(buf, axis_name, perm, chunks)
        src = (idx - s) % n          # buf now holds rank (idx - s)'s shard
        out = lax.dynamic_update_slice_in_dim(out, buf, src * m, axis=0)
        return out, buf

    out, _ = lax.fori_loop(1, n, body, (out, x))
    return out


# ---------------------------------------------------------------------------
# managed_reduce_scatter
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def managed_reduce_scatter(x: Array, axis_name: str,
                           mode: str | None = None,
                           chunks: int | None = None) -> Array:
    """Sum-reduce ``x`` across ``axis_name``, scattering blocks of axis 0
    (tiled): rank i receives ``sum_r x_r[i*m:(i+1)*m]``."""
    return _managed_reduce_scatter_impl(x, axis_name, mode, chunks)


def _managed_reduce_scatter_impl(x, axis_name, mode, chunks):
    n = _axis_size(axis_name)
    if n == 1:
        return x
    eff_mode, c = _resolve("reduce_scatter", axis_name, x, mode, chunks,
                           "reduce_scatter")
    if eff_mode == "bulk":
        return lax.psum_scatter(x, axis_name, scatter_dimension=0, tiled=True)
    return _ring_reduce_scatter(x, axis_name, n, c)


def _rs_fwd(x, axis_name, mode, chunks):
    return _managed_reduce_scatter_impl(x, axis_name, mode, chunks), None


def _rs_bwd(axis_name, mode, chunks, _, dy):
    return (_managed_all_gather_impl(dy, axis_name, mode, chunks),)


managed_reduce_scatter.defvjp(_rs_fwd, _rs_bwd)


def _ring_reduce_scatter(x: Array, axis_name: str, n: int,
                         chunks: int) -> Array:
    idx = lax.axis_index(axis_name)
    assert x.shape[0] % n == 0, (
        f"reduce_scatter axis 0 ({x.shape[0]}) not divisible by {n}")
    m = x.shape[0] // n
    blocks = x.reshape((n, m) + x.shape[1:])
    perm = _ring_perm(n)

    # Block b starts at rank (b+1) and accumulates along the ring; at step s
    # rank i receives the partial of block (i-1-s) and adds its own share.
    send = lax.dynamic_index_in_dim(blocks, (idx - 1) % n, axis=0,
                                    keepdims=False)

    def body(s, buf):
        incoming = _ppermute_chunked(buf, axis_name, perm, chunks)
        blk = (idx - 1 - s) % n
        mine = lax.dynamic_index_in_dim(blocks, blk, axis=0, keepdims=False)
        return incoming + mine

    return lax.fori_loop(1, n, body, send)


# ---------------------------------------------------------------------------
# managed_all_reduce (psum)
# ---------------------------------------------------------------------------


def managed_all_reduce(x: Array, axis_name: str, *, mode: str | None = None,
                       chunks: int | None = None) -> Array:
    """Sum ``x`` across ``axis_name`` (all ranks receive the sum).
    The ring path composes the custom-VJP'd RS/AG, so its transpose is a
    flat-memory ring as well.  A non-divisible leading axis no longer
    silently demotes a forced ring to ``lax.psum``: the operand is
    zero-padded to a multiple of the axis size and sliced back after the
    AG (exact — the pad rows reduce to zero).  The one remaining psum
    fallback (0-d operands) is logged as ``mode='bulk'`` in the
    DecisionRecord so the audit trail shows the demotion."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    scalar = x.ndim == 0
    eff_mode, c = _resolve("all_reduce", axis_name, x,
                           "bulk" if scalar else mode, chunks, "all_reduce")
    if eff_mode == "bulk" or scalar:
        return lax.psum(x, axis_name)
    rows = x.shape[0]
    if rows % n != 0:
        pad = n - rows % n
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    scattered = managed_reduce_scatter(x, axis_name, eff_mode, c)
    full = managed_all_gather(scattered, axis_name, eff_mode, c)
    return full[:rows] if rows != full.shape[0] else full


# ---------------------------------------------------------------------------
# managed_all_to_all
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def managed_all_to_all(x: Array, axis_name: str, split_axis: int = 0,
                       concat_axis: int = 0, mode: str | None = None,
                       chunks: int | None = None) -> Array:
    """All-to-all: block j of ``x`` (along split_axis) goes to rank j; the
    received blocks concatenate along ``concat_axis`` in rank order."""
    return _managed_all_to_all_impl(x, axis_name, split_axis, concat_axis,
                                    mode, chunks)


def _managed_all_to_all_impl(x, axis_name, split_axis, concat_axis, mode,
                             chunks):
    n = _axis_size(axis_name)
    if n == 1:
        return x
    eff_mode, _ = _resolve("all_to_all", axis_name, x, mode, chunks,
                           "all_to_all")
    if eff_mode == "bulk":
        return lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)
    return _ring_all_to_all(x, axis_name, n, split_axis, concat_axis)


def _a2a_fwd(x, axis_name, split_axis, concat_axis, mode, chunks):
    return _managed_all_to_all_impl(x, axis_name, split_axis, concat_axis,
                                    mode, chunks), None


def _a2a_bwd(axis_name, split_axis, concat_axis, mode, chunks, _, dy):
    # transpose of an all-to-all is the reverse all-to-all
    return (_managed_all_to_all_impl(dy, axis_name, concat_axis, split_axis,
                                     mode, chunks),)


managed_all_to_all.defvjp(_a2a_fwd, _a2a_bwd)


def _ring_all_to_all(x: Array, axis_name: str, n: int, split_axis: int,
                     concat_axis: int) -> Array:
    idx = lax.axis_index(axis_name)
    assert x.shape[split_axis] % n == 0
    blocks = jnp.split(x, n, axis=split_axis)     # blocks[j] -> rank j

    # Every shifted permute is independent (all source from x): the n-1
    # fine-grained messages can all be in flight at once.
    out_shape = list(blocks[0].shape)
    # received blocks stack along concat_axis in SOURCE-rank order; the
    # placement stride is the block's own concat-axis extent
    stride = out_shape[concat_axis]
    out = jnp.zeros([s if d != concat_axis else s * n
                     for d, s in enumerate(out_shape)], x.dtype)
    # My own block stays put: out[block idx] = blocks[idx] (dynamic).
    own = _dyn_block(jnp.stack(blocks), idx)
    out = lax.dynamic_update_slice_in_dim(out, own, idx * stride,
                                          axis=concat_axis)
    for s in range(1, n):
        perm = _ring_perm(n, shift=s)
        # send blocks[(idx+s) % n] to rank idx+s; receive from idx-s.
        tosend = _dyn_block(jnp.stack(blocks), (idx + s) % n)
        got = lax.ppermute(tosend, axis_name, perm)
        src = (idx - s) % n
        out = lax.dynamic_update_slice_in_dim(out, got, src * stride,
                                              axis=concat_axis)
    return out


def _dyn_block(stacked: Array, i) -> Array:
    return lax.dynamic_index_in_dim(stacked, i, axis=0, keepdims=False)


# ---------------------------------------------------------------------------
# Fused ring collectives — communication intermingled with the compute that
# produces/consumes it (the paper's Figure 3 strategy, tile-granular).
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def all_gather_matmul(x: Array, w: Array, axis_name: str,
                      mode: str | None = None, chunks: int | None = None,
                      precision=None) -> Array:
    """``all_gather(x, axis) @ w`` with the gather interleaved into the
    matmul:  each ring step multiplies the block that just arrived while the
    next block is in flight.  x: [m_local, k] (sharded on axis 0 over
    ``axis_name``), w: [k, f] (replicated or TP-sharded on f).
    Returns [m_local * n, f].

    VJP (the MDMP duality): dx = matmul_reduce_scatter(dy, w^T);
    dw = gram ring (re-gather x, accumulate x_blk^T dy_blk).
    """
    return _ag_matmul_impl(x, w, axis_name, mode, chunks, precision)


def _ag_matmul_impl(x, w, axis_name, mode, chunks, precision):
    n = _axis_size(axis_name)
    if n == 1:
        return jnp.dot(x, w, precision=precision)
    flops = 2.0 * x.shape[0] * n * x.shape[1] * w.shape[1]
    compute_s = flops / get_config().hw.peak_flops
    eff_mode, c = _resolve("all_gather_matmul", axis_name, x, mode, chunks,
                           "all_gather", compute_time_s=compute_s)
    if eff_mode == "bulk":
        xg = lax.all_gather(x, axis_name, tiled=True)
        return jnp.dot(xg, w, precision=precision)

    idx = lax.axis_index(axis_name)
    m = x.shape[0]
    perm = _ring_perm(n)
    out = jnp.zeros((n * m, w.shape[1]),
                    jnp.result_type(x.dtype, w.dtype))
    out = lax.dynamic_update_slice_in_dim(
        out, jnp.dot(x, w, precision=precision).astype(out.dtype),
        idx * m, axis=0)

    def body(s, carry):
        out, buf = carry
        buf = _ppermute_chunked(buf, axis_name, perm, c)
        src = (idx - s) % n
        blockprod = jnp.dot(buf, w, precision=precision).astype(out.dtype)
        out = lax.dynamic_update_slice_in_dim(out, blockprod, src * m, axis=0)
        return out, buf

    out, _ = lax.fori_loop(1, n, body, (out, x))
    return out


def _gram_ag_ring(a: Array, b: Array, axis_name: str, mode, chunks,
                  precision) -> Array:
    """``all_gather(a, axis)^T @ b`` with the gather interleaved into the
    accumulation: dw-style gram for the ring VJPs.  a: [m_loc, p] sharded
    on axis 0; b: [n*m_loc, q] full rows.  Returns [p, q] (per-rank
    partial — the w shard's gradient needs no further reduction because
    each rank's w shard only saw its own output columns)."""
    n = _axis_size(axis_name)
    if n == 1:
        return jnp.dot(a.T, b, precision=precision)
    eff_mode, c = _resolve("gram_ag_ring", axis_name, a, mode, chunks,
                           "all_gather")
    if eff_mode == "bulk":
        ag = lax.all_gather(a, axis_name, tiled=True)
        return jnp.dot(ag.T, b, precision=precision)

    idx = lax.axis_index(axis_name)
    m = a.shape[0]
    perm = _ring_perm(n)

    def block(buf, src):
        rows = lax.dynamic_slice_in_dim(b, src * m, m, axis=0)
        return jnp.dot(buf.T, rows, precision=precision)

    acc = block(a, idx).astype(jnp.float32)

    def body(s, carry):
        acc, buf = carry
        buf = _ppermute_chunked(buf, axis_name, perm, c)
        src = (idx - s) % n
        return acc + block(buf, src).astype(jnp.float32), buf

    acc, _ = lax.fori_loop(1, n, body, (acc, a))
    return acc.astype(jnp.result_type(a.dtype, b.dtype))


def _agmm_fwd(x, w, axis_name, mode, chunks, precision):
    y = _ag_matmul_impl(x, w, axis_name, mode, chunks, precision)
    return y, (x, w)


def _agmm_bwd(axis_name, mode, chunks, precision, res, dy):
    x, w = res
    dx = _mmrs_impl(dy, w.T, axis_name, mode, chunks, precision)
    dw = _gram_ag_ring(x, dy, axis_name, mode, chunks, precision)
    return dx.astype(x.dtype), dw.astype(w.dtype)


all_gather_matmul.defvjp(_agmm_fwd, _agmm_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def all_gather_matmul_multi(x: Array, ws: Sequence[Array], axis_name: str,
                            mode: str | None = None,
                            chunks: int | None = None,
                            precision=None) -> list[Array]:
    """Like all_gather_matmul but multiplies each arriving block by SEVERAL
    weight matrices in the same ring (fused QKV / fused z,x|B,C|dt
    projections, whose outputs have different shardings and therefore can't
    live in one matrix).  One gather ring, len(ws) matmuls per step."""
    return _ag_matmul_multi_impl(x, ws, axis_name, mode, chunks, precision)


def _ag_matmul_multi_impl(x, ws, axis_name, mode, chunks, precision):
    n = _axis_size(axis_name)
    if n == 1:
        return [jnp.dot(x, w, precision=precision) for w in ws]
    total_cols = sum(w.shape[1] for w in ws)
    flops = 2.0 * x.shape[0] * n * x.shape[1] * total_cols
    compute_s = flops / get_config().hw.peak_flops
    eff_mode, c = _resolve("all_gather_matmul_multi", axis_name, x, mode,
                           chunks, "all_gather", compute_time_s=compute_s)
    if eff_mode == "bulk":
        xg = lax.all_gather(x, axis_name, tiled=True)
        return [jnp.dot(xg, w, precision=precision) for w in ws]

    idx = lax.axis_index(axis_name)
    m = x.shape[0]
    perm = _ring_perm(n)
    outs = tuple(
        jnp.zeros((n * m, w.shape[1]), jnp.result_type(x.dtype, w.dtype))
        for w in ws)

    def place(outs, buf, src):
        return tuple(
            lax.dynamic_update_slice_in_dim(
                o, jnp.dot(buf, w, precision=precision).astype(o.dtype),
                src * m, axis=0)
            for o, w in zip(outs, ws))

    outs = place(outs, x, idx)

    def body(s, carry):
        outs, buf = carry
        buf = _ppermute_chunked(buf, axis_name, perm, c)
        src = (idx - s) % n
        return place(outs, buf, src), buf

    (outs, _) = lax.fori_loop(1, n, body, (outs, x))
    return list(outs)


def _agmm_multi_fwd(x, ws, axis_name, mode, chunks, precision):
    ys = _ag_matmul_multi_impl(x, ws, axis_name, mode, chunks, precision)
    return ys, (x, tuple(ws))


def _agmm_multi_bwd(axis_name, mode, chunks, precision, res, dys):
    x, ws = res
    dx = None
    dws = []
    for w, dy in zip(ws, dys):
        d = _mmrs_impl(dy, w.T, axis_name, mode, chunks, precision)
        dx = d if dx is None else dx + d
        dws.append(_gram_ag_ring(x, dy, axis_name, mode, chunks,
                                 precision).astype(w.dtype))
    return dx.astype(x.dtype), list(dws)


all_gather_matmul_multi.defvjp(_agmm_multi_fwd, _agmm_multi_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def matmul_reduce_scatter(x: Array, w: Array, axis_name: str,
                          mode: str | None = None, chunks: int | None = None,
                          precision=None) -> Array:
    """``reduce_scatter(x @ w, axis)`` with the matmul interleaved into the
    reduction ring: each step computes only the output block about to be
    sent — the paper's "send data as soon as it has been computed".
    x: [M, k_local] with M divisible by axis size, w: [k_local, d]
    (both sharded on the contracting dim over ``axis_name``).
    Returns [M // n, d] (rank i holds block i of rows).

    VJP (duality): dx = all_gather_matmul(dy, w^T);
    dw = gram ring over dy (x^T @ AG(dy)).
    """
    return _mmrs_impl(x, w, axis_name, mode, chunks, precision)


def _mmrs_impl(x, w, axis_name, mode, chunks, precision):
    n = _axis_size(axis_name)
    if n == 1:
        return jnp.dot(x, w, precision=precision)
    flops = 2.0 * x.shape[0] * x.shape[1] * w.shape[1]
    compute_s = flops / get_config().hw.peak_flops
    eff_mode, c = _resolve("matmul_reduce_scatter", axis_name, x, mode,
                           chunks, "reduce_scatter",
                           compute_time_s=compute_s)
    if eff_mode == "bulk":
        y = jnp.dot(x, w, precision=precision)
        return lax.psum_scatter(y, axis_name, scatter_dimension=0,
                                tiled=True)

    idx = lax.axis_index(axis_name)
    assert x.shape[0] % n == 0
    m = x.shape[0] // n
    perm = _ring_perm(n)
    acc_dtype = jnp.result_type(x.dtype, w.dtype)

    def block_prod(b):
        rows = lax.dynamic_slice_in_dim(x, b * m, m, axis=0)
        return jnp.dot(rows, w, precision=precision).astype(acc_dtype)

    send = block_prod((idx - 1) % n)

    def body(s, buf):
        incoming = _ppermute_chunked(buf, axis_name, perm, c)
        blk = (idx - 1 - s) % n
        return incoming + block_prod(blk)

    return lax.fori_loop(1, n, body, send)


def _mmrs_fwd(x, w, axis_name, mode, chunks, precision):
    y = _mmrs_impl(x, w, axis_name, mode, chunks, precision)
    return y, (x, w)


def _mmrs_bwd(axis_name, mode, chunks, precision, res, dy):
    x, w = res
    dx = _ag_matmul_impl(dy, w.T, axis_name, mode, chunks, precision)
    # dw = x^T @ AG(dy): gram ring over dy blocks against x rows
    dw = _gram_ag_ring(dy, x, axis_name, mode, chunks, precision).T
    return dx.astype(x.dtype), dw.astype(w.dtype)


matmul_reduce_scatter.defvjp(_mmrs_fwd, _mmrs_bwd)


# ---------------------------------------------------------------------------
# Managed ring attention (context parallelism)
#
# The paper's Figure-3 strategy mapped onto attention: q stays sequence-
# sharded, KV blocks rotate around the ring via ppermute while the flash
# kernel consumes the block that already arrived, merging partials with the
# online-softmax (m, l, acc) carry.  Activation memory is O(S_loc); the
# per-step transfer hides under the per-block flash once compute dominates
# the link.  ``mode='bulk'`` is the oracle: all-gather the KV and run ONE
# flash call (identical math, bulk communication).  Causal masks skip
# fully-masked future blocks (lax.cond per step — the permute stays
# outside the cond so every rank still participates in the collective).
#
# The custom VJP re-streams the backward ring: dq accumulates locally as
# KV blocks pass by again, while each block's (dk, dv) accumulator rotates
# WITH it, collecting every rank's contribution before arriving back home
# after a full cycle.  Residuals are only (q, k, v, out, lse) — O(S_loc),
# never the gathered sequence.
# ---------------------------------------------------------------------------


def _block_visible(q_off, k_off, sq: int, skv: int, causal: bool,
                   window: int):
    """Whether ANY (qpos, kpos) pair of the block survives the mask.
    Offsets may be traced (ring ranks derive them from axis_index)."""
    vis = jnp.bool_(True)
    if causal:
        vis &= k_off <= q_off + sq - 1
    if window > 0:
        vis &= (q_off - (k_off + skv - 1)) < window
    return vis


def _ring_attn_resolve(q, k, axis_name, causal, mode):
    n = _axis_size(axis_name)
    b, s_loc, h, hd = q.shape
    compute_s = ((0.5 if causal else 1.0) * n
                 * cost_model.attention_flash_step_s(
                     b, s_loc, h, hd, get_config().hw))
    eff_mode, _ = _resolve("ring_attention", axis_name, k, mode, None,
                           "all_gather", compute_time_s=compute_s)
    return eff_mode, n


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def managed_ring_attention(q: Array, k: Array, v: Array, axis_name: str,
                           causal: bool = True, window: int = 0,
                           mode: str | None = None) -> Array:
    """Sequence-sharded attention with KV streamed around ``axis_name``.

    q: [B, S_loc, H, hd]; k, v: [B, S_loc, KV, hd] — every rank holds its
    own sequence block of q AND kv (GQA via head grouping, KV <= H).
    Global positions are rank-derived: q[0] sits at ``axis_index * S_loc``.
    Returns [B, S_loc, H, hd] in q's dtype, allclose to flash attention
    over the all-gathered KV (the ``mode='bulk'`` fallback).
    """
    with dispatch_span("attention.ring", q, op="ring_attention",
                       axis=axis_name, nbytes=2 * _nbytes(k),
                       buffer="kv_blocks"):
        out, _ = _ring_attention_fwd_impl(q, k, v, axis_name, causal,
                                          window, mode)
        return out


def _ring_attention_fwd_impl(q, k, v, axis_name, causal, window, mode):
    from repro.kernels import ops as kernel_ops
    from repro.kernels.flash_attention import finalize_partials
    b, s_loc, h, hd = q.shape
    eff_mode, n = _ring_attn_resolve(q, k, axis_name, causal, mode)
    if n == 1:
        carry = kernel_ops.flash_attention_step(q, k, v, causal=causal,
                                                window=window)
        out, lse = finalize_partials(*carry, out_dtype=q.dtype)
        return out, lse
    idx = lax.axis_index(axis_name)
    # Positions only matter under a mask; keeping q_off literal 0 otherwise
    # avoids a dead axis_index chain in the bulk branch (XLA's SPMD
    # partitioner rejects a partition-id it cannot place).
    q_off = idx * s_loc if (causal or window > 0) else 0

    if eff_mode == "bulk":
        kg = lax.all_gather(k, axis_name, axis=1, tiled=True)
        vg = lax.all_gather(v, axis_name, axis=1, tiled=True)
        carry = kernel_ops.flash_attention_step(
            q, kg, vg, causal=causal, window=window, q_offset=q_off,
            k_offset=0)
        out, lse = finalize_partials(*carry, out_dtype=q.dtype)
        return out, lse

    perm = _ring_perm(n)
    from repro.kernels.flash_attention import init_partials
    m0, l0, acc0 = init_partials(b, s_loc, h, hd)

    def attend_block(carry, kb, vb, k_off):
        mc, lc, ac = carry
        return lax.cond(
            _block_visible(q_off, k_off, s_loc, s_loc, causal, window),
            lambda op: kernel_ops.flash_attention_step(
                q, op[3], op[4], (op[0], op[1], op[2]), causal=causal,
                window=window, q_offset=q_off, k_offset=k_off),
            lambda op: (op[0], op[1], op[2]),
            (mc, lc, ac, kb, vb))

    def body(s, carry):
        mc, lc, ac, kb, vb = carry
        # issue the permute FIRST: the transfer of block s+1 overlaps the
        # flash consuming block s (the MDMP intermingling).
        kb_next = lax.ppermute(kb, axis_name, perm)
        vb_next = lax.ppermute(vb, axis_name, perm)
        src = jnp.mod(idx - s, n)
        mc, lc, ac = attend_block((mc, lc, ac), kb, vb, src * s_loc)
        return mc, lc, ac, kb_next, vb_next

    mc, lc, ac, kb, vb = lax.fori_loop(0, n - 1, body,
                                       (m0, l0, acc0, k, v))
    src = jnp.mod(idx - (n - 1), n)
    mc, lc, ac = attend_block((mc, lc, ac), kb, vb, src * s_loc)
    out, lse = finalize_partials(mc, lc, ac, out_dtype=q.dtype)
    return out, lse


def _ring_attn_fwd(q, k, v, axis_name, causal, window, mode):
    out, lse = _ring_attention_fwd_impl(q, k, v, axis_name, causal, window,
                                        mode)
    return out, (q, k, v, out, lse)


def _ring_attn_bwd(axis_name, causal, window, mode, res, dy):
    from repro.kernels import ops as kernel_ops
    q, k, v, out, lse = res
    b, s_loc, h, hd = q.shape
    eff_mode, n = _ring_attn_resolve(q, k, axis_name, causal, mode)
    dsum = jnp.sum(dy.astype(jnp.float32) * out.astype(jnp.float32),
                   axis=-1)

    if n == 1:
        dq, dk, dv = kernel_ops.flash_attention_bwd_block(
            q, k, v, dy, lse, dsum, causal=causal, window=window)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    idx = lax.axis_index(axis_name)
    q_off = idx * s_loc if (causal or window > 0) else 0

    if eff_mode == "bulk":
        kg = lax.all_gather(k, axis_name, axis=1, tiled=True)
        vg = lax.all_gather(v, axis_name, axis=1, tiled=True)
        dq, dk_full, dv_full = kernel_ops.flash_attention_bwd_block(
            q, kg, vg, dy, lse, dsum, causal=causal, window=window,
            q_offset=q_off, k_offset=0)
        # each rank computed its q-rows' contribution to EVERY kv position;
        # the transpose of the seq all-gather sums + scatters them home
        dk = lax.psum_scatter(dk_full, axis_name, scatter_dimension=1,
                              tiled=True)
        dv = lax.psum_scatter(dv_full, axis_name, scatter_dimension=1,
                              tiled=True)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    perm = _ring_perm(n)

    def bwd_block(carry, s):
        dq, kb, vb, dkb, dvb = carry
        src = jnp.mod(idx - s, n)
        k_off = src * s_loc

        def compute(op):
            dq_c, kb_c, vb_c, dkb_c, dvb_c = op
            dq_i, dk_i, dv_i = kernel_ops.flash_attention_bwd_block(
                q, kb_c, vb_c, dy, lse, dsum, causal=causal, window=window,
                q_offset=q_off, k_offset=k_off)
            return dq_c + dq_i, kb_c, vb_c, dkb_c + dk_i, dvb_c + dv_i

        return lax.cond(
            _block_visible(q_off, k_off, s_loc, s_loc, causal, window),
            compute, lambda op: op, (dq, kb, vb, dkb, dvb))

    def body(s, carry):
        carry = bwd_block(carry, s)
        dq, kb, vb, dkb, dvb = carry
        # (dk, dv) accumulators travel WITH their block: after the full
        # cycle every rank has contributed and the sums are back home.
        kb = lax.ppermute(kb, axis_name, perm)
        vb = lax.ppermute(vb, axis_name, perm)
        dkb = lax.ppermute(dkb, axis_name, perm)
        dvb = lax.ppermute(dvb, axis_name, perm)
        return dq, kb, vb, dkb, dvb

    init = (jnp.zeros(q.shape, jnp.float32), k, v,
            jnp.zeros(k.shape, jnp.float32), jnp.zeros(v.shape, jnp.float32))
    dq, _, _, dk, dv = lax.fori_loop(0, n, body, init)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


managed_ring_attention.defvjp(_ring_attn_fwd, _ring_attn_bwd)


def resolve_attention_schedule(axis_name: str, axis_size: int, batch: int,
                               s_local: int, heads: int, kv_heads: int,
                               head_dim: int, d_model: int, *,
                               dtype_bytes: int = 2, causal: bool = True,
                               mode: str | None = None,
                               schedule: str | None = None
                               ) -> cost_model.AttentionScheduleDecision:
    """The managed-runtime entry for the three-way attention schedule
    (bulk sequence-gather vs ulysses a2a vs ring streaming) — the analogue
    of ``resolve_halo_aggregation`` for the transformer path.  Called at
    trace/plan time with static shapes; the chosen schedule feeds
    ``models/attention.py`` dispatch and lands in the decision log.

    ``mode='bulk'`` pins the paper-faithful unmanaged baseline;
    ``mode='interleaved'`` pins the always-stream schedule (ring);
    ``schedule`` pins an explicit choice (the tuner's measured winner).
    """
    cfg = get_config()
    pk = _plan_knob("attention_schedule", axis_name)
    if pk is not None and schedule is None and mode in (None, "auto"):
        schedule = pk.get("mode")
    eff_mode = mode or cfg.mode
    force = {"bulk": "bulk", "interleaved": "ring"}.get(eff_mode, schedule)
    decision = cost_model.decide_attention_schedule(
        batch, s_local, heads, kv_heads, head_dim, d_model, axis_size,
        dtype_bytes=dtype_bytes, causal=causal, hw=cfg.hw,
        force_schedule=force)
    if cfg.log_decisions:
        log_decision(DecisionRecord(
            op="attention_schedule", axis=axis_name,
            nbytes=2 * batch * s_local * kv_heads * head_dim * dtype_bytes,
            mode=decision.schedule, chunks=max(1, axis_size),
            predicted_bulk_s=decision.bulk_s,
            predicted_interleaved_s=decision.chosen_s))
    return decision


def resolve_pipeline_schedule(axis_name: str, axis_size: int,
                              batch_fwd_s: float, batch_bytes: float, *,
                              n_layers: int | None = None,
                              stash_cap_bytes: float | None = None,
                              candidate_micro: Sequence[int] = (4, 8, 16,
                                                                32),
                              candidate_virtual: Sequence[int] = (2,),
                              overlap_budget: float = 1.0,
                              mode: str | None = None,
                              schedule: str | None = None,
                              n_micro: int | None = None,
                              virtual: int | None = None
                              ) -> cost_model.PipelineScheduleDecision:
    """The managed-runtime entry for the pipeline-schedule knob (gpipe vs
    1f1b vs interleaved, plus the microbatch count M and virtual chunk
    factor v) — the analogue of ``resolve_halo_aggregation`` for the
    pipeline-parallel training loop.  Called at build time with static
    shapes; the chosen (schedule, M, v) feeds
    ``parallel/pipeline.build_schedule`` and lands in the decision log.

    ``mode='bulk'`` pins gpipe (the unmanaged forward-then-backward
    baseline); ``mode='interleaved'`` pins 1f1b (the always-intermingle
    schedule); ``schedule``/``n_micro``/``virtual`` pin an explicit
    choice (the tuner's measured winner).  ``overlap_budget`` is the
    instrumented readiness of the stage boundary
    (``instrument.analyze_region``) — how much of a tick's compute can
    hide the handoff bytes.  The DecisionRecord reuses ``chunks`` to
    carry the microbatch count M."""
    cfg = get_config()
    pk = _plan_knob("pipeline_schedule", axis_name)
    if pk is not None and schedule is None and n_micro is None and \
            mode in (None, "auto"):
        schedule = pk.get("mode")
        n_micro = pk.get("chunks")
        if virtual is None:
            virtual = pk.get("virtual")
    eff_mode = mode or cfg.mode
    # an EXPLICIT schedule wins over the ambient mode (same precedence as
    # cfg.attn_impl vs mdmp_mode): mode only maps to a schedule when none
    # was requested
    force = schedule if schedule is not None else \
        {"bulk": "gpipe", "interleaved": "1f1b"}.get(eff_mode)
    decision = cost_model.decide_pipeline_schedule(
        axis_size, batch_fwd_s, batch_bytes, n_layers=n_layers,
        stash_cap_bytes=stash_cap_bytes,
        candidate_micro=candidate_micro,
        candidate_virtual=candidate_virtual, hw=cfg.hw,
        overlap_budget=overlap_budget, force_schedule=force,
        force_micro=n_micro, force_virtual=virtual)
    if cfg.log_decisions:
        log_decision(DecisionRecord(
            op="pipeline_schedule", axis=axis_name,
            nbytes=int(batch_bytes / max(1, decision.n_micro)),
            mode=decision.schedule, chunks=decision.n_micro,
            predicted_bulk_s=decision.bulk_s,
            predicted_interleaved_s=decision.chosen_s))
    return decision


def resolve_serve_schedule(axis_name: str, batch_slots: int,
                           mean_prompt: float, mean_new: float,
                           n_params: float, *, dtype_bytes: int = 2,
                           max_prompt: float | None = None,
                           measured_step_s: float | None = None,
                           measured_dispatch_s: float | None = None,
                           ttft_budget_s: float | None = None,
                           mode: str | None = None,
                           schedule: str | None = None,
                           chunk: int | None = None
                           ) -> cost_model.ServeScheduleDecision:
    """The managed-runtime entry for the serving schedule (static waves vs
    continuous batching, plus the scheduling-quantum C) — the analogue of
    ``resolve_halo_aggregation`` for the serving runtime.  Called between
    engine quanta with host-side statistics; the chosen (mode, C) feeds
    ``serve/scheduler.py`` and lands in the decision log.

    ``mode='bulk'`` pins static waves (the paper-faithful unmanaged
    baseline, = the seed Generator); ``mode='interleaved'`` pins
    continuous batching; ``schedule``/``chunk`` pin an explicit choice
    (the tuner's measured winner).  Measured step/dispatch seconds from
    ``serve/metrics.py`` override the modeled roofline terms — the
    iteration-(k)->(k+1) correction.  The DecisionRecord reuses ``chunks``
    to carry C and the predicted fields to carry seconds-per-token."""
    cfg = get_config()
    pk = _plan_knob("serve_schedule", axis_name)
    if pk is not None and schedule is None and chunk is None and \
            mode in (None, "auto"):
        schedule = pk.get("mode")
        chunk = pk.get("chunks")
    eff_mode = mode or cfg.mode
    force = {"bulk": "static", "interleaved": "continuous"}.get(eff_mode,
                                                                schedule)
    decision = cost_model.decide_serve_schedule(
        n_params, batch_slots, mean_prompt, mean_new,
        max_prompt=max_prompt, dtype_bytes=dtype_bytes, hw=cfg.hw,
        measured_step_s=measured_step_s,
        measured_dispatch_s=measured_dispatch_s,
        ttft_budget_s=ttft_budget_s, force_mode=force, force_chunk=chunk)
    if cfg.log_decisions:
        log_decision(DecisionRecord(
            op="serve_schedule", axis=axis_name,
            nbytes=int(n_params) * dtype_bytes,
            mode=decision.mode, chunks=decision.chunk,
            predicted_bulk_s=1.0 / max(decision.static_tok_s, 1e-30),
            predicted_interleaved_s=1.0 / max(decision.chosen_tok_s,
                                              1e-30)))
    return decision


def resolve_preempt(axis_name: str, victim_pages: int, page_bytes: int,
                    replay_tokens: int, n_params: float, *,
                    batch_slots: int = 1, dtype_bytes: int = 2,
                    measured_step_s: float | None = None,
                    measured_pcie_bw: float | None = None,
                    chunk_bytes: int | None = None,
                    wait_s: float | None = None,
                    allow_swap: bool = True,
                    mode: str | None = None,
                    policy: str | None = None
                    ) -> cost_model.PreemptDecision:
    """The managed-runtime entry for the serving preemption knob (swap a
    victim's KV pages to host vs drop-and-recompute its prefill vs
    head-of-line wait) — the overload analogue of
    ``resolve_serve_schedule``.  Called by the engine on every
    pool-exhaustion event with the victim's geometry and the instrumented
    queue statistics; the chosen policy drives the eviction and lands in
    the decision log.

    ``mode='bulk'`` pins drop-and-recompute (the unmanaged baseline — no
    host state, every eviction re-earns its KV by replay);
    ``mode='interleaved'`` pins swap (the chunk-metered transfer path);
    an explicit ``policy`` (the tuner's measured winner or a
    ``--preempt`` pin) wins over the ambient mode.  Measured step
    seconds and swap bandwidth from ``serve/metrics.py`` override the
    modeled terms — the iteration-(k)->(k+1) correction.  The
    DecisionRecord reuses ``chunks`` to carry the victim's page count
    and the predicted fields to carry recompute-vs-chosen seconds."""
    cfg = get_config()
    pk = _plan_knob("preempt_policy", axis_name)
    if pk is not None and policy is None and mode in (None, "auto"):
        policy = pk.get("mode")
    eff_mode = mode or cfg.mode
    force = policy if policy is not None else \
        {"bulk": "recompute", "interleaved": "swap"}.get(eff_mode)
    decision = cost_model.decide_preempt(
        victim_pages, page_bytes, replay_tokens, n_params,
        step_s=measured_step_s, batch_slots=batch_slots,
        dtype_bytes=dtype_bytes, pcie_bw=measured_pcie_bw,
        chunk_bytes=chunk_bytes, wait_s=wait_s, allow_swap=allow_swap,
        hw=cfg.hw, force_policy=force)
    if cfg.log_decisions:
        log_decision(DecisionRecord(
            op="preempt_policy", axis=axis_name,
            nbytes=decision.swap_bytes,
            mode=decision.policy, chunks=decision.victim_pages,
            predicted_bulk_s=decision.recompute_s,
            predicted_interleaved_s=decision.chosen_s))
    return decision


def resolve_checkpoint(axis_name: str, step_s: float, snapshot_bytes: int,
                       *, mtbf_s: float = 1800.0,
                       measured_write_bw: float | None = None,
                       measured_ckpt_cost_s: float | None = None,
                       measured_restore_s: float | None = None,
                       mode: str | None = None,
                       interval: int | None = None
                       ) -> cost_model.CheckpointDecision:
    """The managed-runtime entry for the checkpoint-cadence knob (the
    Young/Daly interval) — the analogue of ``resolve_serve_schedule`` for
    the fault-tolerance path.  Called by ``TrainLoop`` between steps with
    the EWMA step time and checkpoint/metrics.py's measured write
    bandwidth / per-checkpoint cost; the chosen interval drives the next
    ``save_async`` and lands in the decision log.

    ``mode='bulk'`` pins the fixed ``ckpt_every=25`` baseline (the
    unmanaged cadence every prior PR shipped); an explicit ``interval``
    wins over the ambient mode (same precedence as every other managed
    knob).  The DecisionRecord reuses ``chunks`` to carry the interval
    and the predicted fields to carry overhead fractions (fixed vs
    chosen)."""
    cfg = get_config()
    pk = _plan_knob("ckpt_interval", axis_name)
    if pk is not None and interval is None and mode in (None, "auto"):
        interval = pk.get("chunks")
    eff_mode = mode or cfg.mode
    force = interval if interval is not None else (
        cost_model.CKPT_FIXED_INTERVAL if eff_mode == "bulk" else None)
    decision = cost_model.decide_checkpoint(
        step_s, snapshot_bytes, mtbf_s=mtbf_s,
        write_bw=measured_write_bw,
        ckpt_cost_s=measured_ckpt_cost_s,
        restore_s=measured_restore_s, hw=cfg.hw, force_interval=force)
    if cfg.log_decisions:
        log_decision(DecisionRecord(
            op="ckpt_interval", axis=axis_name,
            nbytes=int(snapshot_bytes),
            mode=decision.mode, chunks=decision.interval,
            predicted_bulk_s=decision.fixed_overhead,
            predicted_interleaved_s=decision.chosen_overhead))
    return decision


# ---------------------------------------------------------------------------
# Managed expert dispatch (expert parallelism)
#
# The paper's Figure-3 strategy mapped onto MoE token routing: the [E, C,
# D] capacity buffers are the declared communication, and instead of one
# bulk all_to_all each way around the expert FFN, the ring streams one
# rank-block at a time — the NEXT block's ppermute is issued before the
# current block's expert FFN runs, and each of the g capacity chunks'
# results returns home with its own permute as soon as it is computed.
# Equivalent math to a2a -> ffn -> reverse a2a (the bulk oracle); the
# wire hides under the FFN once compute dominates the link.  Plain
# autodiff streams the backward ring (every op is a linear permute, a
# dynamic slice/update, or the expert_fn the caller differentiates).
# ---------------------------------------------------------------------------


def managed_expert_stream(buffers: Array, counts: Array, axis_name: str,
                          expert_fn, *, g: int = 1) -> Array:
    """Stream expert-capacity buffers around ``axis_name``.

    buffers: [E, C, D] capacity rows of THIS rank's tokens (expert-major,
    experts sharded E_loc = E/n per rank); counts: [E] int32 valid-row
    counts (rows past the count are zero padding); ``expert_fn(block,
    valid)`` applies this rank's LOCAL experts to an [E_loc, c, D] block
    (c = C/g) with per-expert valid counts [E_loc].  Returns [E, C, D]:
    row-block e holds the processed rows of expert e for MY tokens —
    exactly ``managed_all_to_all -> ffn -> reverse managed_all_to_all``.
    """
    n = _axis_size(axis_name)
    e, c, d = buffers.shape
    if n == 1:
        return expert_fn(buffers, counts)
    assert e % n == 0, (e, n)
    eff_g = g if (g >= 1 and c % max(1, g) == 0) else 1
    cs = c // eff_g
    e_loc = e // n
    idx = lax.axis_index(axis_name)
    blocks = buffers.reshape(n, e_loc, c, d)
    cnt_blocks = counts.reshape(n, e_loc)

    _resolve("expert_stream", axis_name, buffers, "interleaved", eff_g,
             "all_to_all")
    with dispatch_span("moe.expert_stream", buffers, op="expert_stream",
                       axis=axis_name, nbytes=_nbytes(buffers),
                       chunks=eff_g, buffer="expert_buffers"):
        return _expert_stream_body(blocks, cnt_blocks, axis_name,
                                   expert_fn, n, eff_g, cs, idx)


def _expert_stream_body(blocks, cnt_blocks, axis_name, expert_fn, n,
                        eff_g, cs, idx):
    _, e_loc, c, d = blocks.shape
    e = n * e_loc

    out = None
    cur = _dyn_block(blocks, idx)
    cur_cnt = _dyn_block(cnt_blocks, idx)
    for s in range(n):
        if s + 1 < n:
            # issue the NEXT block's transfer before this block's FFN
            # (the MDMP intermingling)
            perm_fwd = _ring_perm(n, shift=s + 1)
            send_to = jnp.mod(idx + s + 1, n)
            nxt = lax.ppermute(_dyn_block(blocks, send_to), axis_name,
                               perm_fwd)
            nxt_cnt = lax.ppermute(_dyn_block(cnt_blocks, send_to),
                                   axis_name, perm_fwd)
        rets = []
        for j in range(eff_g):
            vj = jnp.clip(cur_cnt - j * cs, 0, cs)
            yj = expert_fn(cur[:, j * cs:(j + 1) * cs], vj)
            if s > 0:
                # the chunk's result returns to its source rank while the
                # next chunk's FFN runs
                yj = lax.ppermute(yj, axis_name, _ring_perm(n, shift=-s))
            rets.append(yj)
        y = rets[0] if len(rets) == 1 else jnp.concatenate(rets, axis=1)
        if out is None:
            out = jnp.zeros((e, c, d), y.dtype)
        # what arrived in the return permute: rank idx+s's experts' output
        # on MY capacity rows
        src_e = jnp.mod(idx + s, n) * e_loc
        out = lax.dynamic_update_slice_in_dim(out, y, src_e, axis=0)
        if s + 1 < n:
            cur, cur_cnt = nxt, nxt_cnt
    return out


def resolve_moe_dispatch(axis_name: str, axis_size: int, tokens_local: int,
                         d_model: int, n_experts: int, top_k: int,
                         d_ff_expert: int, *, mults: int = 3,
                         dtype_bytes: int = 2,
                         capacity_factor: float = 1.25,
                         measured_imbalance: float | None = None,
                         measured_drop_rate: float | None = None,
                         measured_occupancy: float | None = None,
                         layout: str = "ep_a2a",
                         mode: str | None = None,
                         schedule: str | None = None,
                         g: int | None = None,
                         capacity_factor_override: float | None = None
                         ) -> cost_model.MoEDispatchDecision:
    """The managed-runtime entry for the MoE dispatch knob (bulk a2a vs
    chunked-stream vs dense-fallback, plus the capacity factor) — the
    analogue of ``resolve_attention_schedule`` for expert parallelism.
    Called at trace/plan time with static shapes; the chosen (schedule,
    g, capacity_factor) feeds ``models/moe.py`` dispatch and lands in
    the decision log.  ``measured_*`` come from
    ``instrument.capture_routing`` — the runtime routing counters that
    re-resolve the schedule and the capacity online.

    ``mode='bulk'`` pins the paper-faithful unmanaged baseline;
    ``mode='interleaved'`` pins the always-stream schedule; an explicit
    ``schedule`` (the tuner's measured winner, or a pinned
    cfg.moe.dispatch) wins over the ambient mode.  The DecisionRecord
    reuses ``chunks`` to carry the stream chunk count g."""
    cfg = get_config()
    pk = _plan_knob("moe_dispatch", axis_name)
    if pk is not None and schedule is None and g is None and \
            mode in (None, "auto"):
        schedule = pk.get("mode")
        g = pk.get("chunks")
        if capacity_factor_override is None:
            capacity_factor_override = pk.get("capacity_factor")
    eff_mode = mode or cfg.mode
    force = schedule if schedule is not None else \
        {"bulk": "bulk", "interleaved": "stream"}.get(eff_mode)
    decision = cost_model.decide_moe_dispatch(
        tokens_local, d_model, n_experts, top_k, d_ff_expert, axis_size,
        mults=mults, dtype_bytes=dtype_bytes,
        capacity_factor=capacity_factor,
        measured_imbalance=measured_imbalance,
        measured_drop_rate=measured_drop_rate,
        measured_occupancy=measured_occupancy, hw=cfg.hw, layout=layout,
        force_schedule=force, force_g=g,
        force_capacity_factor=capacity_factor_override)
    if cfg.log_decisions:
        log_decision(DecisionRecord(
            op="moe_dispatch", axis=axis_name, nbytes=decision.a2a_bytes,
            mode=decision.schedule, chunks=decision.g,
            predicted_bulk_s=decision.bulk_s,
            predicted_interleaved_s=decision.chosen_s))
    return decision


# ---------------------------------------------------------------------------
# Convenience: sequence-parallel psum replacement
# ---------------------------------------------------------------------------


def managed_psum_scatter_gather(x: Array, axis_name: str, *,
                                mode: str | None = None) -> Array:
    """psum expressed as RS+AG so the two halves can straddle compute
    (Megatron-SP style); numerically identical to psum."""
    return managed_all_gather(
        managed_reduce_scatter(x, axis_name, mode=mode), axis_name,
        mode=mode)
