"""Communication regions — the paper's ``#pragma commregion`` facade.

A ``CommRegion`` is the declarative surface of MDMP: the user states which
operands are sent/received (``region.send(...)`` / ``region.recv(...)``)
and wraps the computation that produces/consumes them.  The region then

  1. traces the wrapped function and runs the data-access instrumentation
     (instrument.py) to find each operand's readiness / consumption slack —
     the trace-time analogue of the paper's runtime read/write counters;
  2. feeds operand bytes + the overlap budget into the alpha-beta cost
     model to pick bulk vs interleaved and a chunk count per declaration;
  3. exposes the resulting ``Plan`` and executes managed collectives
     accordingly.

Outside a region (paper Table 2), nothing is instrumented and every
managed op that specifies ``mode=None`` falls through to the global
MDMPConfig — by default plain bulk collectives with zero overhead.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax

from repro.core import cost_model, instrument
from repro.core.managed import MDMPConfig, get_config


def _decl_site() -> tuple | None:
    """Repo-relative (file, line) of the user frame declaring a spec —
    the provenance the static verifier renders next to each diagnostic."""
    import inspect
    try:
        for fr in inspect.stack(context=0)[2:8]:
            fn = fr.filename
            if fn.replace("\\", "/").endswith("core/region.py"):
                continue
            for marker in ("src/repro/", "tests/", "benchmarks/",
                           "examples/"):
                i = fn.find(marker)
                if i >= 0:
                    return (fn[i:], fr.lineno)
            import os
            return (os.path.basename(fn), fr.lineno)
    except Exception:
        pass
    return None


class UnknownAxisError(ValueError):
    """A declaration references a mesh axis the region does not know.

    Before this check, a typo'd axis name silently priced as size-1
    (every ``axis_sizes.get(axis, 1)`` lookup), so the declaration cost
    nothing and the managed runtime never scheduled it — exactly the
    silent-drift class the static verifier (repro.analysis, MDMP001)
    exists to catch."""

    def __init__(self, region: str, label: str, axis: str,
                 known: Sequence[str]):
        self.region = region
        self.label = label
        self.axis = axis
        self.known = tuple(known)
        super().__init__(
            f"region {region!r}: declaration {label!r} names axis "
            f"{axis!r}, not one of the region's mesh axes "
            f"{sorted(known)} — a typo'd axis would silently price as "
            f"size-1 and never be scheduled (MDMP001)")


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """One declared communication (a ``#pragma send``/``recv``/collective)."""
    label: str
    kind: str                  # "send" | "recv" | "all_gather" | "halo" ...
    axis: str                  # mesh axis the message crosses
    nbytes: int
    collective: str = "all_gather"   # cost-model family
    #: (rows_local, cols) of the stencil block for kind="halo" — the
    #: aggregation decision needs the block geometry, not just bytes
    shape: tuple | None = None
    #: repo-relative (file, line) of the declaring call — the static
    #: verifier's diagnostics point a drifted declaration back here
    site: tuple | None = None


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    spec: CommSpec
    mode: str
    chunks: int
    overlap_budget: float      # fraction of region compute available
    predicted_bulk_s: float
    predicted_interleaved_s: float


@dataclasses.dataclass
class Plan:
    entries: dict[str, PlanEntry]
    total_eqns: int

    def mode_for(self, label: str) -> str:
        return self.entries[label].mode

    def chunks_for(self, label: str) -> int:
        return self.entries[label].chunks

    def k_for(self, label: str) -> int:
        """Aggregation factor chosen for a halo declaration (sweeps per
        k-row exchange; 1 = bulk).  Alias of ``chunks_for`` — the k rides
        in the chunks slot."""
        return self.entries[label].chunks

    def schedule_for(self, label: str) -> str:
        """Schedule chosen for an attention declaration ("bulk" |
        "ulysses" | "ring").  Alias of ``mode_for`` — the schedule name
        rides in the mode slot; feed it to models/attention.py dispatch
        (or ``cfg.attn_impl``, mapping "bulk" -> "megatron")."""
        return self.entries[label].mode

    def summary(self) -> str:
        lines = [f"MDMP plan ({self.total_eqns} eqns in region):"]
        for e in self.entries.values():
            lines.append(
                f"  {e.spec.label:24s} {e.spec.kind:12s} axis={e.spec.axis} "
                f"{e.spec.nbytes/1e6:9.3f}MB -> {e.mode}(chunks={e.chunks}) "
                f"overlap_budget={e.overlap_budget:.2f} "
                f"bulk={e.predicted_bulk_s*1e6:.1f}us "
                f"interleaved={e.predicted_interleaved_s*1e6:.1f}us")
        return "\n".join(lines)


class CommRegion:
    """Declarative communication region.

    Usage (the paper's Figure 4, in JAX)::

        region = CommRegion("jacobi", axis_sizes={"x": 16})
        region.send("halo_lo", axis="x", shape=(NP,), dtype=jnp.float32)
        region.send("halo_hi", axis="x", shape=(NP,), dtype=jnp.float32)
        plan = region.plan(step_fn, u0)       # trace + instrument + decide
        mode = plan.mode_for("halo_lo")        # feed into managed halo call
    """

    def __init__(self, name: str, axis_sizes: dict[str, int],
                 config: MDMPConfig | None = None):
        self.name = name
        self.axis_sizes = dict(axis_sizes)
        self.config = config or get_config()
        self._specs: list[CommSpec] = []
        self._plan: Plan | None = None
        self._report: instrument.RegionReport | None = None

    # -- declarations -------------------------------------------------------

    def _add_spec(self, spec: CommSpec) -> None:
        """Validate + append one declaration.  An axis name absent from
        ``axis_sizes`` raises ``UnknownAxisError`` HERE, at declaration
        time — before this check a typo'd axis silently priced as size-1
        (``axis_sizes.get(axis, 1)``) and the communication was never
        scheduled."""
        if spec.axis not in self.axis_sizes:
            raise UnknownAxisError(self.name, spec.label, spec.axis,
                                   self.axis_sizes.keys())
        if spec.site is None:
            spec = dataclasses.replace(spec, site=_decl_site())
        self._specs.append(spec)

    def _declare(self, label: str, kind: str, axis: str, shape, dtype,
                 collective: str) -> None:
        import numpy as np
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        self._add_spec(CommSpec(label=label, kind=kind, axis=axis,
                                nbytes=nbytes, collective=collective))

    def send(self, label: str, *, axis: str, shape, dtype) -> None:
        self._declare(label, "send", axis, shape, dtype, "all_gather")

    def recv(self, label: str, *, axis: str, shape, dtype) -> None:
        self._declare(label, "recv", axis, shape, dtype, "all_gather")

    def collective(self, label: str, *, axis: str, shape, dtype,
                   collective: str) -> None:
        self._declare(label, collective, axis, shape, dtype, collective)

    def halo(self, label: str, *, axis: str, rows_local: int, cols: int,
             dtype) -> None:
        """Declare a stencil halo exchange (rows sharded over ``axis``).
        Planning runs the AGGREGATION decision for it: the resulting
        PlanEntry's ``chunks`` is the chosen k (sweeps per k-row exchange;
        1 = bulk), to be passed to ``halo.jacobi_solve(mode="aggregated",
        k=plan.chunks_for(label))``."""
        import numpy as np
        nbytes = int(cols) * np.dtype(dtype).itemsize   # one 1-row slab
        self._add_spec(CommSpec(label=label, kind="halo", axis=axis,
                                nbytes=nbytes, collective="halo",
                                shape=(int(rows_local), int(cols))))

    def attention(self, label: str, *, axis: str, batch: int, s_local: int,
                  heads: int, kv_heads: int, head_dim: int, d_model: int,
                  dtype, causal: bool = True) -> None:
        """Declare an SP attention call site (q sequence-sharded over
        ``axis``).  Planning runs the three-way schedule decision for it:
        the resulting PlanEntry's ``mode`` is the chosen schedule ("bulk" |
        "ulysses" | "ring"), read back via ``plan.schedule_for(label)``."""
        import numpy as np
        ib = np.dtype(dtype).itemsize
        nbytes = 2 * batch * s_local * kv_heads * head_dim * ib  # kv block
        self._add_spec(CommSpec(
            label=label, kind="attention", axis=axis, nbytes=nbytes,
            collective="attention",
            shape=(int(batch), int(s_local), int(heads), int(kv_heads),
                   int(head_dim), int(d_model), int(causal), int(ib))))

    def pipeline(self, label: str, *, axis: str, n_layers: int,
                 batch_shape, dtype, batch_fwd_s: float) -> None:
        """Declare a pipeline-parallel stage boundary (layers chunked over
        ``axis``; ``batch_shape`` is the WHOLE batch's activation block at
        the boundary — each tick hands off 1/M of it).  Planning runs the
        pipeline-schedule decision for it, with the boundary operand's
        instrumented readiness as the overlap budget: the resulting
        PlanEntry's ``mode`` is the chosen schedule ("gpipe" | "1f1b" |
        "interleaved", read back via ``plan.schedule_for(label)``) and
        ``chunks`` the microbatch count M, to be fed to
        ``parallel/pipeline.build_schedule``."""
        import numpy as np
        ib = np.dtype(dtype).itemsize
        nbytes = int(np.prod(batch_shape)) * ib
        self._add_spec(CommSpec(
            label=label, kind="pipeline", axis=axis, nbytes=nbytes,
            collective="pipeline",
            shape=(int(n_layers), int(round(batch_fwd_s * 1e12)))))

    def moe(self, label: str, *, axis: str, tokens_local: int,
            d_model: int, n_experts: int, top_k: int, d_ff_expert: int,
            dtype, capacity_factor: float = 1.25,
            mults: int = 3) -> None:
        """Declare an MoE expert-dispatch call site (experts sharded by
        id over ``axis``; ``tokens_local`` routed top-k per layer).
        Planning runs the three-way dispatch decision for it: the
        resulting PlanEntry's ``mode`` is the chosen schedule ("bulk" |
        "stream" | "dense", read back via ``plan.schedule_for(label)``)
        and ``chunks`` the stream chunk count g; the chosen capacity
        factor rides in the decision the managed runtime logs."""
        import numpy as np
        ib = np.dtype(dtype).itemsize
        cap = cost_model.moe_capacity(tokens_local, top_k, n_experts,
                                      capacity_factor)
        self._add_spec(CommSpec(
            label=label, kind="moe", axis=axis,
            nbytes=n_experts * cap * d_model * ib, collective="moe",
            shape=(int(tokens_local), int(d_model), int(n_experts),
                   int(top_k), int(d_ff_expert),
                   int(round(capacity_factor * 1000)), int(mults),
                   int(ib))))

    def serve(self, label: str, *, axis: str, batch_slots: int,
              mean_prompt: int, mean_new: int, n_params: int, dtype,
              max_prompt: int | None = None,
              page_bytes: int | None = None,
              mean_pages: int = 1) -> None:
        """Declare a serving call site (the engine's step loop over
        ``batch_slots`` decode slots).  Planning runs the serve-schedule
        decision for it: the resulting PlanEntry's ``mode`` is the chosen
        batching mode ("static" | "continuous") and ``chunks`` the
        scheduling quantum C, read back via ``plan.mode_for(label)`` /
        ``plan.chunks_for(label)`` and fed to ``serve/scheduler.py``.

        When ``page_bytes`` is given (per-KV-page bytes across layers)
        the overload backstop is declared too: an extra
        ``{label}.preempt`` spec whose planned ``mode`` is the preempt
        policy ("swap" | "recompute" | "wait") the engine should start
        from when the page pool exhausts, priced for a mean victim of
        ``mean_pages`` pages holding ``mean_prompt`` replayable tokens."""
        import numpy as np
        ib = np.dtype(dtype).itemsize
        self._add_spec(CommSpec(
            label=label, kind="serve", axis=axis,
            nbytes=int(n_params) * ib, collective="serve",
            shape=(int(batch_slots), int(mean_prompt), int(mean_new),
                   int(max_prompt if max_prompt is not None
                       else mean_prompt), int(n_params), int(ib))))
        if page_bytes is not None:
            self._add_spec(CommSpec(
                label=f"{label}.preempt", kind="preempt", axis=axis,
                nbytes=int(mean_pages) * int(page_bytes),
                collective="preempt",
                shape=(int(batch_slots), int(page_bytes),
                       int(mean_pages), int(mean_prompt), int(n_params),
                       int(ib))))

    def checkpoint(self, label: str, *, axis: str, snapshot_bytes: int,
                   step_s: float, mtbf_s: float = 1800.0,
                   write_bw: float | None = None) -> None:
        """Declare the checkpoint recovery traffic of a train loop (the
        D2H snapshot drain, ``snapshot_bytes`` per save).  Planning runs
        the Young/Daly cadence decision for it: the resulting PlanEntry's
        ``chunks`` is the chosen interval in steps (``mode`` is "daly" |
        "fixed"), read back via ``plan.chunks_for(label)`` and fed to
        ``TrainLoopConfig.ckpt_every`` — recovery traffic priced like any
        other declared communication."""
        self._add_spec(CommSpec(
            label=label, kind="ckpt", axis=axis,
            nbytes=int(snapshot_bytes), collective="ckpt",
            shape=(int(snapshot_bytes), int(round(step_s * 1e9)),
                   int(round(mtbf_s)),
                   int(round(write_bw)) if write_bw else 0)))

    # -- planning -----------------------------------------------------------

    def plan(self, fn: Callable, *example_args: Any,
             tracked_args: Sequence[int] | None = None,
             compute_time_s: float | None = None) -> Plan:
        """Trace ``fn`` (the region body, per-shard view), instrument the
        access pattern of the tracked args (positionally matched to the
        declared specs) and decide each communication's schedule."""
        n_specs = len(self._specs)
        if tracked_args is None:
            tracked_args = list(range(min(n_specs, 1)))
        labels = [s.label for s in self._specs[:len(tracked_args)]]
        report = instrument.analyze_region(
            fn, *example_args, tracked_args=list(tracked_args), labels=labels)
        self._report = report

        from repro.core import managed

        entries: dict[str, PlanEntry] = {}
        for spec in self._specs:
            if spec.kind == "halo":
                # The aggregation knob: pick k sweeps per exchange.  Routed
                # through managed.resolve_halo_aggregation so the choice
                # lands in the MDMP decision log like every other schedule.
                rows_local, cols = spec.shape
                n = self.axis_sizes.get(spec.axis, 1)
                with managed.use_config(self.config):
                    d = managed.resolve_halo_aggregation(
                        spec.axis, n, rows_local, cols,
                        dtype_bytes=max(1, spec.nbytes // max(1, cols)))
                entries[spec.label] = PlanEntry(
                    spec=spec, mode=d.mode, chunks=d.k, overlap_budget=1.0,
                    predicted_bulk_s=d.bulk_sweep_s,
                    predicted_interleaved_s=d.aggregated_sweep_s)
                continue
            if spec.kind == "attention":
                # The schedule knob: bulk gather vs ulysses a2a vs ring
                # streaming, routed through the managed runtime so the
                # choice lands in the MDMP decision log.
                (batch, s_local, heads, kv_heads, head_dim, d_model,
                 causal, ib) = spec.shape
                n = self.axis_sizes.get(spec.axis, 1)
                with managed.use_config(self.config):
                    d = managed.resolve_attention_schedule(
                        spec.axis, n, batch, s_local, heads, kv_heads,
                        head_dim, d_model, dtype_bytes=ib,
                        causal=bool(causal))
                entries[spec.label] = PlanEntry(
                    spec=spec, mode=d.schedule, chunks=1,
                    overlap_budget=1.0, predicted_bulk_s=d.bulk_s,
                    predicted_interleaved_s=d.chosen_s)
                continue
            if spec.kind == "pipeline":
                # The schedule knob: gpipe vs 1f1b vs interleaved plus the
                # microbatch count, routed through the managed runtime so
                # the choice lands in the MDMP decision log.  The stage
                # boundary's instrumented readiness bounds how much of a
                # tick's compute can hide the handoff bytes.
                n_layers, fwd_ps = spec.shape
                n = self.axis_sizes.get(spec.axis, 1)
                budget = (report.overlap_budget(spec.label)
                          if spec.label in report.records else 1.0)
                with managed.use_config(self.config):
                    d = managed.resolve_pipeline_schedule(
                        spec.axis, n, fwd_ps * 1e-12, spec.nbytes,
                        n_layers=n_layers, overlap_budget=budget)
                entries[spec.label] = PlanEntry(
                    spec=spec, mode=d.schedule, chunks=d.n_micro,
                    overlap_budget=budget, predicted_bulk_s=d.bulk_s,
                    predicted_interleaved_s=d.chosen_s)
                continue
            if spec.kind == "moe":
                # The dispatch knob: bulk a2a vs chunked-stream vs dense
                # fallback plus the capacity factor, routed through the
                # managed runtime so the choice lands in the MDMP
                # decision log.
                (tokens_local, d_model, n_experts, top_k, d_ff_expert,
                 cf_milli, mults, ib) = spec.shape
                n = self.axis_sizes.get(spec.axis, 1)
                with managed.use_config(self.config):
                    d = managed.resolve_moe_dispatch(
                        spec.axis, n, tokens_local, d_model, n_experts,
                        top_k, d_ff_expert, mults=mults, dtype_bytes=ib,
                        capacity_factor=cf_milli / 1000.0)
                entries[spec.label] = PlanEntry(
                    spec=spec, mode=d.schedule, chunks=d.g,
                    overlap_budget=1.0, predicted_bulk_s=d.bulk_s,
                    predicted_interleaved_s=d.chosen_s)
                continue
            if spec.kind == "ckpt":
                # The cadence knob: the Young/Daly interval, routed
                # through the managed runtime so the choice lands in the
                # MDMP decision log — recovery traffic priced like the
                # forward-path collectives.
                nbytes, step_ns, mtbf_s, bw = spec.shape
                with managed.use_config(self.config):
                    d = managed.resolve_checkpoint(
                        spec.axis, step_ns * 1e-9, nbytes,
                        mtbf_s=float(mtbf_s),
                        measured_write_bw=float(bw) if bw else None)
                entries[spec.label] = PlanEntry(
                    spec=spec, mode=d.mode, chunks=d.interval,
                    overlap_budget=1.0,
                    predicted_bulk_s=d.fixed_overhead,
                    predicted_interleaved_s=d.chosen_overhead)
                continue
            if spec.kind == "serve":
                # The batching knob: static waves vs continuous batching
                # plus the scheduling quantum C, routed through the managed
                # runtime so the choice lands in the MDMP decision log.
                (batch_slots, mean_prompt, mean_new, max_prompt,
                 n_params, ib) = spec.shape
                with managed.use_config(self.config):
                    d = managed.resolve_serve_schedule(
                        spec.axis, batch_slots, mean_prompt, mean_new,
                        n_params, dtype_bytes=ib, max_prompt=max_prompt)
                entries[spec.label] = PlanEntry(
                    spec=spec, mode=d.mode, chunks=d.chunk,
                    overlap_budget=1.0,
                    predicted_bulk_s=1.0 / max(d.static_tok_s, 1e-30),
                    predicted_interleaved_s=1.0 / max(d.chosen_tok_s,
                                                      1e-30))
                continue
            if spec.kind == "preempt":
                # The overload backstop knob: swap-to-host vs drop-and-
                # recompute vs head-of-line wait, routed through the
                # managed runtime so the eviction policy lands in the
                # MDMP decision log next to the serve schedule it backs.
                (batch_slots, page_bytes, mean_pages, mean_prompt,
                 n_params, ib) = spec.shape
                with managed.use_config(self.config):
                    d = managed.resolve_preempt(
                        spec.axis, mean_pages, page_bytes, mean_prompt,
                        n_params, batch_slots=batch_slots,
                        dtype_bytes=ib)
                entries[spec.label] = PlanEntry(
                    spec=spec, mode=d.policy, chunks=1,
                    overlap_budget=1.0,
                    predicted_bulk_s=d.recompute_s,
                    predicted_interleaved_s=d.chosen_s)
                continue
            budget = (report.overlap_budget(spec.label)
                      if spec.label in report.records else 1.0)
            # Compute time available for overlap: caller-supplied estimate
            # scaled by the instrumented budget.
            ct = (compute_time_s or 0.0) * budget
            n = self.axis_sizes.get(spec.axis, 1)
            decision = cost_model.decide(
                spec.nbytes, n, compute_time_s=ct, hw=self.config.hw,
                collective=spec.collective,
                force_mode=None if self.config.mode == "auto"
                else self.config.mode)
            entries[spec.label] = PlanEntry(
                spec=spec, mode=decision.mode, chunks=decision.chunks,
                overlap_budget=budget,
                predicted_bulk_s=decision.bulk_time_s,
                predicted_interleaved_s=decision.interleaved_time_s)
        self._plan = Plan(entries=entries, total_eqns=report.total_eqns)
        return self._plan

    @property
    def last_plan(self) -> Plan | None:
        return self._plan

    @property
    def last_report(self) -> instrument.RegionReport | None:
        """The instrumentation report of the last ``plan()`` — the
        readiness windows and extracted collectives the whole-program
        planner lowers against (plan/ir.lower_region)."""
        return self._report

    def lower(self):
        """Lower this region's declarations to planner CommOps (plan/ir),
        windows refined by the last ``plan()``'s instrumentation when
        available.  Lazy import: core must not depend on plan/."""
        from repro.plan.ir import lower_region
        return lower_region(self, self._report)
