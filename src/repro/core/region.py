"""Communication regions — the paper's ``#pragma commregion`` facade.

A ``CommRegion`` is the declarative surface of MDMP: the user states which
operands are sent/received (``region.send(...)`` / ``region.recv(...)``)
and wraps the computation that produces/consumes them.  The region then

  1. traces the wrapped function and runs the data-access instrumentation
     (instrument.py) to find each operand's readiness / consumption slack —
     the trace-time analogue of the paper's runtime read/write counters;
  2. feeds operand bytes + the overlap budget into the alpha-beta cost
     model to pick bulk vs interleaved and a chunk count per declaration;
  3. exposes the resulting ``Plan`` and executes managed collectives
     accordingly.

Outside a region (paper Table 2), nothing is instrumented and every
managed op that specifies ``mode=None`` falls through to the global
MDMPConfig — by default plain bulk collectives with zero overhead.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax

from repro.core import cost_model, instrument
from repro.core.managed import MDMPConfig, get_config


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """One declared communication (a ``#pragma send``/``recv``/collective)."""
    label: str
    kind: str                  # "send" | "recv" | "all_gather" | ...
    axis: str                  # mesh axis the message crosses
    nbytes: int
    collective: str = "all_gather"   # cost-model family


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    spec: CommSpec
    mode: str
    chunks: int
    overlap_budget: float      # fraction of region compute available
    predicted_bulk_s: float
    predicted_interleaved_s: float


@dataclasses.dataclass
class Plan:
    entries: dict[str, PlanEntry]
    total_eqns: int

    def mode_for(self, label: str) -> str:
        return self.entries[label].mode

    def chunks_for(self, label: str) -> int:
        return self.entries[label].chunks

    def summary(self) -> str:
        lines = [f"MDMP plan ({self.total_eqns} eqns in region):"]
        for e in self.entries.values():
            lines.append(
                f"  {e.spec.label:24s} {e.spec.kind:12s} axis={e.spec.axis} "
                f"{e.spec.nbytes/1e6:9.3f}MB -> {e.mode}(chunks={e.chunks}) "
                f"overlap_budget={e.overlap_budget:.2f} "
                f"bulk={e.predicted_bulk_s*1e6:.1f}us "
                f"interleaved={e.predicted_interleaved_s*1e6:.1f}us")
        return "\n".join(lines)


class CommRegion:
    """Declarative communication region.

    Usage (the paper's Figure 4, in JAX)::

        region = CommRegion("jacobi", axis_sizes={"x": 16})
        region.send("halo_lo", axis="x", shape=(NP,), dtype=jnp.float32)
        region.send("halo_hi", axis="x", shape=(NP,), dtype=jnp.float32)
        plan = region.plan(step_fn, u0)       # trace + instrument + decide
        mode = plan.mode_for("halo_lo")        # feed into managed halo call
    """

    def __init__(self, name: str, axis_sizes: dict[str, int],
                 config: MDMPConfig | None = None):
        self.name = name
        self.axis_sizes = dict(axis_sizes)
        self.config = config or get_config()
        self._specs: list[CommSpec] = []
        self._plan: Plan | None = None

    # -- declarations -------------------------------------------------------

    def _declare(self, label: str, kind: str, axis: str, shape, dtype,
                 collective: str) -> None:
        import numpy as np
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        self._specs.append(CommSpec(label=label, kind=kind, axis=axis,
                                    nbytes=nbytes, collective=collective))

    def send(self, label: str, *, axis: str, shape, dtype) -> None:
        self._declare(label, "send", axis, shape, dtype, "all_gather")

    def recv(self, label: str, *, axis: str, shape, dtype) -> None:
        self._declare(label, "recv", axis, shape, dtype, "all_gather")

    def collective(self, label: str, *, axis: str, shape, dtype,
                   collective: str) -> None:
        self._declare(label, collective, axis, shape, dtype, collective)

    # -- planning -----------------------------------------------------------

    def plan(self, fn: Callable, *example_args: Any,
             tracked_args: Sequence[int] | None = None,
             compute_time_s: float | None = None) -> Plan:
        """Trace ``fn`` (the region body, per-shard view), instrument the
        access pattern of the tracked args (positionally matched to the
        declared specs) and decide each communication's schedule."""
        n_specs = len(self._specs)
        if tracked_args is None:
            tracked_args = list(range(min(n_specs, 1)))
        labels = [s.label for s in self._specs[:len(tracked_args)]]
        report = instrument.analyze_region(
            fn, *example_args, tracked_args=list(tracked_args), labels=labels)

        entries: dict[str, PlanEntry] = {}
        for spec in self._specs:
            budget = (report.overlap_budget(spec.label)
                      if spec.label in report.records else 1.0)
            # Compute time available for overlap: caller-supplied estimate
            # scaled by the instrumented budget.
            ct = (compute_time_s or 0.0) * budget
            n = self.axis_sizes.get(spec.axis, 1)
            decision = cost_model.decide(
                spec.nbytes, n, compute_time_s=ct, hw=self.config.hw,
                collective=spec.collective,
                force_mode=None if self.config.mode == "auto"
                else self.config.mode)
            entries[spec.label] = PlanEntry(
                spec=spec, mode=decision.mode, chunks=decision.chunks,
                overlap_budget=budget,
                predicted_bulk_s=decision.bulk_time_s,
                predicted_interleaved_s=decision.interleaved_time_s)
        self._plan = Plan(entries=entries, total_eqns=report.total_eqns)
        return self._plan

    @property
    def last_plan(self) -> Plan | None:
        return self._plan
