"""MDMP core — the paper's contribution as a composable JAX module.

Public surface:
  * managed collectives (bulk / interleaved / auto) .......... managed.py
  * fused comm+compute rings (AG-matmul, matmul-RS) ........... managed.py
  * halo exchange + the paper's Jacobi schedules .............. halo.py
  * communication regions (declarative directives) ............ region.py
  * trace-time read/write instrumentation ..................... instrument.py
  * alpha-beta cost model + roofline terms .................... cost_model.py
  * as-ready gradient reduction / FSDP overlap ................ overlap.py
  * runtime schedule tuner ..................................... tuner.py
"""

from repro.core.cost_model import (DEFAULT_HW, HECTOR_XE6, HELIOS_BULLX,
                                   JUQUEEN_BGQ, TPU_V5E,
                                   HaloAggregationDecision, HardwareModel,
                                   PipelineScheduleDecision, RooflineTerms,
                                   crossover_compute_per_element,
                                   decide, decide_halo_aggregation,
                                   decide_pipeline_schedule,
                                   halo_sweep_time, roofline)
from repro.core.halo import (halo_exchange, jacobi_solve,
                             jacobi_step_aggregated, jacobi_step_bulk,
                             jacobi_step_overlapped)
from repro.core.instrument import AccessRecord, RegionReport, analyze_region
from repro.core.managed import (DecisionRecord, MDMPConfig,
                                all_gather_matmul, clear_decision_log,
                                decision_log, get_config, managed_all_gather,
                                managed_all_reduce, managed_all_to_all,
                                managed_psum_scatter_gather,
                                managed_reduce_scatter, matmul_reduce_scatter,
                                resolve_halo_aggregation,
                                resolve_pipeline_schedule, use_config)
from repro.core.overlap import (bucketed_all_reduce, fsdp_gather,
                                fsdp_gather_tree, grad_accumulate,
                                reduce_replicated_grads)
from repro.core.region import CommRegion, CommSpec, Plan, PlanEntry
from repro.core.tuner import ScheduleTuner, TunerEntry, call_site_key

__all__ = [
    "AccessRecord", "CommRegion", "CommSpec", "DEFAULT_HW", "DecisionRecord",
    "HardwareModel", "HECTOR_XE6", "HELIOS_BULLX", "JUQUEEN_BGQ",
    "MDMPConfig", "Plan", "PlanEntry", "RegionReport", "RooflineTerms",
    "ScheduleTuner", "TPU_V5E", "TunerEntry", "all_gather_matmul",
    "analyze_region", "bucketed_all_reduce", "call_site_key",
    "clear_decision_log", "crossover_compute_per_element", "decide",
    "decide_halo_aggregation", "decision_log", "fsdp_gather",
    "fsdp_gather_tree", "get_config", "grad_accumulate",
    "HaloAggregationDecision", "halo_exchange", "halo_sweep_time",
    "decide_pipeline_schedule", "jacobi_solve", "jacobi_step_aggregated",
    "jacobi_step_bulk", "jacobi_step_overlapped", "managed_all_gather",
    "managed_all_reduce", "managed_all_to_all",
    "managed_psum_scatter_gather", "managed_reduce_scatter",
    "matmul_reduce_scatter", "PipelineScheduleDecision",
    "reduce_replicated_grads", "resolve_halo_aggregation",
    "resolve_pipeline_schedule", "roofline", "use_config",
]
