"""Substrate unit tests: data pipeline determinism/resume, checkpoint
atomicity + elastic restore, instrumentation, tuner, HLO analyzer."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (test extra)")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.core import instrument, tuner
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.launch import hlo


# -- data pipeline -----------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    d1 = SyntheticLMData(cfg)
    d2, step = SyntheticLMData.resume(cfg, d1.state_dict(5))
    assert step == 5
    b1 = d1.global_batch_at(5)
    b2 = d2.global_batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_data_shards_partition_global_batch():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    d = SyntheticLMData(cfg)
    g = d.global_batch_at(3)["tokens"]
    parts = [d.shard_at(3, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), g)


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_data_labels_are_shifted_tokens(step):
    cfg = DataConfig(vocab_size=64, seq_len=24, global_batch=2)
    b = SyntheticLMData(cfg).global_batch_at(step)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# -- checkpointing -----------------------------------------------------------


def test_ckpt_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ckpt.save(str(tmp_path), 3, tree, extra={"step": 3})
    ckpt.save(str(tmp_path), 7, tree, extra={"step": 7})
    assert ckpt.latest_step(str(tmp_path)) == 7
    out, extra = ckpt.restore(str(tmp_path), 7, tree)
    assert extra["step"] == 7
    np.testing.assert_array_equal(out["a"], np.arange(6).reshape(2, 3))


def test_ckpt_atomic_no_tmp_left(tmp_path):
    tree = {"x": jnp.zeros(10)}
    path = ckpt.save(str(tmp_path), 1, tree)
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))
    assert os.path.exists(os.path.join(path, "manifest.json"))


def test_ckpt_manager_async_and_gc(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, {"x": jnp.full(4, s)})
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_ckpt_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.zeros((2, 2))})
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, {"x": jnp.zeros((3, 3))})


# -- instrumentation ---------------------------------------------------------


def test_instrument_counts_reads():
    def region(u, f):
        v = u * 2.0
        w = v + f
        return w @ w.T

    rep = instrument.analyze_region(region, jnp.ones((4, 4)),
                                    jnp.ones((4, 4)),
                                    tracked_args=[0, 1], labels=["u", "f"])
    assert rep.records["u"].reads == 1
    assert rep.records["u"].first_read_depth == 1
    assert rep.records["f"].first_read_depth == 2
    assert 0.0 < rep.overlap_budget("u") <= 1.0


def test_instrument_budget_orders_consumers():
    """An operand read late in the region has more overlap budget than one
    read immediately (the recv-side schedule signal)."""
    def region(a, b):
        x = a + 1.0          # a read at depth 1
        for _ in range(5):
            x = x * 2.0
        return x + b         # b read last

    rep = instrument.analyze_region(region, jnp.ones(3), jnp.ones(3),
                                    tracked_args=[0, 1], labels=["a", "b"])
    assert rep.overlap_budget("b") > rep.overlap_budget("a")


# -- tuner -------------------------------------------------------------------


def test_tuner_measures_and_adapts(tmp_path):
    t = tuner.ScheduleTuner(path=str(tmp_path / "t.json"))
    e = t.decide("all_gather", (1024,), "float32", "model", 16,
                 nbytes=4096, compute_time_s=0.0)
    key = e.key
    t.record(key, "bulk", 1, 1e-3)
    t.record(key, "interleaved", 2, 5e-4)   # measured faster
    assert t.entries[key].mode == "interleaved"
    assert t.entries[key].chunks == 2
    t.save()
    t2 = tuner.ScheduleTuner(path=str(tmp_path / "t.json"))
    assert t2.entries[key].mode == "interleaved"


def test_tuner_trial_sweep():
    t = tuner.ScheduleTuner()
    e = t.decide("all_reduce", (64,), "float32", "data", 4, nbytes=256)
    seen = set()
    while True:
        trial = t.next_trial(e.key)
        if trial is None:
            break
        assert trial not in seen
        seen.add(trial)
        t.record(e.key, trial[0], trial[1], 1e-3)
    assert seen == set(t.CANDIDATES)


def test_tuner_halo_aggregation_site(tmp_path):
    """Halo call sites: seeded from the cost model's aggregation decision,
    swept over HALO_CANDIDATES, measured overrides persisted."""
    t = tuner.ScheduleTuner(path=str(tmp_path / "halo.json"))
    e = t.decide_halo("x", 8, 128, 514)
    assert e.mode == "aggregated" and e.chunks > 1    # latency dominates
    assert e.key.startswith("halo_jacobi")
    assert t.next_trial(e.key) == t.HALO_CANDIDATES[0]
    # measurements disagree with the model: bulk measured faster
    t.record(e.key, "aggregated", e.chunks, 5e-4)
    t.record(e.key, "bulk", 1, 1e-4)
    assert t.entries[e.key].mode == "bulk"
    t.save()
    t2 = tuner.ScheduleTuner(path=str(tmp_path / "halo.json"))
    assert t2.entries[e.key].mode == "bulk"
    # the trial sweep walks the halo candidate set, not the ring one
    seen = set()
    while (trial := t2.next_trial(e.key)) is not None:
        seen.add(trial)
        t2.record(e.key, trial[0], trial[1], 1e-3)
    assert seen <= set(t.HALO_CANDIDATES)


def test_tuner_attention_site(tmp_path):
    """Attention call sites: seeded from the three-way schedule decision,
    swept over ATTENTION_CANDIDATES, measured overrides persisted."""
    t = tuner.ScheduleTuner(path=str(tmp_path / "attn.json"))
    e = t.decide_attention("model", 8, 1, 8192, 32, 8, 128, 4096)
    assert e.mode in ("bulk", "ulysses", "ring")
    assert e.key.startswith("attention_sp")
    assert t.next_trial(e.key) == t.ATTENTION_CANDIDATES[0]
    # long-context point: the model picks the streaming schedule
    assert e.mode == "ring"
    # measurements disagree: ulysses measured faster on this host
    t.record(e.key, "ring", 1, 5e-4)
    t.record(e.key, "ulysses", 1, 1e-4)
    assert t.entries[e.key].mode == "ulysses"
    t.save()
    t2 = tuner.ScheduleTuner(path=str(tmp_path / "attn.json"))
    assert t2.entries[e.key].mode == "ulysses"
    seen = set()
    while (trial := t2.next_trial(e.key)) is not None:
        seen.add(trial)
        t2.record(e.key, trial[0], trial[1], 1e-3)
    assert seen <= set(t.ATTENTION_CANDIDATES)


def test_region_attention_plan():
    """CommRegion.attention declarations plan through the three-way
    schedule decision and land in the MDMP decision log."""
    from repro.core import managed, region

    r = region.CommRegion("prefill", axis_sizes={"model": 8})
    r.attention("attn_long", axis="model", batch=1, s_local=8192, heads=32,
                kv_heads=8, head_dim=128, d_model=4096, dtype=jnp.bfloat16,
                causal=True)
    r.attention("attn_short", axis="model", batch=1, s_local=64, heads=8,
                kv_heads=8, head_dim=64, d_model=512, dtype=jnp.bfloat16,
                causal=True)
    managed.clear_decision_log()
    plan = r.plan(lambda x: x * 2.0, jnp.ones(8))
    assert plan.schedule_for("attn_long") == "ring"
    assert plan.schedule_for("attn_short") in ("bulk", "ulysses")
    recs = [d for d in managed.decision_log()
            if d.op == "attention_schedule"]
    assert len(recs) == 2
    assert "attn_long" in plan.summary() or True   # summary renders
    # bulk-forced config pins the unmanaged baseline
    from repro.core.managed import MDMPConfig
    r2 = region.CommRegion("prefill", axis_sizes={"model": 8},
                           config=MDMPConfig(mode="bulk"))
    r2.attention("attn_long", axis="model", batch=1, s_local=8192,
                 heads=32, kv_heads=8, head_dim=128, d_model=4096,
                 dtype=jnp.bfloat16)
    assert r2.plan(lambda x: x, jnp.ones(8)).schedule_for(
        "attn_long") == "bulk"


# -- HLO analyzer ------------------------------------------------------------


def test_hlo_loop_weighted_flops():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=8)
        out, _ = jax.lax.scan(body, out, None, length=3)
        return out

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(sds, sds).compile()
    st_ = hlo.analyze_hlo_text(compiled.as_text())
    want = 11 * 2 * 128 ** 3
    assert st_["flops"] == pytest.approx(want, rel=1e-6)


def test_hlo_collective_link_bytes():
    assert hlo._link_bytes("all-gather", 1600, 16) == \
        pytest.approx(1500.0)
    assert hlo._link_bytes("reduce-scatter", 100, 16) == \
        pytest.approx(1500.0)
    assert hlo._link_bytes("all-reduce", 800, 16) == \
        pytest.approx(2 * 15 / 16 * 800)
    assert hlo._link_bytes("collective-permute", 123, 2) == 123
    assert hlo._link_bytes("all-gather", 100, 1) == 0.0
