"""mdmplint — the static communication verifier (repro.analysis):
graph construction from the three truth sources, all five pass
families positive + negative, the lint corpus golden codes, the
launcher preflight modes, declaration-time axis validation
(UnknownAxisError / MDMP001), scan-body collective extraction with
trip counts, and the permutation bijection/ring properties of every
permute the repo constructs."""

import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import analysis
from repro.analysis.graph import (BufferAccess, CommGraph, InFlight,
                                  PermuteSite, WaitEdge, _KnobTable)
from repro.core import cost_model, instrument, managed
from repro.core.managed import _ring_perm
from repro.core.region import CommRegion, UnknownAxisError
from repro.plan import CommOp, lower_collectives, train_geometry

CORPUS = os.path.join(os.path.dirname(__file__), "lint_corpus")


def _codes(diags):
    return sorted({d.code for d in diags})


# -- satellite: declaration-time axis validation ----------------------------


def test_typo_axis_raises_at_declaration():
    region = CommRegion("r", axis_sizes={"model": 4, "data": 2})
    with pytest.raises(UnknownAxisError) as ei:
        region.send("grads", axis="modle", shape=(16,),
                    dtype=jnp.float32)
    assert ei.value.axis == "modle"
    assert "MDMP001" in str(ei.value)
    assert region._specs == []          # nothing half-declared


def test_typo_axis_raises_for_subsystem_declarations():
    region = CommRegion("r", axis_sizes={"x": 8})
    with pytest.raises(UnknownAxisError):
        region.halo("h", axis="y", rows_local=32, cols=64,
                    dtype=jnp.float32)
    with pytest.raises(UnknownAxisError):
        region.moe("m", axis="pod", tokens_local=64, d_model=8,
                   n_experts=4, top_k=1, d_ff_expert=16,
                   dtype=jnp.float32)


def test_valid_declaration_captures_site():
    region = CommRegion("r", axis_sizes={"model": 4})
    region.send("kv", axis="model", shape=(16,), dtype=jnp.float32)
    spec = region._specs[0]
    assert spec.site is not None
    assert spec.site[0].endswith("test_analysis.py")
    ops = analysis.from_ops("r", axis_sizes=region.axis_sizes,
                            declared=region.lower()).declared
    assert ops[0].meta["site"][0].endswith("test_analysis.py")


# -- satellite: scan-body collective extraction with trip counts ------------


def test_scan_body_ppermute_extracted_once_with_trips():
    """A ring ppermute inside ``lax.scan`` must surface exactly once per
    logical site, carrying the scan's trip count — not dropped, not
    double-counted."""
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("x", "y"))
    LEN = 5

    def body(a):
        def step(carry, _):
            carry = lax.ppermute(carry, "x", [(0, 0)])
            return carry, carry.sum()
        out, sums = lax.scan(step, a, None, length=LEN)
        return out.sum() + sums.sum()

    f = shard_map(body, mesh=mesh, in_specs=(P("x"),), out_specs=P(),
                  check_rep=False)
    rep = instrument.analyze_region(f, jnp.ones((4, 2), jnp.float32))
    perms = [c for c in rep.collectives if c.primitive == "ppermute"]
    assert len(perms) == 1              # one logical site
    assert perms[0].trips == LEN        # executed LEN times
    assert perms[0].nbytes == 4 * 2 * 4
    # bytes-by-axis prices the trips (the drift pass compares this
    # against declarations)
    assert rep.collective_bytes_by_axis()["x"] == LEN * 4 * 2 * 4
    # provenance survives into the lowered comm-IR op
    ops = lower_collectives(perms, {"x": 1})
    assert ops[0].meta["trips"] == LEN
    assert ops[0].meta["source"].endswith(
        f"test_analysis.py:{body.__code__.co_firstlineno + 2}")


def test_scan_carry_binders_align_with_closure_consts():
    """A scanned body that CLOSES OVER a constant: the sub-jaxpr gains
    constvars, and the carry/xs binder alignment must not slide (the
    binder-misalignment class) — the tracked operand's accesses inside
    the loop still resolve."""
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("x", "y"))
    big = jnp.arange(8, dtype=jnp.float32)        # becomes a const

    def body(a):
        def step(carry, _):
            carry = lax.ppermute(carry + big[:4].sum(), "x", [(0, 0)])
            return carry, ()
        out, _ = lax.scan(step, a, None, length=3)
        return out.sum()

    f = shard_map(body, mesh=mesh, in_specs=(P(None),), out_specs=P(),
                  check_rep=False)
    rep = instrument.analyze_region(f, jnp.ones((4,), jnp.float32))
    perms = [c for c in rep.collectives if c.primitive == "ppermute"]
    assert len(perms) == 1 and perms[0].trips == 3


# -- satellite: bijection / ring-closure properties of repo permutes --------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _check_perm_properties(n: int, shift: int):
    perm = _ring_perm(n, shift)
    srcs, dsts = [a for a, _ in perm], [b for _, b in perm]
    # bijection on the axis
    assert sorted(srcs) == list(range(n))
    assert sorted(dsts) == list(range(n))
    # returns home after axis_size applications
    f = {a: b for a, b in perm}
    for start in range(n):
        i = start
        for _ in range(n):
            i = f[i]
        assert i == start
    # the analyzer agrees
    g = CommGraph("perm", {"ax": n})
    g.permutes = [PermuteSite("p", "ax", n, tuple(perm),
                              ring=(np.gcd(abs(shift) % n or n, n) == 1))]
    assert analysis.run_all(g) == []


def _perm_cases():
    """Every permutation family the repo constructs: ring attention
    fwd/bwd (shift +-1), pipeline fwd/bwd ticks (shift +-1 on the stage
    axis, incl. interleaved chunk wraps riding the same ring), and MoE
    stream chunks (shift s+1 forward, -s return)."""
    for n in range(1, 13):
        yield n, 1                      # ring attention kv / pipeline fwd
        yield n, -1                     # dk-dv ring / pipeline bwd
        for s in range(1, n):           # MoE stream forward shifts
            yield n, s


if HAVE_HYPOTHESIS:
    @given(n=st.integers(min_value=1, max_value=64),
           shift=st.integers(min_value=-64, max_value=64))
    @settings(max_examples=200, deadline=None)
    def test_ring_perm_properties(n, shift):
        _check_perm_properties(n, shift)
else:
    def test_ring_perm_properties():
        # deterministic sweep fallback: hypothesis is not installed in
        # this environment (and nothing may be pip-installed)
        for n, shift in _perm_cases():
            _check_perm_properties(n, shift)
        rng = np.random.default_rng(0)
        for _ in range(100):
            n = int(rng.integers(1, 65))
            _check_perm_properties(n, int(rng.integers(-64, 65)))


def test_moe_stream_pairs_compose_to_identity():
    """Expert-stream step s sends forward with shift s (cumulative) and
    returns results with shift -s — each pair must compose to the
    identity, and the analyzer's pair check must agree."""
    for n in range(2, 9):
        for s in range(1, n):
            fwd = {a: b for a, b in _ring_perm(n, s)}
            ret = {a: b for a, b in _ring_perm(n, -s)}
            assert all(ret[fwd[i]] == i for i in range(n))
    g = CommGraph("moe", {"model": 8})
    g.permutes = [PermuteSite(f"s{s}", "model", 8,
                              tuple(_ring_perm(8, s)), pair=(s, -s))
                  for s in range(1, 8)]
    assert analysis.run_all(g) == []
    g.permutes = [PermuteSite("bad", "model", 8,
                              tuple(_ring_perm(8, 2)), pair=(2, -3))]
    assert _codes(analysis.run_all(g)) == ["MDMP202"]


def test_pipeline_tick_perms_are_inverse_rings():
    s = 6
    fwd = {a: b for a, b in _ring_perm(s, 1)}     # act handoff
    bwd = {a: b for a, b in _ring_perm(s, -1)}    # grad handoff
    assert all(bwd[fwd[i]] == i for i in range(s))
    g = CommGraph("pipe", {"pod": s})
    g.permutes = analysis.derive_permutes(
        [CommOp(kind="pipeline", label="p", op_name="pipeline_schedule",
                axis="pod", axis_size=s, nbytes=1,
                meta={"n_layers": 12})], {"pod": s})
    assert {p.label for p in g.permutes} == {"p.fwd_tick", "p.bwd_tick"}
    assert analysis.run_all(g) == []


# -- the five pass families: positive + negative ----------------------------


def test_pass_axes():
    ok = CommOp(kind="all_reduce", label="g", op_name="all_reduce",
                axis="data", axis_size=2, nbytes=8)
    bad = CommOp(kind="all_reduce", label="g2", op_name="all_reduce",
                 axis="dta", axis_size=2, nbytes=8)
    assert analysis.check_axes(
        CommGraph("t", {"data": 2}, declared=[ok])) == []
    diags = analysis.check_axes(
        CommGraph("t", {"data": 2}, declared=[ok, bad]))
    assert _codes(diags) == ["MDMP001"] and diags[0].label == "g2"


def test_pass_drift():
    decl = [CommOp(kind="all_gather", label="kv", op_name="all_gather",
                   axis="model", axis_size=4, nbytes=1000)]
    traced_ok = [CommOp(kind="collective", label="ag#0",
                        op_name="all_gather", axis="model", axis_size=4,
                        nbytes=1000, meta={"trips": 2})]
    g = CommGraph("t", {"model": 4}, declared=decl, traced=traced_ok)
    assert analysis.check_drift(g) == []
    # trips push the traced bytes past the 4x tolerance -> MDMP102
    g.traced = [CommOp(kind="collective", label="ag#0",
                       op_name="all_gather", axis="model", axis_size=4,
                       nbytes=1000, meta={"trips": 9})]
    assert _codes(analysis.check_drift(g)) == ["MDMP102"]
    # traffic on an undeclared axis -> MDMP101
    g.traced.append(CommOp(kind="collective", label="ps#1",
                           op_name="all_reduce", axis="data",
                           axis_size=2, nbytes=64))
    assert "MDMP101" in _codes(analysis.check_drift(g))
    # declared axis with no traced traffic -> MDMP103 (warning)
    g2 = CommGraph("t", {"model": 4, "data": 2},
                   declared=decl + [CommOp(
                       kind="all_reduce", label="gr",
                       op_name="all_reduce", axis="data", axis_size=2,
                       nbytes=64)],
                   traced=traced_ok)
    d = analysis.check_drift(g2)
    assert _codes(d) == ["MDMP103"]
    assert all(x.severity == "warning" for x in d)
    # direct-collective family mismatch -> MDMP104 (warning)
    g3 = CommGraph("t", {"model": 4},
                   declared=[CommOp(kind="all_to_all", label="a2a",
                                    op_name="all_to_all", axis="model",
                                    axis_size=4, nbytes=1000)],
                   traced=traced_ok)
    assert _codes(analysis.check_drift(g3)) == ["MDMP104"]
    # no trace at all -> nothing to drift against
    assert analysis.check_drift(
        CommGraph("t", {"model": 4}, declared=decl)) == []


def test_pass_permutes():
    g = CommGraph("t", {"model": 4})
    g.permutes = [PermuteSite("ok", "model", 4,
                              tuple(_ring_perm(4)), ring=True)]
    assert analysis.check_permutes(g) == []
    g.permutes = [PermuteSite("dup", "model", 4,
                              ((0, 1), (1, 1), (2, 3), (3, 0)))]
    assert _codes(analysis.check_permutes(g)) == ["MDMP201"]
    g.permutes = [PermuteSite("swap", "model", 4,
                              ((0, 1), (1, 0), (2, 3), (3, 2)),
                              ring=True)]
    assert _codes(analysis.check_permutes(g)) == ["MDMP202"]
    # shift-2 ring on even n splits into two orbits -> not a full cycle
    g.permutes = [PermuteSite("even", "model", 4,
                              tuple(_ring_perm(4, 2)), ring=True)]
    assert _codes(analysis.check_permutes(g)) == ["MDMP202"]


def test_pass_ordering():
    a = CommOp(kind="all_gather", label="a", op_name="all_gather",
               axis="model", axis_size=4, nbytes=8, window=(0.0, 0.5))
    b = CommOp(kind="all_gather", label="b", op_name="all_gather",
               axis="model", axis_size=4, nbytes=8, window=(0.2, 0.7))
    g = CommGraph("t", {"model": 4}, declared=[a, b])
    assert analysis.check_ordering(g) == []       # serialized, acyclic
    # b's wire serializes after a, but a waits on b -> deadlock
    g.waits = [WaitEdge("b", "a", "a gates on b's arrival")]
    d = analysis.check_ordering(g)
    assert _codes(d) == ["MDMP301"] and "a" in d[0].message
    # pure wait cycle with no windows at all
    g2 = CommGraph("t", {"model": 4})
    g2.waits = [WaitEdge("x", "y"), WaitEdge("y", "z"),
                WaitEdge("z", "x")]
    assert _codes(analysis.check_ordering(g2)) == ["MDMP301"]


def test_pass_overlap():
    g = CommGraph("t", {"x": 8})
    g.inflight = [InFlight("ghost", 0.1, 0.5, "halo.xfer")]
    g.accesses = [BufferAccess("ghost", 0.7, "read", "sweep")]
    assert analysis.check_overlap(g) == []        # read after landing
    g.accesses = [BufferAccess("ghost", 0.3, "read", "sweep")]
    assert _codes(analysis.check_overlap(g)) == ["MDMP401"]
    g.accesses = [BufferAccess("ghost", 0.3, "write", "sweep")]
    assert _codes(analysis.check_overlap(g)) == ["MDMP402"]
    # two overlapping in-flight claims on one buffer (donation hazard)
    g.accesses = []
    g.inflight.append(InFlight("ghost", 0.4, 0.9, "halo.xfer2"))
    assert _codes(analysis.check_overlap(g)) == ["MDMP402"]


def test_pass_feasibility():
    moe = CommOp(kind="moe", label="m", op_name="moe_dispatch",
                 axis="model", axis_size=4, nbytes=1,
                 meta={"tokens_local": 64, "top_k": 2, "n_experts": 4,
                       "capacity_factor": 1.0})    # capacity C = 32
    pipe = CommOp(kind="pipeline", label="p", op_name="pipeline_schedule",
                  axis="pod", axis_size=2, nbytes=1,
                  meta={"local_batch": 8, "n_layers": 4,
                        "batch_bytes": 1 << 30})
    halo = CommOp(kind="halo", label="h", op_name="halo_aggregation",
                  axis="x", axis_size=4, nbytes=1,
                  meta={"rows_local": 16, "cols": 64})
    sizes = {"model": 4, "pod": 2, "x": 4}
    good = _KnobTable({"moe_dispatch|model": {"mode": "stream",
                                              "chunks": 4},
                       "pipeline_schedule|pod": {"mode": "1f1b",
                                                 "chunks": 4},
                       "halo_aggregation|x": {"mode": "aggregated",
                                              "chunks": 8}})
    g = CommGraph("t", sizes, declared=[moe, pipe, halo], plan=good,
                  stash_cap_bytes=1 << 40)
    assert analysis.check_feasibility(g) == []
    bad = _KnobTable({"moe_dispatch|model": {"mode": "stream",
                                             "chunks": 5},
                      "pipeline_schedule|pod": {"mode": "interleaved",
                                                "chunks": 3,
                                                "virtual": 2},
                      "halo_aggregation|x": {"mode": "aggregated",
                                             "chunks": 64}})
    g.plan = bad
    g.stash_cap_bytes = 1 << 20
    codes = [d.code for d in analysis.check_feasibility(g)]
    assert codes.count("MDMP501") == 1            # 32 % 5 != 0
    assert codes.count("MDMP502") == 2            # 8 % 3, 3 % S=2
    assert codes.count("MDMP503") == 1            # stash over 1MB cap
    assert codes.count("MDMP504") == 1            # k=64 > 16 rows
    # no plan -> feasibility has nothing to check
    g.plan = None
    assert analysis.check_feasibility(g) == []


# -- the golden corpus -------------------------------------------------------


@pytest.mark.parametrize("path", sorted(glob.glob(
    os.path.join(CORPUS, "*.json"))), ids=os.path.basename)
def test_lint_corpus_golden_codes(path):
    """Every deliberately-broken corpus config yields EXACTLY its golden
    diagnostic codes (and clean.json yields none)."""
    with open(path) as f:
        case = json.load(f)
    graph = analysis.from_corpus(case)
    diags = analysis.run_all(graph)
    assert _codes(diags) == sorted(set(case["expect"]))
    assert analysis.exit_code(diags) == (
        1 if any(analysis.CODES[c][0] == "error"
                 for c in case["expect"]) else 0)


def test_lint_cli_on_corpus(capsys):
    from repro.launch import lint
    rc = lint.main(["--case",
                    os.path.join(CORPUS, "nondivisor_g.json"), "-v"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "MDMP501" in out and "fix      |" in out
    rc = lint.main(["--case", os.path.join(CORPUS, "clean.json")])
    assert rc == 0
    assert "clean (0 diagnostics)" in capsys.readouterr().out


def test_lint_cli_train_geometry(capsys):
    """The launcher-preflight path: geometry-only lint of a pipelined
    train config (no devices needed) comes back clean."""
    from repro.launch import lint
    rc = lint.main(["--target", "train", "--arch", "granite-34b",
                    "--reduced", "--mesh", "2x2x2", "--pipeline", "1f1b",
                    "--batch", "8", "--seq", "32"])
    assert rc == 0
    assert "clean" in capsys.readouterr().out


# -- preflight modes ---------------------------------------------------------


def _broken_graph():
    g = CommGraph("broken", {"model": 4})
    g.permutes = [PermuteSite("dup", "model", 4,
                              ((0, 1), (1, 1), (2, 3), (3, 0)))]
    return g


def test_preflight_off_and_warn_and_strict():
    assert analysis.preflight(_broken_graph(), "off",
                              out=lambda s: None) == []
    managed.clear_decision_log()
    diags = analysis.preflight(_broken_graph(), "warn",
                               out=lambda s: None)
    assert _codes(diags) == ["MDMP201"]
    recs = [r for r in managed.decision_log() if r.op == "lint"]
    assert len(recs) == 1
    assert recs[0].chunks == 1 and recs[0].nbytes == 1   # 1 diag, 1 err
    with pytest.raises(SystemExit):
        analysis.preflight(_broken_graph(), "strict",
                           out=lambda s: None)
    # strict on a clean graph does not raise
    clean = CommGraph("clean", {"model": 4})
    assert analysis.preflight(clean, "strict", out=lambda s: None) == []


def test_strict_renders_side_by_side():
    lines = []
    decl = [CommOp(kind="all_gather", label="kv", op_name="all_gather",
                   axis="model", axis_size=4, nbytes=100,
                   meta={"site": ("src/repro/x.py", 7)})]
    traced = [CommOp(kind="collective", label="ag#0",
                     op_name="all_gather", axis="model", axis_size=4,
                     nbytes=100, meta={"trips": 99,
                                       "source": "src/repro/x.py:52"})]
    g = CommGraph("t", {"model": 4}, declared=decl, traced=traced)
    with pytest.raises(SystemExit):
        analysis.preflight(g, "strict", out=lines.append)
    text = "\n".join(lines)
    assert "declared |" in text and "traced   |" in text
    assert "src/repro/x.py:52" in text      # file:line provenance


# -- graph construction from a real region + trace --------------------------


def test_graph_from_region_trace_and_plan():
    """End-to-end over the three truth sources: declare, trace, plan —
    an undeclared collective in the trace surfaces as MDMP101 with its
    eqn provenance."""
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("x", "y"))
    region = CommRegion("r", axis_sizes={"x": 1, "y": 1})
    region.send("gathered", axis="x", shape=(4, 2), dtype=jnp.float32)

    def body(a, b):
        g = lax.all_gather(a, "x", tiled=True)
        s = lax.psum(b, "y")                 # never declared
        return g.sum() + s.sum()

    f = shard_map(body, mesh=mesh, in_specs=(P("x"), P(None)),
                  out_specs=P(), check_rep=False)
    rep = instrument.analyze_region(f, jnp.ones((4, 2), jnp.float32),
                                    jnp.ones((3,), jnp.float32))
    graph = analysis.from_ops(
        "r", axis_sizes=region.axis_sizes, declared=region.lower(),
        traced=lower_collectives(rep.collectives, region.axis_sizes))
    diags = analysis.run_all(graph)
    undecl = [d for d in diags if d.code == "MDMP101"]
    assert len(undecl) == 1 and undecl[0].axis == "y"
    assert "test_analysis.py" in str(undecl[0].site)


def test_train_geometry_matches_launcher_shapes():
    from repro import configs
    cfg = configs.get_reduced("granite-34b")
    hw = managed.get_config().hw
    geo = train_geometry(cfg, mesh_axes={"pod": 2, "data": 2,
                                         "model": 2},
                         batch=8, seq=32, hw=hw, pipeline="1f1b")
    assert geo["pipeline"]["local_batch"] == 4     # 8 // dp=2
    assert geo["pipeline"]["candidate_micro"] == (1, 2, 4)
    assert geo["grad_bytes"] == int(cfg.param_count()) * 4
    from repro.plan import lower_train_ops
    ops = lower_train_ops(mesh_axes=geo["mesh_axes"],
                          grad_bytes=geo["grad_bytes"],
                          pipeline=geo["pipeline"],
                          attention=geo["attention"], moe=geo["moe"])
    assert {o.kind for o in ops} >= {"pipeline", "all_reduce"}
