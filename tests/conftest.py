"""Top-level test config: the dist_suite needs 8 forced host devices set
BEFORE jax initialises, so it only runs via tests/test_distributed.py's
subprocess (which sets XLA_FLAGS).  Exclude it from in-process collection
unless the devices are already there."""

import jax

collect_ignore_glob = []
if jax.device_count() < 8:
    collect_ignore_glob.append("dist_suite*")
