"""mdmptrace — the observability subsystem (repro.obs): the metrics
registry primitives, the span tracer (nesting, bounded ring, thread
correctness, disabled-is-free), the Chrome-trace export golden schema,
the predicted-vs-measured calibration ledger (perfect run -> ratio 1.0,
2x skew flagged, jit-trace spans excluded, covering attribution), the
Recalibrator warmup/drift policy, capture_decisions scoping, and the
metrics classes' migration onto the shared registry."""

import json
import threading
import time

import pytest

from repro import obs
from repro.core import managed
from repro.obs.calibrate import (CalibrationLedger, Recalibrator,
                                 chosen_predicted_s, cover_with)
from repro.obs.export import (measured_windows, to_chrome_trace,
                              trace_tracks)
from repro.obs.registry import (Counter, Ewma, Extremum, Gauge,
                                Histogram, MetricsRegistry)
from repro.obs.tracer import (NULL, Span, Tracer, dispatch_span,
                              get_tracer, use_tracer)


def _rec(op="halo_aggregation", axis="x", *, mode="interleaved",
         bulk=2e-3, inter=1e-3, nbytes=1024, chunks=4):
    return managed.DecisionRecord(
        op=op, axis=axis, nbytes=nbytes, mode=mode, chunks=chunks,
        predicted_bulk_s=bulk, predicted_interleaved_s=inter)


def _span(name, t0, dur, **attrs):
    return Span(name=name, t0=t0, dur=dur, depth=0, tid=0, attrs=attrs)


# -- registry primitives ----------------------------------------------------


def test_counter_gauge():
    c, g = Counter(), Gauge()
    c.add(3)
    c.add(2.5)
    g.set(7)
    assert c.value == 5.5 and g.value == 7


def test_extremum_min_max_and_empty():
    lo = Extremum(kind="min")
    hi = Extremum(kind="max")
    assert lo.value is None and hi.value is None
    for v in (3.0, 1.0, 2.0):
        lo.observe(v)
        hi.observe(v)
    assert lo.value == 1.0 and hi.value == 3.0 and lo.count == 3


def test_ewma_update_and_drift():
    e = Ewma(alpha=0.5)
    assert e.value is None and e.drift_frac(1.0) == 0.0
    e.update(1.0)
    assert e.drift_frac(None) == float("inf")   # no baseline trips
    assert e.value == 1.0
    e.update(3.0)
    assert e.value == pytest.approx(2.0)
    assert e.drift_frac(1.0) == pytest.approx(1.0)
    assert e.drift_frac(2.0) == pytest.approx(0.0)


def test_histogram_percentiles():
    h = Histogram()
    for v in range(1, 101):
        h.observe(float(v))
    assert h.mean == pytest.approx(50.5)
    assert h.percentile(0.5) == pytest.approx(50.0)
    assert h.percentile(0.99) == pytest.approx(99.0)
    assert h.median == h.percentile(0.5)


def test_registry_get_or_create_and_kind_clash():
    reg = MetricsRegistry()
    c1 = reg.counter("a.b")
    assert reg.counter("a.b") is c1        # same name -> same metric
    with pytest.raises(AssertionError):
        reg.gauge("a.b")                   # name reuse across kinds
    reg.extremum("m", kind="min").observe(2.0)
    snap = reg.snapshot()
    assert snap["m"]["value"] == 2.0 and "a.b" in snap


# -- tracer -----------------------------------------------------------------


def test_span_nesting_depth_and_order():
    tr = Tracer()
    with tr.span("outer", k=1):
        with tr.span("inner"):
            pass
    spans = tr.spans()
    # inner closes first; depths reflect nesting within the thread
    assert [(s.name, s.depth) for s in spans] == [("inner", 1),
                                                  ("outer", 0)]
    inner, outer = spans
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1
    assert outer.attrs == {"k": 1}


def test_ring_bounded_and_drop_count():
    tr = Tracer(capacity=4)
    for i in range(10):
        with tr.span("s", i=i):
            pass
    assert len(tr.spans()) == 4 and tr.n_spans == 10 and tr.dropped == 6
    assert [s.attrs["i"] for s in tr.spans()] == [6, 7, 8, 9]


def test_disabled_is_free_shared_noop():
    assert get_tracer() is NULL
    a = NULL.span("x", big=list(range(100)))
    b = NULL.span("y")
    assert a is b                          # ONE reusable no-op object
    with a:
        pass
    assert NULL.spans() == [] and dispatch_span("z") is a


def test_use_tracer_scoped_and_note():
    tr = Tracer()
    with use_tracer(tr):
        assert get_tracer() is tr
        with tr.span("s") as sp:
            sp.note(nbytes=42)
    assert get_tracer() is NULL
    assert tr.spans()[0].attrs["nbytes"] == 42


def test_tracer_thread_correct_depths():
    tr = Tracer()

    def worker():
        with tr.span("w.outer"):
            with tr.span("w.inner"):
                time.sleep(0.001)

    with tr.span("main.outer"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    d = {s.name: s.depth for s in tr.spans()}
    # the worker's nesting starts at 0 in ITS thread, regardless of the
    # main thread's open span
    assert d == {"main.outer": 0, "w.outer": 0, "w.inner": 1}
    tids = {s.name: s.tid for s in tr.spans()}
    assert tids["w.inner"] != tids["main.outer"]


def test_dispatch_span_tags_jit_trace_time():
    jax = pytest.importorskip("jax")
    tr = Tracer()
    with use_tracer(tr):

        @jax.jit
        def f(x):
            with dispatch_span("inside", x, op="halo_aggregation"):
                return x + 1

        f(1.0)
        with dispatch_span("eager", 2.0, op="halo_aggregation"):
            pass
    tagged = {s.name: s.attrs.get("jit") for s in tr.spans()}
    assert tagged == {"inside": True, "eager": None}


# -- Chrome-trace export golden schema --------------------------------------


def test_chrome_trace_schema():
    tr = Tracer()
    with use_tracer(tr):
        with tr.span("train.step", track="compute", step=0):
            with tr.span("halo.solve", op="halo_aggregation", axis="x",
                         nbytes=64, scale=10):
                pass
        tr.instant("fault", kind="transient")
    rec = _rec()
    managed.log_decision(rec)
    doc = to_chrome_trace(tr, [rec])
    json.loads(json.dumps(doc))            # round-trips as plain JSON

    events = doc["traceEvents"]
    tracks = trace_tracks(doc)
    assert set(tracks.values()) >= {"decisions", "compute", "comm:x"}
    assert tracks[0] == "decisions"

    metas = [e for e in events if e["ph"] == "M"]
    xs = [e for e in events if e["ph"] == "X"]
    instants = [e for e in events if e["ph"] == "i"]
    assert events[: len(metas)] == metas   # metadata first
    assert {e["name"] for e in xs} == {"train.step", "halo.solve"}
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and "args" in e
    # the comm span landed on its axis track with its attrs as args
    halo = next(e for e in xs if e["name"] == "halo.solve")
    assert tracks[halo["tid"]] == "comm:x"
    assert halo["args"]["nbytes"] == 64 and halo["args"]["depth"] == 1
    # nesting invariant survives the us conversion
    step = next(e for e in xs if e["name"] == "train.step")
    assert step["ts"] <= halo["ts"]
    assert halo["ts"] + halo["dur"] <= step["ts"] + step["dur"] + 1e-6

    dec = [e for e in instants if e["tid"] == 0]
    assert len(dec) == 1 and dec[0]["name"] == "decision:halo_aggregation"
    assert dec[0]["args"]["predicted_bulk_s"] == rec.predicted_bulk_s
    assert dec[0]["args"]["predicted_interleaved_s"] \
        == rec.predicted_interleaved_s
    assert doc["otherData"]["n_decisions"] == 1


def test_measured_windows_from_spans():
    spans = [
        _span("swap", 10.0, 1.0, buffer="kv"),
        _span("quantum", 10.25, 0.5, reads="kv", writes=["logits"]),
    ]
    inflight, accesses = measured_windows(spans)
    assert inflight == [("kv", 0.0, 1.0, "swap")]
    assert ("kv", pytest.approx(0.5), "read", "quantum") in [
        (b, t, a, l) for b, t, a, l in accesses]
    assert [a for a in accesses if a[0] == "logits"][0][2] == "write"


# -- calibration ledger -----------------------------------------------------


def test_chosen_prediction_bulk_vs_interleaved():
    assert chosen_predicted_s(_rec(op="fsdp_gather", mode="bulk")) == 2e-3
    assert chosen_predicted_s(
        _rec(op="fsdp_gather", mode="interleaved")) == 1e-3
    # resolver ops store the CHOSEN prediction in interleaved_s
    assert chosen_predicted_s(
        _rec(op="serve_schedule", mode="static")) == 1e-3


def test_calibration_perfect_run_ratio_one():
    led = CalibrationLedger()
    led.correlate([_span("halo.solve", 0.0, 1e-2,
                         op="halo_aggregation", axis="x", scale=10)],
                  [_rec()])               # predicted 1e-3/unit, 10 units
    assert led.coverage() == 1.0
    assert led.ratios()[("halo_aggregation", "x")] \
        == pytest.approx(1.0, rel=1e-6)
    assert led.miscalibrated() == {}
    assert "MISCALIBRATED" not in led.report()


def test_calibration_2x_skew_flagged_with_term():
    led = CalibrationLedger()
    led.correlate([_span("halo.solve", 0.0, 2e-2,
                         op="halo_aggregation", axis="x", scale=10)],
                  [_rec()])
    assert led.miscalibrated()[("halo_aggregation", "x")] \
        == pytest.approx(2.0)
    rep = led.report()
    assert "MISCALIBRATED(+100%)" in rep
    assert "decide_halo_aggregation" in rep   # names the model term


def test_calibration_skips_jit_spans_and_counts_uncorrelated():
    led = CalibrationLedger()
    led.correlate([_span("halo.solve", 0.0, 1e-6,
                         op="halo_aggregation", axis="x", jit=True)],
                  [_rec(), _rec(op="moe_dispatch", axis="ep")])
    assert led.samples == [] and len(led.uncorrelated) == 2
    assert led.coverage() == 0.0
    assert "uncorrelated: 2" in led.report()


def test_calibration_covering_span_counts_coverage_not_ratio():
    spans = [_span("train.step", 0.0, 1e-2, track="compute")]
    assert cover_with(spans, "train.step", ["moe_dispatch"]) == 1
    led = CalibrationLedger()
    led.correlate(spans, [_rec(op="moe_dispatch", axis="ep")])
    assert led.coverage() == 1.0
    assert not led.samples[0].attributed
    assert led.ratios() == {}              # no per-op ratio claimed
    assert "COVERED" in led.report()


def test_recalibrator_warmup_then_drift():
    r = Recalibrator(threshold=0.25, warmup=3)
    assert not r.should_retune()
    r.note(1.0)
    r.note(1.0)
    assert not r.should_retune()           # below warmup
    r.note(1.0)
    assert r.should_retune()               # warmup one-shot
    r.rebase()
    assert r.baseline == pytest.approx(1.0) and not r.should_retune()
    for _ in range(40):
        r.note(1.2)                        # +20% sustained: inside band
    assert not r.should_retune()
    for _ in range(40):
        r.note(1.5)                        # +50% sustained: fires
    assert r.should_retune()


# -- decision capture -------------------------------------------------------


def test_capture_decisions_scoped_and_stamped():
    managed.log_decision(_rec(op="halo_aggregation"))
    with managed.capture_decisions() as cap:
        managed.log_decision(_rec(op="moe_dispatch", axis="ep"))
    managed.log_decision(_rec(op="serve_schedule", axis="serve"))
    assert [r.op for r in cap.records] == ["moe_dispatch"]
    assert cap.records[0].t is not None    # stamped for the timeline


# -- metrics migration onto the shared registry -----------------------------


def test_serve_metrics_on_shared_registry():
    from repro.serve.metrics import ServeMetrics
    reg = MetricsRegistry()
    m = ServeMetrics(registry=reg)
    assert m.step_s_estimate() is None
    m.note_quantum(0.8, chunk=8, useful_steps=12, slots=2)
    m.note_quantum(1.6, chunk=8, useful_steps=12, slots=2)
    assert m.step_s_estimate() == pytest.approx(0.1)   # running min
    m.note_swap(nbytes=256, seconds=0.5)
    assert m.swap_bytes == 256 and m.swap_s == 0.5
    snap = reg.snapshot()
    assert snap["serve.swap_bytes"] == 256
    assert snap["serve.step_s"]["value"] == pytest.approx(0.1)


def test_checkpoint_metrics_on_shared_registry():
    from repro.checkpoint.metrics import CheckpointMetrics
    reg = MetricsRegistry()
    m = CheckpointMetrics(registry=reg)
    m.note_save(step=1, nbytes=1000, snapshot_s=0.1, drain_s=0.5,
                write_s=0.5)
    m.note_save(step=2, nbytes=1000, snapshot_s=0.3, drain_s=1.0,
                write_s=1.0)
    assert m.write_bw_estimate() == pytest.approx(1000.0)  # best rate
    assert m.ckpt_cost_s_estimate() == pytest.approx(0.6)  # best cost
    assert reg.snapshot()["ckpt.write_bw"]["value"] \
        == pytest.approx(1000.0)


# -- trace -> mdmplint pass 4 ----------------------------------------------


def test_attach_trace_flips_overlap_diagnostic():
    from repro.analysis import attach_trace, check_overlap
    from repro.analysis.graph import CommGraph
    g = CommGraph(name="t", axis_sizes={})
    assert check_overlap(g) == []          # declared story: no race
    spans = [
        _span("serve.swap_out", 0.0, 1.0, buffer="kv_pages"),
        _span("serve.quantum", 0.25, 0.5, reads="kv_pages"),
    ]
    g2 = attach_trace(g, spans)
    codes = [d.code for d in check_overlap(g2)]
    assert codes == ["MDMP401"]            # the measured story races
    assert check_overlap(g) == []          # original graph untouched
    # racing writes escalate to MDMP402
    g3 = attach_trace(g, [
        _span("serve.swap_in", 0.0, 1.0, buffer="kv_pages"),
        _span("decode", 0.25, 0.5, writes="kv_pages"),
    ])
    assert [d.code for d in check_overlap(g3)] == ["MDMP402"]
